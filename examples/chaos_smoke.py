"""Chaos smoke: supply shocks end-to-end on the CPU backend.

Four demonstrations of the environment-timeline axis (``env=``):

  1. a preemption storm + spot blackout injected into a market sim, with
     the shock ledger (storms/blackouts observed, dwell times, degraded
     admissions) read back from the same jitted program;
  2. graceful degradation: the same blackout with and without
     ``PanicKernel`` failover — admissions route around the dark pool;
  3. a Markov-modulated calm/storm regime sweep (one compiled program,
     non-stationary world);
  4. the Algorithm-1 learner surviving regime flips with the
     ``max_step`` / ``shock_reset`` guardrails on.

    PYTHONPATH=src python examples/chaos_smoke.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    EnvTimeline,
    Exponential,
    NoticeAwareKernel,
    PanicKernel,
    Regime,
    adaptive_admission_control,
    inject_blackout,
    inject_price_spike,
    inject_storm,
    markov_timeline,
    run_market_sim,
    run_market_sweep,
)
from repro.core.env import SEG_STORM
from repro.core.market import SpotMarket, SpotPool

JOB = Exponential(1.2)
MARKET = SpotMarket(pools=(
    SpotPool(Exponential(1.1), price=1.0, hazard=0.3, notice=0.1),
    SpotPool(Exponential(1.5), price=0.6, hazard=0.8, notice=0.3),
))
KERNEL = NoticeAwareKernel(checkpoint_time=0.05)
KEY = jax.random.key(0)

# -- 1. storm + blackout, shock ledger ----------------------------------
tl = EnvTimeline.constant()
tl = inject_storm(tl, 100.0, 400.0, hazard_mult=6.0)
tl = inject_blackout(tl, 600.0, 800.0, loc=1, n_locs=2)
tl = inject_price_spike(tl, 900.0, 1000.0, price_mult=3.0)
out = run_market_sim(JOB, MARKET, KERNEL, {"r": jnp.float32(3.0)},
                     k=10.0, n_events=8000, key=KEY, rng="slab", env=tl)
print("[1] storm+blackout+spike ledger")
print(f"    storms={out['storms_observed']} "
      f"blackouts={out['blackouts_observed']} "
      f"spikes={out['spikes_observed']} "
      f"boundaries={out['env_boundaries']}")
print(f"    storm_time={out['storm_time']:.0f} "
      f"blackout_time={out['blackout_time']:.0f} "
      f"shock_arrivals={out['shock_arrivals']} "
      f"degraded={out['degraded_admits']}")
assert out["storms_observed"] == tl.count_storms()
assert out["blackouts_observed"] == tl.count_blackouts()
assert out["degraded_admits"] <= out["shock_arrivals"]

# -- 2. PanicKernel failover around the dark pool -----------------------
dark = inject_blackout(EnvTimeline.constant(), 300.0, 700.0, loc=1,
                       n_locs=2)
kw = dict(k=10.0, n_events=8000, key=KEY, rng="slab", env=dark)
plain = run_market_sim(JOB, MARKET, KERNEL, {"r": jnp.float32(3.0)}, **kw)
panic = run_market_sim(JOB, MARKET, PanicKernel(base=KERNEL),
                       {"r": jnp.float32(3.0)}, **kw)
print("[2] blackout failover (pool 1 dark 300..700)")
print(f"    plain: degraded={plain['degraded_admits']} "
      f"pool_served={list(plain['pool_served'])} "
      f"avg_cost={plain['avg_cost']:.3f}")
print(f"    panic: degraded={panic['degraded_admits']} "
      f"pool_served={list(panic['pool_served'])} "
      f"avg_cost={panic['avg_cost']:.3f}")
assert panic["degraded_admits"] < plain["degraded_admits"]
assert panic["avg_cost"] < plain["avg_cost"]

# -- 3. Markov regime sweep (one jit, non-stationary world) -------------
regimes = (Regime(mean_hold=80.0),
           Regime(mean_hold=15.0, hazard_mult=8.0, avail=0.5,
                  kind=SEG_STORM))
mtl = markov_timeline(regimes, horizon=1500.0, seed=2)
sweep = run_market_sweep(JOB, MARKET, KERNEL,
                         {"r": jnp.float32([1.0, 2.0, 4.0])},
                         k=10.0, n_events=6000, key=KEY, n_seeds=2,
                         rng="slab", env=mtl)
print(f"[3] markov sweep: {mtl.n_segments} segments, "
      f"avg_cost per r = "
      f"{np.round(np.asarray(sweep['avg_cost']).mean(axis=-1), 3)}")
assert np.isfinite(np.asarray(sweep["avg_cost"])).all()

# -- 4. learner under regime flips with guardrails ----------------------
shaky = inject_storm(EnvTimeline.constant(), 20.0, 200.0, hazard_mult=8.0)
shaky = inject_price_spike(shaky, 300.0, 500.0, price_mult=3.0)
learn = adaptive_admission_control(
    Exponential(1.0),
    SpotMarket(pools=(SpotPool(Exponential(1.3), price=1.0, hazard=0.2,
                               notice=0.1),)),
    k=10.0, delta=2.0, eta=0.1, r0=1.0, window_events=512, n_windows=30,
    key=jax.random.key(1), env=shaky, max_step=0.5, shock_reset=True)
r = np.asarray(learn["r"])
print(f"[4] learner across flips: r in [{r.min():.2f}, {r.max():.2f}], "
      f"final r*={float(learn['r_star']):.2f}")
assert np.isfinite(r).all()

print("chaos smoke OK")
