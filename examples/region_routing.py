"""Multi-region routing sweeps: N queues, per-region clocks, one jit each.

Four demonstrations:

  1. routing rules compared on a 4-region heterogeneous topology — the
     same admission grid under home / cheapest / least-loaded / weighted
     routing, with cross-region flow and the pooled LP floor;
  2. regions-config axis: the region *price vector* and the per-region
     *demand* (job_scales — the axis the market engine lacks) are swept
     inside one compiled program;
  3. the degenerate ledger: a 1-region topology reproduces the single-queue
     engine bit-for-bit (the PR-4 equivalence contract, checked live);
  4. the host-side MultiRegionCluster routing a live stream, with its
     on-device what-if grid against the same topology.

    PYTHONPATH=src python examples/region_routing.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Exponential,
    NoticeAwareKernel,
    Region,
    RegionTopology,
    RoutingKernel,
    ThreePhaseKernel,
    region_cost_lower_bound,
    run_region_sweep,
    run_sweep,
)

LAM, MU, K = 1 / 12, 1 / 24, 10.0

TOPOLOGY = RegionTopology(regions=(
    Region(Exponential(LAM / 4), Exponential(MU / 4), price=0.5,
           hazard=0.02, notice=0.5, rmax=16),
    Region(Exponential(LAM / 2), Exponential(MU / 4), price=0.3,
           hazard=0.05, notice=0.01, rmax=16),
    Region(Exponential(LAM / 8), Exponential(MU / 4), price=0.2, rmax=16),
    Region(Exponential(LAM / 8), Exponential(MU / 4), price=0.1,
           hazard=0.10, notice=2.0, rmax=16),
))


def main():
    base = NoticeAwareKernel(checkpoint_time=0.05)
    rs = jnp.linspace(0.5, 6.0, 8)

    # 1. routing rules on the same admission grid
    print("== routing rules, 4-region topology (8 r × 4 seeds, one jit each) ==")
    lp_routed = region_cost_lower_bound(K, 27.0, TOPOLOGY, routed=True)
    lp_home = region_cost_lower_bound(K, 27.0, TOPOLOGY, routed=False)
    for choice in ("home", "cheapest", "least_loaded", "weighted"):
        kern = RoutingKernel(base, choice=choice)
        vec = ({"region_logits": jnp.array([0.0, 1.0, 1.0, 2.0])}
               if choice == "weighted" else None)
        out = run_region_sweep(TOPOLOGY, kern, {"r": rs}, vector_params=vec,
                               k=K, n_events=40_000,
                               key=jax.random.key(0), n_seeds=4)
        i = int(np.argmin(out["avg_cost_job"].mean(-1)))
        print(f"  {choice:12s}: best r={float(rs[i]):.2f} "
              f"cost/job={out['avg_cost_job'][i].mean():.3f} "
              f"delay/job={out['avg_delay_job'][i].mean():.1f} "
              f"cross-region={out['cross_region_frac'][i].mean():.0%}")
    print(f"  (cost floors for δ=27-feasible policies: routed {lp_routed:.2f}"
          f" <= home-only {lp_home:.2f} — the value of routing)")

    # 2. regions-config axes: prices and DEMAND swept inside one jit
    kern = RoutingKernel(base, choice="least_loaded")
    scale = np.linspace(0.5, 2.0, 5)
    price_grid = TOPOLOGY.prices()[None, :] * scale[:, None]  # (5, R)
    out = run_region_sweep(TOPOLOGY, kern, {"r": jnp.float32(3.0)}, k=K,
                           prices=price_grid, n_events=40_000,
                           key=jax.random.key(1), n_seeds=2)
    print("\n== regions-config sweep: price scale × seeds (one jit) ==")
    for j, s in enumerate(scale):
        print(f"  price×{s:.2f}: cost/job={out['avg_cost_job'][j].mean():.3f}")
    demand = np.array([[1.0, 1.0, 1.0, 1.0],  # baseline demand
                       [0.25, 4.0, 4.0, 0.25]])  # shifted toward regions 1/2
    out2 = run_region_sweep(TOPOLOGY, kern, {"r": jnp.float32(3.0)}, k=K,
                            job_scales=demand, n_events=40_000,
                            key=jax.random.key(2), n_seeds=2)
    print("== demand shift (job_scales axis, same jit family) ==")
    for j, label in enumerate(("baseline", "shifted")):
        jobs = np.asarray(out2["region_jobs"][j].mean(-2)).round().astype(int)
        print(f"  {label:9s}: region_jobs={jobs} "
              f"cost/job={out2['avg_cost_job'][j].mean():.3f}")

    # 3. the degenerate ledger, checked live
    topo1 = RegionTopology.single(Exponential(LAM), Exponential(MU))
    kw = dict(k=K, n_events=20_000, key=jax.random.key(3), n_seeds=2)
    a = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                  {"r": rs}, **kw)
    b = run_region_sweep(topo1, ThreePhaseKernel(), {"r": rs}, **kw)
    exact = all(np.array_equal(np.asarray(v), np.asarray(b[n]))
                for n, v in a.items())
    print(f"\n== degenerate 1-region == single-queue engine: "
          f"bit-for-bit {exact} ==")

    # 4. host-side routing + on-device what-if
    from repro.cluster.orchestrator import (MultiRegionCluster,
                                            OnlineAdmissionController)

    ctl = OnlineAdmissionController(delta=27.0, r0=2.0)
    cluster = MultiRegionCluster(topology=TOPOLOGY, controller=ctl,
                                 route="cheapest", checkpoint_hours=0.05,
                                 seed=7)
    stats = cluster.run(6_000)
    print("\n== host MultiRegionCluster (cheapest routing, live stream) ==")
    print(f"  completed={stats.jobs_completed} spot={stats.spot_served} "
          f"ondemand={stats.ondemand_served} preempt={stats.preemptions} "
          f"cross-region={stats.cross_region} "
          f"cost/leg={stats.avg_cost:.2f} (controller r={ctl.r:.2f})")
    wi = cluster.what_if_sweep(np.linspace(0.5, 6.0, 6), n_events=10_000,
                               n_seeds=2)
    i = int(np.argmin(wi["avg_cost_job"].mean(-1)))
    print(f"  on-device what-if: best r={np.linspace(0.5, 6.0, 6)[i]:.1f} "
          f"cost/job={wi['avg_cost_job'][i].mean():.2f}")


if __name__ == "__main__":
    main()
