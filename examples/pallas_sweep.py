"""The sweep engine's executors: XLA scan vs the Pallas event kernel.

Runs the same (r × seeds) three-phase grid and a 4-pool preemptible market
grid through ``impl="xla"``, ``impl="pallas"``, and the kernel's scan
reference ``impl="ref"``, then checks the equivalence ledger: pallas == ref
to the last bit, and pallas vs the production XLA executor with integer
event accounting exact and float sums at ~ulp distance (see EXPERIMENTS.md,
"Engine kernel").  On CPU the kernel runs in interpret mode (parity check,
not speed); on TPU it compiles to a fused batched-event kernel with the
engine state resident in VMEM.

    PYTHONPATH=src python examples/pallas_sweep.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Exponential,
    NoticeAwareKernel,
    SpotMarket,
    SpotPool,
    ThreePhaseKernel,
    run_market_sweep,
    run_sweep,
)

from repro.core.engine import INT_STATS as _INT

LAM, MU, K = 1 / 12, 1 / 24, 10.0


def bit_equal(a: dict, b: dict) -> bool:
    return all(np.array_equal(np.asarray(v), np.asarray(b[n]))
               for n, v in a.items())


def xla_ledger(xla: dict, pal: dict) -> str:
    ints = all(np.array_equal(np.asarray(xla[n]), np.asarray(pal[n]))
               for n in _INT if n in xla)
    rel = max(
        float(np.max(np.abs(np.asarray(v, np.float64)
                            - np.asarray(pal[n], np.float64))
                     / np.maximum(np.abs(np.asarray(v, np.float64)), 1e-30)))
        for n, v in xla.items() if n not in _INT)
    return f"ints_exact={ints} max_float_rtol={rel:.1e}"


def main() -> None:
    job, spot = Exponential(LAM), Exponential(MU)
    rs = jnp.linspace(0.25, 4.0, 8)
    kw = dict(k=K, n_events=5_000, key=jax.random.key(0), n_seeds=4,
              rmax=32)
    total = 8 * 4 * 5_000

    print(f"backend={jax.default_backend()}  grid=8r×4seeds  "
          f"{total:,} events per executor")

    outs = {}
    for impl in ("xla", "pallas", "ref"):
        run_sweep(job, spot, ThreePhaseKernel(), {"r": rs}, impl=impl, **kw)
        t0 = time.perf_counter()
        outs[impl] = run_sweep(job, spot, ThreePhaseKernel(), {"r": rs},
                               impl=impl, **kw)
        dt = time.perf_counter() - t0
        print(f"  single-pool {impl:6s}: {total/dt/1e6:6.2f}M ev/s   "
              f"min avg_cost="
              f"{float(outs[impl]['avg_cost'].mean(-1).min()):.3f}")
    print(f"  pallas == ref bit-for-bit: "
          f"{bit_equal(outs['ref'], outs['pallas'])};  vs xla: "
          f"{xla_ledger(outs['xla'], outs['pallas'])}")

    market = SpotMarket(pools=(
        SpotPool(Exponential(MU / 4), price=0.5, hazard=0.02, notice=0.5),
        SpotPool(Exponential(MU / 4), price=0.3, hazard=0.05, notice=0.01),
        SpotPool(Exponential(MU / 4), price=0.2, hazard=0.0),
        SpotPool(Exponential(MU / 4), price=0.1, hazard=0.10, notice=2.0),
    ))
    kern = NoticeAwareKernel(checkpoint_time=0.05)
    outs = {}
    for impl in ("xla", "pallas", "ref"):
        run_market_sweep(job, market, kern, {"r": rs}, impl=impl, **kw)
        t0 = time.perf_counter()
        outs[impl] = run_market_sweep(job, market, kern, {"r": rs},
                                      impl=impl, **kw)
        dt = time.perf_counter() - t0
        pre = float(np.asarray(outs[impl]["preemptions"]).sum())
        print(f"  4-pool mkt  {impl:6s}: {total/dt/1e6:6.2f}M ev/s   "
              f"preemptions={pre:.0f}")
    print(f"  pallas == ref bit-for-bit: "
          f"{bit_equal(outs['ref'], outs['pallas'])};  vs xla: "
          f"{xla_ledger(outs['xla'], outs['pallas'])}")


if __name__ == "__main__":
    main()
