"""Policy-grid sweeps as single compiled programs.

Three sweeps, each ONE jitted call regardless of grid size:

  1. admission knob r × seeds        (Theorem-4 kernel)
  2. r × cost-ratio k 2-D meshgrid   (the paper's k-sensitivity axis)
  3. deterministic-wait X × seeds    (Theorems-2/3 kernel with TRACED
                                      wait-time parameters — the wait
                                      distribution is swept inside the
                                      compiled program, no retracing)

    PYTHONPATH=src python examples/sweep_grids.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DeterministicWait,
    Exponential,
    SingleSlotKernel,
    ThreePhaseKernel,
    run_sweep,
    theorem2_cost,
    theorem5_cost,
)

LAM, MU, K = 1 / 12, 1 / 24, 10.0
JOB, SPOT = Exponential(LAM), Exponential(MU)


def main():
    # 1. r-sweep: cost/delay frontier of the three-phase policy
    rs = jnp.linspace(0.5, 6.0, 12)
    out = run_sweep(JOB, SPOT, ThreePhaseKernel(), {"r": rs}, k=K,
                    n_events=100_000, key=jax.random.key(0), n_seeds=4)
    print("== r-sweep (12 r × 4 seeds, one jit) ==")
    print("  r:      " + " ".join(f"{r:6.2f}" for r in np.asarray(rs)))
    print("  cost:   " + " ".join(f"{c:6.2f}"
                                  for c in out["avg_cost"].mean(-1)))
    print("  delay:  " + " ".join(f"{d:6.2f}"
                                  for d in out["avg_delay"].mean(-1)))
    print("  (Theorem-5 closed forms at integer r: "
          + " ".join(f"E[C_{n}]={theorem5_cost(K, LAM, MU, n):.2f}"
                     for n in (1, 2, 3)) + ")")

    # 2. r × k meshgrid: how the optimal knob shifts with the cost ratio
    r_ax = jnp.linspace(0.5, 5.0, 10)
    k_ax = jnp.array([2.0, 5.0, 10.0, 20.0])
    rg, kg = jnp.meshgrid(r_ax, k_ax, indexing="ij")
    out2 = run_sweep(JOB, SPOT, ThreePhaseKernel(), {"r": rg}, k=kg,
                     n_events=100_000, key=jax.random.key(1), n_seeds=2)
    cost = out2["avg_cost"].mean(-1)  # (10, 4)
    best = np.asarray(r_ax)[cost.argmin(axis=0)]
    print("\n== r × k meshgrid (10×4×2 seeds, one jit) ==")
    for j, k in enumerate(np.asarray(k_ax)):
        print(f"  k={k:5.1f}: min-cost r*={best[j]:.1f} "
              f"cost={cost[:, j].min():.3f}")

    # 3. wait-time parameter sweep with traced params: vary deterministic X
    kernel = SingleSlotKernel(wait=DeterministicWait(1.0))
    xs = jnp.linspace(2.0, 40.0, 10)
    out3 = run_sweep(JOB, SPOT, kernel, {"wait": {"value": xs}}, k=K,
                     n_events=100_000, key=jax.random.key(2), n_seeds=4,
                     rmax=1)
    print("\n== deterministic-wait sweep (10 X × 4 seeds, one jit) ==")
    print("  X:      " + " ".join(f"{x:6.1f}" for x in np.asarray(xs)))
    print("  cost:   " + " ".join(f"{c:6.2f}"
                                  for c in out3["avg_cost"].mean(-1)))
    print("  delay:  " + " ".join(f"{d:6.2f}"
                                  for d in out3["avg_delay"].mean(-1)))
    print(f"  (Theorem-2 bound at δ=3: {theorem2_cost(K, MU, 3.0):.3f})")


if __name__ == "__main__":
    main()
