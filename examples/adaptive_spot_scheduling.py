"""The paper end-to-end: Algorithm 1 learning the optimal admission knob r*
under every §V experimental setting (Figures 2-5), printed as convergence
traces.

    PYTHONPATH=src python examples/adaptive_spot_scheduling.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import (
    BathtubGCP,
    Exponential,
    Gamma,
    adaptive_admission_control,
    theorem2_cost,
    theorem5_cost,
    theorem5_delta,
)

K = 10.0
LAM = 1 / 12


def trace(title, job, spot, delta, r0, *, eta=0.05, n_windows=400,
          window=2048, r_max=16.0, target=None):
    out = adaptive_admission_control(
        job, spot, k=K, delta=delta, eta=eta, eta_decay=0.05, r0=r0,
        r_max=r_max, window_events=window, n_windows=n_windows,
        key=jax.random.key(0))
    print(f"\n== {title} (r0={r0}) ==")
    idxs = np.linspace(0, len(out["r"]) - 1, 8).astype(int)
    print("  window:      " + " ".join(f"{i:7d}" for i in idxs))
    print("  r(n):        " + " ".join(f"{out['r'][i]:7.3f}" for i in idxs))
    print("  cost C(r(n)):" + " ".join(f"{out['running_cost'][i]:7.3f}"
                                       for i in idxs))
    print("  delay d(n):  " + " ".join(f"{out['running_delay'][i]:7.3f}"
                                       for i in idxs))
    tgt = f" (theory {target:.3f})" if target else ""
    print(f"  -> r*={out['r_star']:.3f} cost={out['final_cost']:.3f}{tgt} "
          f"delay={out['final_delay']:.3f} (δ={delta})")
    return out


def main():
    bathtub = BathtubGCP()
    mu_b = bathtub.rate()
    print("Paper §V — spot cost 1, on-demand cost k=10, times in hours")
    print(f"bathtub spot: mean inter-arrival {1/mu_b:.2f}h (μ≈1/12)")

    # Fig 2: bathtub, strong delay constraint
    for r0 in (0.05, 4.0):
        trace("Fig 2: Poisson jobs + bathtub spot, δ=3", Exponential(LAM),
              bathtub, 3.0, r0, target=theorem2_cost(K, mu_b, 3.0))
    # Gamma variant (paper also runs Gamma(12,1) arrivals)
    trace("Fig 2b: Gamma(12,1) jobs + bathtub spot, δ=3", Gamma(12.0, 1.0),
          bathtub, 3.0, 1.0, target=theorem2_cost(K, mu_b, 3.0))

    # Fig 3: bathtub, relaxed delay
    for r0 in (0.3, 6.0):
        trace("Fig 3: bathtub spot, δ=18 (λδ>1)", Exponential(LAM), bathtub,
              18.0, r0, eta=0.02, window=4096, r_max=8.0)

    # Fig 4: memoryless, strong delay
    for r0 in (0.05, 4.0):
        trace("Fig 4: M/M δ=3", Exponential(LAM), Exponential(1 / 24), 3.0,
              r0, target=theorem2_cost(K, 1 / 24, 3.0))

    # Fig 5: memoryless, relaxed delay — r* -> N=3 (Theorem 5)
    print(f"\nTheorem 5: δ_3 = {theorem5_delta(LAM, 1/24, 3):.2f}h, "
          f"E[C_3] = {theorem5_cost(K, LAM, 1/24, 3):.3f}")
    for r0 in (0.5, 8.0):
        trace("Fig 5: M/M δ=27", Exponential(LAM), Exponential(1 / 24), 27.0,
              r0, eta=0.02, window=4096, n_windows=500, r_max=8.0,
              target=theorem5_cost(K, LAM, 1 / 24, 3))


if __name__ == "__main__":
    main()
