"""The paper end-to-end: Algorithm 1 learning the optimal admission knob r*
under every §V experimental setting (Figures 2-5), printed as convergence
traces.

Each figure's two far-apart initializations run as ONE batched learner fleet
(`adaptive_admission_control_batched`): the whole multi-r₀ trajectory is a
single jitted scan, so adding initializations (or a multi-δ sweep — see the
closing section) costs one vmap lane, not another Python loop iteration.

    PYTHONPATH=src python examples/adaptive_spot_scheduling.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BathtubGCP,
    Exponential,
    Gamma,
    adaptive_admission_control_batched,
    theorem2_cost,
    theorem5_cost,
    theorem5_delta,
)

K = 10.0
LAM = 1 / 12


def trace_fleet(title, job, spot, delta, r0s, *, eta=0.05, n_windows=400,
                window=2048, r_max=16.0, target=None):
    out = adaptive_admission_control_batched(
        job, spot, k=K, delta=delta, eta=eta, eta_decay=0.05,
        r0=jnp.asarray(r0s, jnp.float32), r_max=r_max, window_events=window,
        n_windows=n_windows, key=jax.random.key(0))
    for i, r0 in enumerate(r0s):
        print(f"\n== {title} (r0={r0}) ==")
        idxs = np.linspace(0, out["r"].shape[-1] - 1, 8).astype(int)
        print("  window:      " + " ".join(f"{j:7d}" for j in idxs))
        print("  r(n):        " + " ".join(f"{out['r'][i, j]:7.3f}"
                                           for j in idxs))
        print("  cost C(r(n)):" + " ".join(f"{out['running_cost'][i, j]:7.3f}"
                                           for j in idxs))
        print("  delay d(n):  " + " ".join(f"{out['running_delay'][i, j]:7.3f}"
                                           for j in idxs))
        tgt = f" (theory {target:.3f})" if target else ""
        print(f"  -> r*={out['r_star'][i]:.3f} "
              f"cost={out['final_cost'][i]:.3f}{tgt} "
              f"delay={out['final_delay'][i]:.3f} (δ={delta})")
    return out


def main():
    bathtub = BathtubGCP()
    mu_b = bathtub.rate()
    print("Paper §V — spot cost 1, on-demand cost k=10, times in hours")
    print(f"bathtub spot: mean inter-arrival {1/mu_b:.2f}h (μ≈1/12)")

    # Fig 2: bathtub, strong delay constraint — both inits in one fleet
    trace_fleet("Fig 2: Poisson jobs + bathtub spot, δ=3", Exponential(LAM),
                bathtub, 3.0, (0.05, 4.0),
                target=theorem2_cost(K, mu_b, 3.0))
    # Gamma variant (paper also runs Gamma(12,1) arrivals)
    trace_fleet("Fig 2b: Gamma(12,1) jobs + bathtub spot, δ=3",
                Gamma(12.0, 1.0), bathtub, 3.0, (1.0,),
                target=theorem2_cost(K, mu_b, 3.0))

    # Fig 3: bathtub, relaxed delay
    trace_fleet("Fig 3: bathtub spot, δ=18 (λδ>1)", Exponential(LAM),
                bathtub, 18.0, (0.3, 6.0), eta=0.02, window=4096, r_max=8.0)

    # Fig 4: memoryless, strong delay
    trace_fleet("Fig 4: M/M δ=3", Exponential(LAM), Exponential(1 / 24), 3.0,
                (0.05, 4.0), target=theorem2_cost(K, 1 / 24, 3.0))

    # Fig 5: memoryless, relaxed delay — r* -> N=3 (Theorem 5)
    print(f"\nTheorem 5: δ_3 = {theorem5_delta(LAM, 1/24, 3):.2f}h, "
          f"E[C_3] = {theorem5_cost(K, LAM, 1/24, 3):.3f}")
    trace_fleet("Fig 5: M/M δ=27", Exponential(LAM), Exponential(1 / 24),
                27.0, (0.5, 8.0), eta=0.02, window=4096, n_windows=500,
                r_max=8.0, target=theorem5_cost(K, LAM, 1 / 24, 3))

    # Beyond the paper: a 12-target multi-δ fleet in one jitted scan — the
    # learned δ→(r*, cost) frontier, no per-δ Python loop.
    deltas = np.linspace(2.0, 30.0, 12)
    out = adaptive_admission_control_batched(
        Exponential(LAM), Exponential(1 / 24), k=K,
        delta=jnp.asarray(deltas, jnp.float32), eta=0.02, eta_decay=0.05,
        r0=1.0, r_max=8.0, window_events=4096, n_windows=300,
        key=jax.random.key(1))
    print("\n== multi-δ fleet (12 learners, one scan) ==")
    print("  δ:     " + " ".join(f"{d:6.1f}" for d in deltas))
    print("  r*:    " + " ".join(f"{r:6.2f}" for r in out["r_star"]))
    print("  cost:  " + " ".join(f"{c:6.2f}" for c in out["final_cost"]))


if __name__ == "__main__":
    main()
