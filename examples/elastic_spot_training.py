"""Elastic spot training: a stream of training jobs dispatched by the
paper's admission controller onto a simulated spot/on-demand cluster, with
REAL JAX training work per leg, preemption → checkpoint → re-admission, and
cost accounting vs an on-demand-only baseline.

The coda closes the loop with the engine's ``work=`` axis: the blocking
save, the elastic restore, and one warm train step are each wall-timed,
``restart_overhead_from_timing`` turns the measured seconds into engine
work units, and a checkpoint-priced market replay reports the survival
ledger that this cluster's jobs would have produced.

    PYTHONPATH=src python examples/elastic_spot_training.py
"""
import dataclasses
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.cluster.orchestrator import OnlineAdmissionController, SpotCluster
from repro.configs import get_config
from repro.core import (BathtubGCP, Exponential, NoticeAwareKernel,
                        SpotMarket, SpotPool, WorkModel,
                        restart_overhead_from_timing, run_market_sim,
                        theorem2_cost)
from repro.data.pipeline import DataPipeline
from repro.models.registry import build_model
from repro.train.steps import init_train_state, make_train_step

K, LAM, DELTA = 10.0, 1 / 12, 3.0
STEPS_PER_LEG = 2


def main(horizon: int = 12_000):
    # tiny real model so each spot leg does real gradient work
    cfg = get_config("mamba2-780m", smoke=True)
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    state_holder = {"state": init_train_state(model, jax.random.key(0)),
                    "steps_done": 0}
    data = DataPipeline(vocab_size=cfg.vocab_size, global_batch=4,
                        seq_len=64, seed=0)
    step_fn = jax.jit(make_train_step(model, base_lr=1e-3))
    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="elastic_"))

    def run_leg(job):
        for _ in range(STEPS_PER_LEG):
            state_holder["state"], m = step_fn(state_holder["state"],
                                               data.next())
            state_holder["steps_done"] += 1
        state_holder["last_loss"] = float(m["loss"])

    def on_preempt(job):
        # advance notice: blocking checkpoint inside the notice window
        ckpt.save(state_holder["steps_done"], state_holder["state"],
                  extra={"data": data.state()}, blocking=True)

    ctl = OnlineAdmissionController(delta=DELTA, eta=0.05, r0=1.0,
                                    window_jobs=64)
    spot = BathtubGCP()
    cluster = SpotCluster(
        job_process=Exponential(LAM), spot_process=spot, k_cost=K,
        controller=ctl, preemption_prob=0.10, on_spot_run=run_leg,
        on_ondemand_run=run_leg, on_preempt=on_preempt, seed=0)

    print("spot/on-demand training cluster — paper policy as dispatcher")
    stats = cluster.run(horizon)
    base = K  # on-demand-only pays k per job
    print(f"jobs completed:      {stats.jobs_completed}")
    print(f"  spot legs:         {stats.spot_served}")
    print(f"  on-demand legs:    {stats.ondemand_served}")
    print(f"  preemptions:       {stats.preemptions} "
          f"(checkpoints {stats.checkpoints}, re-admitted {stats.restores})")
    print(f"train steps done:    {state_holder['steps_done']} "
          f"(last loss {state_holder.get('last_loss', float('nan')):.3f})")
    print(f"avg cost/job:        {stats.avg_cost:.3f} "
          f"(on-demand-only: {base:.1f}; "
          f"theory floor ≈ {theorem2_cost(K, spot.rate(), DELTA):.3f})")
    print(f"avg delay/job:       {stats.avg_delay:.3f}h (budget {DELTA}h)")
    print(f"savings vs on-demand: {(1 - stats.avg_cost / base) * 100:.1f}%")
    print(f"learned r*:          {ctl.r:.3f}")
    print(f"checkpoints kept:    {ckpt.all_steps()}")

    # ---- checkpoint-priced replay: measured timing seeds the work= axis
    t0 = time.perf_counter()
    st, _ = step_fn(state_holder["state"], data.next())
    jax.block_until_ready(st)
    step_s = max(time.perf_counter() - t0, 1e-6)
    state_holder["state"] = st

    t0 = time.perf_counter()
    ckpt.save(state_holder["steps_done"], state_holder["state"],
              extra={"data": data.state()}, blocking=True)
    save_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    ckpt.restore(ckpt.latest_step(), state_holder["state"])
    restore_s = time.perf_counter() - t0

    # one engine work unit == one spot leg (STEPS_PER_LEG train steps)
    overhead = restart_overhead_from_timing(save_s, restore_s, step_s,
                                            steps_per_unit=STEPS_PER_LEG)
    work = WorkModel.on_notice(0.05, total_work=1.0,
                               restart_overhead=min(overhead, 2.0))
    market = SpotMarket((
        SpotPool(BathtubGCP(), price=0.6, hazard=0.5, notice=0.1),))
    replay = run_market_sim(
        Exponential(LAM), market, NoticeAwareKernel(checkpoint_time=0.05),
        {"r": ctl.r},  # the admission rate the controller just learned
        k=K, n_events=4_000, key=jax.random.key(1), work=work)
    print(f"\ncheckpoint timing:   step {step_s * 1e3:.0f}ms  "
          f"save {save_s * 1e3:.0f}ms  restore {restore_s * 1e3:.0f}ms  "
          f"→ restart_overhead {overhead:.2f} legs")
    print(f"engine replay (work=): cost/job {replay['avg_cost']:.3f}, "
          f"finished {replay['jobs_finished']:.0f}, "
          f"checkpoints {replay['checkpoints_taken']:.0f}, "
          f"work recomputed {replay['work_recomputed']:.2f} legs")


if __name__ == "__main__":
    # optional event-count horizon (CI smoke uses a short one)
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 12_000)
