"""Elastic spot training: a stream of training jobs dispatched by the
paper's admission controller onto a simulated spot/on-demand cluster, with
REAL JAX training work per leg, preemption → checkpoint → re-admission, and
cost accounting vs an on-demand-only baseline.

    PYTHONPATH=src python examples/elastic_spot_training.py
"""
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.cluster.orchestrator import OnlineAdmissionController, SpotCluster
from repro.configs import get_config
from repro.core import BathtubGCP, Exponential, theorem2_cost
from repro.data.pipeline import DataPipeline
from repro.models.registry import build_model
from repro.train.steps import init_train_state, make_train_step

K, LAM, DELTA = 10.0, 1 / 12, 3.0
STEPS_PER_LEG = 2


def main():
    # tiny real model so each spot leg does real gradient work
    cfg = get_config("mamba2-780m", smoke=True)
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    state_holder = {"state": init_train_state(model, jax.random.key(0)),
                    "steps_done": 0}
    data = DataPipeline(vocab_size=cfg.vocab_size, global_batch=4,
                        seq_len=64, seed=0)
    step_fn = jax.jit(make_train_step(model, base_lr=1e-3))
    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="elastic_"))

    def run_leg(job):
        for _ in range(STEPS_PER_LEG):
            state_holder["state"], m = step_fn(state_holder["state"],
                                               data.next())
            state_holder["steps_done"] += 1
        state_holder["last_loss"] = float(m["loss"])

    def on_preempt(job):
        # advance notice: blocking checkpoint inside the notice window
        ckpt.save(state_holder["steps_done"], state_holder["state"],
                  extra={"data": data.state()}, blocking=True)

    ctl = OnlineAdmissionController(delta=DELTA, eta=0.05, r0=1.0,
                                    window_jobs=64)
    spot = BathtubGCP()
    cluster = SpotCluster(
        job_process=Exponential(LAM), spot_process=spot, k_cost=K,
        controller=ctl, preemption_prob=0.10, on_spot_run=run_leg,
        on_ondemand_run=run_leg, on_preempt=on_preempt, seed=0)

    print("spot/on-demand training cluster — paper policy as dispatcher")
    stats = cluster.run(12_000)
    base = K  # on-demand-only pays k per job
    print(f"jobs completed:      {stats.jobs_completed}")
    print(f"  spot legs:         {stats.spot_served}")
    print(f"  on-demand legs:    {stats.ondemand_served}")
    print(f"  preemptions:       {stats.preemptions} "
          f"(checkpoints {stats.checkpoints}, re-admitted {stats.restores})")
    print(f"train steps done:    {state_holder['steps_done']} "
          f"(last loss {state_holder.get('last_loss', float('nan')):.3f})")
    print(f"avg cost/job:        {stats.avg_cost:.3f} "
          f"(on-demand-only: {base:.1f}; "
          f"theory floor ≈ {theorem2_cost(K, spot.rate(), DELTA):.3f})")
    print(f"avg delay/job:       {stats.avg_delay:.3f}h (budget {DELTA}h)")
    print(f"savings vs on-demand: {(1 - stats.avg_cost / base) * 100:.1f}%")
    print(f"learned r*:          {ctl.r:.3f}")
    print(f"checkpoints kept:    {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
