"""Spot-market sweeps: heterogeneous pools + preemption-with-notice.

Four demonstrations, each ONE jitted call regardless of grid size:

  1. admission knob r × seeds on a 4-pool market with preemption — the
     notice-aware kernel checkpoints revoked jobs that fit the notice
     window and defects the rest;
  2. pools-config axis: the pool *price vector* is swept inside the same
     compiled program (market conditions as a grid dimension);
  3. pool-choice rules compared at fixed r (cheapest / fastest / uniform);
  4. a batched fleet of Algorithm-1 learners trained against the
     preemptible market, one per delay target.

The multi-pool knapsack LP (repro.core.lp.market_knapsack_lp) provides the
policy-independent cost floor for comparison.

    PYTHONPATH=src python examples/market_sweeps.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Exponential,
    NoticeAwareKernel,
    PoolChoiceKernel,
    SpotMarket,
    SpotPool,
    ThreePhaseKernel,
    adaptive_admission_control_batched,
    market_knapsack_lp,
    run_market_sweep,
)

LAM, MU, K = 1 / 12, 1 / 24, 10.0
JOB = Exponential(LAM)

MARKET = SpotMarket(pools=(
    SpotPool(Exponential(MU / 4), price=0.5, hazard=0.02, notice=0.5),
    SpotPool(Exponential(MU / 4), price=0.3, hazard=0.05, notice=0.01),
    SpotPool(Exponential(MU / 4), price=0.2, hazard=0.0),
    SpotPool(Exponential(MU / 4), price=0.1, hazard=0.10, notice=2.0),
))


def main():
    kern = NoticeAwareKernel(checkpoint_time=0.05)

    # 1. r-sweep on the preemptible market
    rs = jnp.linspace(0.5, 6.0, 12)
    out = run_market_sweep(JOB, MARKET, kern, {"r": rs}, k=K,
                           n_events=60_000, key=jax.random.key(0), n_seeds=4)
    lp = market_knapsack_lp(K, LAM, 27.0, MARKET, include_preemption=True)
    print("== r-sweep, 4-pool market w/ preemption (12 r × 4 seeds, one jit) ==")
    print("  r:        " + " ".join(f"{r:6.2f}" for r in np.asarray(rs)))
    print("  cost/job: " + " ".join(f"{c:6.2f}"
                                    for c in out["avg_cost_job"].mean(-1)))
    print("  delay/job:" + " ".join(f"{d:6.2f}"
                                    for d in out["avg_delay_job"].mean(-1)))
    print("  preempts: " + " ".join(f"{p:6.0f}"
                                    for p in out["preemptions"].mean(-1)))
    print(f"  (LP floor at δ=27, preemption-priced: {lp['objective']:.2f}; "
          f"fill order {lp['support']})")

    # 2. pools-config axis: price the whole market up/down inside one jit
    scale = np.linspace(0.5, 2.0, 6)
    price_grid = MARKET.prices()[None, :] * scale[:, None]  # (6, P)
    out2 = run_market_sweep(JOB, MARKET, kern, {"r": jnp.float32(3.0)}, k=K,
                            prices=price_grid, n_events=60_000,
                            key=jax.random.key(1), n_seeds=2)
    print("\n== pools-config sweep: price scale × seeds (one jit) ==")
    for j, s in enumerate(scale):
        print(f"  price×{s:.2f}: cost/job={out2['avg_cost_job'][j].mean():.3f} "
              f"spot_spend={out2['spot_cost'][j].mean():.0f}")

    # 3. pool-choice rules at fixed r
    print("\n== pool-choice rules at r=3 ==")
    for choice in ("cheapest", "fastest", "uniform"):
        kern_c = PoolChoiceKernel(ThreePhaseKernel(), choice=choice)
        o = run_market_sweep(JOB, MARKET, kern_c, {"r": jnp.float32(3.0)},
                             k=K, n_events=60_000, key=jax.random.key(2),
                             n_seeds=2)
        served = o["pool_served"].mean(-2)  # (P,) mean over seeds
        print(f"  {choice:12s}: cost/job={o['avg_cost_job'].mean():.3f} "
              f"pool_served={np.round(served).astype(int)}")

    # 4. Algorithm-1 fleet on the preemptible market (one jitted scan)
    deltas = jnp.array([3.0, 9.0, 27.0])
    fleet = adaptive_admission_control_batched(
        JOB, MARKET, k=K, delta=deltas, eta=0.05, eta_decay=0.05,
        window_events=1024, n_windows=60, key=jax.random.key(3))
    print("\n== Algorithm-1 fleet on the market (3 δ-learners, one jit) ==")
    for i, d in enumerate(np.asarray(deltas)):
        print(f"  δ={d:5.1f}: r*={fleet['r_star'][i]:.2f} "
              f"cost={fleet['final_cost'][i]:.2f} "
              f"delay={fleet['final_delay'][i]:.2f} "
              f"preemptions={fleet['preemptions_total'][i]:.0f}")


if __name__ == "__main__":
    main()
