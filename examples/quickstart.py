"""Quickstart: train a small LM end-to-end on CPU with the full stack —
data pipeline, AdamW + cosine schedule, grad clipping, checkpointing.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]

Uses the qwen3-family smoke config scaled up a little (~7M params); a few
hundred steps take a couple of minutes on CPU and the loss drops well below
the uniform-random floor.
"""
import argparse
import dataclasses
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.models.registry import build_model
from repro.train.steps import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config("qwen3-32b", smoke=True)
    cfg = dataclasses.replace(cfg, num_layers=4, d_model=256, d_ff=512,
                              num_heads=8, num_kv_heads=2, head_dim=32,
                              vocab_size=2048, remat=False)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    data = DataPipeline(vocab_size=cfg.vocab_size, global_batch=args.batch,
                        seq_len=args.seq, seed=0)
    step_fn = jax.jit(
        make_train_step(model, base_lr=3e-3, warmup=20,
                        total_steps=args.steps),
        donate_argnums=(0,))
    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="quickstart_"))

    t0 = time.time()
    for i in range(args.steps):
        state, metrics = step_fn(state, data.next())
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, state, extra={"data": data.state()})
            print(f"  async checkpoint @ step {i+1}")
    ckpt.wait()
    print(f"done in {time.time()-t0:.0f}s; checkpoints: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
