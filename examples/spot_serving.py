"""Spot-aware serving: batched greedy decoding where each request either
queues for cheap spot decode slots or bursts to on-demand, dispatched by the
paper's admission controller.

    PYTHONPATH=src python examples/spot_serving.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.cluster.orchestrator import OnlineAdmissionController
from repro.configs import get_config
from repro.core import Exponential
from repro.models.registry import build_model
from repro.serving.engine import BatchedServer, SpotServingFrontend

K = 10.0


def main():
    cfg = get_config("qwen1.5-4b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    server = BatchedServer(model, params, max_batch=4, max_len=64)

    # requests every ~2 time units; spot slots every ~3 — delay budget 5
    ctl = OnlineAdmissionController(delta=5.0, eta=0.1, r0=2.0,
                                    window_jobs=16, r_max=12.0)
    frontend = SpotServingFrontend(
        server, spot_process=Exponential(1 / 3.0), controller=ctl,
        k_cost=K, batch_size=4)
    out = frontend.run_stream(Exponential(1 / 2.0), n_requests=60,
                              prompt_len=16, max_new=8,
                              vocab=cfg.vocab_size)
    print("spot-aware serving (cost: spot=1, on-demand=k=10)")
    print(f"requests completed:  {out['completed']}")
    print(f"served on spot:      {out['spot_fraction']*100:.1f}%")
    print(f"avg cost/request:    {out['avg_cost']:.3f} (on-demand-only: 10)")
    print(f"avg delay/request:   {out['avg_delay']:.3f} (budget 5.0)")
    print(f"learned r*:          {out['r_star']:.3f}")
    sample = frontend.completed[0]
    print(f"sample completion ({sample.pool}): {sample.tokens_out}")


if __name__ == "__main__":
    main()
