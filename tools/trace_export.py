"""Export a Chrome/Perfetto trace from an engine sim with tracing on.

Runs a small market sim with ``telemetry=Telemetry(trace_cap=...)``,
drains the device event ring into global-time records
(:func:`repro.obs.trace.device_trace_records`), and writes the Chrome
``traceEvents`` JSON that ``ui.perfetto.dev`` / ``chrome://tracing``
load directly:

    PYTHONPATH=src python tools/trace_export.py --out trace.json

``--loop region`` exports the multi-region loop instead; ``--host``
replays the *host* orchestrator (:class:`repro.cluster.SpotCluster`)
through a :class:`repro.obs.TraceRecorder` — same record schema, so both
producers exercise the same exporter.  ``tools/check_trace.py`` validates
the output shape in CI.
"""
from __future__ import annotations

import argparse
import json


def build_records(loop: str, *, n_events: int, trace_cap: int,
                  host: bool, seed: int) -> tuple[list, dict]:
    """Run the sim and return (records, summary-ish metadata)."""
    import jax
    import jax.numpy as jnp

    from repro.core import Exponential, ThreePhaseKernel
    from repro.core.market import SpotMarket, SpotPool
    from repro.core.regions import Region, RegionTopology
    from repro.obs import Telemetry, TraceRecorder, device_trace_records

    lam, mu = 1.2, 0.9
    if host:
        from repro.cluster.orchestrator import (OnlineAdmissionController,
                                                SpotCluster)
        tracer = TraceRecorder()
        cluster = SpotCluster(
            job_process=Exponential(lam),
            market=SpotMarket(pools=(
                SpotPool(Exponential(mu / 2), price=0.4, hazard=0.05,
                         notice=0.5),
                SpotPool(Exponential(mu / 2), price=0.7, hazard=0.01),
            )),
            controller=OnlineAdmissionController(delta=8.0),
            tracer=tracer, seed=seed)
        cluster.run(n_events)
        meta = {"producer": "host", "n_records": len(tracer.records),
                "dropped": tracer.dropped}
        return tracer.records, meta

    tel = Telemetry(trace_cap=trace_cap)
    key = jax.random.key(seed)
    params = {"r": jnp.float32(2.0)}
    if loop == "market":
        from repro.core.engine import run_market_sim
        market = SpotMarket(pools=(
            SpotPool(Exponential(mu / 2), price=0.4, hazard=0.05,
                     notice=0.5),
            SpotPool(Exponential(mu / 2), price=0.7, hazard=0.01),
        ))
        out = run_market_sim(Exponential(lam), market, ThreePhaseKernel(),
                             params, k=10.0, n_events=n_events, key=key,
                             telemetry=tel)
    elif loop == "region":
        from repro.core.engine import run_region_sim
        topo = RegionTopology(regions=(
            Region(Exponential(lam / 2), Exponential(mu / 2), price=0.4,
                   hazard=0.05),
            Region(Exponential(lam / 2), Exponential(mu / 2), price=0.8,
                   hazard=0.01),
        ))
        out = run_region_sim(topo, ThreePhaseKernel(), params, k=10.0,
                             n_events=n_events, key=key, telemetry=tel)
    else:
        from repro.core.engine import run_sim
        out = run_sim(Exponential(lam), Exponential(mu), ThreePhaseKernel(),
                      params, k=10.0, n_events=n_events, key=key,
                      telemetry=tel)
    trace = out["trace"]
    records = device_trace_records(trace, trace["time_windows"])
    meta = {"producer": f"device/{loop}", "n_records": len(records),
            "events_total": int(sum(out["events"])),
            "p99_wait": float(out["p99_wait"])}
    return records, meta


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--loop", default="market",
                    choices=("single", "market", "region"))
    ap.add_argument("--n-events", type=int, default=4_000)
    ap.add_argument("--trace-cap", type=int, default=4_096)
    ap.add_argument("--host", action="store_true",
                    help="replay the host orchestrator instead of the "
                         "device engine")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.obs import write_perfetto

    records, meta = build_records(args.loop, n_events=args.n_events,
                                  trace_cap=args.trace_cap, host=args.host,
                                  seed=args.seed)
    label = f"{meta['producer']} ({args.n_events} events)"
    write_perfetto(args.out, records, label=label)
    print(json.dumps({"out": args.out, **meta}, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
