"""Validate a Chrome/Perfetto trace JSON produced by tools/trace_export.py.

Checks the structural contract that makes the file loadable by
``ui.perfetto.dev`` / ``chrome://tracing`` and meaningful for this repo:

  * top level: a ``traceEvents`` array (Chrome JSON object format);
  * thread-name metadata ("ph": "M") for every event-type track and the
    queue-length counter track;
  * every instant event ("ph": "i"): a known event type in its name,
    numeric non-negative ``ts``, and ``loc``/``qlen`` args;
  * every counter event ("ph": "C"): a numeric ``jobs`` arg;
  * timestamps non-decreasing per track is NOT required (merged streams
    interleave), but the global min must be >= 0;
  * at least ``--min-events`` instant events (sanity against an empty
    export).

    python tools/check_trace.py trace.json --min-events 100
"""
from __future__ import annotations

import argparse
import json
import sys

EVENT_TYPES = ("job", "spot", "preempt", "deadline")


def check(path: str, min_events: int) -> list[str]:
    with open(path) as f:
        doc = json.load(f)
    errors = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"{path}: no traceEvents array"]

    named_tids = set()
    n_instant = n_counter = 0
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "thread_name":
                named_tids.add(ev.get("tid"))
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph == "i":
            n_instant += 1
            args = ev.get("args", {})
            if not any(ev.get("name", "").startswith(t + "@")
                       for t in EVENT_TYPES):
                errors.append(f"event {i}: unknown type {ev.get('name')!r}")
            for field in ("loc", "qlen"):
                if not isinstance(args.get(field), int):
                    errors.append(f"event {i}: missing arg {field!r}")
        elif ph == "C":
            n_counter += 1
            if not isinstance(ev.get("args", {}).get("jobs"), int):
                errors.append(f"event {i}: counter without jobs arg")
        else:
            errors.append(f"event {i}: unexpected phase {ph!r}")
        if len(errors) > 20:
            errors.append("... (truncated)")
            break

    if None in named_tids or not named_tids:
        errors.append("missing thread_name metadata")
    if n_instant < min_events:
        errors.append(f"only {n_instant} instant events "
                      f"(need >= {min_events})")
    if n_counter != n_instant:
        errors.append(f"counter/instant mismatch ({n_counter} vs "
                      f"{n_instant})")
    if not errors:
        print(f"{path}: OK — {n_instant} events on {len(named_tids)} "
              f"named tracks")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace")
    ap.add_argument("--min-events", type=int, default=1)
    args = ap.parse_args()
    errors = check(args.trace, args.min_events)
    for err in errors:
        print(f"INVALID: {err}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
