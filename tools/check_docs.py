"""Execute the documentation's code: every fenced ``python`` block runs.

    PYTHONPATH=src python tools/check_docs.py [FILES...]

Default files: README.md and docs/kernels.md.  Each file's ``python``
blocks are executed top-to-bottom in ONE namespace per file (so a later
block can use names an earlier block defined), with the repo root as cwd.
Blocks fenced as ``bash`` are checked more cheaply: any line that sets
PYTHONPATH and invokes a repo script/module gets its *target* verified to
exist, so the quickstart cannot drift from the tree.  Exits non-zero on
the first failure — the CI docs job gates on it, which is what keeps the
README's promise that every command/import it shows runs green.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_FILES = ["README.md", "docs/kernels.md", "docs/observability.md",
                 "docs/robustness.md", "docs/scaling.md"]

_FENCE = re.compile(r"```(\w+)?\n(.*?)```", re.DOTALL)


def extract_blocks(text: str):
    for match in _FENCE.finditer(text):
        yield (match.group(1) or "").strip(), match.group(2)


def check_bash_block(block: str, path: str) -> None:
    """Verify that scripts/modules a bash block invokes exist in the tree."""
    for line in block.splitlines():
        line = line.split("#", 1)[0].strip()
        if not line:
            continue
        tokens = line.split()
        for i, tok in enumerate(tokens):
            if tok.endswith(".py") and not tok.startswith("-"):
                target = REPO / tok
                if not target.exists():
                    raise FileNotFoundError(
                        f"{path}: bash block references missing file {tok}")
            if tok == "-m" and i + 1 < len(tokens):
                mod = tokens[i + 1]
                if mod == "pytest":
                    continue  # the tier-1 CI job runs the suite itself
                mod_path = mod.replace(".", "/")
                if not ((REPO / "src" / (mod_path + ".py")).exists()
                        or (REPO / "src" / mod_path).exists()
                        or (REPO / (mod_path + ".py")).exists()
                        or (REPO / mod_path).exists()):
                    raise FileNotFoundError(
                        f"{path}: bash block references missing module {mod}")


def run_file(path: str) -> int:
    import types

    text = (REPO / path).read_text()
    # a real registered module, so dataclasses etc. defined in doc blocks
    # can resolve their __module__ during class construction
    mod_name = "docs_check_" + re.sub(r"\W", "_", path)
    module = types.ModuleType(mod_name)
    sys.modules[mod_name] = module
    namespace = module.__dict__
    n_python = 0
    for lang, block in extract_blocks(text):
        if lang == "python":
            n_python += 1
            print(f"[check_docs] {path}: executing python block #{n_python} "
                  f"({len(block.splitlines())} lines)")
            code = compile(block, f"{path}:block{n_python}", "exec")
            exec(code, namespace)  # noqa: S102 - that is the point
        elif lang == "bash":
            check_bash_block(block, path)
    print(f"[check_docs] {path}: OK ({n_python} python blocks executed)")
    return n_python


def main(argv: list[str]) -> None:
    files = argv or DEFAULT_FILES
    total = 0
    for path in files:
        total += run_file(path)
    if not total:
        raise SystemExit("no python blocks found — docs check is vacuous")
    print(f"[check_docs] all green: {total} python blocks across "
          f"{len(files)} files")


if __name__ == "__main__":
    main(sys.argv[1:])
