"""Perf-regression guard for the bench-smoke CI job.

Compares freshly-measured smoke BENCH jsons against the committed
baseline copies (benchmarks/baselines/) and fails — nonzero exit — if any
guarded throughput key drops more than its allowed fraction below the
baseline.  Keys are dotted paths into the JSON; higher is better.

Single-file mode (the original interface):

    python tools/check_bench_regression.py \
        --baseline benchmarks/baselines/BENCH_event_rng_smoke.json \
        --fresh BENCH_event_rng_smoke.json \
        --key headline.region_slab_events_per_s \
        --key headline.region_slab_speedup_x \
        --max-drop 0.30

Suite mode — one manifest guards every smoke bench in one invocation:

    python tools/check_bench_regression.py \
        --suite benchmarks/baselines/suite_smoke.json

The manifest is a JSON list of ``{"baseline", "fresh", "keys"}`` entries
where each key is ``{"key": "dotted.path", "max_drop": 0.30}``
(``max_drop`` optional, default 0.30).  Ratio-style keys (speedups,
overhead factors) are machine-independent and get tight drops; absolute
events/s floors are generous (60%) because smoke runners are noisy — the
guard exists to catch order-of-magnitude regressions (an accidentally
retained per-event threefry ladder, a de-jitted hot path), not 5% jitter.
Refresh a baseline by re-running ``benchmarks/run.py --smoke --only ...``
on a quiet machine and committing the new file.
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_MAX_DROP = 0.30


def lookup(tree: dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"{dotted!r} not found (missing {part!r})")
        node = node[part]
    return float(node)


def check_file(baseline_path: str, fresh_path: str,
               keys: list[tuple[str, float]]) -> list[str]:
    """Guard ``keys`` (dotted path, max_drop) of fresh vs baseline."""
    with open(baseline_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    failures = []
    for key, max_drop in keys:
        b, v = lookup(base, key), lookup(fresh, key)
        floor = b * (1.0 - max_drop)
        verdict = "OK" if v >= floor else "REGRESSION"
        print(f"{verdict:>10}  {fresh_path}:{key}: fresh={v:.4g} "
              f"baseline={b:.4g} floor={floor:.4g}")
        if v < floor:
            failures.append(f"{fresh_path}:{key}")
    return failures


def run_suite(manifest_path: str) -> list[str]:
    with open(manifest_path) as f:
        manifest = json.load(f)
    failures = []
    for entry in manifest:
        keys = [(k["key"], float(k.get("max_drop", DEFAULT_MAX_DROP)))
                for k in entry["keys"]]
        failures += check_file(entry["baseline"], entry["fresh"], keys)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", metavar="MANIFEST.json",
                    help="guard every entry of a suite manifest")
    ap.add_argument("--baseline")
    ap.add_argument("--fresh")
    ap.add_argument("--key", action="append", metavar="DOTTED.PATH",
                    help="throughput key to guard (repeatable)")
    ap.add_argument("--max-drop", type=float, default=DEFAULT_MAX_DROP,
                    help="maximum allowed fractional drop vs baseline")
    args = ap.parse_args()

    if args.suite:
        if args.baseline or args.fresh or args.key:
            ap.error("--suite is exclusive with --baseline/--fresh/--key")
        failures = run_suite(args.suite)
    else:
        if not (args.baseline and args.fresh and args.key):
            ap.error("need --suite or all of --baseline/--fresh/--key")
        failures = check_file(args.baseline, args.fresh,
                              [(k, args.max_drop) for k in args.key])
    if failures:
        print(f"perf regression: {failures} dropped below the committed "
              f"smoke baseline floors", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
