"""Perf-regression guard for the bench-smoke CI job.

Compares a freshly-measured smoke BENCH json against the committed
baseline copy (benchmarks/baselines/) and fails — nonzero exit — if any
guarded throughput key drops more than ``--max-drop`` (default 30%) below
the baseline.  Keys are dotted paths into the JSON; higher is better.

    python tools/check_bench_regression.py \
        --baseline benchmarks/baselines/BENCH_event_rng_smoke.json \
        --fresh BENCH_event_rng_smoke.json \
        --key headline.region_slab_events_per_s \
        --key headline.region_slab_speedup_x \
        --max-drop 0.30

Smoke runners are noisy; 30% headroom is deliberately generous — the guard
exists to catch order-of-magnitude regressions (an accidentally retained
per-event threefry ladder, a de-jitted hot path), not 5% jitter.  Refresh
the baseline by re-running ``benchmarks/run.py --smoke --only event_rng``
on a quiet machine and committing the new file.
"""
from __future__ import annotations

import argparse
import json
import sys


def lookup(tree: dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"{dotted!r} not found (missing {part!r})")
        node = node[part]
    return float(node)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--key", action="append", required=True,
                    metavar="DOTTED.PATH",
                    help="throughput key to guard (repeatable)")
    ap.add_argument("--max-drop", type=float, default=0.30,
                    help="maximum allowed fractional drop vs baseline")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    failures = []
    for key in args.key:
        b, v = lookup(base, key), lookup(fresh, key)
        floor = b * (1.0 - args.max_drop)
        verdict = "OK" if v >= floor else "REGRESSION"
        print(f"{verdict:>10}  {key}: fresh={v:.4g} baseline={b:.4g} "
              f"floor={floor:.4g}")
        if v < floor:
            failures.append(key)
    if failures:
        print(f"perf regression: {failures} dropped more than "
              f"{args.max_drop:.0%} below the committed smoke baseline",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
