"""Freeze the env=None lowering digests to tests/data/hlo_pr6.json.

    PYTHONPATH=src python tools/freeze_hlo_baseline.py

Run from a tree whose ``env=None`` program is the reference (the PR-6
engine, or any tree whose env-off lowering is known-good); the frozen
test tests/test_env.py::test_env_none_lowering_unchanged then pins every
subsequent tree's env-off lowering against it byte-for-byte (same jax
version + backend only — the digests are compiler-version specific).
"""
from __future__ import annotations

import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tests"))

from _hlo_matrix import environment_tag, lowering_digests  # noqa: E402


def main() -> None:
    payload = {**environment_tag(), "digests": lowering_digests()}
    out = REPO / "tests" / "data" / "hlo_pr6.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"[freeze_hlo_baseline] wrote {len(payload['digests'])} digests "
          f"to {out} (jax {payload['jax_version']}, "
          f"backend {payload['backend']})")


if __name__ == "__main__":
    main()
