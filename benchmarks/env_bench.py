"""Environment-timeline overhead: the ``env=`` axis measured on vs off.

The supply-shock contract is two-sided: ``env=None`` must compile the
*identical* program (zero cost — frozen byte-for-byte in
tests/test_env.py), and ``env=EnvTimeline(...)`` must stay cheap enough
to sweep non-stationary scenarios at engine speed.  This bench measures
the on-cost on the market sweep at three timeline densities:

  * ``off``    — today's program, the stationary baseline path;
  * ``const``  — a single open-ended segment (the timeline machinery is
                 live but no boundary ever fires);
  * ``storms`` — a Markov-modulated calm/storm timeline whose boundary
                 events actually interleave with the clock race.

Writes BENCH_env.json next to the repo root.  The headline is the
constant-timeline throughput (events/s with the env axis on); CI's
regression gate guards it via benchmarks/baselines/suite_smoke.json, and
docs/robustness.md + EXPERIMENTS.md quote this file for the on-cost.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import Exponential, NoticeAwareKernel, run_market_sweep
from repro.core.env import EnvTimeline, Regime, SEG_STORM, markov_timeline
from repro.core.market import SpotMarket, SpotPool
from repro.obs.timing import provenance, time_compiled

LAM, MU, K = 1 / 12, 1 / 24, 10.0
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCALE = 1.0


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    name = "BENCH_env.json" if _SCALE == 1.0 else "BENCH_env_smoke.json"
    return os.path.join(_REPO_ROOT, name)


def _market() -> SpotMarket:
    return SpotMarket(pools=(
        SpotPool(Exponential(MU / 2), price=0.4, hazard=0.02, notice=0.5),
        SpotPool(Exponential(MU / 2), price=0.7, hazard=0.005, notice=0.0),
    ))


def _storm_timeline(horizon: float) -> EnvTimeline:
    """Calm/storm Markov modulator dense enough that boundaries land
    inside the benched horizon (mean holds ~1% of it)."""
    return markov_timeline(
        (Regime(mean_hold=horizon / 60.0),
         Regime(mean_hold=horizon / 200.0, hazard_mult=8.0, avail=0.5,
                kind=SEG_STORM)),
        horizon=horizon, seed=0)


def measure_env_overhead(n_r: int = 16, n_seeds: int = 4,
                         n_events: int | None = None,
                         rmax: int = 32) -> dict:
    """Time the market sweep env-off / constant-timeline / storm-timeline."""
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    job = Exponential(LAM)
    market = _market()
    kern = NoticeAwareKernel()
    rs = jnp.linspace(0.25, 4.0, n_r)
    key = jax.random.key(0)
    common = dict(k=K, n_events=n_events, key=key, n_seeds=n_seeds,
                  rmax=rmax)
    # horizon estimate: merged event rate ~ job + spot arrivals
    horizon = n_events / (LAM + MU)
    modes = {
        "off": None,
        "const": EnvTimeline.constant(),
        "storms": _storm_timeline(horizon),
    }
    timings, boundaries = {}, 0
    for mode, env in modes.items():
        out, timing = time_compiled(
            lambda env=env: run_market_sweep(job, market, kern, {"r": rs},
                                             env=env, **common))
        timings[mode] = timing
        if mode == "storms":
            boundaries = int(jnp.sum(jnp.asarray(out["env_boundaries"])))

    grid_points = n_r * n_seeds
    total_events = grid_points * n_events
    t_off = timings["off"]["t_run_s"]
    t_const = timings["const"]["t_run_s"]
    t_storm = timings["storms"]["t_run_s"]
    result = {
        "grid_points": grid_points,
        "n_r": n_r,
        "n_seeds": n_seeds,
        "n_pools": market.n_pools,
        "n_events_per_point": n_events,
        "total_events": total_events,
        "rmax": rmax,
        "storm_segments": _storm_timeline(horizon).n_segments,
        "storm_boundaries_observed": boundaries,
        "t_off_s": t_off,
        "t_const_s": t_const,
        "t_storms_s": t_storm,
        "off_events_per_s": total_events / t_off,
        "const_events_per_s": total_events / t_const,
        "storms_events_per_s": total_events / t_storm,
        "const_overhead_x": t_const / t_off,
        "storms_overhead_x": t_storm / t_off,
        "backend": jax.default_backend(),
        "provenance": provenance(seed=0, env="off/const/storms"),
    }
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    return result


def bench_env_overhead():
    """Benchmark-harness entry: rows + headline (const-env events/s)."""
    res = measure_env_overhead()
    rows = [{
        "name": f"env/{res['grid_points']}pt_market_grid",
        "us_per_call": res["t_const_s"] * 1e6,
        "derived": (
            f"{res['grid_points']} points × {res['n_events_per_point']} ev: "
            f"off={res['t_off_s']:.2f}s const={res['t_const_s']:.2f}s "
            f"({res['const_overhead_x']:.2f}x) "
            f"storms={res['t_storms_s']:.2f}s "
            f"({res['storms_overhead_x']:.2f}x, "
            f"{res['storm_boundaries_observed']} boundaries)"),
    }]
    return rows, res["const_events_per_s"]


if __name__ == "__main__":
    rows, headline = bench_env_overhead()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.0f},{row['derived']}")
    print(f"headline const_events_per_s={headline:.0f}")
