"""Sweep-engine throughput: one batched jit vs the per-point Python loop.

Measures an (r × seed) admission-knob grid run two ways at equal total
events:

  * ``loop``  — one ``run_queue_sim`` call per grid point (the seed's only
    option: each point dispatches its own compiled scan from Python);
  * ``sweep`` — the whole grid as ONE ``run_sweep`` program (nested vmap).

Writes BENCH_sweep.json next to the repo root so CI and
``benchmarks/roofline.py`` can consume the numbers.  Compile time is
recorded separately from steady-state run time for the batched path
(``benchmarks/_timing.py``); the comparison is steady-state wall clock.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import provenance, time_compiled
from repro.core import Exponential, ThreePhaseKernel, run_queue_sim, run_sweep

LAM, MU, K = 1 / 12, 1 / 24, 10.0
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCALE = 1.0


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    # full-scale runs refresh the version-controlled reference numbers;
    # smoke runs write a separate (gitignored) file so they never clobber it
    name = "BENCH_sweep.json" if _SCALE == 1.0 else "BENCH_sweep_smoke.json"
    return os.path.join(_REPO_ROOT, name)


def measure_sweep_speedup(n_r: int = 16, n_seeds: int = 4,
                          n_events: int | None = None,
                          rmax: int = 64) -> dict:
    """Time the grid both ways; return a result dict (also JSON-dumped)."""
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    job, spot = Exponential(LAM), Exponential(MU)
    kernel = ThreePhaseKernel()
    rs = jnp.linspace(0.25, 4.0, n_r)
    key = jax.random.key(0)
    seed_keys = jax.random.split(key, n_seeds)

    out, sweep_timing = time_compiled(
        lambda: run_sweep(job, spot, kernel, {"r": rs}, k=K,
                          n_events=n_events, key=key, n_seeds=n_seeds,
                          rmax=rmax))
    t_sweep = sweep_timing["t_run_s"]
    # warm the per-point compiled path too (its compile cost is one trace)
    run_queue_sim(job, spot, k=K, r=0.25, n_events=n_events,
                  key=seed_keys[0], rmax=rmax)

    t0 = time.perf_counter()
    loop_cost = np.zeros((n_r, n_seeds))
    for i, r in enumerate(np.asarray(rs)):
        for s in range(n_seeds):
            loop_cost[i, s] = run_queue_sim(
                job, spot, k=K, r=float(r), n_events=n_events,
                key=seed_keys[s], rmax=rmax)["avg_cost"]
    t_loop = time.perf_counter() - t0

    grid_points = n_r * n_seeds
    total_events = grid_points * n_events
    result = {
        "grid_points": grid_points,
        "n_r": n_r,
        "n_seeds": n_seeds,
        "n_events_per_point": n_events,
        "total_events": total_events,
        "rmax": rmax,
        "rng": "split",  # the frozen stream (see BENCH_event_rng.json)
        "t_sweep_s": t_sweep,
        "t_sweep_compile_s": sweep_timing["t_compile_s"],
        "t_loop_s": t_loop,
        "speedup": t_loop / t_sweep,
        "sweep_events_per_s": total_events / t_sweep,
        "loop_events_per_s": total_events / t_loop,
        "max_abs_cost_diff": float(
            np.max(np.abs(out["avg_cost"] - loop_cost))),
        "backend": jax.default_backend(),
        "provenance": provenance(seed=0, telemetry="off"),
    }
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    return result


def bench_sweep_engine():
    """Benchmark-harness entry: rows + headline speedup."""
    res = measure_sweep_speedup()
    rows = [{
        "name": f"sweep/{res['grid_points']}pt_grid",
        "us_per_call": res["t_sweep_s"] * 1e6,
        "derived": (
            f"{res['grid_points']} points × {res['n_events_per_point']} ev: "
            f"sweep={res['t_sweep_s']:.2f}s loop={res['t_loop_s']:.2f}s "
            f"speedup={res['speedup']:.1f}x "
            f"({res['sweep_events_per_s']/1e6:.2f}M ev/s batched; "
            f"max|Δcost|={res['max_abs_cost_diff']:.1e})"
        ),
    }]
    return rows, res["speedup"]
