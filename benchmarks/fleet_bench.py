"""Fleet scaling: the ``shard="lanes"`` sweep across simulated devices.

Each device count runs in its own subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the flag must be
set before the JAX backend initializes, so the parent process (which holds
the single real device) can never measure multi-device itself.  The child
times one sharded single-queue sweep (``impl="xla"``, ``rng="slab"``, the
recommended fast path) with :func:`repro.obs.timing.time_compiled`, so the
curve carries the compile-vs-steady split per device count.

Writes BENCH_fleet.json (BENCH_fleet_smoke.json under ``--smoke``) with a
``devices → {t_run_s, t_compile_s, events_per_s}`` scaling curve and the
usual provenance stamp.  The headline (guarded by CI's suite manifest) is
the 1-device sharded throughput: on a CPU host the simulated devices all
share the same cores, so the *absolute* curve is flat-ish by construction
— the bench's job is to keep the sharded dispatch itself from regressing
and to report honest numbers for docs/scaling.md / EXPERIMENTS.md, not to
demonstrate CPU speedups.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCALE = 1.0


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    name = "BENCH_fleet.json" if _SCALE == 1.0 else "BENCH_fleet_smoke.json"
    return os.path.join(_REPO_ROOT, name)


# child source: measure one sharded sweep at this process's device count.
# Parameters arrive via argv (n_devices, n_r, n_seeds, n_events); the
# result leaves as one JSON line on stdout.
_CHILD = """
import json, os, sys
n_dev, n_r, n_seeds, n_events = map(int, sys.argv[1:5])
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=%d" % n_dev)
import jax, jax.numpy as jnp
from repro.core import Exponential, ThreePhaseKernel, run_sweep
from repro.distributed.sharding import lane_mesh
from repro.obs.timing import time_compiled

assert len(jax.devices()) >= n_dev, (n_dev, jax.devices())
kw = dict(k=10.0, n_events=n_events, key=jax.random.key(0),
          n_seeds=n_seeds, rmax=32, rng="slab",
          shard="lanes", mesh=lane_mesh(n_dev))
out, timing = time_compiled(lambda: run_sweep(
    Exponential(1 / 12), Exponential(1 / 24), ThreePhaseKernel(),
    {"r": jnp.linspace(0.25, 4.0, n_r)}, **kw))
timing["jobs_completed"] = int(jnp.sum(jnp.asarray(out["jobs_completed"])))
print(json.dumps(timing))
"""


def _measure_child(n_devices: int, n_r: int, n_seeds: int,
                   n_events: int) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # the child sets its own, pre-backend
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO_ROOT, "src")]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, str(n_devices), str(n_r),
         str(n_seeds), str(n_events)],
        capture_output=True, text=True, env=env, cwd=_REPO_ROOT,
        timeout=1_800)
    if out.returncode != 0:
        raise RuntimeError(
            f"fleet child ({n_devices} devices) failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def measure_fleet_scaling(device_counts=None, n_r: int = 32,
                          n_seeds: int = 4,
                          n_events: int | None = None) -> dict:
    """Devices × lanes scaling curve for the sharded sweep dispatch."""
    if device_counts is None:
        device_counts = (1, 2) if _SCALE < 1.0 else (1, 2, 4, 8)
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    lanes = n_r * n_seeds
    total_events = lanes * n_events
    curve = {}
    for n_dev in device_counts:
        timing = _measure_child(n_dev, n_r, n_seeds, n_events)
        curve[str(n_dev)] = {
            "t_run_s": timing["t_run_s"],
            "t_compile_s": timing["t_compile_s"],
            "events_per_s": total_events / timing["t_run_s"],
            "lanes_per_device": -(-lanes // n_dev),
        }
    from repro.obs.timing import provenance

    one = curve[str(device_counts[0])]
    result = {
        "device_counts": list(device_counts),
        "n_r": n_r,
        "n_seeds": n_seeds,
        "lanes": lanes,
        "n_events_per_lane": n_events,
        "total_events": total_events,
        "curve": curve,
        "events_per_s_1dev": one["events_per_s"],
        "provenance": provenance(
            seed=0, impl="xla", rng="slab", shard="lanes",
            simulated_devices="--xla_force_host_platform_device_count"),
    }
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    return result


def bench_fleet_scaling():
    """Benchmark-harness entry: rows + headline (1-device sharded ev/s)."""
    res = measure_fleet_scaling()
    rows = []
    for n_dev in res["device_counts"]:
        c = res["curve"][str(n_dev)]
        rows.append({
            "name": f"fleet/{n_dev}dev_{res['lanes']}lanes",
            "us_per_call": c["t_run_s"] * 1e6,
            "derived": (
                f"{res['lanes']} lanes × {res['n_events_per_lane']} ev on "
                f"{n_dev} simulated device(s): {c['events_per_s']:.0f} ev/s "
                f"(compile {c['t_compile_s']:.2f}s, "
                f"{c['lanes_per_device']} lanes/device)"),
        })
    return rows, res["events_per_s_1dev"]


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        set_scale(0.1)
    rows, headline = bench_fleet_scaling()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.0f},{row['derived']}")
    print(f"headline events_per_s_1dev={headline:.0f}")
