"""Work-axis overhead + the can't-be-late deadline tournament.

The checkpoint-priced-recovery contract is two-sided: ``work=None`` must
compile the *identical* program (zero cost — the frozen HLO baseline in
tests/test_env.py covers it), and ``work=WorkModel(...)`` must stay
cheap enough to sweep work-structured scenarios at engine speed.  This
bench measures the on-cost on the market sweep at three work densities:

  * ``off``      — today's program, jobs as atomic units;
  * ``identity`` — ``WorkModel()`` (the bit-for-bit identity config:
                   ledger machinery live, semantics unchanged);
  * ``priced``   — multi-unit jobs with checkpoint-on-notice, restart
                   overhead, and live deadlines (every ledger column
                   exercised).

It then replays the committed adversarial k80-style availability trace
(tests/data/spot_trace_k80.json) as the deadline tournament: the base
notice-aware kernel vs the :class:`~repro.core.work.CantBeLateKernel`
safety net vs the all-on-demand cost floor — the numbers EXPERIMENTS.md
§"Checkpoint-priced recovery" quotes.

Writes BENCH_deadline.json (BENCH_deadline_smoke.json under --smoke).
The headline is the identity-model throughput (events/s with the work
axis on); CI's regression gate guards it via
benchmarks/baselines/suite_smoke.json.
"""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from repro.core import (CantBeLateKernel, Exponential, NoticeAwareKernel,
                        WorkModel, all_ondemand_cost, run_market_sim,
                        run_market_sweep, timeline_from_trace)
from repro.core.market import SpotMarket, SpotPool
from repro.obs.timing import provenance, time_compiled

LAM, MU, K = 1 / 12, 1 / 24, 10.0
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
_TRACE = os.path.join(_REPO_ROOT, "tests", "data", "spot_trace_k80.json")

_SCALE = 1.0


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    name = ("BENCH_deadline.json" if _SCALE == 1.0
            else "BENCH_deadline_smoke.json")
    return os.path.join(_REPO_ROOT, name)


def _market() -> SpotMarket:
    return SpotMarket(pools=(
        SpotPool(Exponential(MU / 2), price=0.4, hazard=0.02, notice=0.5),
        SpotPool(Exponential(MU / 2), price=0.7, hazard=0.005, notice=0.0),
    ))


def _priced() -> WorkModel:
    return WorkModel.on_notice(0.2, total_work=3.0, restart_overhead=0.5,
                               deadline=120.0, od_time=10.0)


def measure_work_overhead(n_r: int = 16, n_seeds: int = 4,
                          n_events: int | None = None,
                          rmax: int = 32) -> dict:
    """Time the market sweep work-off / identity-model / priced-model."""
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    job = Exponential(LAM)
    market = _market()
    kern = NoticeAwareKernel()
    rs = jnp.linspace(0.25, 4.0, n_r)
    common = dict(k=K, n_events=n_events, key=jax.random.key(0),
                  n_seeds=n_seeds, rmax=rmax)
    modes = {"off": None, "identity": WorkModel(), "priced": _priced()}
    timings, recomputed = {}, 0.0
    for mode, work in modes.items():
        out, timing = time_compiled(
            lambda work=work: run_market_sweep(job, market, kern,
                                               {"r": rs}, work=work,
                                               **common))
        timings[mode] = timing
        if mode == "priced":
            recomputed = float(jnp.sum(jnp.asarray(out["work_recomputed"])))

    grid_points = n_r * n_seeds
    total_events = grid_points * n_events
    t_off = timings["off"]["t_run_s"]
    t_id = timings["identity"]["t_run_s"]
    t_priced = timings["priced"]["t_run_s"]
    return {
        "grid_points": grid_points,
        "n_r": n_r,
        "n_seeds": n_seeds,
        "n_events_per_point": n_events,
        "total_events": total_events,
        "rmax": rmax,
        "t_off_s": t_off,
        "t_identity_s": t_id,
        "t_priced_s": t_priced,
        "off_events_per_s": total_events / t_off,
        "identity_events_per_s": total_events / t_id,
        "priced_events_per_s": total_events / t_priced,
        "identity_overhead_x": t_id / t_off,
        "priced_overhead_x": t_priced / t_off,
        "priced_work_recomputed": recomputed,
    }


def measure_tournament(n_events: int | None = None) -> dict:
    """Base kernel vs safety net vs all-on-demand on the k80 trace."""
    if n_events is None:
        n_events = max(2_500, int(25_000 * _SCALE))
    with open(_TRACE) as f:
        d = json.load(f)
    env = timeline_from_trace(d["times"], d["avail"])
    market = SpotMarket(pools=tuple(
        SpotPool(arrival=Exponential(r), price=p["price"],
                 hazard=p["hazard"], notice=p["notice"])
        for r, p in zip((0.8, 0.6), d["pools"])))
    work = WorkModel.on_notice(0.05, total_work=1.0, restart_overhead=0.2,
                               deadline=2.5, od_time=0.5)
    base_kern = NoticeAwareKernel(checkpoint_time=0.05)
    k = 5.0
    common = dict(k=k, n_events=n_events, key=jax.random.key(7),
                  burn_in=0, env=env, work=work)
    entries = {}
    for name, kern in (("base", base_kern),
                       ("safety_net",
                        CantBeLateKernel(base_kern, slack_buffer=0.2))):
        out, timing = time_compiled(
            lambda kern=kern: run_market_sim(
                Exponential(1.2), market, kern, {"r": jnp.float32(2.0)},
                **common))
        entries[name] = {
            "avg_cost": float(out["avg_cost"]),
            "deadline_misses": int(out["deadline_misses"]),
            "deadline_miss_rate": float(out["deadline_miss_rate"]),
            "panic_entries": int(out["panic_entries"]),
            "jobs_finished": int(out["jobs_finished"]),
            "blackout_time": float(out["blackout_time"]),
            "t_run_s": timing["t_run_s"],
        }
    return {
        "trace": os.path.relpath(_TRACE, _REPO_ROOT),
        "n_events": n_events,
        "k": k,
        "all_ondemand_cost_per_job": all_ondemand_cost(k, 1),
        **entries,
    }


def bench_deadline():
    """Benchmark-harness entry: rows + headline (identity-work ev/s)."""
    overhead = measure_work_overhead()
    tour = measure_tournament()
    result = {**overhead, "tournament": tour,
              "backend": jax.default_backend(),
              "provenance": provenance(seed=0, work="off/identity/priced",
                                       trace=tour["trace"])}
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    rows = [
        {
            "name": f"work/{overhead['grid_points']}pt_market_grid",
            "us_per_call": overhead["t_identity_s"] * 1e6,
            "derived": (
                f"{overhead['grid_points']} points × "
                f"{overhead['n_events_per_point']} ev: "
                f"off={overhead['t_off_s']:.2f}s "
                f"identity={overhead['t_identity_s']:.2f}s "
                f"({overhead['identity_overhead_x']:.2f}x) "
                f"priced={overhead['t_priced_s']:.2f}s "
                f"({overhead['priced_overhead_x']:.2f}x)"),
        },
        {
            "name": "deadline/k80_tournament",
            "us_per_call": tour["safety_net"]["t_run_s"] * 1e6,
            "derived": (
                f"base misses {tour['base']['deadline_misses']} "
                f"@ {tour['base']['avg_cost']:.2f}/job; safety net "
                f"misses {tour['safety_net']['deadline_misses']} "
                f"({tour['safety_net']['panic_entries']} panics) "
                f"@ {tour['safety_net']['avg_cost']:.2f}/job; "
                f"all-on-demand {tour['all_ondemand_cost_per_job']:.2f}"),
        },
    ]
    return rows, result["identity_events_per_s"]


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        set_scale(0.1)
    rows, headline = bench_deadline()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.0f},{row['derived']}")
    print(f"headline identity_events_per_s={headline:.0f}")
