"""Event-loop RNG streams: slab vs split, at matched grid/events (PR 5).

Times the market and region sweep engines on BOTH PRNG streams at exactly
the configurations of BENCH_market.json / BENCH_region.json (same grids,
same event counts, same kernels — the split numbers here ARE those benches'
engines re-measured in-process, i.e. the PR-4 baseline):

  * ``rng="split"`` — the frozen per-event key-ladder stream: 4-6
    ``jax.random.split`` threefry calls plus a per-pool/per-region
    ``fold_in`` + ``exponential`` clock refresh per event;
  * ``rng="slab"``  — one counter-based uint32 slab per float32 window,
    draws consumed by static column index, preemption clock vectors
    superposed into one scalar clock (see EXPERIMENTS.md §"Event-loop
    RNG").

Writes BENCH_event_rng.json next to the repo root (smoke runs write a
separate BENCH_event_rng_smoke.json, the committed copy of which is the
CI perf-regression baseline — tools/check_bench_regression.py fails the
bench-smoke job if the slab/split speedup ratio drops >30%, or absolute
slab events/s >60%, below it).  The
acceptance target: slab region events/s ≥ 2× the split (PR-4) baseline,
with compile and steady-state times recorded separately for every cell.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import provenance, time_compiled
from benchmarks.market_bench import bench_market
from benchmarks.region_bench import bench_topology
from repro.core import (
    Exponential,
    NoticeAwareKernel,
    RoutingKernel,
    ThreePhaseKernel,
    run_market_sweep,
    run_region_sweep,
    run_sweep,
)

LAM, MU, K = 1 / 12, 1 / 24, 10.0
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCALE = 1.0


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    name = ("BENCH_event_rng.json" if _SCALE == 1.0
            else "BENCH_event_rng_smoke.json")
    return os.path.join(_REPO_ROOT, name)


def measure_event_rng(n_r: int = 16, n_seeds: int = 4,
                      n_events: int | None = None,
                      rmax_region: int = 16, rmax_market: int = 64) -> dict:
    """Both loops × both streams at the BENCH_market/BENCH_region configs."""
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    job = Exponential(LAM)
    market = bench_market()
    topo = bench_topology(rmax_region)
    rs = jnp.linspace(0.25, 4.0, n_r)
    key = jax.random.key(0)
    mkern = NoticeAwareKernel(checkpoint_time=0.05)
    rkern = RoutingKernel(NoticeAwareKernel(checkpoint_time=0.05),
                          choice="least_loaded")
    grid_points = n_r * n_seeds
    total_events = grid_points * n_events
    common = dict(k=K, n_events=n_events, key=key, n_seeds=n_seeds)

    result = {
        "grid_points": grid_points,
        "n_r": n_r,
        "n_seeds": n_seeds,
        "n_events_per_point": n_events,
        "total_events": total_events,
        "n_pools": market.n_pools,
        "n_regions": topo.n_regions,
        "rmax_market": rmax_market,
        "rmax_per_region": rmax_region,
        "backend": jax.default_backend(),
        "provenance": provenance(seed=0, telemetry="off"),
    }

    for loop, run in (
        ("single", lambda rng: run_sweep(
            Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
            {"r": rs}, rmax=rmax_market, rng=rng, **common)),
        ("market", lambda rng: run_market_sweep(
            job, market, mkern, {"r": rs}, rmax=rmax_market, rng=rng,
            **common)),
        ("region", lambda rng: run_region_sweep(
            topo, rkern, {"r": rs}, rng=rng, **common)),
    ):
        cells = {}
        for rng in ("split", "slab"):
            out, timing = time_compiled(lambda rng=rng: run(rng))
            cells[rng] = {
                "rng": rng,
                **timing,
                "events_per_s": total_events / timing["t_run_s"],
                "preemptions_total": float(
                    np.asarray(out["preemptions"]).sum())
                if "preemptions" in out else 0.0,
            }
        cells["slab_speedup_x"] = (cells["slab"]["events_per_s"]
                                   / cells["split"]["events_per_s"])
        result[loop] = cells

    # per-event overhead of the market machinery vs the single-queue
    # engine ON THE SAME STREAM (the BENCH_market.json ratio, per stream)
    for loop in ("market", "region"):
        for rng in ("split", "slab"):
            result[loop][rng]["overhead_vs_single_x"] = (
                result["single"][rng]["events_per_s"]
                / result[loop][rng]["events_per_s"])

    result["headline"] = {
        # the acceptance target: slab region sweep vs the split (PR-4
        # baseline) stream, same grid, same events
        "region_split_events_per_s":
            result["region"]["split"]["events_per_s"],
        "region_slab_events_per_s":
            result["region"]["slab"]["events_per_s"],
        "region_slab_speedup_x": result["region"]["slab_speedup_x"],
        "market_slab_speedup_x": result["market"]["slab_speedup_x"],
        "market_overhead_split_x":
            result["market"]["split"]["overhead_vs_single_x"],
        "market_overhead_slab_x":
            result["market"]["slab"]["overhead_vs_single_x"],
        "target_region_speedup_x": 2.0,
        "meets_target": result["region"]["slab_speedup_x"] >= 2.0,
    }
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    return result


def bench_event_rng():
    """Benchmark-harness entry: rows + headline (slab region events/s)."""
    res = measure_event_rng()
    rows = []
    for loop in ("market", "region"):
        c = res[loop]
        rows.append({
            "name": f"event_rng/{loop}/{res['grid_points']}pt_grid",
            "us_per_call": c["slab"]["t_run_s"] * 1e6,
            "derived": (
                f"{res['grid_points']} pts × {res['n_events_per_point']} ev: "
                f"slab={c['slab']['events_per_s']/1e6:.2f}M ev/s "
                f"split={c['split']['events_per_s']/1e6:.2f}M ev/s "
                f"speedup={c['slab_speedup_x']:.2f}x "
                f"(compile slab={c['slab']['t_compile_s']:.1f}s "
                f"split={c['split']['t_compile_s']:.1f}s)"
            ),
        })
    return rows, res["headline"]["region_slab_events_per_s"]
