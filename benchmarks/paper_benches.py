"""Paper-figure/table benchmarks.

One function per figure/table in the paper; each returns (rows, derived)
where rows are CSV-able dicts and derived is a headline scalar checked
against the paper's claim.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (
    BathtubGCP,
    Exponential,
    Gamma,
    Uniform,
    adaptive_admission_control,
    optimal_deterministic,
    run_queue_sim,
    run_single_slot_sim,
    theorem1_cost,
    theorem2_cost,
    theorem2_delta_max,
    theorem5_cost,
    theorem5_delta,
)
from repro.core.lp import waittime_lp, waittime_lp_cost

LAM, MU, K = 1 / 12, 1 / 24, 10.0


def _timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def bench_theorem1_cost_law():
    """Theorem 1: E[C] = k − (k−1)(μ/λ)(1−π₀) across process mixes."""
    mixes = [
        ("M/M", Exponential(LAM), Exponential(MU), 1.5),
        ("G(gamma)/M", Gamma(12.0, 1.0), Exponential(MU), 2.0),
        ("M/G(unif)", Exponential(LAM), Uniform(0.0, 48.0), 1.0),
        ("M/G(bathtub)", Exponential(LAM), BathtubGCP(), 1.0),
    ]
    rows = []
    worst = 0.0
    for name, job, spot, r in mixes:
        res, us = _timed(lambda: run_queue_sim(
            job, spot, k=K, r=r, n_events=200_000, key=jax.random.key(1)))
        pred = theorem1_cost(K, job.rate(), spot.rate(), res["pi0_spot"])
        err = abs(pred - res["avg_cost"])
        worst = max(worst, err)
        rows.append({"name": f"theorem1/{name}", "us_per_call": us,
                     "derived": f"sim={res['avg_cost']:.4f} "
                                f"thm1={pred:.4f} err={err:.4f}"})
    return rows, worst


def bench_fig2_bathtub_strong():
    """Fig 2: bathtub spot, Poisson(1/12) jobs, δ=3h → cost ≈ 7.75."""
    spot = BathtubGCP()
    target = theorem2_cost(K, spot.rate(), 3.0)
    rows = []
    for r0 in (0.05, 4.0):
        out, us = _timed(lambda: adaptive_admission_control(
            Exponential(LAM), spot, k=K, delta=3.0, eta=0.05, eta_decay=0.05,
            r0=r0, window_events=2048, n_windows=400, key=jax.random.key(2)))
        rows.append({
            "name": f"fig2/bathtub_delta3_r0={r0}", "us_per_call": us,
            "derived": f"cost={out['final_cost']:.3f} target≈{target:.3f} "
                       f"delay={out['final_delay']:.2f} r*={out['r_star']:.3f}",
        })
    return rows, target


def bench_fig3_bathtub_relaxed():
    """Fig 3: bathtub spot, δ=18h (λδ>1): both inits converge to a common
    cost (no closed form in this regime)."""
    spot = BathtubGCP()
    outs = []
    rows = []
    for r0 in (0.3, 6.0):
        out, us = _timed(lambda: adaptive_admission_control(
            Exponential(LAM), spot, k=K, delta=18.0, eta=0.02, eta_decay=0.05,
            r0=r0, r_max=8.0, window_events=4096, n_windows=400,
            key=jax.random.key(3)))
        outs.append(out)
        rows.append({
            "name": f"fig3/bathtub_delta18_r0={r0}", "us_per_call": us,
            "derived": f"cost={out['final_cost']:.3f} "
                       f"delay={out['final_delay']:.2f} r*={out['r_star']:.3f}",
        })
    gap = abs(outs[0]["final_cost"] - outs[1]["final_cost"])
    rows.append({"name": "fig3/convergence_gap", "us_per_call": 0,
                 "derived": f"cost_gap={gap:.3f}"})
    return rows, gap


def bench_fig4_mm_strong():
    """Fig 4: M/M, δ=3 → cost → k−(k−1)μδ = 8.875."""
    rows = []
    for r0 in (0.05, 4.0):
        out, us = _timed(lambda: adaptive_admission_control(
            Exponential(LAM), Exponential(MU), k=K, delta=3.0, eta=0.05,
            eta_decay=0.05, r0=r0, window_events=2048, n_windows=400,
            key=jax.random.key(4)))
        rows.append({
            "name": f"fig4/mm_delta3_r0={r0}", "us_per_call": us,
            "derived": f"cost={out['final_cost']:.3f} target=8.875 "
                       f"delay={out['final_delay']:.2f}",
        })
    return rows, 8.875


def bench_fig5_mm_relaxed():
    """Fig 5: M/M, δ=27 → r* → 3, cost → E[C₃] = 5.8 (Theorem 5)."""
    rows = []
    for r0 in (0.5, 8.0):
        out, us = _timed(lambda: adaptive_admission_control(
            Exponential(LAM), Exponential(MU), k=K, delta=27.0, eta=0.02,
            eta_decay=0.05, r0=r0, r_max=8.0, window_events=4096,
            n_windows=500, key=jax.random.key(5)))
        rows.append({
            "name": f"fig5/mm_delta27_r0={r0}", "us_per_call": us,
            "derived": f"r*={out['r_star']:.3f} (target 3) "
                       f"cost={out['final_cost']:.3f} (target "
                       f"{theorem5_cost(K, LAM, MU, 3):.3f}) "
                       f"delay={out['final_delay']:.2f}",
        })
    return rows, theorem5_cost(K, LAM, MU, 3)


def bench_theorem5_table():
    """Theorem 5 closed forms vs simulation, N = 1..6."""
    rows = []
    worst = 0.0
    for n in range(1, 7):
        res, us = _timed(lambda: run_queue_sim(
            Exponential(LAM), Exponential(MU), k=K, r=float(n),
            n_events=200_000, key=jax.random.key(10 + n)))
        c_thm = theorem5_cost(K, LAM, MU, n)
        d_thm = theorem5_delta(LAM, MU, n)
        worst = max(worst, abs(res["avg_cost"] - c_thm))
        rows.append({
            "name": f"theorem5/N={n}", "us_per_call": us,
            "derived": f"cost sim={res['avg_cost']:.4f} thm={c_thm:.4f}; "
                       f"delay sim={res['avg_delay']:.2f} thm={d_thm:.2f}",
        })
    return rows, worst


def bench_waittime_optimality():
    """Theorem 3 / Corollaries: closed-form optima vs LP oracle vs sim."""
    rows = []
    delta = 3.0
    # Corollary 4 deterministic wait under Exp spot
    det = optimal_deterministic(LAM, MU, delta)
    res, us = _timed(lambda: run_single_slot_sim(
        Exponential(LAM), Exponential(MU), det, k=K, n_events=200_000,
        key=jax.random.key(20)))
    rows.append({"name": "waittime/corollary4_det", "us_per_call": us,
                 "derived": f"cost={res['avg_cost']:.4f} "
                            f"target={theorem2_cost(K, MU, delta):.4f} "
                            f"X*={det.value:.3f}h"})
    # Corollary 1 via LP on uniform spot
    spot = Uniform(0.0, 48.0)
    lp, us = _timed(lambda: waittime_lp(spot, LAM, delta))
    rows.append({
        "name": "waittime/corollary1_lp", "us_per_call": us,
        "derived": f"support={np.round(lp.support, 2).tolist()} "
                   f"mass={np.round(lp.masses, 4).tolist()} "
                   f"cost={waittime_lp_cost(K, LAM, delta, lp):.4f} "
                   f"target={theorem2_cost(K, spot.rate(), delta):.4f}",
    })
    # regime boundary
    rows.append({
        "name": "waittime/theorem2_boundary", "us_per_call": 0,
        "derived": f"delta_max={theorem2_delta_max(Exponential(LAM), Exponential(MU)):.3f}h"
                   " (=1/(λ+μ)=8)"})
    return rows, theorem2_cost(K, MU, delta)
