"""Paper-figure/table benchmarks, built on the batched sweep engine.

One function per figure/table in the paper; each returns (rows, derived)
where rows are CSV-able dicts and derived is a headline scalar checked
against the paper's claim.

Grid-shaped benches (the Theorem-5 table, the two-initialization adaptive
figures) run as ONE jitted program each via
:func:`repro.core.engine.run_sweep` / ``adaptive_admission_control_batched``
instead of the seed's one-Python-call-per-point loops.

``set_scale(s)`` shrinks event counts for smoke runs (``benchmarks/run.py
--smoke``); statistical tolerances in the derived strings are only
meaningful at scale 1.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BathtubGCP,
    Exponential,
    Gamma,
    ThreePhaseKernel,
    Uniform,
    adaptive_admission_control_batched,
    optimal_deterministic,
    run_single_slot_sim,
    run_sweep,
    theorem1_cost,
    theorem2_cost,
    theorem2_delta_max,
    theorem5_cost,
    theorem5_delta,
)
from repro.core.lp import waittime_lp, waittime_lp_cost

LAM, MU, K = 1 / 12, 1 / 24, 10.0

_SCALE = 1.0


def set_scale(scale: float) -> None:
    """Scale event/window counts (smoke mode uses e.g. 0.05)."""
    global _SCALE
    _SCALE = scale


def _n(base: int, floor: int = 2048) -> int:
    return max(floor, int(base * _SCALE))


def _timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def bench_theorem1_cost_law():
    """Theorem 1: E[C] = k − (k−1)(μ/λ)(1−π₀) across process mixes.

    Each mix is a different static (job, spot) pair — its own compiled
    program — but every mix checks the law at four admission knobs in one
    batched ``run_sweep`` call.
    """
    mixes = [
        ("M/M", Exponential(LAM), Exponential(MU)),
        ("G(gamma)/M", Gamma(12.0, 1.0), Exponential(MU)),
        ("M/G(unif)", Exponential(LAM), Uniform(0.0, 48.0)),
        ("M/G(bathtub)", Exponential(LAM), BathtubGCP()),
    ]
    rs = jnp.array([0.5, 1.0, 1.5, 2.0])
    rows = []
    worst = 0.0
    for name, job, spot in mixes:
        res, us = _timed(lambda: run_sweep(
            job, spot, ThreePhaseKernel(), {"r": rs}, k=K,
            n_events=_n(200_000), key=jax.random.key(1)))
        lam, mu = job.rate(), spot.rate()
        pred = theorem1_cost(K, lam, mu, res["pi0_spot"][..., 0])
        err = float(np.max(np.abs(pred - res["avg_cost"][..., 0])))
        worst = max(worst, err)
        rows.append({"name": f"theorem1/{name}", "us_per_call": us,
                     "derived": f"4-knob sweep worst |sim-thm1|={err:.4f}"})
    return rows, worst


def bench_fig2_bathtub_strong():
    """Fig 2: bathtub spot, Poisson(1/12) jobs, δ=3h → cost ≈ 7.75.

    Both initializations advance as one batched learner fleet."""
    spot = BathtubGCP()
    target = theorem2_cost(K, spot.rate(), 3.0)
    r0s = (0.05, 4.0)
    out, us = _timed(lambda: adaptive_admission_control_batched(
        Exponential(LAM), spot, k=K, delta=3.0, eta=0.05, eta_decay=0.05,
        r0=jnp.array(r0s), window_events=2048, n_windows=_n(400, 50),
        key=jax.random.key(2)))
    rows = [{
        "name": f"fig2/bathtub_delta3_r0={r0}", "us_per_call": us / len(r0s),
        "derived": f"cost={out['final_cost'][i]:.3f} target≈{target:.3f} "
                   f"delay={out['final_delay'][i]:.2f} "
                   f"r*={out['r_star'][i]:.3f}",
    } for i, r0 in enumerate(r0s)]
    return rows, target


def bench_fig3_bathtub_relaxed():
    """Fig 3: bathtub spot, δ=18h (λδ>1): both inits converge to a common
    cost (no closed form in this regime)."""
    spot = BathtubGCP()
    r0s = (0.3, 6.0)
    out, us = _timed(lambda: adaptive_admission_control_batched(
        Exponential(LAM), spot, k=K, delta=18.0, eta=0.02, eta_decay=0.05,
        r0=jnp.array(r0s), r_max=8.0, window_events=4096,
        n_windows=_n(400, 50), key=jax.random.key(3)))
    rows = [{
        "name": f"fig3/bathtub_delta18_r0={r0}", "us_per_call": us / len(r0s),
        "derived": f"cost={out['final_cost'][i]:.3f} "
                   f"delay={out['final_delay'][i]:.2f} "
                   f"r*={out['r_star'][i]:.3f}",
    } for i, r0 in enumerate(r0s)]
    gap = abs(out["final_cost"][0] - out["final_cost"][1])
    rows.append({"name": "fig3/convergence_gap", "us_per_call": 0,
                 "derived": f"cost_gap={gap:.3f}"})
    return rows, gap


def bench_fig4_mm_strong():
    """Fig 4: M/M, δ=3 → cost → k−(k−1)μδ = 8.875."""
    r0s = (0.05, 4.0)
    out, us = _timed(lambda: adaptive_admission_control_batched(
        Exponential(LAM), Exponential(MU), k=K, delta=3.0, eta=0.05,
        eta_decay=0.05, r0=jnp.array(r0s), window_events=2048,
        n_windows=_n(400, 50), key=jax.random.key(4)))
    rows = [{
        "name": f"fig4/mm_delta3_r0={r0}", "us_per_call": us / len(r0s),
        "derived": f"cost={out['final_cost'][i]:.3f} target=8.875 "
                   f"delay={out['final_delay'][i]:.2f}",
    } for i, r0 in enumerate(r0s)]
    return rows, 8.875


def bench_fig5_mm_relaxed():
    """Fig 5: M/M, δ=27 → r* → 3, cost → E[C₃] = 5.8 (Theorem 5)."""
    r0s = (0.5, 8.0)
    out, us = _timed(lambda: adaptive_admission_control_batched(
        Exponential(LAM), Exponential(MU), k=K, delta=27.0, eta=0.02,
        eta_decay=0.05, r0=jnp.array(r0s), r_max=8.0, window_events=4096,
        n_windows=_n(500, 50), key=jax.random.key(5)))
    rows = [{
        "name": f"fig5/mm_delta27_r0={r0}", "us_per_call": us / len(r0s),
        "derived": f"r*={out['r_star'][i]:.3f} (target 3) "
                   f"cost={out['final_cost'][i]:.3f} (target "
                   f"{theorem5_cost(K, LAM, MU, 3):.3f}) "
                   f"delay={out['final_delay'][i]:.2f}",
    } for i, r0 in enumerate(r0s)]
    return rows, theorem5_cost(K, LAM, MU, 3)


def bench_theorem5_table():
    """Theorem 5 closed forms vs simulation, N = 1..6 — one sweep call."""
    ns = np.arange(1, 7)
    res, us = _timed(lambda: run_sweep(
        Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
        {"r": jnp.asarray(ns, jnp.float32)}, k=K, n_events=_n(200_000),
        key=jax.random.key(10)))
    rows = []
    worst = 0.0
    for i, n in enumerate(ns):
        cost = float(res["avg_cost"][i, 0])
        delay = float(res["avg_delay"][i, 0])
        c_thm = theorem5_cost(K, LAM, MU, int(n))
        d_thm = theorem5_delta(LAM, MU, int(n))
        worst = max(worst, abs(cost - c_thm))
        rows.append({
            "name": f"theorem5/N={n}", "us_per_call": us / len(ns),
            "derived": f"cost sim={cost:.4f} thm={c_thm:.4f}; "
                       f"delay sim={delay:.2f} thm={d_thm:.2f}",
        })
    return rows, worst


def bench_waittime_optimality():
    """Theorem 3 / Corollaries: closed-form optima vs LP oracle vs sim."""
    rows = []
    delta = 3.0
    # Corollary 4 deterministic wait under Exp spot
    det = optimal_deterministic(LAM, MU, delta)
    res, us = _timed(lambda: run_single_slot_sim(
        Exponential(LAM), Exponential(MU), det, k=K, n_events=_n(200_000),
        key=jax.random.key(20)))
    rows.append({"name": "waittime/corollary4_det", "us_per_call": us,
                 "derived": f"cost={res['avg_cost']:.4f} "
                            f"target={theorem2_cost(K, MU, delta):.4f} "
                            f"X*={det.value:.3f}h"})
    # Corollary 1 via LP on uniform spot
    spot = Uniform(0.0, 48.0)
    lp, us = _timed(lambda: waittime_lp(spot, LAM, delta))
    rows.append({
        "name": "waittime/corollary1_lp", "us_per_call": us,
        "derived": f"support={np.round(lp.support, 2).tolist()} "
                   f"mass={np.round(lp.masses, 4).tolist()} "
                   f"cost={waittime_lp_cost(K, LAM, delta, lp):.4f} "
                   f"target={theorem2_cost(K, spot.rate(), delta):.4f}",
    })
    # regime boundary
    rows.append({
        "name": "waittime/theorem2_boundary", "us_per_call": 0,
        "derived": f"delta_max={theorem2_delta_max(Exponential(LAM), Exponential(MU)):.3f}h"
                   " (=1/(λ+μ)=8)"})
    return rows, theorem2_cost(K, MU, delta)
