"""Benchmark harness: one function per paper table/figure + the roofline
table from dry-run artifacts.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import paper_benches as pb
    from benchmarks.roofline import bench_roofline

    benches = [
        pb.bench_theorem1_cost_law,
        pb.bench_fig2_bathtub_strong,
        pb.bench_fig3_bathtub_relaxed,
        pb.bench_fig4_mm_strong,
        pb.bench_fig5_mm_relaxed,
        pb.bench_theorem5_table,
        pb.bench_waittime_optimality,
        bench_roofline,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        try:
            rows, _ = bench()
            for row in rows:
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.0f},{derived}")
        except Exception as exc:  # keep the harness going
            failures += 1
            print(f"{bench.__name__},0,ERROR: {exc}", file=sys.stdout)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
