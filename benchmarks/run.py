"""Benchmark harness: one function per paper table/figure, the sweep-engine
throughput bench, and the roofline table from dry-run artifacts.

    PYTHONPATH=src python benchmarks/run.py [--smoke] [--json PATH] [--only SUBSTR]

Prints ``name,us_per_call,derived`` CSV.  ``--smoke`` shrinks event counts
(~20× fewer events) so the whole suite runs in a couple of minutes on CPU —
statistical targets in the derived strings only hold at full scale, but the
sweep-engine speedup numbers still land in BENCH_sweep.json.  ``--json``
additionally dumps all rows (plus per-bench headline scalars) to PATH.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scale event counts down ~20x")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump rows to a BENCH_*.json file")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only benches whose name contains SUBSTR")
    args = ap.parse_args()

    from benchmarks import deadline_bench
    from benchmarks import engine_kernel_bench
    from benchmarks import env_bench
    from benchmarks import event_rng_bench
    from benchmarks import fleet_bench
    from benchmarks import market_bench
    from benchmarks import obs_bench
    from benchmarks import paper_benches as pb
    from benchmarks import region_bench
    from benchmarks import sweep_bench
    from benchmarks.roofline import bench_engine_roofline, bench_roofline

    if args.smoke:
        pb.set_scale(0.05)
        sweep_bench.set_scale(0.1)
        market_bench.set_scale(0.1)
        engine_kernel_bench.set_scale(0.1)
        region_bench.set_scale(0.1)
        event_rng_bench.set_scale(0.1)
        obs_bench.set_scale(0.1)
        env_bench.set_scale(0.1)
        deadline_bench.set_scale(0.1)
        fleet_bench.set_scale(0.1)

    benches = [
        pb.bench_theorem1_cost_law,
        pb.bench_fig2_bathtub_strong,
        pb.bench_fig3_bathtub_relaxed,
        pb.bench_fig4_mm_strong,
        pb.bench_fig5_mm_relaxed,
        pb.bench_theorem5_table,
        pb.bench_waittime_optimality,
        sweep_bench.bench_sweep_engine,  # writes BENCH_sweep.json
        market_bench.bench_market_engine,  # writes BENCH_market.json
        engine_kernel_bench.bench_engine_kernel,  # BENCH_engine_kernel.json
        region_bench.bench_region_engine,  # writes BENCH_region.json
        event_rng_bench.bench_event_rng,  # writes BENCH_event_rng.json
        obs_bench.bench_telemetry_overhead,  # writes BENCH_obs.json
        env_bench.bench_env_overhead,  # writes BENCH_env.json
        deadline_bench.bench_deadline,  # writes BENCH_deadline.json
        fleet_bench.bench_fleet_scaling,  # writes BENCH_fleet.json
        bench_engine_roofline,  # reads them back
        bench_roofline,
    ]
    if args.only:
        benches = [b for b in benches if args.only in b.__name__]
    print("name,us_per_call,derived")
    all_rows = []
    failures = 0
    for bench in benches:
        try:
            rows, headline = bench()
            for row in rows:
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.0f},{derived}")
            all_rows.append({"bench": bench.__name__, "rows": rows,
                             "headline": float(headline)})
        except Exception as exc:  # keep the harness going
            failures += 1
            print(f"{bench.__name__},0,ERROR: {exc}", file=sys.stdout)
            all_rows.append({"bench": bench.__name__, "error": str(exc)})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "benches": all_rows}, f,
                      indent=2, default=str)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
