"""Roofline derivation from dry-run artifacts (§Roofline of EXPERIMENTS.md).

Hardware constants (TPU v5e target):
  peak bf16 compute   197 TFLOP/s per chip
  HBM bandwidth       819 GB/s per chip
  ICI link bandwidth  ~50 GB/s per link

Terms per (arch × shape × mesh), all in seconds per step:
  compute    = HLO_FLOPs_per_device / peak
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / link_bw

(cost_analysis() reports per-device numbers, verified against a hand-counted
einsum; wire bytes come from the loop-aware HLO parse.)

Derived:
  bottleneck        = argmax of the three terms
  MODEL_FLOPS       = 6·N·D (dense train) / 6·N_active·D (MoE) / 2·N·D
                      (inference fwd), D = tokens processed
  useful_ratio      = MODEL_FLOPS / (HLO_FLOPs × chips)  — remat/redundancy
  mfu_bound         = MODEL_FLOPS / (chips × peak × max(term))  — the
                      roofline fraction: model-useful utilization if the
                      step ran exactly at its dominant-term bound.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "artifacts")


def model_flops(art: dict) -> float:
    cell = art["cell"]
    n_active = art["active_params"]
    if cell["kind"] == "train":
        tokens = cell["seq_len"] * cell["global_batch"]
        return 6.0 * n_active * tokens
    if cell["kind"] == "prefill":
        tokens = cell["seq_len"] * cell["global_batch"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell["global_batch"]


def derive(art: dict) -> dict:
    chips = art["chips"]
    compute = art["flops_per_device"] / PEAK_FLOPS
    memory = art["bytes_accessed_per_device"] / HBM_BW
    collective = art["collectives"]["wire_bytes_per_device"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(art)
    hlo_total = art["flops_per_device"] * chips
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(terms.values())
    mfu_bound = mf / (chips * PEAK_FLOPS * bound) if bound else 0.0
    return {
        **{k: art[k] for k in ("arch", "shape", "mesh", "chips")},
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_ratio": useful,
        "mfu_bound": mfu_bound,
        "peak_gib": art["memory"]["peak_bytes_estimate"] / 2**30,
        "tpu_peak_gib": art["memory"].get("tpu_peak_model", 0) / 2**30,
        "tag": art.get("tag", "baseline"),
    }


def load_all(tag: str | None = None) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        art = json.load(open(path))
        art_tag = art.get("tag", "baseline")
        if tag is None and art_tag != "baseline":
            continue
        if tag is not None and art_tag != tag:
            continue
        rows.append(derive(art))
    return rows


def bench_engine_roofline():
    """Sweep-engine throughput roofline from BENCH_sweep.json.

    The event loop's working set per (event × grid point) is the engine
    state + stats (~``16·rmax + 96`` bytes read+written); comparing achieved
    event throughput against the streaming-bandwidth bound says how far the
    batched engine sits from its memory roofline on this host.  (Run
    ``benchmarks/sweep_bench.py`` first — benchmarks/run.py orders them.)
    """
    root = os.path.join(os.path.dirname(__file__), "..")
    paths = [os.path.join(root, n)
             for n in ("BENCH_sweep.json", "BENCH_sweep_smoke.json")]
    path = next((p for p in paths if os.path.exists(p)), None)
    if path is None:
        return [{"name": "engine_roofline/missing", "us_per_call": 0,
                 "derived": "BENCH_sweep.json not found; run sweep bench"}], 0.0
    r = json.load(open(path))
    state_bytes = 2 * (16 * r["rmax"] + 96)  # state+stats, read and written
    # CPU hosts: assume ~20 GB/s sustained single-core-ish stream as the
    # reference bound; TPU/GPU backends use HBM_BW.
    bw = HBM_BW if r.get("backend") not in (None, "cpu") else 20e9
    bound_ev_s = bw / state_bytes
    frac = r["sweep_events_per_s"] / bound_ev_s
    rows = [{
        "name": f"engine_roofline/{r['grid_points']}pt",
        "us_per_call": 0,
        "derived": (
            f"batched {r['sweep_events_per_s']/1e6:.2f}M ev/s vs "
            f"stream-bound {bound_ev_s/1e6:.0f}M ev/s "
            f"({frac*100:.1f}% of roofline; loop path "
            f"{r['loop_events_per_s']/1e6:.2f}M ev/s; "
            f"speedup {r['speedup']:.1f}x on {r.get('backend', '?')})"
        ),
    }]
    # Pallas batched-event kernel row: same streaming bound, but a compiled
    # kernel keeps the window resident in VMEM so the HBM term amortizes
    # over the whole event block — interpret-mode numbers are parity checks,
    # not kernel speed, and are labeled as such.
    kpaths = [os.path.join(root, n) for n in
              ("BENCH_engine_kernel.json", "BENCH_engine_kernel_smoke.json")]
    kpath = next((p for p in kpaths if os.path.exists(p)), None)
    if kpath is not None:
        kr = json.load(open(kpath))
        mode = "interpret" if kr.get("interpret") else "compiled"
        ev_s = kr["single"]["pallas_events_per_s"]
        kfrac = ev_s / bound_ev_s
        rows.append({
            "name": f"engine_roofline/pallas_{kr['grid_points']}pt_{mode}",
            "us_per_call": 0,
            "derived": (
                f"pallas({mode}) {ev_s/1e6:.2f}M ev/s "
                f"({kfrac*100:.1f}% of stream-bound; "
                f"{kr['single']['pallas_speedup_x']:.2f}x vs xla executor; "
                f"market {kr['market']['pallas_events_per_s']/1e6:.2f}M "
                f"ev/s; bit_equal_ref={kr['single']['bit_equal_ref']})"
            ),
        })
    return rows, frac


def bench_roofline():
    """Emit one row per baseline cell (single-pod mesh = the §Roofline
    table; multi-pod proves the pod axis shards)."""
    rows = []
    for r in load_all():
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            "us_per_call": 0,
            "derived": (
                f"compute={r['compute_s']*1e3:.2f}ms "
                f"memory={r['memory_s']*1e3:.2f}ms "
                f"collective={r['collective_s']*1e3:.2f}ms "
                f"bottleneck={r['bottleneck']} "
                f"useful={r['useful_ratio']:.2f} "
                f"mfu_bound={r['mfu_bound']:.3f}"
            ),
        })
    frac = [r["mfu_bound"] for r in load_all()
            if r["mesh"] == "pod_16x16" and r["shape"] == "train_4k"]
    avg = sum(frac) / len(frac) if frac else 0.0
    return rows, avg


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (ms) | memory (ms) | "
           "collective (ms) | bottleneck | useful | MFU-bound | raw peak GiB "
           "| TPU peak GiB |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | **{r['bottleneck']}** "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} "
            f"| {r['peak_gib']:.1f} | {r['tpu_peak_gib']:.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load_all()
    print(markdown_table(rows))
