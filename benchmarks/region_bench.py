"""Multi-region routing sweep throughput vs the single-queue engine.

Times two sweeps at equal total events:

  * ``single`` — the PR-1 engine (:func:`repro.core.run_sweep`): one job
    clock, one spot clock, one queue, the same (r × seeds) grid;
  * ``region`` — the multi-region engine
    (:func:`repro.core.run_region_sweep`) on a 4-region heterogeneous
    topology with routing at admission: per-region job/spot/preempt clock
    vectors, the packed (sum rmax_r) slot partition, and a least-loaded
    :class:`repro.core.regions.RoutingKernel` over the notice-aware base —
    the whole (params × k × regions-config × seeds) batch as ONE jitted
    nested-vmap program.

The ratio is the price of the region machinery per event (R-wide clock
minima over demand AND supply, partition masks, the routing hook).  The
topology splits the paper's λ and μ across regions, so both engines push
the same total demand against the same total supply.  Writes
BENCH_region.json next to the repo root (smoke runs write a separate
gitignored BENCH_region_smoke.json); compile time is recorded separately
from the steady-state numbers (``benchmarks/_timing.py``).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import provenance, time_compiled
from repro.core import (
    Exponential,
    NoticeAwareKernel,
    Region,
    RegionTopology,
    RoutingKernel,
    ThreePhaseKernel,
    run_region_sweep,
    run_sweep,
)

LAM, MU, K = 1 / 12, 1 / 24, 10.0
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCALE = 1.0


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    name = "BENCH_region.json" if _SCALE == 1.0 else "BENCH_region_smoke.json"
    return os.path.join(_REPO_ROOT, name)


def bench_topology(rmax: int) -> RegionTopology:
    """The reference 4-region topology: total demand λ and supply μ equal
    the paper's single-queue rates, split across heterogeneous regions."""
    return RegionTopology(regions=(
        Region(Exponential(LAM / 4), Exponential(MU / 4), price=0.5,
               hazard=0.02, notice=0.5, rmax=rmax),
        Region(Exponential(LAM / 2), Exponential(MU / 4), price=0.3,
               hazard=0.05, notice=0.01, rmax=rmax),
        Region(Exponential(LAM / 8), Exponential(MU / 4), price=0.2,
               rmax=rmax),
        Region(Exponential(LAM / 8), Exponential(MU / 4), price=0.1,
               hazard=0.10, notice=2.0, rmax=rmax),
    ))


def measure_region_throughput(n_r: int = 16, n_seeds: int = 4,
                              n_events: int | None = None,
                              rmax: int = 16) -> dict:
    """Time both engines on the same grid; return a result dict (also
    JSON-dumped).  ``rmax`` is PER REGION: the region engine carries a
    4×rmax packed slot array vs the single engine's (4·rmax,) queue, so
    per-event state is matched, not just total events."""
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    topo = bench_topology(rmax)
    job = Exponential(LAM)
    spot = Exponential(MU)
    rs = jnp.linspace(0.25, 4.0, n_r)
    key = jax.random.key(0)
    kern = RoutingKernel(NoticeAwareKernel(checkpoint_time=0.05),
                         choice="least_loaded")

    common = dict(k=K, n_events=n_events, key=key, n_seeds=n_seeds)
    _, single_timing = time_compiled(
        lambda: run_sweep(job, spot, ThreePhaseKernel(), {"r": rs},
                          rmax=4 * rmax, **common))
    out, region_timing = time_compiled(
        lambda: run_region_sweep(topo, kern, {"r": rs}, **common))
    t_single = single_timing["t_run_s"]
    t_region = region_timing["t_run_s"]

    grid_points = n_r * n_seeds
    total_events = grid_points * n_events
    result = {
        "grid_points": grid_points,
        "n_r": n_r,
        "n_seeds": n_seeds,
        "n_regions": topo.n_regions,
        "n_events_per_point": n_events,
        "total_events": total_events,
        "rmax_per_region": rmax,
        "rng": "split",  # the frozen stream (see BENCH_event_rng.json)
        "one_jit": True,  # the whole region grid is one compiled program
        "t_region_s": t_region,
        "t_single_s": t_single,
        "t_region_compile_s": region_timing["t_compile_s"],
        "t_single_compile_s": single_timing["t_compile_s"],
        "region_events_per_s": total_events / t_region,
        "single_events_per_s": total_events / t_single,
        "region_overhead_x": t_region / t_single,
        "cross_region_frac": float(
            np.asarray(out["cross_region_frac"]).mean()),
        "preemptions_total": float(np.asarray(out["preemptions"]).sum()),
        "backend": jax.default_backend(),
        "provenance": provenance(seed=0, telemetry="off"),
    }
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    return result


def bench_region_engine():
    """Benchmark-harness entry: rows + headline (region events/s)."""
    res = measure_region_throughput()
    rows = [{
        "name": (f"region/{res['n_regions']}region_"
                 f"{res['grid_points']}pt_grid"),
        "us_per_call": res["t_region_s"] * 1e6,
        "derived": (
            f"{res['n_regions']} regions × {res['grid_points']} points × "
            f"{res['n_events_per_point']} ev (one jit): "
            f"region={res['t_region_s']:.2f}s "
            f"single={res['t_single_s']:.2f}s "
            f"overhead={res['region_overhead_x']:.2f}x "
            f"({res['region_events_per_s']/1e6:.2f}M ev/s; "
            f"cross-region {res['cross_region_frac']:.0%}; "
            f"{res['preemptions_total']:.0f} preemptions)"
        ),
    }]
    return rows, res["region_events_per_s"]
