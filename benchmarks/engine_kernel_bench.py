"""Pallas batched-event kernel vs the XLA scan executor, at equal events.

Times the same (r × seeds) grid through both executors of the sweep engine
(``impl="xla"`` vs ``impl="pallas"``), single-pool and 4-pool market, and
records the equivalence ledger while timing: bitwise vs the scan-reference
oracle (``impl="ref"``), integer-exact + max float rtol vs the production
XLA executor.  Writes BENCH_engine_kernel.json next to the repo root
(smoke runs write a separate gitignored BENCH_engine_kernel_smoke.json).

Interpretation of the numbers (recorded in the JSON):

  * on a compiled backend (TPU: ``interpret=False``) the kernel keeps the
    (tile, rmax) engine state resident in VMEM across a whole event window,
    so its events/s is the headline claim (target ≥2× the XLA executor
    events/s of BENCH_sweep.json / BENCH_market.json at equal total
    events);
  * on CPU-only hosts the kernel necessarily runs through the Pallas
    *interpreter* (``interpret=True``) — those numbers measure dispatch
    overhead + bitwise parity, NOT kernel speed, and are reported
    separately under ``"interpret": true`` so they are never compared
    against the compiled target.

Compile time is recorded separately from the steady-state numbers
(``benchmarks/_timing.py``).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import provenance, time_compiled
from benchmarks.market_bench import bench_market
from repro.core import (
    Exponential,
    NoticeAwareKernel,
    ThreePhaseKernel,
    run_market_sweep,
    run_sweep,
)
from repro.core.engine import INT_STATS as _INT_STATS

LAM, MU, K = 1 / 12, 1 / 24, 10.0
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCALE = 1.0

#: kernel-launch geometry recorded in the JSON (see EXPERIMENTS.md)
TILE = 256


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    name = ("BENCH_engine_kernel.json" if _SCALE == 1.0
            else "BENCH_engine_kernel_smoke.json")
    return os.path.join(_REPO_ROOT, name)


def _stats_equal(a: dict, b: dict) -> bool:
    return all(np.array_equal(np.asarray(v), np.asarray(b[n]))
               for n, v in a.items())


def _parity(pal: dict, ref: dict, xla: dict) -> dict:
    """The two-sided equivalence record: bitwise vs the scan reference
    (impl="ref", the oracle), int-exact + max float rtol vs the production
    XLA executor (see EXPERIMENTS.md for why these differ)."""
    int_eq = all(np.array_equal(np.asarray(xla[n]), np.asarray(pal[n]))
                 for n in _INT_STATS if n in xla)
    rel = 0.0
    for n, v in xla.items():
        if n in _INT_STATS:
            continue
        a, b = np.asarray(v, np.float64), np.asarray(pal[n], np.float64)
        denom = np.maximum(np.abs(a), 1e-30)
        rel = max(rel, float(np.max(np.abs(a - b) / denom)))
    return {"bit_equal_ref": _stats_equal(ref, pal),
            "int_equal_xla": int_eq,
            "max_float_rtol_xla": rel}


def _baseline(name: str, key: str) -> float | None:
    path = os.path.join(_REPO_ROOT, name)
    if not os.path.exists(path):
        return None
    return json.load(open(path)).get(key)


def measure_engine_kernel(n_r: int = 16, n_seeds: int = 4,
                          n_events: int | None = None,
                          rmax: int = 64) -> dict:
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    interpret = jax.default_backend() != "tpu"
    job, spot = Exponential(LAM), Exponential(MU)
    rs = jnp.linspace(0.25, 4.0, n_r)
    key = jax.random.key(0)
    common = dict(k=K, n_events=n_events, key=key, n_seeds=n_seeds,
                  rmax=rmax)
    grid_points = n_r * n_seeds
    total_events = grid_points * n_events

    compile_s = {}

    def timed(fn, label=None):
        out, timing = time_compiled(fn)
        if label:
            compile_s[label] = timing["t_compile_s"]
        return out, timing["t_run_s"]

    result = {
        "grid_points": grid_points,
        "n_r": n_r,
        "n_seeds": n_seeds,
        "n_events_per_point": n_events,
        "total_events": total_events,
        "rmax": rmax,
        "rng": "split",  # the frozen stream (see BENCH_event_rng.json)
        "tile": TILE,
        "event_block": min(1 << 16, n_events),
        "interpret": interpret,
        "backend": jax.default_backend(),
        "baseline_sweep_events_per_s": _baseline(
            "BENCH_sweep.json", "sweep_events_per_s"),
        "baseline_market_events_per_s": _baseline(
            "BENCH_market.json", "market_events_per_s"),
        "provenance": provenance(seed=0, telemetry="off"),
    }

    kern = ThreePhaseKernel()
    xla, t_xla = timed(lambda: run_sweep(job, spot, kern, {"r": rs},
                                         **common), "single_xla")
    pal, t_pal = timed(lambda: run_sweep(job, spot, kern, {"r": rs},
                                         impl="pallas", tile=TILE,
                                         interpret=interpret, **common),
                       "single_pallas")
    ref = run_sweep(job, spot, kern, {"r": rs}, impl="ref", **common)
    result["single"] = {
        "t_xla_s": t_xla,
        "t_pallas_s": t_pal,
        "xla_events_per_s": total_events / t_xla,
        "pallas_events_per_s": total_events / t_pal,
        "pallas_speedup_x": t_xla / t_pal,
        **_parity(pal, ref, xla),
    }

    market = bench_market()  # the reference 4-pool market
    mkern = NoticeAwareKernel(checkpoint_time=0.05)
    xla_m, t_xla_m = timed(lambda: run_market_sweep(
        job, market, mkern, {"r": rs}, **common), "market_xla")
    pal_m, t_pal_m = timed(lambda: run_market_sweep(
        job, market, mkern, {"r": rs}, impl="pallas", tile=TILE,
        interpret=interpret, **common), "market_pallas")
    ref_m = run_market_sweep(job, market, mkern, {"r": rs}, impl="ref",
                             **common)
    result["market"] = {
        "n_pools": market.n_pools,
        "t_xla_s": t_xla_m,
        "t_pallas_s": t_pal_m,
        "xla_events_per_s": total_events / t_xla_m,
        "pallas_events_per_s": total_events / t_pal_m,
        "pallas_speedup_x": t_xla_m / t_pal_m,
        **_parity(pal_m, ref_m, xla_m),
    }

    result["t_compile_s"] = compile_s
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    return result


def bench_engine_kernel():
    """Benchmark-harness entry: rows + headline (pallas events/s, single)."""
    res = measure_engine_kernel()
    mode = "interpret" if res["interpret"] else "compiled"
    rows = []
    for name in ("single", "market"):
        r = res[name]
        rows.append({
            "name": f"engine_kernel/{name}/{res['grid_points']}pt_{mode}",
            "us_per_call": r["t_pallas_s"] * 1e6,
            "derived": (
                f"{res['grid_points']} pts × {res['n_events_per_point']} ev "
                f"({mode}; tile={res['tile']}): "
                f"pallas={r['pallas_events_per_s']/1e6:.2f}M ev/s "
                f"xla={r['xla_events_per_s']/1e6:.2f}M ev/s "
                f"({r['pallas_speedup_x']:.2f}x; "
                f"bit_equal_ref={r['bit_equal_ref']} "
                f"int_equal_xla={r['int_equal_xla']})"
            ),
        })
    return rows, res["single"]["pallas_events_per_s"]
