"""Multi-pool market sweep throughput vs the single-pool engine.

Times two sweeps at equal total events:

  * ``single`` — the PR-1 engine (:func:`repro.core.run_sweep`): one spot
    clock, no preemption, the same (r × seeds) grid;
  * ``market`` — the spot-market engine (:func:`repro.core.run_market_sweep`)
    on a 4-pool heterogeneous market with preemption-with-notice: per-pool
    ``next_spot``/``next_preempt`` clock vectors, pool-tagged queue slots,
    and the notice-aware kernel — the whole (≥16-point grid × seeds) batch
    as ONE jitted nested-vmap program.

The ratio is the price of the market machinery per event (wider clock
minima, pool-eligibility masks, preemption branch).  Writes
BENCH_market.json next to the repo root (smoke runs write a separate
gitignored BENCH_market_smoke.json); compile time is recorded separately
from the steady-state numbers (``benchmarks/_timing.py``).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import provenance, time_compiled
from repro.core import (
    Exponential,
    NoticeAwareKernel,
    SpotMarket,
    SpotPool,
    ThreePhaseKernel,
    run_market_sweep,
    run_sweep,
)

LAM, MU, K = 1 / 12, 1 / 24, 10.0
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCALE = 1.0


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    name = "BENCH_market.json" if _SCALE == 1.0 else "BENCH_market_smoke.json"
    return os.path.join(_REPO_ROOT, name)


def bench_market() -> SpotMarket:
    """The reference 4-pool market: total slot rate = the paper's μ, split
    across pools with heterogeneous prices and hazards."""
    return SpotMarket(pools=(
        SpotPool(Exponential(MU / 4), price=0.5, hazard=0.02, notice=0.5),
        SpotPool(Exponential(MU / 4), price=0.3, hazard=0.05, notice=0.01),
        SpotPool(Exponential(MU / 4), price=0.2, hazard=0.0),
        SpotPool(Exponential(MU / 4), price=0.1, hazard=0.10, notice=2.0),
    ))


def measure_market_throughput(n_r: int = 16, n_seeds: int = 4,
                              n_events: int | None = None,
                              rmax: int = 64) -> dict:
    """Time both engines on the same grid; return a result dict (also
    JSON-dumped)."""
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    job = Exponential(LAM)
    spot = Exponential(MU)
    market = bench_market()
    rs = jnp.linspace(0.25, 4.0, n_r)
    key = jax.random.key(0)
    kern = NoticeAwareKernel(checkpoint_time=0.05)

    common = dict(k=K, n_events=n_events, key=key, n_seeds=n_seeds,
                  rmax=rmax)
    _, single_timing = time_compiled(
        lambda: run_sweep(job, spot, ThreePhaseKernel(), {"r": rs},
                          **common))
    out, market_timing = time_compiled(
        lambda: run_market_sweep(job, market, kern, {"r": rs}, **common))
    t_single = single_timing["t_run_s"]
    t_market = market_timing["t_run_s"]

    grid_points = n_r * n_seeds
    total_events = grid_points * n_events
    result = {
        "grid_points": grid_points,
        "n_r": n_r,
        "n_seeds": n_seeds,
        "n_pools": market.n_pools,
        "n_events_per_point": n_events,
        "total_events": total_events,
        "rmax": rmax,
        "rng": "split",  # the frozen stream (see BENCH_event_rng.json)
        "one_jit": True,  # the whole market grid is one compiled program
        "t_market_s": t_market,
        "t_single_s": t_single,
        "t_market_compile_s": market_timing["t_compile_s"],
        "t_single_compile_s": single_timing["t_compile_s"],
        "market_events_per_s": total_events / t_market,
        "single_events_per_s": total_events / t_single,
        "market_overhead_x": t_market / t_single,
        "preemptions_total": float(np.asarray(out["preemptions"]).sum()),
        "resumed_total": float(np.asarray(out["resumed"]).sum()),
        "backend": jax.default_backend(),
        "provenance": provenance(seed=0, telemetry="off"),
    }
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    return result


def bench_market_engine():
    """Benchmark-harness entry: rows + headline (market events/s)."""
    res = measure_market_throughput()
    rows = [{
        "name": (f"market/{res['n_pools']}pool_"
                 f"{res['grid_points']}pt_grid"),
        "us_per_call": res["t_market_s"] * 1e6,
        "derived": (
            f"{res['n_pools']} pools × {res['grid_points']} points × "
            f"{res['n_events_per_point']} ev (one jit): "
            f"market={res['t_market_s']:.2f}s "
            f"single={res['t_single_s']:.2f}s "
            f"overhead={res['market_overhead_x']:.2f}x "
            f"({res['market_events_per_s']/1e6:.2f}M ev/s; "
            f"{res['preemptions_total']:.0f} preemptions)"
        ),
    }]
    return rows, res["market_events_per_s"]
