"""Shared bench timing: separate compile time from steady-state run time.

Every BENCH_*.json records both numbers (plus the ``rng=`` stream the bench
ran): ``t_compile_s`` is the first-call overhead (trace + XLA compile),
``t_run_s`` the steady-state wall clock of an already-compiled call with
``jax.block_until_ready`` on the result — the number every events/s figure
is derived from.  The old harness warmed with one identical-shape call and
timed the second; this helper keeps that structure but records what the
warmup cost instead of throwing it away.
"""
from __future__ import annotations

import time

import jax


def time_compiled(call, *, runs: int = 1):
    """Time ``call`` (a 0-arg closure returning a pytree) compile + steady.

    Returns ``(result, timing)`` with ``timing = {"t_first_s", "t_run_s",
    "t_compile_s"}``: the first call pays trace + compile + one run; the
    steady-state number is the mean of ``runs`` further calls, each blocked
    to completion.  ``t_compile_s`` is the difference, floored at zero.
    """
    t0 = time.perf_counter()
    out = jax.block_until_ready(call())
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(runs):
        out = jax.block_until_ready(call())
    t_run = (time.perf_counter() - t0) / runs
    return out, {"t_first_s": t_first, "t_run_s": t_run,
                 "t_compile_s": max(t_first - t_run, 0.0)}
