"""Shim — the bench timing helper moved to :mod:`repro.obs.timing`.

Kept so older bench invocations (``from _timing import time_compiled``)
keep working; new code should import :func:`repro.obs.timing.time_compiled`
and stamp results with :func:`repro.obs.timing.provenance`.
"""
from repro.obs.timing import provenance, time_compiled  # noqa: F401
