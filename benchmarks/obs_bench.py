"""Telemetry overhead: the ``telemetry=`` axis measured on vs off.

The observability contract is two-sided: ``telemetry=None`` must compile
the *identical* program (zero cost — frozen bitwise in tests/test_obs.py),
and ``telemetry=Telemetry(...)`` must stay cheap enough to leave on for
real sweeps.  This bench measures both sides on the market sweep (the
loop with the most telemetry surface: per-pool counters, two histograms,
notice accounting):

  * ``off``   — today's program, the PR-5 baseline path;
  * ``stats`` — histograms + counters, no event ring;
  * ``trace`` — stats plus a ``trace_cap=256`` event ring per lane.

Writes BENCH_obs.json next to the repo root.  The headline is the
``stats`` overhead factor (t_stats / t_off); CI's regression gate guards
the *off* path via the other BENCH files, and docs/EXPERIMENTS quote this
file for the on-cost.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Exponential, ThreePhaseKernel, run_market_sweep
from repro.core.market import SpotMarket, SpotPool
from repro.obs import Telemetry
from repro.obs.timing import provenance, time_compiled

LAM, MU, K = 1 / 12, 1 / 24, 10.0
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCALE = 1.0


def set_scale(scale: float) -> None:
    global _SCALE
    _SCALE = scale


def _bench_json_path() -> str:
    name = "BENCH_obs.json" if _SCALE == 1.0 else "BENCH_obs_smoke.json"
    return os.path.join(_REPO_ROOT, name)


def _market() -> SpotMarket:
    return SpotMarket(pools=(
        SpotPool(Exponential(MU / 2), price=0.4, hazard=0.02, notice=0.5),
        SpotPool(Exponential(MU / 2), price=0.7, hazard=0.005, notice=0.0),
    ))


def measure_telemetry_overhead(n_r: int = 16, n_seeds: int = 4,
                               n_events: int | None = None,
                               rmax: int = 32) -> dict:
    """Time the market sweep off / stats-only / stats+trace."""
    if n_events is None:
        n_events = max(2_000, int(50_000 * _SCALE))
    job = Exponential(LAM)
    market = _market()
    kern = ThreePhaseKernel()
    rs = jnp.linspace(0.25, 4.0, n_r)
    key = jax.random.key(0)
    common = dict(k=K, n_events=n_events, key=key, n_seeds=n_seeds,
                  rmax=rmax)

    modes = {
        "off": None,
        "stats": Telemetry(),
        "trace": Telemetry(trace_cap=256),
    }
    timings, p99 = {}, None
    for mode, tel in modes.items():
        out, timing = time_compiled(
            lambda tel=tel: run_market_sweep(job, market, kern, {"r": rs},
                                             telemetry=tel, **common))
        timings[mode] = timing
        if mode == "stats":
            p99 = float(np.asarray(out["p99_wait"]).mean())

    grid_points = n_r * n_seeds
    total_events = grid_points * n_events
    t_off = timings["off"]["t_run_s"]
    result = {
        "grid_points": grid_points,
        "n_r": n_r,
        "n_seeds": n_seeds,
        "n_pools": market.n_pools,
        "n_events_per_point": n_events,
        "total_events": total_events,
        "rmax": rmax,
        "t_off_s": t_off,
        "t_stats_s": timings["stats"]["t_run_s"],
        "t_trace_s": timings["trace"]["t_run_s"],
        "off_events_per_s": total_events / t_off,
        "stats_events_per_s": total_events / timings["stats"]["t_run_s"],
        "trace_events_per_s": total_events / timings["trace"]["t_run_s"],
        "stats_overhead_x": timings["stats"]["t_run_s"] / t_off,
        "trace_overhead_x": timings["trace"]["t_run_s"] / t_off,
        "mean_p99_wait": p99,
        "backend": jax.default_backend(),
        "provenance": provenance(seed=0, telemetry="off/stats/trace"),
    }
    with open(_bench_json_path(), "w") as f:
        json.dump(result, f, indent=2)
    return result


def bench_telemetry_overhead():
    """Benchmark-harness entry: rows + headline (stats overhead factor)."""
    res = measure_telemetry_overhead()
    rows = [{
        "name": f"obs/{res['grid_points']}pt_market_grid",
        "us_per_call": res["t_stats_s"] * 1e6,
        "derived": (
            f"{res['grid_points']} points × {res['n_events_per_point']} ev: "
            f"off={res['t_off_s']:.2f}s stats={res['t_stats_s']:.2f}s "
            f"trace={res['t_trace_s']:.2f}s "
            f"(stats {res['stats_overhead_x']:.2f}x, "
            f"trace {res['trace_overhead_x']:.2f}x; "
            f"mean P99 wait {res['mean_p99_wait']:.2f}h)"
        ),
    }]
    return rows, res["stats_overhead_x"]


if __name__ == "__main__":
    print(json.dumps(measure_telemetry_overhead(), indent=2))
