"""Gradient compression with error feedback (distributed-optimization trick).

Int8 quantization with per-tensor scale + error-feedback residual: the
quantization error of step t is added back to the gradient at step t+1, so
the *accumulated* update is unbiased (Seide et al. / 1-bit SGD lineage;
convergence verified in tests/test_distributed.py on a real model).

Wire format: a real multi-pod runtime ships the int8 payload + one fp32
scale per tensor over DCN — a 2× reduction vs bf16 gradients (4× vs fp32).
The roofline accounting in EXPERIMENTS.md applies this ratio to the
gradient all-reduce bytes when ``compress_grads`` is enabled; inside XLA the
collective itself still moves the dequantized values (XLA has no int8
all-reduce with wide accumulation), which we note as a runtime-integration
gap rather than an algorithmic one.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict  # error-feedback carry, same shapes as grads (fp32)


def ef_init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x fp -> (int8 payload, fp32 scale).  Symmetric per-tensor."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState) -> tuple[dict, EFState, dict]:
    """Apply EF-int8 compression; returns (compressed grads, new EF state,
    diagnostics).  The returned grads are the dequantized values a receiver
    would reconstruct — feed them to the optimizer."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        dq = dequantize_int8(q, scale)
        return dq.astype(g.dtype), gf - dq

    out = jax.tree.map(one, grads, ef.residual)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    diag = {"compression_ratio": 2.0}  # bf16 -> int8 payload
    return new_grads, EFState(residual=new_res), diag
