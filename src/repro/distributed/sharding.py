"""Logical→physical sharding rules (GSPMD PartitionSpecs by tree path).

Two families live here: the seed's LM-layer GSPMD rules (param / batch /
cache / ZeRO-1 specs below) and the event engine's lane-axis helpers
(:func:`lane_mesh` / :func:`lane_spec` / :func:`pad_lanes` +
:func:`shard_map_1d`), which back the sweep entry points' ``shard="lanes"``
dispatch — the flattened (grid × seeds) lane axis partitioned across a
1-D device mesh (docs/scaling.md).

Axis convention (production mesh, DESIGN.md §5):
  batch        → ("pod", "data")   (DP across pods and within a pod)
  heads / FFN hidden / experts / vocab / d_inner → "model"  (TP / EP)
  everything small (norms, biases of unshardable dims, B/C projections of
  SSD with ngroups=1, routers) → replicated

Divisibility is checked against the actual mesh axis size — e.g. granite's
single KV head or qwen1.5's 20 query heads fall back to replication instead
of producing an invalid spec (recorded per-param, visible in tests).

ZeRO-1 (``zero1_state_specs``): optimizer-state trees additionally shard
their largest still-unsharded divisible axis over "data", reproducing the
ZeRO-1 gather/scatter pattern through GSPMD.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

# jax.shard_map graduated from jax.experimental.shard_map (and renamed its
# replication-check kwarg check_rep -> check_vma) in jax 0.6; support both.
# Same shim as repro.layers.moe — duplicated here so the event engine's
# sharded sweeps never import the LM layer stack.
if hasattr(jax, "shard_map"):
    def shard_map_1d(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map_1d(f, *, mesh, in_specs, out_specs):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)


#: Mesh axis name for the engine's flattened sweep lane axis.
LANE_AXIS = "lanes"


def lane_mesh(devices: int | list | None = None, *,
              axis: str = LANE_AXIS) -> Mesh:
    """1-D device mesh over the sweep engine's flattened lane axis.

    ``devices`` is a device count (the first N local devices), an explicit
    device sequence, or None for every local device.  Simulated host
    devices come from ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    set before the JAX backend initializes (see docs/scaling.md).
    """
    if devices is None:
        devs = jax.devices()
    elif isinstance(devices, int):
        local = jax.devices()
        if devices < 1 or devices > len(local):
            raise ValueError(
                f"lane_mesh: requested {devices} devices but "
                f"{len(local)} are available (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N before the "
                f"backend initializes to simulate more on CPU)")
        devs = local[:devices]
    else:
        devs = list(devices)
    return Mesh(np.array(devs), (axis,))


def lane_spec(mesh: Mesh) -> P:
    """PartitionSpec placing a leading lane axis on ``mesh``'s only axis."""
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"lane sharding needs a 1-D mesh, got axes {mesh.axis_names}")
    return P(mesh.axis_names[0])


def pad_lanes(tree, n_pad: int):
    """Pad every lane-leading leaf with ``n_pad`` copies of lane 0.

    Lane 0 is a real lane, so the pad lanes run valid simulations (no
    NaN/inf hazards from zero-filled params); the caller slices them off
    after the sharded run.  The lane count becomes divisible by the mesh
    size — the pad half of the sharded sweeps' pad-and-mask contract.
    """
    if n_pad == 0:
        return tree
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (n_pad,) + x.shape[1:])], axis=0),
        tree)


def _path_names(path) -> list[str]:
    names = []
    for e in path:
        if isinstance(e, DictKey):
            names.append(str(e.key))
        elif isinstance(e, GetAttrKey):
            names.append(e.name)
        elif isinstance(e, SequenceKey):
            names.append(str(e.idx))
    return names


def _with_axis(rank: int, axis: int, name: str) -> P:
    spec = [None] * rank
    spec[axis] = name
    return P(*spec)


def param_specs(params, *, model_axis: str = "model",
                model_size: int, num_heads: int, num_kv_heads: int) -> Any:
    """PartitionSpec tree mirroring a (possibly layer-stacked) param tree."""

    def rule(path, leaf):
        names = _path_names(path)
        last = names[-1]
        rank = len(leaf.shape)
        in_moe = "moe" in names

        def div(n):
            return n % model_size == 0

        if last == "embed":
            return P(model_axis, None) if div(leaf.shape[0]) else P(None, None)
        if last == "lm_head":
            return P(None, model_axis) if div(leaf.shape[1]) else P(None, None)
        if last == "wq":
            return (_with_axis(rank, rank - 2, model_axis)
                    if div(leaf.shape[rank - 2]) else P(*[None] * rank))
        if last in ("wk", "wv"):
            return (_with_axis(rank, rank - 2, model_axis)
                    if div(leaf.shape[rank - 2]) else P(*[None] * rank))
        if last == "wo":
            return (_with_axis(rank, rank - 3, model_axis)
                    if div(leaf.shape[rank - 3]) else P(*[None] * rank))
        if last in ("bq", "bk", "bv"):
            return (_with_axis(rank, rank - 2, model_axis)
                    if div(leaf.shape[rank - 2]) else P(*[None] * rank))
        if last in ("w_gate", "w_up"):
            if in_moe:  # (..., E, D, F): expert-parallel
                return (_with_axis(rank, rank - 3, model_axis)
                        if div(leaf.shape[rank - 3]) else P(*[None] * rank))
            return (_with_axis(rank, rank - 1, model_axis)
                    if div(leaf.shape[rank - 1]) else P(*[None] * rank))
        if last == "w_down":
            if in_moe:  # (..., E, F, D)
                return (_with_axis(rank, rank - 3, model_axis)
                        if div(leaf.shape[rank - 3]) else P(*[None] * rank))
            return (_with_axis(rank, rank - 2, model_axis)
                    if div(leaf.shape[rank - 2]) else P(*[None] * rank))
        if last in ("z_proj", "x_proj", "dt_proj"):
            return (_with_axis(rank, rank - 1, model_axis)
                    if div(leaf.shape[rank - 1]) else P(*[None] * rank))
        if last in ("conv_x_w", "conv_x_b"):
            return (_with_axis(rank, rank - 1, model_axis)
                    if div(leaf.shape[rank - 1]) else P(*[None] * rank))
        if last == "out_proj":
            return (_with_axis(rank, rank - 2, model_axis)
                    if div(leaf.shape[rank - 2]) else P(*[None] * rank))
        # router, b_proj/c_proj, conv_bc_*, norms, A_log/D/dt_bias, scales
        return P(*[None] * rank)

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_specs(batch, batch_axes: tuple) -> Any:
    """Input-batch specs: shard the batch dim; positions lead with axis 3."""

    def rule(path, leaf):
        names = _path_names(path)
        rank = len(leaf.shape)
        if names[-1] == "positions":  # (3, B, S)
            return P(None, batch_axes, *([None] * (rank - 2)))
        return P(batch_axes, *([None] * (rank - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_specs(cache, *, batch_axes: tuple, model_axis: str = "model",
                model_size: int, shard_kv_seq: bool = False) -> Any:
    """Decode-cache specs.  Layer-stacked KV: (L, B, S, KH, hd)."""

    def rule(path, leaf):
        names = _path_names(path)
        last = names[-1]
        rank = len(leaf.shape)
        if last == "index":
            return P()
        if last in ("k", "v", "attn_k", "attn_v", "self_k", "self_v",
                    "cross_k", "cross_v"):
            kh, hd = leaf.shape[3], leaf.shape[4]
            if kh % model_size == 0:
                return P(None, batch_axes, None, model_axis, None)
            if hd % model_size == 0:
                # GQA-narrow archs (granite kv=1, qwen3 kv=8, ...): shard the
                # head_dim — contractions over hd become partial-sum +
                # all-reduce, and every seq slice/update stays shard-local
                # (seq-sharding makes GSPMD gather the cache per KV chunk).
                return P(None, batch_axes, None, None, model_axis)
            if shard_kv_seq and leaf.shape[2] % model_size == 0:
                return P(None, batch_axes, model_axis, None, None)
            return P(None, batch_axes, None, None, None)
        if last == "conv":  # (L, B, W-1, C)
            return P(None, batch_axes, None, None)
        if last == "state":  # (L, B, H, P, N)
            h = leaf.shape[2]
            head = model_axis if h % model_size == 0 else None
            return P(None, batch_axes, head, None, None)
        return P(*[None] * rank)

    return jax.tree_util.tree_map_with_path(rule, cache)


def zero1_state_specs(param_spec_tree, params, *, data_axes: tuple,
                      data_size: int) -> Any:
    """Add "data" sharding to the largest unsharded divisible axis."""

    def rule(spec, leaf):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        # already data-sharded (e.g. params that went through fsdp)
        used = set()
        for d in dims:
            if d is None:
                continue
            for a in (d if isinstance(d, tuple) else (d,)):
                used.add(a)
        if any(a in used for a in data_axes):
            return P(*dims)
        best, best_size = None, 0
        for i, (d, s) in enumerate(zip(dims, leaf.shape)):
            if d is None and s % data_size == 0 and s >= data_size \
                    and s > best_size:
                best, best_size = i, s
        if best is None:
            return P(*dims)
        dims[best] = data_axes if len(data_axes) > 1 else data_axes[0]
        return P(*dims)

    return jax.tree_util.tree_map(rule, param_spec_tree, params,
                                  is_leaf=lambda x: isinstance(x, P))
