"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

80L, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064.
Backbone only per the assignment: the patch/vision frontend is a STUB —
``input_specs()`` supplies fused patch+token embeddings (B, S, 8192) and
3-axis M-RoPE positions (3, B, S).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mrope=True,
    qkv_bias=True,
    input_mode="embeddings",
    optimizer="adafactor",
    fsdp=True,
    train_accum=8,
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke",
    family="vlm",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mrope=True,
    qkv_bias=True,
    input_mode="embeddings",
    attn_chunk_q=32,
    attn_chunk_k=32,
)
