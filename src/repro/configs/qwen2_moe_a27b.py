"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L, d_model 2048, 16 heads (kv=16), per-expert d_ff 1408, vocab 151936,
MoE 60 experts top-4 + 4 always-on shared experts.  Routed experts are
padded 60 → 64 under expert parallelism (padded experts masked in routing).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    vocab_size=151936,
    qkv_bias=True,
    fsdp=True,
    train_accum=8,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    moe_d_ff=64,
    num_experts=8,
    num_experts_per_tok=2,
    num_shared_experts=2,
    vocab_size=512,
    qkv_bias=True,
    capacity_factor=8.0,  # no token drops in smoke consistency tests
    attn_chunk_q=32,
    attn_chunk_k=32,
)
