"""granite-20b [dense] — llama-arch code model [arXiv:2405.04324; hf].

52L, d_model 6144, 48 heads (GQA kv=1 — multi-query), d_ff 24576, vocab 49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_variant="gelu",  # GPTBigCode-style 2-matrix MLP -> ~20B params
    fsdp=True,
    train_accum=8,
)

SMOKE = ModelConfig(
    name="granite-20b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    d_ff=256,
    vocab_size=512,
    mlp_variant="gelu",
    attn_chunk_q=32,
    attn_chunk_k=32,
)
