"""zamba2-1.2b [hybrid] — Mamba2 + shared attention blocks
[arXiv:2411.15242; hf].

38 Mamba2 layers, d_model 2048, shared attention block (32 heads, MHA,
d_ff 8192) applied every 6 layers with per-application KV caches,
ssm_state 64, vocab 32000.  Sub-quadratic ⇒ runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    attn_every=6,
    train_accum=4,
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke",
    family="hybrid",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
    attn_every=2,
    attn_chunk_q=32,
    attn_chunk_k=32,
)
