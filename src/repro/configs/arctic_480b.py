"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

35L, d_model 7168, 56 heads (GQA kv=8), per-expert d_ff 4864, vocab 32000,
MoE 128 experts top-2 with a dense residual MLP in parallel.  960 GB of bf16
parameters ⇒ Adafactor optimizer (AdamW fp32 state would need 22 GB/chip on
a 256-chip v5e pod — documented in EXPERIMENTS.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    moe_d_ff=4864,
    num_experts=128,
    num_experts_per_tok=2,
    num_shared_experts=0,
    dense_residual=True,
    vocab_size=32000,
    optimizer="adafactor",
    fsdp=True,
    train_accum=8,
)

SMOKE = ModelConfig(
    name="arctic-480b-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=128,
    moe_d_ff=64,
    num_experts=8,
    num_experts_per_tok=2,
    num_shared_experts=0,
    dense_residual=True,
    vocab_size=512,
    optimizer="adafactor",
    capacity_factor=8.0,  # no token drops in smoke consistency tests
    attn_chunk_q=32,
    attn_chunk_k=32,
)
