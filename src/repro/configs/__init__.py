"""Architecture registry: the 10 assigned architectures + paper-scheduler
configs.  ``get_config(name)`` returns the exact published config;
``get_config(name, smoke=True)`` returns the reduced same-family config used
by CPU smoke tests."""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeCell

_MODULES = {
    "granite-20b": "repro.configs.granite_20b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a27b",
    "arctic-480b": "repro.configs.arctic_480b",
    "whisper-small": "repro.configs.whisper_small",
    "zamba2-1.2b": "repro.configs.zamba2_12b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "mamba2-780m": "repro.configs.mamba2_780m",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.CONFIG


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """Which of the 4 assigned shape cells this arch runs.

    long_500k requires sub-quadratic sequence mixing (SSM/hybrid families);
    pure full-attention archs skip it (recorded as SKIP in the roofline
    table, rationale in DESIGN.md §Arch-applicability).
    """
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        shapes.append("long_500k")
    return shapes
