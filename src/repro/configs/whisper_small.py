"""whisper-small [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].

12L encoder + 12L decoder, d_model 768, 12 heads (kv=12), d_ff 3072,
vocab 51865.  The mel/conv frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, S, 768).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    num_encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp_variant="gelu",  # Whisper uses GELU MLPs
    input_mode="tokens",  # decoder tokens; encoder takes stub frames
)

SMOKE = ModelConfig(
    name="whisper-small-smoke",
    family="encdec",
    num_layers=2,
    num_encoder_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    mlp_variant="gelu",
    attn_chunk_q=32,
    attn_chunk_k=32,
)
