"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

40L, d_model 2560, 20 heads (kv=20, MHA), d_ff 6912, vocab 151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    fsdp=True,  # 20 heads don't shard over model=16; shard attn over data
    train_accum=4,
)

SMOKE = ModelConfig(
    name="qwen1.5-4b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    qkv_bias=True,
    attn_chunk_q=32,
    attn_chunk_k=32,
)
