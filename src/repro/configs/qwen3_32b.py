"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

64L, d_model 5120, 64 heads (GQA kv=8), d_ff 25600, vocab 151936.
head_dim is 128 explicitly (Qwen3 decouples head_dim from d_model/num_heads;
d_model/64 = 80 would be MXU-unaligned and does not match the HF config).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    fsdp=True,
    train_accum=8,
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    qk_norm=True,
    attn_chunk_q=32,
    attn_chunk_k=32,
)
