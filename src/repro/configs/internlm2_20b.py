"""internlm2-20b [dense] — GQA [arXiv:2403.17297; hf].

48L, d_model 6144, 48 heads (GQA kv=8), d_ff 16384, vocab 92544.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    fsdp=True,
    train_accum=8,
)

SMOKE = ModelConfig(
    name="internlm2-20b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attn_chunk_q=32,
    attn_chunk_k=32,
)
