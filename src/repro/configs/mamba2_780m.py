"""mamba2-780m [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L, d_model 1536 (attention-free), vocab 50280, ssm_state 128,
d_inner = 2×1536 = 3072, headdim 64 ⇒ 48 SSD heads.  Sub-quadratic ⇒ runs
the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    train_accum=4,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    num_layers=2,
    d_model=128,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
)
