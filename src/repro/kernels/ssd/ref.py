"""Pure-jnp oracle for the SSD kernel: the O(L) sequential recurrence."""
from repro.layers.ssm import ssd_reference as ssd_ref  # noqa: F401
