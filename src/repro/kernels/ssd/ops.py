"""jit'd public wrapper for the SSD kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.ssd import ssd_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, d_skip, b_in, c_in, *, chunk: int = 256,
        interpret: bool = True) -> jax.Array:
    """Mamba2 SSD: x (B,L,H,P); dt (B,L,H); b/c (B,L,N) -> (B,L,H,P)."""
    return ssd_pallas(x, dt, a_log, d_skip, b_in, c_in, chunk=chunk,
                      interpret=interpret)
