"""Mamba2 SSD (state-space dual) chunked-scan Pallas TPU kernel.

TPU adaptation of the SSD algorithm (the Mamba2 paper's own formulation —
the CUDA selective scan has no TPU analogue; SSD's chunk decomposition is
the MXU-native equivalent):

  per chunk c of length Q:      (all dense matmuls → MXU)
    Y_diag = (L ⊙ (C Bᵀ)) · (x·dt)          intra-chunk, (Q×Q)·(Q×P)
    Y_off  = (C · h_prev) ⊙ exp(cum)        inter-chunk read
    S_c    = (B ⊙ decay_rest)ᵀ · (x·dt)     chunk state contribution
    h      = exp(cum_Q)·h_prev + S_c        O(P·N) recurrence in VMEM scratch

grid = (B, H, num_chunks) with the chunk axis innermost: the recurrent state
h (P×N fp32) lives in VMEM scratch across the whole sequence of one (batch,
head) pair and never round-trips to HBM — the kernel streams x/dt/B/C tiles
in and Y tiles out at exactly their HBM footprint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, d_ref, b_ref, c_ref, y_ref, h_scr, *,
                q_chunk: int):
    """One (b, h, c) grid step.

    x_ref (1, Q, 1, P); dt_ref (1, Q, 1); a_ref/d_ref (1,);
    b_ref/c_ref (1, Q, N); y_ref (1, Q, 1, P); h_scr (P, N) fp32.
    """
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    b_in = b_ref[0].astype(jnp.float32)  # (Q, N)
    c_in = c_ref[0].astype(jnp.float32)  # (Q, N)
    a_neg = -jnp.exp(a_ref[0].astype(jnp.float32))  # scalar A < 0
    d_skip = d_ref[0].astype(jnp.float32)

    da = dt * a_neg  # (Q,)
    cum = jnp.cumsum(da)  # (Q,) inclusive
    seg = cum[:, None] - cum[None, :]  # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    decay = jnp.where(qi >= kj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(c_in, b_in, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    xdt = x * dt[:, None]  # (Q, P)
    y_diag = jax.lax.dot_general(scores * decay, xdt,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: read h_prev, emit, then update
    h_prev = h_scr[...]  # (P, N)
    decay_in = jnp.exp(cum)  # (Q,)
    y_off = jax.lax.dot_general(c_in, h_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * decay_in[:, None]  # (Q, P)

    decay_rest = jnp.exp(cum[-1] - cum)  # (Q,)
    bw = b_in * decay_rest[:, None]  # (Q, N)
    s_c = jax.lax.dot_general(xdt, bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    h_scr[...] = h_prev * jnp.exp(cum[-1]) + s_c

    y = y_diag + y_off + d_skip * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_pallas(x, dt, a_log, d_skip, b_in, c_in, *, chunk: int = 256,
               interpret: bool = True) -> jax.Array:
    """x (B, L, H, P); dt (B, L, H) fp32; b/c (B, L, N) -> y (B, L, H, P)."""
    B, L, H, P = x.shape
    N = b_in.shape[-1]
    Q = min(chunk, L)
    if L % Q:
        raise ValueError(f"L={L} must tile by chunk={Q}")
    nc = L // Q
    kernel = functools.partial(_ssd_kernel, q_chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, d_skip, b_in, c_in)
