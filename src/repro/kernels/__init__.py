# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# kernels/sweep: the Pallas batched-event kernel behind
# repro.core.engine's impl="pallas" executor (the (grid × slot)
# event-loop hot path named in ROADMAP.md).
