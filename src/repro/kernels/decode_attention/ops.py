"""jit'd public wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_bh


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, kv_len, *, block_k: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q (B, 1, H, D); k/v (B, S, KH, D); kv_len scalar -> (B, 1, H, D)."""
    B, _, H, D = q.shape
    S, KH = k.shape[1], k.shape[2]
    g = H // KH
    qr = q.reshape(B, KH, g, D).reshape(B * KH, g, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KH, S, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KH, S, D)
    o = decode_attention_bh(qr, kr, vr, kv_len, block_k=block_k,
                            interpret=interpret)
    return o.reshape(B, KH, g, D).reshape(B, 1, H, D)
