"""Single-token GQA decode-attention Pallas kernel.

Decode attention is memory-bound: one query token streams the whole KV cache
(S·KH·D·2 bytes) through VMEM at ~zero arithmetic intensity.  The kernel's
job is pure bandwidth: KV tiles of (bk, D) are streamed per (batch·kv-head)
grid row while the (g, D) output accumulates in VMEM scratch — no (S)-sized
intermediate ever exists in HBM.

grid = (B·KH, num_kv_blocks), KV innermost so scratch persists per row.
``kv_len`` is a traced scalar (SMEM) so one compiled kernel serves any cache
fill level — tiles beyond kv_len are skipped entirely (bandwidth saving,
not just masking).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, bk: int, nk: int):
    """q_ref (1, g, D); k_ref/v_ref (1, bk, D); o_ref (1, g, D)."""
    kj = pl.program_id(1)
    kv_len = len_ref[0]

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_first = kj * bk

    def compute():
        q = q_ref[0].astype(jnp.float32)  # (g, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[..., None] + pv

    # skip tiles entirely past the fill level — saves bandwidth, not just math
    pl.when(k_first < kv_len)(compute)

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


def decode_attention_bh(q, k, v, kv_len, *, block_k: int = 512,
                        interpret: bool = True) -> jax.Array:
    """q (BH, g, D); k/v (BH, S, D); kv_len scalar int32 -> (BH, g, D)."""
    BH, g, D = q.shape
    S = k.shape[1]
    bk = min(block_k, S)
    if S % bk:
        raise ValueError(f"S={S} must tile by {bk}")
    nk = S // bk
    kernel = functools.partial(_decode_kernel, bk=bk, nk=nk)
    len_arr = jnp.reshape(jnp.asarray(kv_len, jnp.int32), (1,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1, g, D), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, *_: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, *_: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, D), lambda b, j, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, g, D), q.dtype),
        interpret=interpret,
    )(len_arr, q, k, v)
