"""Pure-jnp oracle for the decode-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def decode_attention_ref(q, k, v, kv_len) -> jax.Array:
    """q (B, 1, H, D); k/v (B, S, KH, D); kv_len scalar -> (B, 1, H, D)."""
    B, _, H, D = q.shape
    S, KH = k.shape[1], k.shape[2]
    g = H // KH
    qg = q.reshape(B, KH, g, D).astype(jnp.float32)
    s = jnp.einsum("bngd,bsnd->bngs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    valid = jnp.arange(S) < kv_len
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    y = jnp.einsum("bngs,bsnd->bngd", p, v.astype(jnp.float32))
    return y.reshape(B, 1, H, D).astype(q.dtype)
