"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, q_offset: int = 0,
                  sk_valid: Optional[int] = None) -> jax.Array:
    """q (B, Sq, H, D); k/v (B, Sk, KH, D) -> (B, Sq, H, D), fp32 math."""
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    g = H // KH
    qg = q.reshape(B, Sq, KH, g, D).astype(jnp.float32)
    s = jnp.einsum("bqngd,bsnd->bqngs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask &= qpos[:, None] >= kpos[None, :]
    if sk_valid is not None:
        mask &= kpos[None, :] < sk_valid
    s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    y = jnp.einsum("bqngs,bsnd->bqngd", p, v.astype(jnp.float32))
    return y.reshape(B, Sq, H, D).astype(q.dtype)
