"""jit'd public wrapper: (B, S, H, D) layout -> kernel's (B·KH, g, S, D)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bh


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "q_offset", "sk_valid",
                     "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, q_offset: int = 0,
                    sk_valid: Optional[int] = None,
                    interpret: bool = True) -> jax.Array:
    """GQA flash attention.  q (B,Sq,H,D); k/v (B,Sk,KH,D) -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    g = H // KH
    qr = q.reshape(B, Sq, KH, g, D).transpose(0, 2, 3, 1, 4)  # (B,KH,g,Sq,D)
    qr = qr.reshape(B * KH, g, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KH, Sk, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KH, Sk, D)
    o = flash_attention_bh(qr, kr, vr, causal=causal, block_q=block_q,
                           block_k=block_k, q_offset=q_offset,
                           sk_valid=sk_valid, interpret=interpret)
    o = o.reshape(B, KH, g, Sq, D).transpose(0, 3, 1, 2, 4)
    return o.reshape(B, Sq, H, D)
