"""Flash-attention Pallas TPU kernel (GQA-aware, causal).

Tiling: grid = (B·KH, num_q_blocks, num_kv_blocks); the KV-block axis is the
innermost (fastest) grid dimension, so the VMEM scratch accumulators (m, l,
acc) persist across KV blocks of one Q block — the classic TPU "revisiting"
flash-attention schedule.  Q tiles carry the whole GQA group ``g = H/KH`` so
each K/V tile is loaded into VMEM once per *group* instead of once per query
head (the memory win GQA exists for).

Block shapes target the MXU: Q tile (g·bq, D) × K tile (bk, D) with bq, bk
multiples of 128 at production sizes (tests sweep smaller shapes in interpret
mode).  fp32 accumulation throughout; logits never leave VMEM.

Causal masking: KV tiles strictly in the future of a whole Q tile are skipped
with ``pl.when`` (compute guard — the grid itself stays rectangular, as
Pallas TPU requires); the diagonal tile applies the element mask.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, nk: int, causal: bool, q_offset: int,
                  sk_valid: int):
    """One (bh, qi, kj) grid step.

    q_ref (1, g, bq, D); k_ref/v_ref (1, bk, D); o_ref (1, g, bq, D);
    scratch m/l (g, bq), acc (g, bq, D), fp32.
    """
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_first = q_offset + qi * bq  # global position of this Q tile's first row
    k_first = kj * bk

    def compute():
        q = q_ref[0].astype(jnp.float32)  # (g, bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (g, bq, bk)
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        mask = kpos < sk_valid
        if causal:
            qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask &= qpos >= kpos
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        v = v_ref[0].astype(jnp.float32)  # (bk, D)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[..., None] + pv

    if causal:
        # skip KV tiles strictly in the causal future of the whole Q tile
        pl.when(k_first <= q_first + bq - 1)(compute)
    else:
        compute()

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, causal: bool = True, block_q: int = 128,
                       block_k: int = 128, q_offset: int = 0,
                       sk_valid: Optional[int] = None,
                       interpret: bool = True) -> jax.Array:
    """q (BH, g, Sq, D); k/v (BH, Sk, D) -> (BH, g, Sq, D)."""
    BH, g, Sq, D = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError(f"Sq={Sq}/Sk={Sk} must tile by ({bq},{bk})")
    nq = Sq // bq
    nk = Sk // bk
    sk_valid = Sk if sk_valid is None else sk_valid

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
        q_offset=q_offset, sk_valid=sk_valid,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g, bq, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, bq, D), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, g, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
