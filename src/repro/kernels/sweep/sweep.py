"""Pallas batched-event kernel for the (grid × slot) sweep hot loop.

The engine's event loop (:mod:`repro.core.engine`) is scalar control flow
over small per-lane state: a handful of clocks plus (rmax,) slot arrays.
Under the XLA ``vmap``-of-``scan`` schedule every one of the N width-``rmax``
selects in the event body is a separate HLO op whose operands round-trip
through HBM once per event.  This kernel flips the layout: a *tile* of
simulation lanes is laid out as (tile, rmax) arrays resident in VMEM, and a
whole float32 window of events (the chunk the engine already uses for
precision) runs as ONE fused kernel body — clock min/argmin merge,
FIFO-oldest/first-free slot reductions, and the one-hot join/leave updates
all stay on-chip for the entire event block.

Tiling: ``grid = (n_tiles, n_windows)`` with the window axis innermost.  The
final-state *output* blocks have an index map that ignores the window axis,
so each lane tile's state block stays resident in VMEM across all of its
windows (the same revisiting schedule as the flash-attention accumulators,
with the out refs themselves as the resident storage): window 0 seeds the
state block from the initial-state inputs, every window reads/writes it
in place, and it is flushed to HBM once per lane tile.  Per-window event
counts arrive as an i32 vector (one entry per window — burn-in, full
chunks, tail), so burn-in and the remainder window run through the same
kernel body.

Genericity: the kernel is parameterized by a per-lane ``step(state, stats,
params) -> (state, stats)`` event body and arbitrary state/params/stats
pytrees, so the single-pool engine, the spot-market engine (per-pool
clock vectors, per-pool stat counters), and the multi-region engine
(state blocks grown a region axis: (tile, R) job/spot/preempt clock
vectors, (tile, sum rmax_r) packed slot partitions) share this one
kernel family with zero kernel-side changes — and so do the optional
state/stat extensions that pair onto the carry (the ``env=`` timeline
cursor, the ``work=`` per-slot work structure with its survival-ledger
block: (tile, rmax) progress/overhead/checkpoint/life planes riding in
the same VMEM-resident state tile).  The
body is ``jax.vmap``-ed across the tile inside the kernel, which keeps each
lane's arithmetic — including its threefry PRNG stream — bit-for-bit
identical to the ``lax.scan`` reference path (see ref.py and
tests/test_sweep_kernel.py).

``interpret=True`` (the CPU fallback) runs the same kernel body through the
Pallas interpreter so tier-1 stays green on hosts without an accelerator;
compiled Mosaic lowering targets TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resident_spec(shape: tuple, tile: int) -> pl.BlockSpec:
    """(tile, *rest) block at lane-tile ``t``, resident across windows."""
    rest = tuple(shape[1:])
    return pl.BlockSpec((tile,) + rest,
                        lambda t, w, _n=len(rest): (t,) + (0,) * _n)


def _window_spec(shape: tuple, tile: int) -> pl.BlockSpec:
    """(tile, 1, *rest) block at (lane-tile ``t``, window ``w``)."""
    rest = tuple(shape[2:])
    return pl.BlockSpec((tile, 1) + rest,
                        lambda t, w, _n=len(rest): (t, w) + (0,) * _n)


def _sweep_kernel(nev_ref, *refs, step, epilogue, n_state, n_params, n_xs,
                  state_tree, params_tree, xs_tree, stats_zero, tile):
    """One (lane-tile, window) grid step: a full event block, fused.

    nev_ref (1,) i32 — events in this window; refs order is
    [state_in..., params..., xs...] then [state_out..., stats_out...].
    state_out doubles as the VMEM-resident engine state across the window
    axis; xs blocks (when present) are (tile, 1, max_ev, ...) per-window
    per-event inputs — the engine's PRNG slab — indexed row-by-row inside
    the event loop, so a slab-driven body performs zero in-kernel RNG.
    """
    wj = pl.program_id(1)
    state_in = refs[:n_state]
    params_in = refs[n_state:n_state + n_params]
    xs_in = refs[n_state + n_params:n_state + n_params + n_xs]
    n_in = n_state + n_params + n_xs
    state_out = refs[n_in:n_in + n_state]
    stats_out = refs[n_in + n_state:]

    @pl.when(wj == 0)
    def _seed():
        for dst, src in zip(state_out, state_in):
            dst[...] = src[...]

    state = jax.tree.unflatten(state_tree, [r[...] for r in state_out])
    params = jax.tree.unflatten(params_tree, [r[...] for r in params_in])
    # fresh float32/int32 window accumulators, re-zeroed every window — the
    # engine's chunked-precision scheme, unchanged
    stats = jax.tree.map(lambda z: jnp.zeros((tile,) + z.shape, z.dtype),
                         stats_zero)
    vstep = jax.vmap(step)

    if n_xs:
        xs_block = jax.tree.unflatten(xs_tree, [r[...] for r in xs_in])

        def event(i, carry):
            st, acc = carry
            x = jax.tree.map(
                lambda b: jax.lax.dynamic_index_in_dim(b[:, 0], i, axis=1,
                                                       keepdims=False),
                xs_block)
            return vstep(st, acc, params, x)
    else:
        def event(_, carry):
            st, acc = carry
            return vstep(st, acc, params)

    state, stats = jax.lax.fori_loop(0, nev_ref[0], event, (state, stats))
    if epilogue is not None:
        state = jax.vmap(epilogue)(state)
    for dst, leaf in zip(state_out, jax.tree.leaves(state)):
        dst[...] = leaf
    for dst, leaf in zip(stats_out, jax.tree.leaves(stats)):
        dst[...] = leaf[:, None]


def batched_event_windows(step, state, params, stats_zero, events_per_window,
                          *, xs=None, tile: int = 256, interpret: bool = True,
                          epilogue=None):
    """Run stacked event windows for a batch of simulation lanes on-chip.

    Args:
      step: per-lane event body ``(state, stats, params) -> (state, stats)``
        over unbatched pytrees (vmap-ed across the lane tile in-kernel);
        with ``xs``, the body takes a fourth argument — this event's xs row.
      state: pytree of ``(B, ...)`` arrays — per-lane initial engine state.
      params: pytree of ``(B, ...)`` arrays — per-lane traced parameters.
      stats_zero: pytree of *unbatched* zero accumulators defining the
        per-window stats shapes/dtypes (e.g. ``WindowStats.zeros()``).
      events_per_window: static-length sequence of per-window event counts.
      xs: optional pytree of ``(B, n_windows, max_ev, ...)`` per-event
        window inputs (``max_ev`` = max of ``events_per_window``; rows past
        a window's count are ignored).  Each window's block streams in as a
        (tile, 1, max_ev, ...) VMEM input — the engine's PRNG slab path.
      tile: lanes per kernel instance (clamped to B; B is padded up to a
        tile multiple with copies of lane 0, sliced off on return).
      interpret: run through the Pallas interpreter (the CPU fallback).
      epilogue: optional per-lane ``state -> state`` applied after each
        window (the engine's order-rebase hook).

    Returns ``(final_state, stats)`` where stats leaves are shaped
    ``(B, n_windows, ...)`` — one float32 window of sums per entry of
    ``events_per_window``, assembled in float64 downstream.
    """
    state_leaves, state_tree = jax.tree.flatten(state)
    params_leaves, params_tree = jax.tree.flatten(params)
    xs_leaves, xs_tree = jax.tree.flatten(xs)
    b = state_leaves[0].shape[0]
    tile = max(1, min(tile, b))
    pad = -b % tile
    if pad:
        def padlane(x):
            fill = jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])
            return jnp.concatenate([x, fill])

        state_leaves = [padlane(x) for x in state_leaves]
        params_leaves = [padlane(x) for x in params_leaves]
        xs_leaves = [padlane(x) for x in xs_leaves]
    bp = b + pad
    n_windows = len(events_per_window)
    nev = jnp.asarray(events_per_window, jnp.int32)

    stats_leaves = jax.tree.leaves(stats_zero)
    state_structs = [jax.ShapeDtypeStruct((bp,) + x.shape[1:], x.dtype)
                     for x in state_leaves]
    stats_structs = [jax.ShapeDtypeStruct((bp, n_windows) + z.shape, z.dtype)
                     for z in stats_leaves]
    kernel = functools.partial(
        _sweep_kernel, step=step, epilogue=epilogue,
        n_state=len(state_leaves), n_params=len(params_leaves),
        n_xs=len(xs_leaves), state_tree=state_tree, params_tree=params_tree,
        xs_tree=xs_tree, stats_zero=stats_zero, tile=tile,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bp // tile, n_windows),
        in_specs=[pl.BlockSpec((1,), lambda t, w: (w,))]
        + [_resident_spec(x.shape, tile) for x in state_leaves]
        + [_resident_spec(x.shape, tile) for x in params_leaves]
        + [_window_spec(x.shape, tile) for x in xs_leaves],
        out_specs=[_resident_spec(s.shape, tile) for s in state_structs]
        + [_window_spec(s.shape, tile) for s in stats_structs],
        out_shape=state_structs + stats_structs,
        interpret=interpret,
    )(nev, *state_leaves, *params_leaves, *xs_leaves)
    n_state = len(state_leaves)
    unpad = (lambda x: x[:b]) if pad else (lambda x: x)
    final_state = jax.tree.unflatten(state_tree,
                                     [unpad(x) for x in out[:n_state]])
    _, stats_tree = jax.tree.flatten(stats_zero)
    stats = jax.tree.unflatten(stats_tree, [unpad(x) for x in out[n_state:]])
    return final_state, stats
