"""Batched-event Pallas kernel for the sweep engine's (grid × slot) hot loop.

House layout (see flash_attention/ssd): ``sweep.py`` carries the kernel,
``ops.py`` the public wrapper, ``ref.py`` the pure-JAX reference the kernel
must match bit-for-bit.  Consumed by :mod:`repro.core.engine` via
``run_sweep(..., impl="pallas")`` / ``run_market_sweep(..., impl="pallas")``.
"""
from repro.kernels.sweep.ops import batched_events, default_interpret
from repro.kernels.sweep.ref import batched_event_windows_ref
from repro.kernels.sweep.sweep import batched_event_windows

__all__ = ["batched_events", "batched_event_windows",
           "batched_event_windows_ref", "default_interpret"]
