"""Pure-JAX reference for the batched-event sweep kernel.

Same contract as :func:`repro.kernels.sweep.sweep.batched_event_windows`,
built from the ops the engine's ``lax.scan`` path uses: a ``vmap``-ed event
body inside a ``fori_loop`` per window, windows unrolled in Python.  The
kernel must reproduce this reference **bit-for-bit** — the event body is the
same traced function in both, so any divergence is a kernel layout bug, not
numerics (tests/test_sweep_kernel.py asserts exact equality).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_event_windows_ref(step, state, params, stats_zero,
                              events_per_window, *, epilogue=None):
    """Reference: ``(final_state, stats)`` with stats leaves (B, W, ...)."""
    b = jax.tree.leaves(state)[0].shape[0]
    vstep = jax.vmap(step)

    def window(state, n_ev):
        zeros = jax.tree.map(
            lambda z: jnp.zeros((b,) + z.shape, z.dtype), stats_zero)

        def event(_, carry):
            st, acc = carry
            return vstep(st, acc, params)

        state, acc = jax.lax.fori_loop(0, n_ev, event, (state, zeros))
        if epilogue is not None:
            state = jax.vmap(epilogue)(state)
        return state, acc

    windows = []
    for n_ev in events_per_window:
        state, acc = window(state, n_ev)
        windows.append(acc)
    stats = jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *windows)
    return state, stats
