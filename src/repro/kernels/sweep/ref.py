"""Pure-JAX reference for the batched-event sweep kernel.

Same contract as :func:`repro.kernels.sweep.sweep.batched_event_windows`,
built from the ops the engine's ``lax.scan`` path uses: a ``vmap``-ed event
body inside a ``fori_loop`` per window, windows unrolled in Python.  The
kernel must reproduce this reference **bit-for-bit** — the event body is the
same traced function in both, so any divergence is a kernel layout bug, not
numerics (tests/test_sweep_kernel.py asserts exact equality).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_event_windows_ref(step, state, params, stats_zero,
                              events_per_window, *, xs=None, epilogue=None):
    """Reference: ``(final_state, stats)`` with stats leaves (B, W, ...).

    ``xs`` (optional) matches the kernel's contract: a pytree of
    ``(B, n_windows, max_ev, ...)`` per-event window inputs; the event body
    then takes a fourth argument — this event's row.
    """
    b = jax.tree.leaves(state)[0].shape[0]
    vstep = jax.vmap(step)

    def window(state, n_ev, xw):
        zeros = jax.tree.map(
            lambda z: jnp.zeros((b,) + z.shape, z.dtype), stats_zero)

        def event(i, carry):
            st, acc = carry
            if xw is None:
                return vstep(st, acc, params)
            x = jax.tree.map(
                lambda leaf: jax.lax.dynamic_index_in_dim(
                    leaf, i, axis=1, keepdims=False), xw)
            return vstep(st, acc, params, x)

        state, acc = jax.lax.fori_loop(0, n_ev, event, (state, zeros))
        if epilogue is not None:
            state = jax.vmap(epilogue)(state)
        return state, acc

    windows = []
    for w, n_ev in enumerate(events_per_window):
        xw = None if xs is None else jax.tree.map(lambda leaf: leaf[:, w],
                                                  xs)
        state, acc = window(state, n_ev, xw)
        windows.append(acc)
    stats = jax.tree.map(lambda *leaves: jnp.stack(leaves, axis=1), *windows)
    return state, stats
