"""Public wrapper for the batched-event sweep kernel.

Unlike the attention/SSD ops this entry is not jitted here: ``step`` is a
per-call closure (the engine binds its event body over static descriptors),
so the callers — :mod:`repro.core.engine`'s ``impl="pallas"`` dispatch —
wrap it in their own module-scope jits with the descriptors as static args.

``interpret=None`` auto-selects: compiled Mosaic on TPU backends, the
Pallas interpreter everywhere else (CPU/GPU), so the same call site is
correct on every host and tier-1 stays green without an accelerator.
"""
from __future__ import annotations

import jax

from repro.kernels.sweep.sweep import batched_event_windows


def default_interpret() -> bool:
    """True unless the default backend can compile the kernel (TPU)."""
    return jax.default_backend() != "tpu"


def batched_events(step, state, params, stats_zero, events_per_window, *,
                   xs=None, tile: int = 256, interpret: bool | None = None,
                   epilogue=None):
    """Run stacked event windows on-chip; see ``batched_event_windows``."""
    if interpret is None:
        interpret = default_interpret()
    return batched_event_windows(step, state, params, stats_zero,
                                 events_per_window, xs=xs, tile=tile,
                                 interpret=interpret, epilogue=epilogue)
