"""Spot/on-demand cluster orchestration driven by the paper's policy.

This is the paper *deployed*: a stream of delay-sensitive jobs (training
legs / batch-inference requests) arrives at a cluster whose cheap capacity
is spot pods (stochastic availability, advance-notice preemption) and whose
guaranteed capacity is on-demand pods at cost ``k``.

Components:
  * :class:`OnlineAdmissionController` — Algorithm 1 running *online* on the
    live event stream (the jit'd scan in repro.core.adaptive is the
    offline/on-device twin; this one consumes real callbacks).  Admission
    decisions go through :func:`repro.core.policies.three_phase_admit_prob`
    — the same admission law the engine kernels trace — and
    :meth:`OnlineAdmissionController.kernel` hands the current knob to
    :func:`repro.core.engine.run_sweep`/``run_sim`` for on-device what-if
    sweeps against the live controller state.
  * :class:`SpotCluster` — discrete-event cluster: job arrivals, spot-slot
    arrivals, preemptions with notice.  Jobs admitted to the spot queue wait
    (Theorem 4: X = ∞ below the knob); rejected jobs run on-demand
    immediately.  Preempted jobs checkpoint within the notice window and
    re-enter admission — the paper's policy doubles as the recovery policy.
  * Straggler mitigation: per-pod EWMA of step time; a pod flagged at
    >``straggler_factor``× the median is treated as preempted-with-notice.

The event loop is host-side Python (it orchestrates real JAX work — see
examples/elastic_spot_training.py); all statistics mirror
repro.core.simulator so Theorem-1 cost accounting applies unchanged.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.policies import (
    ThreePhaseKernel,
    ThreePhasePolicy,
    three_phase_admit_prob,
)


class OnlineAdmissionController:
    """Algorithm 1 on a live stream: windowed delay → projected SGD on r."""

    def __init__(self, *, delta: float, eta: float = 0.05,
                 eta_decay: float = 0.05, r0: float = 1.0,
                 r_max: float = 16.0, window_jobs: int = 64):
        self.delta = delta
        self.eta = eta
        self.eta_decay = eta_decay
        self.r = r0
        self.r_max = r_max
        self.window_jobs = window_jobs
        self._delays: list[float] = []
        self._updates = 0
        self.history: list[float] = [r0]

    def policy(self) -> ThreePhasePolicy:
        return ThreePhasePolicy(r=self.r)

    def kernel(self) -> ThreePhaseKernel:
        """The engine kernel twin; pair with :meth:`kernel_params`."""
        return ThreePhaseKernel()

    def kernel_params(self) -> dict:
        return self.policy().kernel_params()

    def admit(self, queue_len: int, rng: np.random.Generator) -> bool:
        return rng.random() < three_phase_admit_prob(queue_len, self.r)

    def on_job_complete(self, delay: float) -> None:
        self._delays.append(delay)
        if len(self._delays) >= self.window_jobs:
            d = float(np.mean(self._delays))
            self._delays.clear()
            step = self.eta / math.sqrt(1.0 + self.eta_decay * self._updates)
            self._updates += 1
            self.r = min(self.r_max, max(0.0, self.r - step * (d - self.delta)))
            self.history.append(self.r)


@dataclasses.dataclass
class Job:
    job_id: int
    arrival_time: float
    work_steps: int  # training steps this job needs


@dataclasses.dataclass
class ClusterStats:
    jobs_completed: int = 0
    spot_served: int = 0
    ondemand_served: int = 0
    preemptions: int = 0
    stragglers_evicted: int = 0
    checkpoints: int = 0
    restores: int = 0
    total_cost: float = 0.0
    total_delay: float = 0.0

    @property
    def avg_cost(self) -> float:
        return self.total_cost / max(self.jobs_completed, 1)

    @property
    def avg_delay(self) -> float:
        return self.total_delay / max(self.jobs_completed, 1)


class SpotCluster:
    """Discrete-event spot/on-demand cluster with admission control."""

    def __init__(self, *, job_process: ArrivalProcess,
                 spot_process: ArrivalProcess, k_cost: float = 10.0,
                 controller: OnlineAdmissionController,
                 preemption_prob: float = 0.0,
                 notice_hours: float = 0.05,
                 straggler_factor: float = 1.5,
                 on_spot_run: Optional[Callable] = None,
                 on_ondemand_run: Optional[Callable] = None,
                 on_preempt: Optional[Callable] = None,
                 seed: int = 0):
        self.jobs = job_process
        self.spots = spot_process
        self.k = k_cost
        self.ctl = controller
        self.preemption_prob = preemption_prob
        self.notice = notice_hours
        self.straggler_factor = straggler_factor
        self.on_spot_run = on_spot_run
        self.on_ondemand_run = on_ondemand_run
        self.on_preempt = on_preempt
        self.rng = np.random.default_rng(seed)
        self.queue: deque[Job] = deque()
        self.stats = ClusterStats()
        self._t = 0.0
        self._job_counter = 0
        self._step_times: dict[int, float] = {}  # pod EWMA

    # --------------------------------------------------------------- events
    def _sample(self, proc: ArrivalProcess) -> float:
        import jax

        key = jax.random.key(int(self.rng.integers(2**31)))
        return float(proc.sample(key))

    def run(self, n_events: int, *, work_steps: int = 1) -> ClusterStats:
        next_job = self._sample(self.jobs)
        next_spot = self._sample(self.spots)
        for _ in range(n_events):
            if next_job <= next_spot:
                self._t += next_job
                next_spot -= next_job
                next_job = self._sample(self.jobs)
                self._job_arrival(work_steps)
            else:
                self._t += next_spot
                next_job -= next_spot
                next_spot = self._sample(self.spots)
                self._spot_arrival()
        return self.stats

    def _job_arrival(self, work_steps: int) -> None:
        self._job_counter += 1
        job = Job(self._job_counter, self._t, work_steps)
        if self.ctl.admit(len(self.queue), self.rng):
            self.queue.append(job)  # Theorem 4: wait indefinitely
        else:
            self._run_ondemand(job)

    def _spot_arrival(self) -> None:
        if not self.queue:
            return
        job = self.queue.popleft()
        delay = self._t - job.arrival_time
        preempted = self.rng.random() < self.preemption_prob
        if preempted:
            # advance notice → checkpoint → re-admission (recovery = policy)
            self.stats.preemptions += 1
            self.stats.checkpoints += 1
            if self.on_preempt is not None:
                self.on_preempt(job)
            self.stats.total_cost += 1.0  # the partial spot leg was paid
            if self.ctl.admit(len(self.queue), self.rng):
                self.stats.restores += 1
                self.queue.append(dataclasses.replace(
                    job, arrival_time=self._t))
                self.stats.total_delay += delay
                # completion will be counted when the retry finishes
                self.ctl.on_job_complete(delay)
                self.stats.jobs_completed += 1  # leg accounting
            else:
                self._run_ondemand(job, extra_delay=delay)
            return
        if self.on_spot_run is not None:
            self.on_spot_run(job)
        self.stats.jobs_completed += 1
        self.stats.spot_served += 1
        self.stats.total_cost += 1.0
        self.stats.total_delay += delay
        self.ctl.on_job_complete(delay)

    def _run_ondemand(self, job: Job, extra_delay: float = 0.0) -> None:
        if self.on_ondemand_run is not None:
            self.on_ondemand_run(job)
        self.stats.jobs_completed += 1
        self.stats.ondemand_served += 1
        self.stats.total_cost += self.k
        self.stats.total_delay += extra_delay
        self.ctl.on_job_complete(extra_delay)

    # ----------------------------------------------------------- stragglers
    def observe_step_time(self, pod_id: int, seconds: float) -> bool:
        """EWMA straggler detector; returns True if the pod was evicted."""
        prev = self._step_times.get(pod_id, seconds)
        ewma = 0.7 * prev + 0.3 * seconds
        self._step_times[pod_id] = ewma
        if len(self._step_times) >= 2:
            median = float(np.median(list(self._step_times.values())))
            if ewma > self.straggler_factor * median:
                self.stats.stragglers_evicted += 1
                del self._step_times[pod_id]
                return True
        return False
