"""Spot/on-demand cluster orchestration driven by the paper's policy.

This is the paper *deployed*: a stream of delay-sensitive jobs (training
legs / batch-inference requests) arrives at a cluster whose cheap capacity
is spot pods (stochastic availability, advance-notice preemption) and whose
guaranteed capacity is on-demand pods at cost ``k``.

Since PR 2 the host path is a **thin consumer of the on-device spot-market
subsystem** (:mod:`repro.core.market`): the cluster's capacity model is a
:class:`~repro.core.market.SpotMarket` — P heterogeneous pools with
per-pool prices, slot processes, and Poisson preemption hazards — and the
live event loop mirrors the engine's merged clock vector (per-pool
``next_slot``/``next_preempt`` + the job clock).  Every law is shared with
the traced kernels: admission goes through
:func:`repro.core.policies.three_phase_admit_prob`, preemption recovery
through :func:`repro.core.market.checkpoint_within_notice` + re-admission
(exactly :class:`repro.core.market.NoticeAwareKernel`), and
:meth:`SpotCluster.what_if_sweep` hands the live controller state to
:func:`repro.core.engine.run_market_sweep` for on-device what-if grids
against the *same* market the host is serving.

Components:
  * :class:`OnlineAdmissionController` — Algorithm 1 running *online* on the
    live event stream (the jit'd scan in repro.core.adaptive is the
    offline/on-device twin; this one consumes real callbacks), plus the
    pool-choice hook (cheapest-price, the engine kernels' default rule).
  * :class:`SpotCluster` — discrete-event cluster: job arrivals, per-pool
    spot slots, hazard-clock preemptions with notice, and the legacy
    Bernoulli preemption-at-service model (``preemption_prob``).  Jobs
    admitted to the spot queue are tagged with a pool and wait (Theorem 4:
    X = ∞ below the knob); rejected jobs run on-demand immediately.
    Preempted jobs checkpoint within the notice window and re-enter
    admission — the paper's policy doubles as the recovery policy.
  * Straggler mitigation: per-pod EWMA of step time; a pod flagged at
    >``straggler_factor``× the median is treated as preempted-with-notice.

The event loop is host-side Python (it orchestrates real JAX work — see
examples/elastic_spot_training.py); all statistics mirror the engine's
market accounting so Theorem-1 cost laws apply unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.clocks import hazard_clock, thinning_pick
from repro.core.market import (
    NoticeAwareKernel,
    SpotMarket,
    checkpoint_within_notice,
)
from repro.core.policies import (
    ThreePhaseKernel,
    ThreePhasePolicy,
    deadline_slack,
    three_phase_admit_prob,
)
from repro.core.regions import RegionTopology, host_route
from repro.obs.timing import annotate
from repro.obs.trace import TraceRecorder


class OnlineAdmissionController:
    """Algorithm 1 on a live stream: windowed delay → projected SGD on r."""

    def __init__(self, *, delta: float, eta: float = 0.05,
                 eta_decay: float = 0.05, r0: float = 1.0,
                 r_max: float = 16.0, window_jobs: int = 64):
        self.delta = delta
        self.eta = eta
        self.eta_decay = eta_decay
        self.r = r0
        self.r_max = r_max
        self.window_jobs = window_jobs
        self._delays: list[float] = []
        self._updates = 0
        self.history: list[float] = [r0]

    def policy(self) -> ThreePhasePolicy:
        return ThreePhasePolicy(r=self.r)

    def kernel(self) -> ThreePhaseKernel:
        """The engine kernel twin; pair with :meth:`kernel_params`."""
        return ThreePhaseKernel()

    def kernel_params(self) -> dict:
        return self.policy().kernel_params()

    def admit(self, queue_len: int, rng: np.random.Generator) -> bool:
        return rng.random() < three_phase_admit_prob(queue_len, self.r)

    def choose_pool(self, market: SpotMarket, qlen_pool: list[int],
                    alive=None) -> int:
        """Pool-choice hook — cheapest price, the engine kernels' default.

        ``alive`` (optional bool mask) restricts the choice to live pools
        — the host twin of :class:`repro.core.market.PanicKernel`; all-dead
        raises ``RuntimeError`` (the cluster's cue to run on-demand).
        """
        del qlen_pool
        prices = market.prices()
        if alive is not None:
            alive = np.asarray(alive, bool)
            if not alive.any():
                raise RuntimeError("choose_pool: no pool alive")
            prices = np.where(alive, prices, np.inf)
        return int(np.argmin(prices))

    def choose_region(self, topology: RegionTopology,
                      qlen_region: list[int], home: int = 0,
                      rule: str = "cheapest", alive=None) -> int:
        """Routing hook — the deterministic :func:`repro.core.regions.
        host_route` rules (host twin of the engine's ``route`` hook).
        ``alive`` forwards the region health mask (failover semantics in
        :func:`repro.core.regions.host_route`)."""
        return host_route(rule, prices=topology.prices(),
                          rates=topology.rates(), qlens=qlen_region,
                          home=home, alive=alive)

    def on_job_complete(self, delay: float) -> None:
        self._delays.append(delay)
        if len(self._delays) >= self.window_jobs:
            d = float(np.mean(self._delays))
            self._delays.clear()
            step = self.eta / math.sqrt(1.0 + self.eta_decay * self._updates)
            self._updates += 1
            self.r = min(self.r_max, max(0.0, self.r - step * (d - self.delta)))
            self.history.append(self.r)


def _sample_interarrival(proc: ArrivalProcess,
                         rng: np.random.Generator) -> float:
    """One inter-arrival draw for a host clock (shared by both clusters)."""
    import jax

    key = jax.random.key(int(rng.integers(2**31)))
    return float(proc.sample(key))


def _sample_superposed_preempt(hazards,
                               rng: np.random.Generator) -> tuple[float, int]:
    """(time, pool) of the next preemption under the superposed clock.

    Host twin of the engine's ``rng="slab"`` preemption machinery: ONE
    ``Exp(Σ h_p)`` draw plus a hazard-weighted thinning pick replaces the
    per-pool clock vector — the same shared law
    (:func:`repro.core.clocks.hazard_clock` /
    :func:`repro.core.clocks.thinning_pick`), exactly the vector clocks'
    joint (min, argmin) distribution.
    """
    return (hazard_clock(hazards, rng.random()),
            thinning_pick(hazards, rng.random()))


@dataclasses.dataclass(frozen=True)
class ExponentialBackoff:
    """Retry schedule for re-admission after a preemption under supply
    stress: a revoked job whose first re-admission draw fails waits
    ``base_delay``, retries, and doubles the wait up to ``max_retries``
    times before defecting to on-demand.  Host-side resilience knob —
    the clusters take ``retry=ExponentialBackoff(...)``; the default
    (``retry=None``) draws nothing and reproduces the historical event
    stream bit-for-bit.
    """

    base_delay: float = 0.05
    factor: float = 2.0
    max_retries: int = 3

    def __post_init__(self):
        if self.base_delay <= 0 or self.factor < 1 or self.max_retries < 1:
            raise ValueError("backoff needs base_delay>0, factor>=1, "
                             "max_retries>=1")

    def delays(self):
        d = self.base_delay
        for _ in range(self.max_retries):
            yield d
            d *= self.factor


def _retry_admit(ctl, rng, retry: ExponentialBackoff, qlen: int,
                 stats) -> tuple[bool, float]:
    """Backed-off re-admission attempts: (admitted?, extra wait charged).

    Shared by both clusters' preemption recovery: each attempt waits the
    next backoff delay (charged to the job either way) and redraws the
    admission law; exhaustion defects to on-demand.
    """
    extra = 0.0
    for wait in retry.delays():
        stats.retries += 1
        extra += wait
        if ctl.admit(qlen, rng):
            return True, extra
    return False, extra


@dataclasses.dataclass
class Job:
    job_id: int
    arrival_time: float
    work_steps: int  # training steps this job needs
    pool: int = 0  # spot pool the job is placed on


@dataclasses.dataclass
class ClusterStats:
    jobs_completed: int = 0
    spot_served: int = 0
    ondemand_served: int = 0
    preemptions: int = 0
    stragglers_evicted: int = 0
    checkpoints: int = 0
    restores: int = 0
    total_cost: float = 0.0
    total_delay: float = 0.0
    spot_cost: float = 0.0  # spend on spot pools incl. partial legs
    retries: int = 0  # backed-off re-admission attempts (retry= set)
    degraded_jobs: int = 0  # forced on-demand: no live pool/region

    @property
    def avg_cost(self) -> float:
        return self.total_cost / max(self.jobs_completed, 1)

    @property
    def avg_delay(self) -> float:
        return self.total_delay / max(self.jobs_completed, 1)


class SpotCluster:
    """Discrete-event spot/on-demand cluster with admission control.

    Capacity is described by a :class:`SpotMarket`; the classic single-pool
    constructor (``spot_process=...``) builds the degenerate one-pool market
    and behaves exactly as before.  Pool preemption hazards fire host-side
    clocks that mirror the engine's ``next_preempt`` vector; the legacy
    ``preemption_prob`` Bernoulli-at-service model is kept for callers that
    want revocation without hazard clocks.
    """

    def __init__(self, *, job_process: ArrivalProcess,
                 spot_process: Optional[ArrivalProcess] = None,
                 market: Optional[SpotMarket] = None, k_cost: float = 10.0,
                 controller: OnlineAdmissionController,
                 preemption_prob: float = 0.0,
                 notice_hours: float = 0.05,
                 checkpoint_hours: float = 0.0,
                 straggler_factor: float = 1.5,
                 on_spot_run: Optional[Callable] = None,
                 on_ondemand_run: Optional[Callable] = None,
                 on_preempt: Optional[Callable] = None,
                 tracer: Optional[TraceRecorder] = None,
                 retry: Optional[ExponentialBackoff] = None,
                 seed: int = 0):
        if (market is None) == (spot_process is None):
            raise ValueError("pass exactly one of spot_process / market")
        if market is None:
            market = SpotMarket.single(spot_process, notice=notice_hours)
        self.market = market
        self.jobs = job_process
        self.k = k_cost
        self.ctl = controller
        self.preemption_prob = preemption_prob
        self.notice = notice_hours
        self.checkpoint_hours = checkpoint_hours
        self.straggler_factor = straggler_factor
        self.on_spot_run = on_spot_run
        self.on_ondemand_run = on_ondemand_run
        self.on_preempt = on_preempt
        self.tracer = tracer
        self.retry = retry
        self.rng = np.random.default_rng(seed)
        self.queue: deque[Job] = deque()
        self.stats = ClusterStats()
        self.pool_served = [0] * market.n_pools
        self.pool_alive = [True] * market.n_pools
        self._t = 0.0
        self._job_counter = 0
        self._step_times: dict[int, float] = {}  # pod EWMA

    # --------------------------------------------------------------- health
    def kill_pool(self, pool: int) -> None:
        """Mark a pool dark (blackout): its slots stop serving and new
        admissions route around it.  Queued jobs wait for :meth:`revive_pool`
        (paused instances), exactly the engine's blackout semantics."""
        self.pool_alive[pool] = False

    def revive_pool(self, pool: int) -> None:
        self.pool_alive[pool] = True

    # --------------------------------------------------------------- events
    def _sample(self, proc: ArrivalProcess) -> float:
        return _sample_interarrival(proc, self.rng)

    def run(self, n_events: int, *, work_steps: int = 1) -> ClusterStats:
        """Run the merged per-pool clock loop (job-first on exact ties,
        the host's historical order; ties are measure-zero for continuous
        samplers)."""
        pools = self.market.pools
        hazards = self.market.hazards()
        next_job = self._sample(self.jobs)
        next_slot = [self._sample(p.arrival) for p in pools]
        # ONE superposed preemption clock for the whole market (the shared
        # hazard-superposition law; see _sample_superposed_preempt)
        next_pre, p_pre = _sample_superposed_preempt(hazards, self.rng)
        for _ in range(n_events):
            p_slot = int(np.argmin(next_slot))
            m_slot = next_slot[p_slot]
            dt = min(next_job, m_slot, next_pre)
            self._t += dt
            next_job -= dt
            for p in range(len(pools)):
                next_slot[p] -= dt
            if math.isfinite(next_pre):
                next_pre -= dt
            if next_job <= 0.0:
                next_job = self._sample(self.jobs)
                self._job_arrival(work_steps)
            elif next_slot[p_slot] <= 0.0:
                next_slot[p_slot] = self._sample(pools[p_slot].arrival)
                self._spot_arrival(p_slot)
            else:
                fired = p_pre
                next_pre, p_pre = _sample_superposed_preempt(hazards,
                                                             self.rng)
                self._preempt_event(fired)
        return self.stats

    def _qlen_pool(self) -> list[int]:
        counts = [0] * self.market.n_pools
        for job in self.queue:
            counts[job.pool] += 1
        return counts

    def _job_arrival(self, work_steps: int) -> None:
        self._job_counter += 1
        if all(self.pool_alive):  # healthy path: the historical call shape
            pool = self.ctl.choose_pool(self.market, self._qlen_pool())
        else:
            try:
                pool = self.ctl.choose_pool(self.market, self._qlen_pool(),
                                            alive=self.pool_alive)
            except RuntimeError:  # every pool dark: degrade to on-demand
                self.stats.degraded_jobs += 1
                self._run_ondemand(Job(self._job_counter, self._t,
                                       work_steps))
                return
        job = Job(self._job_counter, self._t, work_steps, pool=pool)
        if self.ctl.admit(len(self.queue), self.rng):
            self.queue.append(job)  # Theorem 4: wait indefinitely
        else:
            self._run_ondemand(job)
        if self.tracer is not None:
            self.tracer.record(self._t, "job", loc=pool,
                               qlen=len(self.queue))

    def _pop_oldest(self, pool: int) -> Optional[Job]:
        for i, job in enumerate(self.queue):  # FIFO-oldest on this pool
            if job.pool == pool:
                del self.queue[i]
                return job
        return None

    def _spot_arrival(self, pool_idx: int) -> None:
        if not self.pool_alive[pool_idx]:
            return  # dark pool: the slot never materializes
        job = self._pop_oldest(pool_idx)
        if self.tracer is not None:
            self.tracer.record(
                self._t, "spot", loc=pool_idx, qlen=len(self.queue),
                **({} if job is None
                   else {"wait": self._t - job.arrival_time}))
        if job is None:
            return
        price = self.market.pools[pool_idx].price
        delay = self._t - job.arrival_time
        preempted = self.rng.random() < self.preemption_prob
        if preempted:
            # legacy Bernoulli-at-service revocation: checkpoint within the
            # notice -> re-admission (recovery = policy).  The same notice
            # law as the hazard-clock path gates the checkpoint; the
            # default checkpoint_hours=0.0 always fits (historical
            # behaviour).
            self.stats.preemptions += 1
            if self.on_preempt is not None:
                self.on_preempt(job)
            self.stats.total_cost += price  # the partial spot leg was paid
            self.stats.spot_cost += price
            pool = self.market.pools[pool_idx]
            within = checkpoint_within_notice(self.checkpoint_hours,
                                              pool.notice)
            if within:
                self.stats.checkpoints += 1
            if within and self.ctl.admit(len(self.queue), self.rng):
                self.stats.restores += 1
                self.queue.append(dataclasses.replace(
                    job, arrival_time=self._t))
                self.stats.total_delay += delay
                # completion will be counted when the retry finishes
                self.ctl.on_job_complete(delay)
                self.stats.jobs_completed += 1  # leg accounting
            else:
                self._run_ondemand(job, extra_delay=delay)
            return
        if self.on_spot_run is not None:
            self.on_spot_run(job)
        self.stats.jobs_completed += 1
        self.stats.spot_served += 1
        self.pool_served[pool_idx] += 1
        self.stats.total_cost += price
        self.stats.spot_cost += price
        self.stats.total_delay += delay
        self.ctl.on_job_complete(delay)

    def _preempt_event(self, pool_idx: int) -> None:
        """Hazard-clock revocation: the engine's preempt event, host-side.

        The FIFO-oldest pool job loses its instance; the partial leg is
        paid; the job checkpoints iff it fits the notice window
        (:func:`checkpoint_within_notice`) AND re-admission accepts it —
        else it defects to on-demand.  Mirrors NoticeAwareKernel exactly.
        """
        job = self._pop_oldest(pool_idx)
        if self.tracer is not None:
            self.tracer.record(self._t, "preempt", loc=pool_idx,
                               qlen=len(self.queue))
        if job is None:
            return  # the revoked instance was idle
        pool = self.market.pools[pool_idx]
        delay = self._t - job.arrival_time
        self.stats.preemptions += 1
        if self.on_preempt is not None:
            self.on_preempt(job)
        self.stats.total_cost += pool.price
        self.stats.spot_cost += pool.price
        within = checkpoint_within_notice(self.checkpoint_hours, pool.notice)
        if within:
            self.stats.checkpoints += 1
        admitted = within and self.ctl.admit(len(self.queue), self.rng)
        extra = 0.0
        if within and not admitted and self.retry is not None:
            admitted, extra = _retry_admit(self.ctl, self.rng, self.retry,
                                           len(self.queue), self.stats)
        if admitted:
            self.stats.restores += 1
            self.queue.append(dataclasses.replace(job, arrival_time=self._t))
            self.stats.total_delay += delay + extra
            self.stats.jobs_completed += 1  # leg accounting
            self.ctl.on_job_complete(delay + extra)
        else:
            self._run_ondemand(job, extra_delay=delay + extra)

    def _run_ondemand(self, job: Job, extra_delay: float = 0.0) -> None:
        if self.on_ondemand_run is not None:
            self.on_ondemand_run(job)
        self.stats.jobs_completed += 1
        self.stats.ondemand_served += 1
        self.stats.total_cost += self.k
        self.stats.total_delay += extra_delay
        self.ctl.on_job_complete(extra_delay)

    # ---------------------------------------------------- on-device what-if
    def what_if_sweep(self, rs, *, n_events: int = 20_000, n_seeds: int = 2,
                      k=None, key=None, telemetry=None, shard: str = "none",
                      mesh=None) -> dict:
        """Sweep admission knobs against THIS cluster's market, on-device.

        Runs :func:`repro.core.engine.run_market_sweep` with the cluster's
        market and recovery parameters — the host is a thin consumer: the
        what-if grid for "where should the controller's r sit" is one
        compiled program, not a host loop.  ``telemetry=`` forwards a
        :class:`repro.obs.Telemetry` so the grid also reports P50/P99
        waits and per-pool counters.  ``shard="lanes"`` (with an optional
        ``mesh``) partitions the what-if lane axis across devices exactly
        as in :func:`repro.core.engine.run_sweep` — wide grids answer at
        fleet scale (docs/scaling.md).
        """
        import jax
        import jax.numpy as jnp

        from repro.core.engine import run_market_sweep

        if key is None:
            key = jax.random.key(int(self.rng.integers(2**31)))
        kern = NoticeAwareKernel(checkpoint_time=self.checkpoint_hours)
        with annotate("repro.cluster.what_if_sweep[market]"):
            return run_market_sweep(
                self.jobs, self.market, kern,
                {"r": jnp.asarray(rs, jnp.float32)},
                k=self.k if k is None else k, n_events=n_events, key=key,
                n_seeds=n_seeds, telemetry=telemetry, shard=shard, mesh=mesh,
            )

    # ------------------------------------------------------ deadline slack
    def job_slack(self, *, deadline: float, job: Job,
                  od_step_hours: float, buffer: float = 0.0) -> float:
        """Host-side can't-be-late watchdog for a live job.

        The engine's :class:`~repro.core.work.CantBeLateKernel` law on the
        orchestrator's clock: how much longer ``job`` may keep waiting on
        spot before migrating to on-demand (``od_step_hours`` per
        remaining work step) would no longer meet ``deadline``
        (:func:`repro.core.policies.deadline_slack` — the same arithmetic
        the traced watchdog uses).  ``<= 0`` means migrate NOW.
        """
        return float(deadline_slack(deadline, self._t - job.arrival_time,
                                    float(job.work_steps), od_step_hours,
                                    buffer))

    # ----------------------------------------------------------- stragglers
    def observe_step_time(self, pod_id: int, seconds: float) -> bool:
        """EWMA straggler detector; returns True if the pod was evicted."""
        prev = self._step_times.get(pod_id, seconds)
        ewma = 0.7 * prev + 0.3 * seconds
        self._step_times[pod_id] = ewma
        if len(self._step_times) >= 2:
            median = float(np.median(list(self._step_times.values())))
            if ewma > self.straggler_factor * median:
                self.stats.stragglers_evicted += 1
                del self._step_times[pod_id]
                return True
        return False


@dataclasses.dataclass
class RegionClusterStats(ClusterStats):
    """Cluster stats + per-region served/routed counters.

    :class:`MultiRegionCluster` constructs the per-region lists at
    topology size; a bare ``RegionClusterStats()`` starts them empty.
    """

    region_served: list = dataclasses.field(default_factory=list)
    region_routed: list = dataclasses.field(default_factory=list)
    cross_region: int = 0


class MultiRegionCluster:
    """Host-side multi-region routing over a :class:`RegionTopology`.

    The live twin of the engine's region loop (``run_region_sim``): one
    merged host clock set — per-region job arrivals, spot slots, and hazard
    preemptions — with routing at admission through the controller's
    :meth:`OnlineAdmissionController.choose_region` hook and admission
    against the *target* region's queue (per-region instances of the
    three-phase law, exactly the traced :class:`repro.core.regions.
    RoutingKernel` semantics).  Preempted jobs follow the PR-2 recovery
    model: pay the partial leg, checkpoint within the notice window
    (:func:`repro.core.market.checkpoint_within_notice`), re-enter
    admission in their own region.  Statistics mirror the engine's region
    accounting so the Theorem-1 region cost law applies unchanged;
    :meth:`what_if_sweep` hands the live topology to
    :func:`repro.core.engine.run_region_sweep` for on-device what-if grids.
    """

    #: routing rules the live host loop supports — the deterministic
    #: subset of :func:`repro.core.regions.choose_region` (randomized
    #: rules stay on the traced path; ``what_if_sweep`` accepts them all
    #: via ``choice=``)
    HOST_ROUTES = ("home", "cheapest", "fastest", "least_loaded")

    def __init__(self, *, topology: RegionTopology,
                 controller: OnlineAdmissionController,
                 k_cost: float = 10.0, route: str = "cheapest",
                 checkpoint_hours: float = 0.0,
                 tracer: Optional[TraceRecorder] = None,
                 retry: Optional[ExponentialBackoff] = None, seed: int = 0):
        if route not in self.HOST_ROUTES:
            raise ValueError(
                f"unknown host routing rule {route!r}; the live loop "
                f"supports {self.HOST_ROUTES} (randomized rules run "
                f"on-device — pass them to what_if_sweep(choice=...))")
        self.topology = topology
        self.ctl = controller
        self.k = k_cost
        self.route = route
        self.checkpoint_hours = checkpoint_hours
        self.tracer = tracer
        self.retry = retry
        self.rng = np.random.default_rng(seed)
        self.queues: list[deque[Job]] = [deque()
                                         for _ in topology.regions]
        self.stats = RegionClusterStats(
            region_served=[0] * topology.n_regions,
            region_routed=[0] * topology.n_regions)
        self.region_alive = [True] * topology.n_regions
        self._t = 0.0
        self._job_counter = 0

    # --------------------------------------------------------------- health
    def kill_region(self, region: int, *, drain: bool = False) -> None:
        """Mark a region dark (blackout): its slots stop serving and new
        admissions route around it (:func:`repro.core.regions.host_route`
        with the alive mask).  Queued jobs wait for :meth:`revive_region`
        (paused instances — the engine's blackout semantics); with
        ``drain=True`` they defect to on-demand immediately instead.
        """
        self.region_alive[region] = False
        if drain:
            queue = self.queues[region]
            while queue:
                job = queue.popleft()
                self.stats.degraded_jobs += 1
                self._run_ondemand(job,
                                   extra_delay=self._t - job.arrival_time)

    def revive_region(self, region: int) -> None:
        self.region_alive[region] = True

    # --------------------------------------------------------------- events
    def _sample(self, proc: ArrivalProcess) -> float:
        return _sample_interarrival(proc, self.rng)

    def qlen_region(self) -> list[int]:
        return [len(q) for q in self.queues]

    def run(self, n_events: int) -> RegionClusterStats:
        """Run the merged per-region clock loop (tie order: slot > preempt
        > job, regions tie by position — ties are measure-zero for
        continuous samplers)."""
        regions = self.topology.regions
        hazards = self.topology.hazards()
        next_job = [self._sample(r.job) for r in regions]
        next_slot = [self._sample(r.spot) for r in regions]
        # ONE superposed preemption clock across regions (shared law; see
        # _sample_superposed_preempt)
        next_pre, r_pre = _sample_superposed_preempt(hazards, self.rng)
        for _ in range(n_events):
            r_job = int(np.argmin(next_job))
            r_slot = int(np.argmin(next_slot))
            dt = min(next_job[r_job], next_slot[r_slot], next_pre)
            self._t += dt
            for r in range(len(regions)):
                next_job[r] -= dt
                next_slot[r] -= dt
            if math.isfinite(next_pre):
                next_pre -= dt
            if next_slot[r_slot] <= 0.0:
                next_slot[r_slot] = self._sample(regions[r_slot].spot)
                self._spot_arrival(r_slot)
            elif next_pre <= 0.0:
                fired = r_pre
                next_pre, r_pre = _sample_superposed_preempt(hazards,
                                                             self.rng)
                self._preempt_event(fired)
            else:
                next_job[r_job] = self._sample(regions[r_job].job)
                self._job_arrival(r_job)
        return self.stats

    def _job_arrival(self, home: int) -> None:
        self._job_counter += 1
        if all(self.region_alive):  # healthy path: historical call shape
            target = self.ctl.choose_region(self.topology,
                                            self.qlen_region(), home=home,
                                            rule=self.route)
        else:
            try:
                target = self.ctl.choose_region(
                    self.topology, self.qlen_region(), home=home,
                    rule=self.route, alive=self.region_alive)
            except RuntimeError:  # every region dark: degrade to on-demand
                self.stats.degraded_jobs += 1
                self._run_ondemand(Job(self._job_counter, self._t,
                                       work_steps=1, pool=home))
                return
        job = Job(self._job_counter, self._t, work_steps=1, pool=target)
        region = self.topology.regions[target]
        qlen_t = len(self.queues[target])
        if (qlen_t < region.rmax
                and self.ctl.admit(qlen_t, self.rng)):
            self.queues[target].append(job)
            self.stats.region_routed[target] += 1
            if target != home:
                self.stats.cross_region += 1
        else:
            self._run_ondemand(job)
        if self.tracer is not None:
            self.tracer.record(self._t, "job", loc=target,
                               qlen=sum(self.qlen_region()))

    def _spot_arrival(self, region_idx: int) -> None:
        if not self.region_alive[region_idx]:
            return  # dark region: the slot never materializes
        queue = self.queues[region_idx]
        if self.tracer is not None:
            self.tracer.record(
                self._t, "spot", loc=region_idx,
                qlen=sum(self.qlen_region()) - (1 if queue else 0),
                **({"wait": self._t - queue[0].arrival_time}
                   if queue else {}))
        if not queue:
            return
        job = queue.popleft()  # FIFO within the region partition
        region = self.topology.regions[region_idx]
        delay = self._t - job.arrival_time
        self.stats.jobs_completed += 1
        self.stats.spot_served += 1
        self.stats.region_served[region_idx] += 1
        self.stats.total_cost += region.price
        self.stats.spot_cost += region.price
        self.stats.total_delay += delay
        self.ctl.on_job_complete(delay)

    def _preempt_event(self, region_idx: int) -> None:
        """Hazard-clock revocation, the PR-2 recovery model per region."""
        queue = self.queues[region_idx]
        if self.tracer is not None:
            self.tracer.record(self._t, "preempt", loc=region_idx,
                               qlen=sum(self.qlen_region())
                               - (1 if queue else 0))
        if not queue:
            return  # the revoked instance was idle
        job = queue.popleft()
        region = self.topology.regions[region_idx]
        delay = self._t - job.arrival_time
        self.stats.preemptions += 1
        self.stats.total_cost += region.price
        self.stats.spot_cost += region.price
        within = checkpoint_within_notice(self.checkpoint_hours,
                                          region.notice)
        if within:
            self.stats.checkpoints += 1
        admitted = within and self.ctl.admit(len(queue), self.rng)
        extra = 0.0
        if within and not admitted and self.retry is not None:
            admitted, extra = _retry_admit(self.ctl, self.rng, self.retry,
                                           len(queue), self.stats)
        if admitted:
            self.stats.restores += 1
            queue.append(dataclasses.replace(job, arrival_time=self._t))
            self.stats.total_delay += delay + extra
            self.stats.jobs_completed += 1  # leg accounting
            self.ctl.on_job_complete(delay + extra)
        else:
            self._run_ondemand(job, extra_delay=delay + extra)

    def _run_ondemand(self, job: Job, extra_delay: float = 0.0) -> None:
        del job
        self.stats.jobs_completed += 1
        self.stats.ondemand_served += 1
        self.stats.total_cost += self.k
        self.stats.total_delay += extra_delay
        self.ctl.on_job_complete(extra_delay)

    # ---------------------------------------------------- on-device what-if
    def what_if_sweep(self, rs, *, n_events: int = 20_000, n_seeds: int = 2,
                      k=None, key=None, choice: str | None = None,
                      telemetry=None, shard: str = "none", mesh=None) -> dict:
        """Sweep admission knobs against THIS cluster's topology, on-device.

        Runs :func:`repro.core.engine.run_region_sweep` with the cluster's
        topology, routing rule, and recovery parameters — one compiled
        program for the whole what-if grid, not a host loop.  ``telemetry=``
        forwards a :class:`repro.obs.Telemetry` so the grid also reports
        P50/P99 waits and per-region counters.  ``shard="lanes"`` (with an
        optional ``mesh=``) partitions the what-if grid's lane axis across
        local devices — same contract as the engine entry points.
        """
        import jax
        import jax.numpy as jnp

        from repro.core.engine import run_region_sweep
        from repro.core.regions import RoutingKernel

        if key is None:
            key = jax.random.key(int(self.rng.integers(2**31)))
        kern = RoutingKernel(
            NoticeAwareKernel(checkpoint_time=self.checkpoint_hours),
            choice=self.route if choice is None else choice)
        with annotate("repro.cluster.what_if_sweep[region]"):
            return run_region_sweep(
                self.topology, kern, {"r": jnp.asarray(rs, jnp.float32)},
                k=self.k if k is None else k, n_events=n_events, key=key,
                n_seeds=n_seeds, telemetry=telemetry, shard=shard, mesh=mesh,
            )
