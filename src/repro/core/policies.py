"""Scheduling policies from the paper, as composable descriptors.

The central object is the Theorem-4 **three-phase policy** parameterized by a
single continuous knob ``r = N̂ + q`` (eq. 12):

  * queue length  < N̂ : admit, wait indefinitely (X = ∞)   [phase 1]
  * queue length == N̂ : admit with probability q = r − N̂    [phase 2]
  * queue length  > N̂ : dispatch straight to on-demand      [phase 3]

``SingleSlotPolicy`` is the strong-delay-regime specialization (Theorems 2/3):
queue capped at one with an explicit maximal-wait distribution.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.waittime import WaitTime, InfiniteWait


@dataclasses.dataclass(frozen=True)
class ThreePhasePolicy:
    """Theorem-4 greedy policy with fractional admission knob ``r``."""

    r: float

    @property
    def n_hat(self) -> int:
        return int(math.floor(self.r))

    @property
    def q(self) -> float:
        return self.r - math.floor(self.r)

    def admit_prob(self, qlen: int) -> float:
        if qlen < self.n_hat:
            return 1.0
        if qlen == self.n_hat:
            return self.q
        return 0.0

    def admit_prob_traced(self, qlen: jax.Array, r: jax.Array) -> jax.Array:
        n_hat = jnp.floor(r)
        qf = qlen.astype(jnp.float32)
        return jnp.where(qf < n_hat, 1.0, jnp.where(qf == n_hat, r - n_hat, 0.0))


@dataclasses.dataclass(frozen=True)
class SingleSlotPolicy:
    """Queue-length ≤ 1 with maximal wait-time distribution (Theorems 2/3)."""

    wait: WaitTime = InfiniteWait()

    def admit_prob(self, qlen: int) -> float:
        return 1.0 if qlen == 0 else 0.0


def phase_boundaries(r: float) -> tuple[int, float]:
    """(N̂, q) decomposition of the fractional queue cap."""
    n_hat = int(math.floor(r))
    return n_hat, r - n_hat
