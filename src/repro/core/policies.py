"""Scheduling policies from the paper: one admission law, two engine kernels.

The central object is the Theorem-4 **three-phase policy** parameterized by a
single continuous knob ``r = N̂ + q`` (eq. 12):

  * queue length  < N̂ : admit, wait indefinitely (X = ∞)   [phase 1]
  * queue length == N̂ : admit with probability q = r − N̂    [phase 2]
  * queue length  > N̂ : dispatch straight to on-demand      [phase 3]

:func:`three_phase_admit_prob` is the single source of that admission math —
shared by the traced engine kernel, the host-side policy descriptor, and the
cluster orchestrator (the seed carried three copies).

The engine kernels (see docs/kernels.md for the full protocol reference —
``admit`` / ``admit_market`` / ``on_preempt`` / ``route``, the event-tie
order, and a worked custom-kernel example):

  * :class:`ThreePhaseKernel` — Theorem 4; params ``{"r": f32}``; admitted
    jobs wait indefinitely.
  * :class:`SingleSlotKernel` — Theorems 2/3; queue capped at one, each
    admitted job stamped with a sampled maximal wait X (budget) and defecting
    to on-demand when it expires.  Wait-time parameters may be traced via
    ``params["wait"]`` (see :meth:`repro.core.waittime.WaitTime.params`) so a
    wait-time family can be swept inside one compiled program.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.waittime import WaitTime, InfiniteWait

_INF = np.float32(3e38)  # np scalar: inlines as a literal in kernel traces


def deadline_slack(deadline, life, remaining_work, od_time, buffer=0.0):
    """Slack before a job can no longer finish on time, even on demand.

    ``deadline − life − remaining_work·od_time − buffer``: how much longer
    the job may sit on spot before migrating to on-demand (which serves a
    unit of work every ``od_time``) would still meet the deadline.  The
    can't-be-late law (:class:`repro.core.work.CantBeLateKernel`): defect
    the moment slack hits zero; a job admitted with positive slack then
    never misses.  One arithmetic expression serving both backends — host
    numpy scalars (the cluster orchestrator) and traced jnp arrays (the
    engine's safety-net watchdog) — like :func:`three_phase_admit_prob`.
    """
    return deadline - life - remaining_work * od_time - buffer


def three_phase_admit_prob(qlen, r):
    """P(admit | queue length) under the Theorem-4 three-phase law.

    The one admission formula in the codebase.  Two numeric backends: host
    scalars take a pure-Python path (the cluster orchestrator calls this
    once per live event; an un-jitted jnp round-trip costs ~1 ms per call),
    traced JAX inputs take the jnp path the engine kernel scans over.
    """
    if not (isinstance(qlen, jax.Array) or isinstance(r, jax.Array)):
        n_hat = math.floor(r)
        if qlen < n_hat:
            return 1.0
        return r - n_hat if qlen == n_hat else 0.0
    n_hat = jnp.floor(r)
    frac = r - n_hat
    qf = jnp.asarray(qlen, jnp.float32)
    return jnp.where(qf < n_hat, 1.0, jnp.where(qf == n_hat, frac, 0.0))


@dataclasses.dataclass(frozen=True)
class ThreePhaseKernel:
    """Theorem-4 engine kernel; params ``{"r": f32}``.

    Slab-aware (``rng="slab"``): ``admit_u`` owns one uniform column —
    the Bernoulli admission draw (docs/kernels.md, "Randomness protocol").
    """

    def init_params(self, r: float) -> dict:
        return {"r": jnp.float32(r)}

    def admit(self, params, qlen, key):
        p = three_phase_admit_prob(qlen, params["r"])
        return jax.random.uniform(key) < p, _INF

    def slab_cols(self, hook, n):
        del n
        return 1 if hook == "admit" else None

    def admit_u(self, params, qlen, u):
        p = three_phase_admit_prob(qlen, params["r"])
        return u[0] < p, _INF


@dataclasses.dataclass(frozen=True)
class SingleSlotKernel:
    """Theorems-2/3 engine kernel: queue ≤ 1 with maximal wait X.

    A job joins only if the queue is empty and its sampled wait budget is
    positive (X = 0 means "go on-demand immediately", as in Corollary 1's
    two-point optimum); otherwise it dispatches to on-demand at once.
    """

    wait: WaitTime = InfiniteWait()

    def init_params(self, traced_wait: bool = False) -> dict:
        return {"wait": self.wait.params()} if traced_wait else {}

    def admit(self, params, qlen, key):
        wp = params.get("wait") if isinstance(params, dict) else None
        x = (self.wait.sample_from(wp, key) if wp else self.wait.sample(key))
        return (qlen == 0) & (x > 0.0), x

    def slab_cols(self, hook, n):
        del n
        # admission itself is deterministic given X; the wait-time family
        # owns the columns (0 for Infinite/Deterministic waits)
        return self.wait.u_dim if hook == "admit" else None

    def admit_u(self, params, qlen, u):
        wp = params.get("wait") if isinstance(params, dict) else None
        x = self.wait.sample_from_u(wp if wp else self.wait.params(), u)
        return (qlen == 0) & (x > 0.0), x


@dataclasses.dataclass(frozen=True)
class ThreePhasePolicy:
    """Host-side descriptor of the Theorem-4 policy at fixed ``r``."""

    r: float

    @property
    def n_hat(self) -> int:
        return int(math.floor(self.r))

    @property
    def q(self) -> float:
        return self.r - math.floor(self.r)

    def admit_prob(self, qlen: int) -> float:
        return three_phase_admit_prob(qlen, self.r)

    def kernel(self) -> ThreePhaseKernel:
        return ThreePhaseKernel()

    def kernel_params(self) -> dict:
        return {"r": jnp.float32(self.r)}


@dataclasses.dataclass(frozen=True)
class SingleSlotPolicy:
    """Queue-length ≤ 1 with maximal wait-time distribution (Theorems 2/3)."""

    wait: WaitTime = InfiniteWait()

    def admit_prob(self, qlen: int) -> float:
        return 1.0 if qlen == 0 else 0.0

    def kernel(self) -> SingleSlotKernel:
        return SingleSlotKernel(wait=self.wait)

    def kernel_params(self) -> dict:
        return {}


def phase_boundaries(r: float) -> tuple[int, float]:
    """(N̂, q) decomposition of the fractional queue cap."""
    n_hat = int(math.floor(r))
    return n_hat, r - n_hat
