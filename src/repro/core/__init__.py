"""The paper's contribution: opportunistic spot/on-demand scheduling.

Architecture (post-engine-refactor):
  * arrival processes    — :mod:`repro.core.arrivals`
  * cost laws            — :mod:`repro.core.cost` (Theorem 1)
  * closed forms         — :mod:`repro.core.analytic` (Theorems 2, 5)
  * wait-time theory     — :mod:`repro.core.waittime` (Theorem 3, Cor. 1-4)
  * LP oracles           — :mod:`repro.core.lp`
  * policy kernels       — :mod:`repro.core.policies` (Theorem 4; the one
                           admission law shared by engine, host descriptors,
                           and the cluster orchestrator)
  * sweep engine         — :mod:`repro.core.engine` (the single
                           merged-renewal event loop; ``run_sweep`` runs a
                           whole policy grid × seed fleet as one jitted
                           program with chunked float32 windows, through
                           either the XLA scan executor or the Pallas
                           batched-event kernel — ``impl="pallas"``,
                           :mod:`repro.kernels.sweep` — bit-for-bit)
  * spot market          — :mod:`repro.core.market` (P heterogeneous pools
                           with per-pool prices and preemption-with-notice;
                           ``run_market_sweep`` batches params × k ×
                           pools-config × seeds in one jit; a degenerate
                           1-pool zero-hazard market IS the PR-1 engine,
                           bit-for-bit)
  * multi-region routing — :mod:`repro.core.regions` (N region-partitioned
                           queues with per-region job/spot/preempt clocks
                           and a ``route`` hook; ``run_region_sweep``
                           batches params × k × regions-config × seeds —
                           incl. per-region demand via ``job_scales`` —
                           in one jit; a degenerate 1-region topology IS
                           the PR-3 engine, bit-for-bit)
  * seed-compat wrappers — :mod:`repro.core.simulator`
                           (``run_queue_sim`` / ``run_single_slot_sim``)
  * Algorithm 1          — :mod:`repro.core.adaptive` (single and batched
                           multi-δ learners on the market engine)

New scenarios plug in as policy kernels + arrival processes: an engine
kernel is ~10 lines (see ``ThreePhaseKernel``), and everything downstream
(sweeps, Algorithm 1, benchmarks) is generic over it.  Market-aware kernels
add a pool-choice hook (``admit_market``) and a preemption-recovery hook
(``on_preempt``); region-aware kernels add a routing hook (``route``) —
wrap any kernel in :class:`repro.core.regions.RoutingKernel` to get one.
docs/kernels.md is the full protocol reference.
"""
from repro.core.arrivals import (
    ArrivalProcess,
    BathtubGCP,
    Deterministic,
    Exponential,
    Gamma,
    Uniform,
    prob_A_le_S,
)
from repro.core.adaptive import (
    adaptive_admission_control,
    adaptive_admission_control_batched,
)
from repro.core.analytic import (
    mm1n_pi,
    theorem2_cost,
    theorem2_delta_max,
    theorem5_cost,
    theorem5_delta,
)
from repro.core.cost import (
    all_ondemand_cost,
    cost_lower_bound,
    market_cost_lower_bound,
    pi0_from_cost,
    region_cost_lower_bound,
    theorem1_cost,
    theorem1_market_cost,
    theorem1_region_cost,
)
from repro.core.engine import (
    DEFAULT_CHUNK_EVENTS,
    EngineState,
    NonFiniteStatsError,
    Telemetry,
    MarketState,
    MarketWindowStats,
    PolicyKernel,
    RegionState,
    RegionWindowStats,
    WindowStats,
    run_market_sim,
    run_market_sweep,
    run_region_sim,
    run_region_sweep,
    run_sim,
    run_sweep,
    summarize,
    summarize_market,
    summarize_region,
)
from repro.core.env import (
    EnvTimeline,
    Regime,
    inject_blackout,
    inject_price_spike,
    inject_storm,
    markov_timeline,
    timeline_from_trace,
)
from repro.core.lp import (
    knapsack_lp,
    market_knapsack_lp,
    region_knapsack_lp,
    waittime_lp,
)
from repro.core.regions import (
    Region,
    RegionTopology,
    RegionView,
    RoutingKernel,
    as_topology,
    choose_region,
    host_route,
)
from repro.core.market import (
    MarketPolicyKernel,
    NoticeAwareKernel,
    PanicKernel,
    PoolChoiceKernel,
    PoolState,
    SpotMarket,
    SpotPool,
    as_market,
    checkpoint_within_notice,
    choose_pool,
)
from repro.core.policies import (
    SingleSlotKernel,
    SingleSlotPolicy,
    ThreePhaseKernel,
    ThreePhasePolicy,
    three_phase_admit_prob,
)
from repro.core.policies import deadline_slack
from repro.core.simulator import run_queue_sim, run_single_slot_sim
from repro.core.work import (
    CantBeLateKernel,
    WorkModel,
    WorkState,
    init_work_state,
    restart_overhead_from_timing,
)
from repro.core.waittime import (
    DeterministicWait,
    ExponentialWait,
    InfiniteWait,
    TwoPointWait,
    laplace_target,
    optimal_deterministic,
    optimal_exp_rate,
    optimal_two_point,
)

__all__ = [
    "ArrivalProcess", "BathtubGCP", "Deterministic", "Exponential", "Gamma",
    "Uniform", "prob_A_le_S", "adaptive_admission_control",
    "adaptive_admission_control_batched", "mm1n_pi", "theorem2_cost",
    "theorem2_delta_max", "theorem5_cost", "theorem5_delta",
    "cost_lower_bound", "market_cost_lower_bound", "pi0_from_cost",
    "region_cost_lower_bound", "theorem1_cost", "theorem1_market_cost",
    "theorem1_region_cost", "all_ondemand_cost", "DEFAULT_CHUNK_EVENTS",
    "EngineState", "EnvTimeline", "MarketState", "NonFiniteStatsError",
    "Regime", "Telemetry",
    "MarketWindowStats", "PolicyKernel", "RegionState", "RegionWindowStats",
    "WindowStats", "inject_blackout", "inject_price_spike", "inject_storm",
    "markov_timeline", "timeline_from_trace", "run_market_sim",
    "run_market_sweep", "run_region_sim", "run_region_sweep", "run_sim",
    "run_sweep", "summarize",
    "summarize_market", "summarize_region", "knapsack_lp",
    "market_knapsack_lp", "region_knapsack_lp", "waittime_lp",
    "MarketPolicyKernel", "NoticeAwareKernel", "PanicKernel",
    "PoolChoiceKernel",
    "PoolState", "SpotMarket", "SpotPool", "as_market",
    "checkpoint_within_notice", "choose_pool", "Region", "RegionTopology",
    "RegionView", "RoutingKernel", "as_topology", "choose_region",
    "host_route", "SingleSlotKernel",
    "SingleSlotPolicy", "ThreePhaseKernel", "ThreePhasePolicy",
    "three_phase_admit_prob", "deadline_slack", "run_queue_sim",
    "run_single_slot_sim", "CantBeLateKernel", "WorkModel", "WorkState",
    "init_work_state", "restart_overhead_from_timing",
    "DeterministicWait", "ExponentialWait", "InfiniteWait", "TwoPointWait",
    "laplace_target", "optimal_deterministic", "optimal_exp_rate",
    "optimal_two_point",
]
