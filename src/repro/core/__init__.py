"""The paper's contribution: opportunistic spot/on-demand scheduling.

Architecture (post-engine-refactor):
  * arrival processes    — :mod:`repro.core.arrivals`
  * cost laws            — :mod:`repro.core.cost` (Theorem 1)
  * closed forms         — :mod:`repro.core.analytic` (Theorems 2, 5)
  * wait-time theory     — :mod:`repro.core.waittime` (Theorem 3, Cor. 1-4)
  * LP oracles           — :mod:`repro.core.lp`
  * policy kernels       — :mod:`repro.core.policies` (Theorem 4; the one
                           admission law shared by engine, host descriptors,
                           and the cluster orchestrator)
  * sweep engine         — :mod:`repro.core.engine` (the single
                           merged-renewal event loop; ``run_sweep`` runs a
                           whole policy grid × seed fleet as one jitted
                           program with chunked float32 windows)
  * seed-compat wrappers — :mod:`repro.core.simulator`
                           (``run_queue_sim`` / ``run_single_slot_sim``)
  * Algorithm 1          — :mod:`repro.core.adaptive` (single and batched
                           multi-δ learners on the engine)

New scenarios plug in as policy kernels + arrival processes: an engine
kernel is ~10 lines (see ``ThreePhaseKernel``), and everything downstream
(sweeps, Algorithm 1, benchmarks) is generic over it.
"""
from repro.core.arrivals import (
    ArrivalProcess,
    BathtubGCP,
    Deterministic,
    Exponential,
    Gamma,
    Uniform,
    prob_A_le_S,
)
from repro.core.adaptive import (
    adaptive_admission_control,
    adaptive_admission_control_batched,
)
from repro.core.analytic import (
    mm1n_pi,
    theorem2_cost,
    theorem2_delta_max,
    theorem5_cost,
    theorem5_delta,
)
from repro.core.cost import cost_lower_bound, pi0_from_cost, theorem1_cost
from repro.core.engine import (
    EngineState,
    PolicyKernel,
    WindowStats,
    run_sim,
    run_sweep,
    summarize,
)
from repro.core.policies import (
    SingleSlotKernel,
    SingleSlotPolicy,
    ThreePhaseKernel,
    ThreePhasePolicy,
    three_phase_admit_prob,
)
from repro.core.simulator import run_queue_sim, run_single_slot_sim
from repro.core.waittime import (
    DeterministicWait,
    ExponentialWait,
    InfiniteWait,
    TwoPointWait,
    laplace_target,
    optimal_deterministic,
    optimal_exp_rate,
    optimal_two_point,
)

__all__ = [
    "ArrivalProcess", "BathtubGCP", "Deterministic", "Exponential", "Gamma",
    "Uniform", "prob_A_le_S", "adaptive_admission_control",
    "adaptive_admission_control_batched", "mm1n_pi", "theorem2_cost",
    "theorem2_delta_max", "theorem5_cost", "theorem5_delta",
    "cost_lower_bound", "pi0_from_cost", "theorem1_cost", "EngineState",
    "PolicyKernel", "WindowStats", "run_sim", "run_sweep", "summarize",
    "SingleSlotKernel", "SingleSlotPolicy", "ThreePhaseKernel",
    "ThreePhasePolicy", "three_phase_admit_prob", "run_queue_sim",
    "run_single_slot_sim", "DeterministicWait", "ExponentialWait",
    "InfiniteWait", "TwoPointWait", "laplace_target",
    "optimal_deterministic", "optimal_exp_rate", "optimal_two_point",
]
