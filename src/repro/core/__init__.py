"""The paper's contribution: opportunistic spot/on-demand scheduling.

Public API:
  * arrival processes    — :mod:`repro.core.arrivals`
  * cost laws            — :mod:`repro.core.cost` (Theorem 1)
  * closed forms         — :mod:`repro.core.analytic` (Theorems 2, 5)
  * wait-time theory     — :mod:`repro.core.waittime` (Theorem 3, Cor. 1-4)
  * LP oracles           — :mod:`repro.core.lp`
  * policies             — :mod:`repro.core.policies` (Theorem 4)
  * simulators           — :mod:`repro.core.simulator`
  * Algorithm 1          — :mod:`repro.core.adaptive`
"""
from repro.core.arrivals import (
    ArrivalProcess,
    BathtubGCP,
    Deterministic,
    Exponential,
    Gamma,
    Uniform,
    prob_A_le_S,
)
from repro.core.adaptive import adaptive_admission_control
from repro.core.analytic import (
    mm1n_pi,
    theorem2_cost,
    theorem2_delta_max,
    theorem5_cost,
    theorem5_delta,
)
from repro.core.cost import cost_lower_bound, pi0_from_cost, theorem1_cost
from repro.core.policies import SingleSlotPolicy, ThreePhasePolicy
from repro.core.simulator import run_queue_sim, run_single_slot_sim
from repro.core.waittime import (
    DeterministicWait,
    ExponentialWait,
    InfiniteWait,
    TwoPointWait,
    laplace_target,
    optimal_deterministic,
    optimal_exp_rate,
    optimal_two_point,
)

__all__ = [
    "ArrivalProcess", "BathtubGCP", "Deterministic", "Exponential", "Gamma",
    "Uniform", "prob_A_le_S", "adaptive_admission_control", "mm1n_pi",
    "theorem2_cost", "theorem2_delta_max", "theorem5_cost", "theorem5_delta",
    "cost_lower_bound", "pi0_from_cost", "theorem1_cost", "SingleSlotPolicy",
    "ThreePhasePolicy", "run_queue_sim", "run_single_slot_sim",
    "DeterministicWait", "ExponentialWait", "InfiniteWait", "TwoPointWait",
    "laplace_target", "optimal_deterministic", "optimal_exp_rate",
    "optimal_two_point",
]
