"""Maximal wait-time distributions and their paper-optimal constructors.

Theorem 3 reduces the choice of the single-slot policy to the choice of the
*maximal wait time* distribution f_X.  The corollaries give closed forms:

  * Corollary 1 (finite-support spot, S ∈ [0, L]): optimal X puts mass only
    at {0} and [L, ∞) with P(X ≥ L) = μδ/(1 − λδ)  →  :func:`optimal_two_point`.
  * Corollary 3 (exponential spot): any f_X with Laplace transform
    L{f_X}(μ) = (1 − (λ+μ)δ)/(1 − λδ) is optimal → :func:`laplace_target`.
  * Remark 2: within the exponential family X ~ Exp(φ), φ = 1/δ − (μ + λ)
    →  :func:`optimal_exp_rate`.
  * Corollary 4 (min-max wait): the unique deterministic optimum
    X = (1/μ)·log[(1−λδ)/(1−(λ+μ)δ)]  →  :func:`optimal_deterministic`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.clocks import exp_from_u as _exp_from_u

_INF = 3e38


@dataclasses.dataclass(frozen=True)
class WaitTime:
    """Static descriptor of the maximal-wait distribution X (traceable).

    Two sampling entry points: :meth:`sample` bakes this instance's fields in
    as trace-time constants, while :meth:`sample_from` reads them from a
    traced ``params`` dict (as produced by :meth:`params`) so a wait-time
    *family* can be swept/vmapped inside one compiled program — the
    distribution's shape is static, only its parameters are traced.

    For the engine's ``rng="slab"`` stream, :meth:`sample_from_u` transforms
    ``u_dim`` pre-drawn float32 uniforms instead of consuming a key (equal
    in distribution, not bitwise — see :mod:`repro.core.clocks`).
    """

    #: uniform draws :meth:`sample_from_u` consumes (slab stream)
    u_dim: ClassVar[int] = 0

    def params(self) -> dict:
        """Traced-parameter pytree for :meth:`sample_from`."""
        return {}

    def sample_from(self, params: dict, key: jax.Array) -> jax.Array:
        """Draw X with this family's shape but parameters from ``params``."""
        raise NotImplementedError

    def sample_from_u(self, params: dict, u: jax.Array) -> jax.Array:
        """Slab-stream draw from ``u[:u_dim]`` float32 uniforms."""
        raise NotImplementedError

    def sample(self, key: jax.Array) -> jax.Array:
        return self.sample_from(self.params(), key)

    def mean(self) -> float:
        raise NotImplementedError

    def laplace(self, s: float) -> float:
        """E[e^{-sX}] where defined (used to check Corollary 3)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class InfiniteWait(WaitTime):
    """X = ∞ — wait indefinitely for a spot slot (Theorem 4 phases 1-2)."""

    def sample_from(self, params, key):
        del params, key
        return jnp.asarray(_INF, jnp.float32)

    def sample_from_u(self, params, u):
        del params, u
        return jnp.asarray(_INF, jnp.float32)

    def mean(self):
        return math.inf

    def laplace(self, s):
        return 0.0


@dataclasses.dataclass(frozen=True)
class TwoPointWait(WaitTime):
    """X = ``value`` w.p. ``p`` else 0 (Corollary 1 / Remark 1)."""

    p: float
    value: float

    u_dim: ClassVar[int] = 1

    def params(self):
        return {"p": jnp.float32(self.p), "value": jnp.float32(self.value)}

    def sample_from(self, params, key):
        take = jax.random.uniform(key) < params["p"]
        return jnp.where(take, params["value"], jnp.float32(0.0))

    def sample_from_u(self, params, u):
        return jnp.where(u[0] < params["p"], params["value"],
                         jnp.float32(0.0))

    def mean(self):
        return self.p * self.value

    def laplace(self, s):
        return (1.0 - self.p) + self.p * math.exp(-s * self.value)


@dataclasses.dataclass(frozen=True)
class ExponentialWait(WaitTime):
    rate_: float

    u_dim: ClassVar[int] = 1

    def params(self):
        return {"rate": jnp.float32(self.rate_)}

    def sample_from(self, params, key):
        return jax.random.exponential(key, dtype=jnp.float32) / params["rate"]

    def sample_from_u(self, params, u):
        return _exp_from_u(u[0]) / params["rate"]

    def mean(self):
        return 1.0 / self.rate_

    def laplace(self, s):
        return self.rate_ / (self.rate_ + s)


@dataclasses.dataclass(frozen=True)
class DeterministicWait(WaitTime):
    value: float

    def params(self):
        return {"value": jnp.float32(self.value)}

    def sample_from(self, params, key):
        del key
        return params["value"]

    def sample_from_u(self, params, u):
        del u
        return params["value"]

    def mean(self):
        return self.value

    def laplace(self, s):
        return math.exp(-s * self.value)


# ---------------------------------------------------------------------------
# Paper-optimal constructors
# ---------------------------------------------------------------------------


def strong_delay_bound(p_A_le_S: float, lam: float) -> float:
    """Theorem 2's regime boundary: δ ≤ P(A ≤ S_μ)/λ."""
    return p_A_le_S / lam


def optimal_two_point(lam: float, mu: float, delta: float, L: float) -> TwoPointWait:
    """Corollary 1 + Remark 1: mass p at L (min-max choice), 1-p at 0."""
    p = mu * delta / (1.0 - lam * delta)
    if not 0.0 <= p <= 1.0:
        raise ValueError(
            f"infeasible two-point mass p={p:.4f} (λ={lam}, μ={mu}, δ={delta})"
        )
    return TwoPointWait(p=p, value=L)


def laplace_target(lam: float, mu: float, delta: float) -> float:
    """Corollary 3: required L{f_X}(μ) for optimality under Exp(μ) spot."""
    return (1.0 - (lam + mu) * delta) / (1.0 - lam * delta)


def optimal_exp_rate(lam: float, mu: float, delta: float) -> ExponentialWait:
    """Remark 2: X ~ Exp(φ) with φ = 1/δ − (μ + λ)."""
    phi = 1.0 / delta - (mu + lam)
    if phi <= 0:
        raise ValueError(f"δ={delta} too large for exponential wait (φ={phi:.4f})")
    return ExponentialWait(rate_=phi)


def optimal_deterministic(lam: float, mu: float, delta: float) -> DeterministicWait:
    """Corollary 4: unique min-max-wait optimum (deterministic)."""
    num = 1.0 - lam * delta
    den = 1.0 - (lam + mu) * delta
    if den <= 0:
        raise ValueError(f"δ={delta} outside the strong-delay regime")
    return DeterministicWait(value=math.log(num / den) / mu)
