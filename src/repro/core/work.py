"""Work-structured jobs: checkpoint-priced recovery and can't-be-late
safety nets.

The base engine treats a job as an atomic unit: one spot slot serves it,
one preemption resumes it for free.  The ``work=`` axis replaces that
resume bit with a traced per-job *work structure*: every job carries
``total_work`` units to serve, a preemption without a checkpoint rolls it
back to its last checkpointed progress, and every resume pays
``restart_overhead`` units before real progress restarts — the
checkpoint-within-notice law (:func:`repro.core.market.
checkpoint_within_notice`) now costs real simulated work.

Two pieces live here:

- :class:`WorkModel` — the static descriptor (hashable, rides in jit
  ``static_argnames`` like the kernel), whose :meth:`WorkModel.params`
  emits the traced float32 parameter dict the event bodies consume (the
  ``mp``/``rp``/``ep`` idiom).  Its constructors are the checkpoint-kernel
  family: :meth:`WorkModel.never` (roll back to zero),
  :meth:`WorkModel.on_notice` (checkpoint saves iff it fits the
  preemption notice window), :meth:`WorkModel.periodic`
  (checkpoint every ``period`` units of progress, each costing
  ``cost`` extra units of work).
- :class:`CantBeLateKernel` — a safety-net wrapper over any policy
  kernel: the engine tracks per-job slack
  ``deadline − life − remaining_work·od_time − slack_buffer``
  (:func:`repro.core.policies.deadline_slack`) and force-migrates a job
  to on-demand the moment its slack would go critical, so a job admitted
  with positive slack *cannot* miss its deadline — the panic-mode
  guarantee of the ``cant_be_late`` problem family.

The zero-cost contract is two-sided: ``work=None`` lowers byte-identical
HLO (no work ops are ever traced), and the identity model
``WorkModel()`` (one unit of work, zero overhead, never checkpoint, no
deadline) reproduces the base engine's statistics bit-for-bit on every
loop × executor × rng cell (frozen in ``tests/test_work.py``).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, NamedTuple

import jax.numpy as jnp
import numpy as np

_INF = np.float32(3e38)

_CKPT_MODES = ("never", "notice", "periodic")


class WorkState(NamedTuple):
    """Traced per-slot work structure (float32, one row per engine slot).

    ``prog`` is progress toward ``total_work``; ``oh`` is the outstanding
    restart-overhead debt served before progress resumes; ``ckpt`` is the
    progress saved at the last checkpoint (rollback target); ``life`` is
    the age since admission — never reset on resume, so deadline
    accounting spans preemptions.
    """

    prog: jnp.ndarray
    oh: jnp.ndarray
    ckpt: jnp.ndarray
    life: jnp.ndarray


def init_work_state(n_slots: int, lanes: int | None = None) -> WorkState:
    """Zero work structure for ``n_slots`` slots (optionally per-lane)."""
    shape = (n_slots,) if lanes is None else (lanes, n_slots)
    z = jnp.zeros(shape, jnp.float32)
    return WorkState(prog=z, oh=z, ckpt=z, life=z)


@dataclasses.dataclass(frozen=True)
class WorkModel:
    """Static work-structure descriptor (the checkpoint-kernel family).

    ``total_work`` is in service units (one spot service serves one unit);
    ``restart_overhead`` is the extra units a resumed job re-serves before
    making progress.  ``ckpt`` selects the checkpoint discipline
    statically: ``"never"`` rolls back to the last saved point (zero
    unless periodic), ``"notice"`` saves current progress at preemption
    iff ``ckpt_time`` fits the firing pool's notice window, ``"periodic"``
    saves every ``ckpt_period`` units of progress at ``ckpt_cost`` extra
    units each.  ``deadline`` (in time units since admission) and
    ``od_time`` (time per unit of work on demand) feed the survival
    ledger's hard deadline-miss accounting and the
    :class:`CantBeLateKernel` slack law.  The default is the *identity
    model*: bit-for-bit today's engine.
    """

    total_work: float = 1.0
    restart_overhead: float = 0.0
    ckpt: str = "never"
    ckpt_time: float = 0.0
    ckpt_period: float = 0.0
    ckpt_cost: float = 0.0
    deadline: float = float(_INF)
    od_time: float = 0.0

    def __post_init__(self):
        if self.ckpt not in _CKPT_MODES:
            raise ValueError(
                f"ckpt must be one of {_CKPT_MODES}, got {self.ckpt!r}")
        if self.total_work <= 0:
            raise ValueError("total_work must be positive")
        if self.ckpt == "periodic" and self.ckpt_period <= 0:
            raise ValueError("periodic checkpointing needs ckpt_period > 0")

    def params(self) -> dict:
        """Traced float32 parameter dict consumed by the event bodies."""
        return {
            "total_work": jnp.float32(self.total_work),
            "restart_overhead": jnp.float32(self.restart_overhead),
            "ckpt_time": jnp.float32(self.ckpt_time),
            "ckpt_period": jnp.float32(self.ckpt_period),
            "ckpt_cost": jnp.float32(self.ckpt_cost),
            "deadline": jnp.float32(min(float(self.deadline), float(_INF))),
            "od_time": jnp.float32(self.od_time),
        }

    # ---- the checkpoint-kernel family ----------------------------------
    @classmethod
    def never(cls, **kw) -> "WorkModel":
        """No checkpoints: every rollback loses all progress."""
        return cls(ckpt="never", **kw)

    @classmethod
    def on_notice(cls, ckpt_time: float, **kw) -> "WorkModel":
        """Checkpoint during the preemption notice window iff it fits."""
        return cls(ckpt="notice", ckpt_time=ckpt_time, **kw)

    @classmethod
    def periodic(cls, period: float, cost: float = 0.0, **kw) -> "WorkModel":
        """Checkpoint every ``period`` units of progress, at ``cost``
        extra units of work each."""
        return cls(ckpt="periodic", ckpt_period=period, ckpt_cost=cost, **kw)


def restart_overhead_from_timing(save_seconds: float, restore_seconds: float,
                                 step_seconds: float,
                                 steps_per_unit: float = 1.0) -> float:
    """Seed :attr:`WorkModel.restart_overhead` from measured wall time.

    A resume re-pays the checkpoint restore plus the blocking save that
    produced it, expressed in engine work units: one unit is
    ``steps_per_unit`` training steps of ``step_seconds`` wall time each.
    This is the bridge from :class:`repro.checkpoint.manager.
    CheckpointManager` timing (examples/elastic_spot_training.py times a
    blocking save + elastic restore around real train steps) to the
    ``work=`` axis.
    """
    if step_seconds <= 0 or steps_per_unit <= 0:
        raise ValueError("step_seconds and steps_per_unit must be positive")
    return float(save_seconds + restore_seconds) / (
        float(step_seconds) * float(steps_per_unit))


@dataclasses.dataclass(frozen=True)
class CantBeLateKernel:
    """Safety-net wrapper: force-migrate to on-demand before it's too late.

    Wraps any policy kernel (delegating every hook — ``admit``,
    ``admit_market``, ``on_preempt``, ``route``, the ``*_u`` twins,
    ``slab_cols``, ``init_params`` — to ``base``) and arms the engine's
    per-job slack watchdog: a job whose slack
    ``deadline − life − (overhead + remaining_work)·od_time −
    slack_buffer`` is about to go negative is defected to on-demand via
    the existing deadline machinery, recorded as a *panic entry* in the
    survival ledger.  A job admitted with positive slack therefore never
    misses its deadline (``work=`` must be set; the entry points reject
    the wrapper without it).  Wrap outermost — foreign ``__getattr__``
    delegation (e.g. :class:`~repro.core.market.PanicKernel`) does not
    forward the ``safety_net`` marker.
    """

    base: object
    slack_buffer: float = 0.0

    safety_net: ClassVar[bool] = True

    def __getattr__(self, name):
        if name.startswith("_") or name == "base":
            raise AttributeError(name)
        return getattr(object.__getattribute__(self, "base"), name)
