"""Seed-compatible simulator entry points, now thin wrappers over the engine.

The two event loops this module used to carry (a multi-slot queue loop and a
single-slot maximal-wait loop, near-duplicates of each other) live on as two
policy kernels plugged into :mod:`repro.core.engine`'s single merged-renewal
event loop:

  * :func:`run_queue_sim` — Theorem-4 three-phase policy at fixed ``r``
    (:class:`repro.core.policies.ThreePhaseKernel`); admitted jobs wait
    indefinitely.
  * :func:`run_single_slot_sim` — the queue-length-≤-1 system of Theorems 2/3
    (:class:`repro.core.policies.SingleSlotKernel`) where the waiting job
    defects to on-demand when its sampled maximal wait X expires.

Both reproduce the seed simulators bit-for-bit per seed (the engine uses the
same per-event PRNG split layout and float32 accumulation order; verified in
tests/test_core_engine.py against frozen copies of the seed event bodies) —
with one documented exception: event-time *ties* now resolve spot-first
(the seed's single-slot priority) where the seed queue loop resolved them
job-first.  Ties are measure-zero for every continuous inter-arrival family;
only simultaneous ``Deterministic`` job/spot processes can observe the
difference.
Compiled entry points are cached at module scope in the engine — the seed's
``burn_in`` path re-wrapped its window function in a fresh ``jax.jit`` on
every call.

For parameter grids, use :func:`repro.core.engine.run_sweep` instead of
looping over these wrappers: it runs the whole (grid × seeds) fleet as one
jitted program.
"""
from __future__ import annotations

import jax

from repro.core.arrivals import ArrivalProcess
from repro.core.engine import (  # noqa: F401  (re-exported for compat)
    DEFAULT_CHUNK_EVENTS,
    EngineState,
    WindowStats,
    run_sim,
    run_sweep,
    summarize,
)
from repro.core.policies import SingleSlotKernel, ThreePhaseKernel
from repro.core.waittime import WaitTime

_THREE_PHASE = ThreePhaseKernel()


def run_queue_sim(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    *,
    k: float = 10.0,
    r: float,
    n_events: int,
    key: jax.Array,
    rmax: int = 64,
    burn_in: int = 0,
    chunk_events: int | None = DEFAULT_CHUNK_EVENTS,
) -> dict:
    """Simulate the Theorem-4 policy at fixed ``r``; return long-run stats.

    ``chunk_events`` shares :data:`repro.core.engine.DEFAULT_CHUNK_EVENTS`
    with every engine entry point; horizons within one chunk accumulate in
    a single float32 window, which is the seed's bit-for-bit behaviour.
    """
    return run_sim(
        job, spot, _THREE_PHASE, _THREE_PHASE.init_params(r), k=k,
        n_events=n_events, key=key, rmax=rmax, burn_in=burn_in,
        chunk_events=chunk_events,
    )


def run_single_slot_sim(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    wait: WaitTime,
    *,
    k: float = 10.0,
    n_events: int,
    key: jax.Array,
    chunk_events: int | None = DEFAULT_CHUNK_EVENTS,
) -> dict:
    """Simulate the single-slot (queue ≤ 1) policy with maximal wait X."""
    return run_sim(
        job, spot, SingleSlotKernel(wait=wait), {}, k=k, n_events=n_events,
        key=key, rmax=1, chunk_events=chunk_events,
    )
