"""Event-driven G/G/1+spot queue simulators, fully jit-compiled.

Two simulators, both written as ``lax.scan`` over *merged renewal events* so
an entire multi-million-event trajectory compiles once and runs on any JAX
backend:

  * :func:`run_queue_sim` — the multi-slot queue driven by the paper's
    three-phase policy (Theorem 4) with fractional admission ``r = N̂ + q``
    (eq. 12). Jobs that join wait indefinitely (X = ∞) as Theorem 4 requires.

  * :func:`run_single_slot_sim` — the queue-length-≤-1 system of Theorems 2/3
    where the waiting job has a sampled *maximal wait time* X and defects to
    an on-demand instance when X expires.

Numerical design: instead of absolute event times (which overflow float32
precision over long horizons) each queued job carries an *age* that is
incremented by the inter-event gap ``dt``; waits therefore stay ~O(mean
inter-arrival) in magnitude.  Per-window sums stay small; long-run averages
are assembled in float64 on the host from the per-window outputs.

Cost accounting (paper §II): a spot service costs 1, an on-demand dispatch
costs k.  Delay of a job is its total time in system: 0 for an immediate
on-demand dispatch, its queue wait for a spot-served job, and its (expired)
wait for a job that defects to on-demand.

π₀ is tracked the way Theorem 1's proof uses it — the long-run fraction of
*spot arrivals* that find the queue empty — alongside the time-averaged
empty-queue fraction.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.waittime import WaitTime

_INF = jnp.float32(3e38)


class WindowStats(NamedTuple):
    """Per-window accumulators (float32 sums / int32 counts)."""

    jobs_arrived: jax.Array
    jobs_completed: jax.Array
    spot_served: jax.Array
    ondemand: jax.Array
    cost_sum: jax.Array
    delay_sum: jax.Array
    time_elapsed: jax.Array
    empty_time: jax.Array
    spot_arrivals: jax.Array
    spot_found_empty: jax.Array

    @staticmethod
    def zeros() -> "WindowStats":
        z = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        return WindowStats(zi, zi, zi, zi, z, z, z, z, zi, zi)


class QueueCarry(NamedTuple):
    key: jax.Array
    next_job: jax.Array  # time until next job arrival
    next_spot: jax.Array  # time until next spot arrival
    ages: jax.Array  # (rmax,) ages of queued jobs (ring buffer)
    head: jax.Array  # int32 ring head
    qlen: jax.Array  # int32 queue length


def _admit_prob_three_phase(qlen: jax.Array, r: jax.Array) -> jax.Array:
    """Theorem-4 three-phase admission: P(admit | queue length)."""
    n_hat = jnp.floor(r)
    frac = r - n_hat
    qf = qlen.astype(jnp.float32)
    return jnp.where(qf < n_hat, 1.0, jnp.where(qf == n_hat, frac, 0.0))


def init_queue_carry(key: jax.Array, job: ArrivalProcess, spot: ArrivalProcess,
                     rmax: int) -> QueueCarry:
    kj, ks, kc = jax.random.split(key, 3)
    return QueueCarry(
        key=kc,
        next_job=job.sample(kj),
        next_spot=spot.sample(ks),
        ages=jnp.zeros((rmax,), jnp.float32),
        head=jnp.zeros((), jnp.int32),
        qlen=jnp.zeros((), jnp.int32),
    )


def _queue_event(job: ArrivalProcess, spot: ArrivalProcess, k_cost: float,
                 rmax: int, carry: QueueCarry, stats: WindowStats,
                 r: jax.Array) -> tuple[QueueCarry, WindowStats]:
    """Process one merged event (job arrival or spot arrival)."""
    key, k_job, k_spot, k_adm = jax.random.split(carry.key, 4)
    is_job = carry.next_job <= carry.next_spot
    dt = jnp.minimum(carry.next_job, carry.next_spot)

    ages = carry.ages + dt

    # ---- job-arrival branch quantities ----
    p_admit = _admit_prob_three_phase(carry.qlen, r)
    admit = (jax.random.uniform(k_adm) < p_admit) & (carry.qlen < rmax)
    tail = (carry.head + carry.qlen) % rmax
    ages_job = jnp.where(
        admit, ages.at[tail].set(0.0), ages
    )
    qlen_job = carry.qlen + jnp.where(admit, 1, 0)
    # not admitted -> immediate on-demand dispatch (cost k, delay 0)
    od_inc = jnp.where(admit, 0, 1)

    # ---- spot-arrival branch quantities ----
    has_job = carry.qlen > 0
    wait = ages[carry.head]
    head_spot = jnp.where(has_job, (carry.head + 1) % rmax, carry.head)
    qlen_spot = carry.qlen - jnp.where(has_job, 1, 0)

    # ---- merge ----
    new_carry = QueueCarry(
        key=key,
        next_job=jnp.where(is_job, job.sample(k_job), carry.next_job - dt),
        next_spot=jnp.where(is_job, carry.next_spot - dt, spot.sample(k_spot)),
        ages=jnp.where(is_job, ages_job, ages),
        head=jnp.where(is_job, carry.head, head_spot),
        qlen=jnp.where(is_job, qlen_job, qlen_spot),
    )
    served = (~is_job) & has_job
    new_stats = WindowStats(
        jobs_arrived=stats.jobs_arrived + jnp.where(is_job, 1, 0),
        jobs_completed=stats.jobs_completed
        + jnp.where(is_job, od_inc, jnp.where(served, 1, 0)),
        spot_served=stats.spot_served + jnp.where(served, 1, 0),
        ondemand=stats.ondemand + jnp.where(is_job, od_inc, 0),
        cost_sum=stats.cost_sum
        + jnp.where(is_job, od_inc.astype(jnp.float32) * k_cost, 0.0)
        + jnp.where(served, 1.0, 0.0),
        delay_sum=stats.delay_sum + jnp.where(served, wait, 0.0),
        time_elapsed=stats.time_elapsed + dt,
        empty_time=stats.empty_time + jnp.where(carry.qlen == 0, dt, 0.0),
        spot_arrivals=stats.spot_arrivals + jnp.where(is_job, 0, 1),
        spot_found_empty=stats.spot_found_empty
        + jnp.where((~is_job) & (~has_job), 1, 0),
    )
    return new_carry, new_stats


def run_queue_window(job: ArrivalProcess, spot: ArrivalProcess, k_cost: float,
                     rmax: int, carry: QueueCarry, r: jax.Array,
                     n_events: int) -> tuple[QueueCarry, WindowStats]:
    """Run ``n_events`` merged events under fixed admission knob ``r``."""

    def body(state, _):
        c, s = state
        c, s = _queue_event(job, spot, k_cost, rmax, c, s, r)
        return (c, s), None

    (carry, stats), _ = jax.lax.scan(
        body, (carry, WindowStats.zeros()), None, length=n_events
    )
    return carry, stats


@functools.partial(
    jax.jit, static_argnames=("job", "spot", "k_cost", "rmax", "n_events")
)
def _run_queue_sim_jit(job, spot, k_cost, rmax, n_events, r, key):
    carry = init_queue_carry(key, job, spot, rmax)
    return run_queue_window(job, spot, k_cost, rmax, carry, r, n_events)


def run_queue_sim(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    *,
    k: float = 10.0,
    r: float,
    n_events: int,
    key: jax.Array,
    rmax: int = 64,
    burn_in: int = 0,
) -> dict:
    """Simulate the Theorem-4 policy at fixed ``r``; return long-run stats."""
    if burn_in:
        carry = init_queue_carry(key, job, spot, rmax)
        carry, _ = jax.jit(
            run_queue_window, static_argnames=("job", "spot", "k_cost", "rmax",
                                               "n_events"),
        )(job, spot, float(k), rmax, carry, jnp.float32(r), burn_in)
        carry, stats = jax.jit(
            run_queue_window, static_argnames=("job", "spot", "k_cost", "rmax",
                                               "n_events"),
        )(job, spot, float(k), rmax, carry, jnp.float32(r), n_events)
    else:
        _, stats = _run_queue_sim_jit(
            job, spot, float(k), rmax, n_events, jnp.float32(r), key
        )
    return _summarize(stats)


def _summarize(stats: WindowStats) -> dict:
    s = jax.tree.map(lambda x: np.asarray(x, np.float64), stats)
    completed = max(s.jobs_completed, 1.0)
    arrived = max(s.jobs_arrived, 1.0)
    return {
        "jobs_arrived": float(s.jobs_arrived),
        "jobs_completed": float(s.jobs_completed),
        "spot_served": float(s.spot_served),
        "ondemand": float(s.ondemand),
        "avg_cost": float(s.cost_sum / completed),
        "avg_delay": float(s.delay_sum / completed),
        "time": float(s.time_elapsed),
        "pi0_time": float(s.empty_time / max(s.time_elapsed, 1e-12)),
        "pi0_spot": float(
            s.spot_found_empty / max(s.spot_arrivals, 1.0)
        ),
        "spot_utilization": float(
            (s.spot_arrivals - s.spot_found_empty) / max(s.spot_arrivals, 1.0)
        ),
        "arrival_rate": float(arrived / max(s.time_elapsed, 1e-12)),
    }


# ---------------------------------------------------------------------------
# Single-slot system with maximal wait time X (Theorems 2/3, Corollaries 1-4)
# ---------------------------------------------------------------------------


class SingleSlotCarry(NamedTuple):
    key: jax.Array
    next_job: jax.Array
    next_spot: jax.Array
    occupied: jax.Array  # bool
    age: jax.Array  # current job's wait so far
    x_left: jax.Array  # remaining wait budget of current job


def _single_slot_event(job: ArrivalProcess, spot: ArrivalProcess,
                       wait: WaitTime, k_cost: float,
                       carry: SingleSlotCarry,
                       stats: WindowStats) -> tuple[SingleSlotCarry, WindowStats]:
    key, k_job, k_spot, k_x = jax.random.split(carry.key, 4)
    deadline = jnp.where(carry.occupied, carry.x_left, _INF)
    dt = jnp.minimum(jnp.minimum(carry.next_job, carry.next_spot), deadline)
    # Event priority on ties: spot > deadline > job (measure-zero for
    # continuous distributions; deterministic X makes spot-at-deadline serve).
    is_spot = carry.next_spot <= jnp.minimum(carry.next_job, deadline)
    is_deadline = (~is_spot) & (deadline <= carry.next_job)
    is_job = (~is_spot) & (~is_deadline)

    age = carry.age + dt
    served = is_spot & carry.occupied
    defected = is_deadline  # only fires when occupied
    x_new = wait.sample(k_x)
    joins = is_job & (~carry.occupied) & (x_new > 0.0)
    od_now = is_job & (carry.occupied | (x_new <= 0.0))

    new_carry = SingleSlotCarry(
        key=key,
        next_job=jnp.where(is_job, job.sample(k_job), carry.next_job - dt),
        next_spot=jnp.where(is_spot, spot.sample(k_spot), carry.next_spot - dt),
        occupied=jnp.where(served | defected, False,
                           jnp.where(joins, True, carry.occupied)),
        age=jnp.where(joins, 0.0, age),
        x_left=jnp.where(joins, x_new,
                         jnp.where(carry.occupied, carry.x_left - dt, _INF)),
    )
    completed_inc = (served | defected | od_now).astype(jnp.int32)
    new_stats = WindowStats(
        jobs_arrived=stats.jobs_arrived + is_job.astype(jnp.int32),
        jobs_completed=stats.jobs_completed + completed_inc,
        spot_served=stats.spot_served + served.astype(jnp.int32),
        ondemand=stats.ondemand + (defected | od_now).astype(jnp.int32),
        cost_sum=stats.cost_sum
        + jnp.where(served, 1.0, 0.0)
        + jnp.where(defected | od_now, k_cost, 0.0),
        delay_sum=stats.delay_sum + jnp.where(served | defected, age, 0.0),
        time_elapsed=stats.time_elapsed + dt,
        empty_time=stats.empty_time + jnp.where(carry.occupied, 0.0, dt),
        spot_arrivals=stats.spot_arrivals + is_spot.astype(jnp.int32),
        spot_found_empty=stats.spot_found_empty
        + (is_spot & (~carry.occupied)).astype(jnp.int32),
    )
    return new_carry, new_stats


@functools.partial(
    jax.jit, static_argnames=("job", "spot", "wait", "k_cost", "n_events")
)
def _run_single_slot_jit(job, spot, wait, k_cost, n_events, key):
    kj, ks, kc = jax.random.split(key, 3)
    carry = SingleSlotCarry(
        key=kc,
        next_job=job.sample(kj),
        next_spot=spot.sample(ks),
        occupied=jnp.zeros((), jnp.bool_),
        age=jnp.zeros((), jnp.float32),
        x_left=_INF,
    )

    def body(state, _):
        c, s = state
        c, s = _single_slot_event(job, spot, wait, k_cost, c, s)
        return (c, s), None

    (carry, stats), _ = jax.lax.scan(
        body, (carry, WindowStats.zeros()), None, length=n_events
    )
    return carry, stats


def run_single_slot_sim(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    wait: WaitTime,
    *,
    k: float = 10.0,
    n_events: int,
    key: jax.Array,
) -> dict:
    """Simulate the single-slot (queue ≤ 1) policy with maximal wait X."""
    _, stats = _run_single_slot_jit(job, spot, wait, float(k), n_events, key)
    return _summarize(stats)
