"""Shared clock + PRNG machinery for the engine's three event loops.

Two randomness streams, one module (PR 5):

``rng="split"`` — the frozen PR-1..4 stream.  Every event splits the lane
key into a 4/5/6-way ladder (job / spot / policy / preempt? / route?) and
every clock-vector refresh folds a per-pool/per-region tag into its subkey
before sampling.  The market and region loops used to carry near-identical
copies of that plumbing (``_pool_spot_keys`` / ``_region_fold_keys``, the
tag-folded preempt-clock refresh, the split ladders); the one copy lives
here now (:func:`split_event_keys`, :func:`tagged_keys`,
:func:`sample_clock_vector`, :func:`sample_hazard_clocks`) and stays
bit-for-bit the PR-4 stream — the seed-compat wrappers and every frozen
degenerate-ledger test run on it unchanged.

``rng="slab"`` — the fast stream.  Profiling the loops shows per-event
PRNG *key arithmetic* (threefry ladders + per-pool ``fold_in`` +
``exponential``), not policy logic, dominates: a 4-region preemptible event
costs ~25 threefry invocations.  The slab stream deletes all of it from the
event body:

  * One counter-based :func:`jax.random.bits` call generates a
    ``(window_events, n_cols)`` uint32 **slab** per float32 window
    (:func:`window_slab`); the event body consumes draws by *static column
    index* (:class:`SlabLayout`), converting raw bits to uniforms /
    exponentials with plain arithmetic (:func:`u01`, :func:`exp_from_u`).
    In the Pallas executor the slab arrives as a plain VMEM input block per
    window — zero in-kernel key arithmetic.
  * The per-pool/per-region Poisson preemption clocks collapse to ONE
    scalar clock at the *superposed* total hazard: the minimum of
    independent ``Exp(h_p)`` clocks is ``Exp(Σ h_p)`` and (by
    memorylessness) the firing pool is an independent categorical draw with
    weights ``h_p`` — :func:`hazard_clock` + :func:`thinning_pick` are that
    law, *exactly* the per-pool vector-clock process (see EXPERIMENTS.md
    §"Event-loop RNG" for the proof sketch and the draw-column table).

Slab-vs-split equivalence is **distributional** (the slab stream holds the
pallas == ref == xla bitwise integer ledger on its own terms; KS tests pin
the slab-vs-split marginals — tests/test_event_rng.py); the split stream
keeps its frozen bitwise contracts.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

_INF = np.float32(3e38)  # np scalar: inlines as a literal in kernel traces

#: uint32 slab columns reserved when a kernel hook is *not* slab-aware: two
#: raw key words are synthesized into a legacy PRNG key (:func:`synth_key`)
#: and the unchanged key-based hook is called.
KEY_SYNTH_COLS = 2


# ---------------------------------------------------------------------------
# Split-mode plumbing (the frozen PR-1..4 stream), deduplicated
# ---------------------------------------------------------------------------


def split_event_keys(key, preempt_on: bool = False, has_route: bool = False):
    """The per-event split ladder, one copy for all three loops.

    Returns ``(key, k_job, k_spot, k_pol, k_pre, k_rt)`` with ``k_pre`` /
    ``k_rt`` present only when their static flag is set (``None``
    otherwise).  The ladder width and subkey order (policy, then preempt,
    then route) are exactly the PR-2/PR-4 layouts, so every frozen
    bit-for-bit contract is preserved.
    """
    n = 4 + int(preempt_on) + int(has_route)
    ks = jax.random.split(key, n)
    k_pre = ks[4] if preempt_on else None
    k_rt = ks[4 + int(preempt_on)] if has_route else None
    return ks[0], ks[1], ks[2], ks[3], k_pre, k_rt


def tagged_keys(tags: tuple, k: jax.Array) -> list:
    """Per-tag sampling keys, label-independent via ``fold_in(k, tag)``.

    A single tag uses ``k`` directly — the PR-1 key layout — so the
    degenerate 1-pool/1-region engines stay bit-for-bit the PR-1 engine.
    Shared by the market (pool tags) and region (region tags) loops.
    """
    if len(tags) == 1:
        return [k]
    return [jax.random.fold_in(k, t) for t in tags]


def sample_clock_vector(procs: tuple, tags: tuple, k: jax.Array,
                        scale: jax.Array) -> jax.Array:
    """Stacked per-tag renewal samples × a traced scale vector.

    One implementation for the market's spot clocks and the region loop's
    job and spot clocks (same fold-in layout, same stacking order).
    """
    samples = [p.sample(kk) for p, kk in zip(procs, tagged_keys(tags, k))]
    return jnp.stack(samples) * scale


def sample_hazard_clocks(tags: tuple, k: jax.Array,
                         hazard: jax.Array) -> jax.Array:
    """``Exp(h_t)`` revocation clocks per tag; ``h_t = 0`` never fires (INF).

    Always tag-folded (the PR-2 preempt layout has no 1-pool shortcut).
    """
    u = jnp.stack([
        jax.random.exponential(jax.random.fold_in(k, t), dtype=jnp.float32)
        for t in tags
    ])
    return jnp.where(hazard > 0.0, u / jnp.maximum(hazard, jnp.float32(1e-30)),
                     _INF)


# ---------------------------------------------------------------------------
# Raw-bits → draws (slab mode)
# ---------------------------------------------------------------------------


def u01(bits: jax.Array) -> jax.Array:
    """uint32 bits → float32 uniforms on [0, 1) (24-bit resolution)."""
    return (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(2.0 ** -24)


def exp_from_u(u: jax.Array) -> jax.Array:
    """Unit-rate exponential via inverse CDF (the sampler's ``-log1p(-U)``)."""
    return -jnp.log1p(-u)


def gumbel_from_u(u: jax.Array) -> jax.Array:
    """Standard Gumbel via inverse CDF, guarded at u = 0."""
    return -jnp.log(-jnp.log(jnp.maximum(u, np.float32(1e-12))))


def synth_key(bits: jax.Array) -> jax.Array:
    """Two uint32 slab columns → a raw threefry key for legacy kernel hooks.

    The fallback path for kernels without ``*_u`` hooks: the hook still
    receives a key and draws in-body (1-2 small threefry calls), but the
    engine's own per-event ladders and clock refreshes stay slab-driven.
    """
    return jnp.stack([bits[0], bits[1]])


# ---------------------------------------------------------------------------
# Superposed Poisson preemption clock (shared law, host + traced)
# ---------------------------------------------------------------------------


def hazard_clock(hazard, u):
    """Time to the next preemption event under the superposed total hazard.

    ``min(Exp(h_1), …, Exp(h_P)) ~ Exp(Σ h_p)``: one inverse-CDF draw at the
    total hazard replaces the O(P) per-pool vector refresh; a zero total
    never fires (INF).  Host scalars take the pure-Python path (the cluster
    orchestrator's twin), traced inputs the jnp path the engine scans.
    """
    if not (isinstance(hazard, jax.Array) or isinstance(u, jax.Array)):
        total = float(np.sum(hazard))
        if total <= 0.0:
            return math.inf  # host clocks use true inf, traced ones _INF
        return -math.log1p(-float(u)) / total
    h = jnp.asarray(hazard, jnp.float32)
    total = jnp.sum(h)
    return jnp.where(total > 0.0,
                     exp_from_u(jnp.asarray(u, jnp.float32))
                     / jnp.maximum(total, jnp.float32(1e-30)),
                     _INF)


def thinning_pick(hazard, u):
    """Which pool fired: a categorical draw with weights ``h_p``.

    By memorylessness the argmin of independent exponential clocks is
    independent of their min, with P(pool p) = h_p / Σ h_q — so a fresh
    uniform thinned over the hazard cumsum reproduces the vector clocks'
    (firing time, firing pool) joint law exactly.  Zero-hazard pools are
    never picked.  Dual host/traced backend like :func:`hazard_clock`.
    """
    if not (isinstance(hazard, jax.Array) or isinstance(u, jax.Array)):
        cum = np.cumsum(np.asarray(hazard, np.float64))
        if cum[-1] <= 0.0:
            return 0
        return int(min(np.sum(float(u) * cum[-1] >= cum[:-1]),
                       len(cum) - 1))
    h = jnp.asarray(hazard, jnp.float32)
    cum = jnp.cumsum(h)
    pick = jnp.sum((jnp.asarray(u, jnp.float32) * cum[-1] >= cum[:-1])
                   .astype(jnp.int32))
    return jnp.minimum(pick, h.shape[0] - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Slab layout: who owns which draw columns
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SlabLayout:
    """Static per-trace column map of one event's slab row.

    Spans are ``(start, n)`` uint32 column ranges; modes say how the
    corresponding kernel hook consumes its span: ``"u"`` = slab-aware hook
    (``admit_u`` / ``admit_market_u`` / ``on_preempt_u`` / ``route_u``)
    receiving float32 uniforms, ``"key"`` = two raw columns synthesized into
    a legacy key (:func:`synth_key`), ``"none"`` = hook absent.  The
    preempt span is always two columns: [superposed clock draw, thinning
    pick].  See docs/kernels.md ("Randomness protocol") for the authoring
    rules and EXPERIMENTS.md for the full table.
    """

    n_cols: int
    job: tuple[int, int]
    spot: tuple[int, int]
    admit: tuple[int, int]
    admit_mode: str  # "u" | "key"
    market_admit: bool  # admit span feeds admit_market (vs plain admit)
    preempt: tuple[int, int] | None
    on_preempt: tuple[int, int] | None
    on_preempt_mode: str  # "u" | "key" | "none"
    route: tuple[int, int] | None
    route_mode: str  # "u" | "key" | "none"

    def bits(self, x: jax.Array, span: tuple[int, int]) -> jax.Array:
        """Raw uint32 columns of one span (static slice)."""
        return x[span[0]:span[0] + span[1]]

    def uniforms(self, x: jax.Array, span: tuple[int, int]) -> jax.Array:
        """One span as float32 uniforms on [0, 1)."""
        return u01(self.bits(x, span))


def kernel_slab_cols(kernel, hook: str, n: int) -> int | None:
    """Columns a kernel's slab-aware ``hook`` owns, or None for fallback.

    A kernel is slab-aware for ``hook`` iff it defines BOTH ``{hook}_u``
    and ``slab_cols(hook, n)`` returning a non-None count (``n`` is the
    pool/region count, for choice rules whose width depends on it).
    """
    if getattr(kernel, hook + "_u", None) is None:
        return None
    slab_cols = getattr(kernel, "slab_cols", None)
    if slab_cols is None:
        return None
    return slab_cols(hook, n)


def choice_cols(choice: str, n: int) -> int:
    """Uniform columns a pool/region choice rule consumes (see
    ``choose_pool_u`` / ``choose_region_u``)."""
    if choice == "uniform":
        return 1
    if choice == "weighted":
        return n
    return 0  # deterministic argmin rules (and "home") draw nothing


def build_slab_layout(kernel, *, job_udim: int, spot_udim: int, n: int = 1,
                      preempt_on: bool = False, has_route: bool = False,
                      market: bool = False) -> SlabLayout:
    """Assign this trace's slab columns: engine clocks first, hooks after.

    Column order is [job refresh | spot refresh | admit hook | preempt
    clock+pick | on_preempt hook | route hook]; spans not needed by the
    static config are absent, so a degenerate config's layout reduces
    exactly to the simpler loop's (the slab analogue of the degenerate
    bitwise ledger).
    """
    cursor = 0

    def take(width: int) -> tuple[int, int]:
        nonlocal cursor
        span = (cursor, width)
        cursor += width
        return span

    job = take(job_udim)
    spot = take(spot_udim)
    # the market/region loops route admission to admit_market when the
    # kernel has one; the single-queue loop always uses plain admit
    market_admit = market and hasattr(kernel, "admit_market")
    hook = "admit_market" if market_admit else "admit"
    cols = kernel_slab_cols(kernel, hook, n)
    admit_mode = "key" if cols is None else "u"
    admit = take(KEY_SYNTH_COLS if cols is None else cols)
    preempt = take(2) if preempt_on else None
    on_preempt, on_preempt_mode = None, "none"
    if preempt_on and hasattr(kernel, "on_preempt"):
        cols = kernel_slab_cols(kernel, "on_preempt", n)
        on_preempt_mode = "key" if cols is None else "u"
        on_preempt = take(KEY_SYNTH_COLS if cols is None else cols)
    route, route_mode = None, "none"
    if has_route:
        cols = kernel_slab_cols(kernel, "route", n)
        route_mode = "key" if cols is None else "u"
        route = take(KEY_SYNTH_COLS if cols is None else cols)
    return SlabLayout(
        n_cols=max(cursor, 1), job=job, spot=spot, admit=admit,
        admit_mode=admit_mode, market_admit=market_admit, preempt=preempt,
        on_preempt=on_preempt, on_preempt_mode=on_preempt_mode, route=route,
        route_mode=route_mode)


def process_udim(proc) -> int:
    """Uniform columns an arrival process needs per draw, with a clear
    error pointing at ``rng="split"`` for families without a slab sampler."""
    dim = getattr(proc, "u_dim", None)
    if dim is None:
        raise NotImplementedError(
            f"{proc!r} has no slab sampler (u_dim/sample_u); "
            "run this configuration with rng='split'")
    return int(dim)


# ---------------------------------------------------------------------------
# Slab generation (one counter-based bits call per float32 window)
# ---------------------------------------------------------------------------


def window_slab(key: jax.Array, n_events: int,
                n_cols: int) -> tuple[jax.Array, jax.Array]:
    """Advance the lane key one window; return (new_key, (n_events, n_cols)
    uint32 slab).  Typed and raw uint32 keys produce the same stream, so
    the XLA scan path (typed lane keys) and the Pallas lane layout (raw
    keys) consume bitwise-identical slabs.
    """
    ks = jax.random.split(key)
    return ks[0], jax.random.bits(ks[1], (n_events, n_cols), jnp.uint32)


def lane_window_slabs(key: jax.Array, plan: tuple[int, ...],
                      n_cols: int) -> jax.Array:
    """All of one lane's window slabs, stacked (n_windows, max_ev, n_cols).

    Uses the exact per-window shapes of :func:`window_slab` (the ladder the
    scan executor walks) and zero-pads each window up to the plan maximum,
    so the rows a window actually consumes are bitwise the scan path's —
    the Pallas/ref executors feed this stack in as a per-window input
    block.
    """
    max_ev = max(plan)
    slabs = []
    for n_ev in plan:
        key, slab = window_slab(key, n_ev, n_cols)
        slabs.append(jnp.pad(slab, ((0, max_ev - n_ev), (0, 0))))
    return jnp.stack(slabs)
