"""Multi-region topology — N queues, per-region clocks, routing at admission.

The paper's model is ONE delay-constrained queue over one spot supply.  Real
fleets span *regions* (cloud region × instance family) with heterogeneous
prices, availability, preemption behaviour — and their own demand: jobs
arrive *in* a region but can be *routed* to any region's queue at admission
(cf. the per-option strategy zoos of Wu et al. and Bhuyan et al. in
PAPERS.md).  This module is the static descriptor layer of the on-device
multi-region subsystem; the event loop lives in :mod:`repro.core.engine`
(``run_region_sim`` / ``run_region_sweep``).

  * :class:`Region` — one region: a job arrival process (demand), a spot
    slot process (supply), price ``c_r``, preemption hazard ``h_r`` +
    notice window (the PR-2 market axes, one pool per region), and a static
    queue capacity ``rmax_r``.
  * :class:`RegionTopology` — a static, hashable tuple of regions.  The
    engine packs the per-region ``(rmax_r,)`` queue partitions as ONE
    ``(sum rmax_r,)`` slot array with a *static* slot→region map, and
    carries per-region ``next_job``/``next_spot``/``next_preempt`` clock
    vectors merged into the renewal loop (ties: spot > preempt > deadline >
    job, regions tie by position — the PR-2 order, unchanged).
  * routing hook — the policy-kernel protocol gains::

        route(params, qlens, region_state, key) -> region

    consulted once per job arrival with the per-region queue lengths and a
    :class:`RegionView` of prices/hazards/rates/occupancy (``home`` is the
    region whose job clock fired).  The admission law then runs against the
    *target* region's queue length, so every existing kernel — three-phase,
    single-slot, NoticeAware — becomes a per-region instance under a
    :class:`RoutingKernel` wrapper.  Kernels without a ``route`` hook keep
    jobs in their home region, which is exactly the degenerate case: a
    1-region topology reproduces the PR-3 engine **bit-for-bit** (frozen in
    tests/test_core_regions.py).
  * Per-region PRNG streams are keyed ``fold_in(key, region.tag)`` — the
    label-independent identity of the PR-2 pools — so permuting regions
    (keeping tags) leaves every sampled stream, and therefore all scalar
    statistics, exactly invariant (property-tested like pool relabeling).

See docs/kernels.md for the full kernel-protocol reference and
EXPERIMENTS.md §"Multi-region" for the modeling rationale and measured
numbers.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.clocks import choice_cols, gumbel_from_u

_INF = np.float32(3e38)  # np scalar: inlines as a literal in kernel traces


# ---------------------------------------------------------------------------
# Descriptors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Region:
    """One region: demand (job process) + supply (spot process) + economics.

    ``tag`` is the region's stable PRNG-stream identity (defaults to its
    index in the topology); keep tags fixed when permuting regions to get
    bitwise relabel-invariance.  ``rmax`` is the region's static queue
    partition size — regions may be heterogeneous in capacity.
    """

    job: ArrivalProcess
    spot: ArrivalProcess
    price: float = 1.0
    hazard: float = 0.0  # preemption events per unit time on the running job
    notice: float = 0.0  # advance-notice window length
    rmax: int = 64
    tag: int | None = None

    def job_rate(self) -> float:
        return self.job.rate()

    def spot_rate(self) -> float:
        return self.spot.rate()


@dataclasses.dataclass(frozen=True)
class RegionTopology:
    """N heterogeneous regions as one static, hashable descriptor."""

    regions: tuple[Region, ...]

    def __post_init__(self):
        if not self.regions:
            raise ValueError("a RegionTopology needs at least one region")
        tagged = tuple(
            dataclasses.replace(r, tag=i) if r.tag is None else r
            for i, r in enumerate(self.regions)
        )
        tags = [r.tag for r in tagged]
        if len(set(tags)) != len(tags):
            raise ValueError(f"region tags must be unique, got {tags}")
        for r in tagged:
            if r.rmax < 1:
                raise ValueError("every region needs rmax >= 1")
        object.__setattr__(self, "regions", tagged)

    # ------------------------------------------------------------- structure
    @property
    def n_regions(self) -> int:
        return len(self.regions)

    @property
    def total_slots(self) -> int:
        """Size of the packed slot array: sum of per-region ``rmax_r``."""
        return sum(r.rmax for r in self.regions)

    @property
    def preemptible(self) -> bool:
        """Static: does any region carry a preemption hazard?"""
        return any(r.hazard > 0.0 for r in self.regions)

    @property
    def is_degenerate(self) -> bool:
        """1 region, unit price, zero hazard — the PR-3 engine, bit-for-bit."""
        r = self.regions[0]
        return self.n_regions == 1 and r.hazard == 0.0 and r.price == 1.0

    def slot_offsets(self) -> np.ndarray:
        """Static start offset of each region's slot partition (host ints)."""
        return np.cumsum([0] + [r.rmax for r in self.regions[:-1]]).astype(
            np.int32)

    # ------------------------------------------------------------ host views
    def prices(self) -> np.ndarray:
        return np.array([r.price for r in self.regions], np.float64)

    def hazards(self) -> np.ndarray:
        return np.array([r.hazard for r in self.regions], np.float64)

    def notices(self) -> np.ndarray:
        return np.array([r.notice for r in self.regions], np.float64)

    def rates(self) -> np.ndarray:
        """Per-region spot slot rates μ_r (the supply side; the name matches
        :meth:`repro.core.market.SpotMarket.rates` so the topology plugs
        straight into :func:`repro.core.lp.market_knapsack_lp`)."""
        return np.array([r.spot_rate() for r in self.regions], np.float64)

    def job_rates(self) -> np.ndarray:
        return np.array([r.job_rate() for r in self.regions], np.float64)

    def total_job_rate(self) -> float:
        return float(self.job_rates().sum())

    def rmaxes(self) -> np.ndarray:
        return np.array([r.rmax for r in self.regions], np.int32)

    # --------------------------------------------------------- traced params
    def params(self) -> dict:
        """Traced region-config pytree consumed by the engine event loop.

        ``spot_scale``/``job_scale`` multiply inter-arrival times (scale > 1
        = scarcer slots / slower demand) — distribution-generic availability
        and demand axes a sweep can trace without retracing the arrival
        families.  ``rate``/``job_rate`` ride in the traced params (not
        materialized in the event body) so the body stays
        constant-capture-free under the Pallas kernel trace; ``rmax`` rides
        along for the same reason (the capacity check needs the per-region
        vector, and an inline jnp constant would be hoisted as a const,
        which pallas_call rejects).
        """
        n = self.n_regions
        return {
            "price": jnp.asarray(self.prices(), jnp.float32),
            "hazard": jnp.asarray(self.hazards(), jnp.float32),
            "notice": jnp.asarray(self.notices(), jnp.float32),
            "spot_scale": jnp.ones((n,), jnp.float32),
            "job_scale": jnp.ones((n,), jnp.float32),
            "rate": jnp.asarray(self.rates(), jnp.float32),
            "job_rate": jnp.asarray(self.job_rates(), jnp.float32),
            "rmax": jnp.asarray(self.rmaxes(), jnp.int32),
        }

    # ------------------------------------------------------------- utilities
    @staticmethod
    def single(job: ArrivalProcess, spot: ArrivalProcess, *,
               price: float = 1.0, hazard: float = 0.0, notice: float = 0.0,
               rmax: int = 64) -> "RegionTopology":
        """A one-region topology (``hazard=0, price=1`` is the PR-3
        degenerate case)."""
        return RegionTopology(regions=(Region(
            job=job, spot=spot, price=price, hazard=hazard, notice=notice,
            rmax=rmax, tag=0),))

    def relabel(self, perm: Sequence[int]) -> "RegionTopology":
        """Permute region positions, keeping each region's tag (PRNG
        identity)."""
        if sorted(perm) != list(range(self.n_regions)):
            raise ValueError(f"not a permutation of {self.n_regions} regions")
        return RegionTopology(regions=tuple(self.regions[i] for i in perm))


def as_topology(obj) -> RegionTopology:
    """Coerce a Region (or a topology) to a RegionTopology."""
    if isinstance(obj, RegionTopology):
        return obj
    if isinstance(obj, Region):
        return RegionTopology(regions=(obj,))
    raise TypeError(f"expected Region or RegionTopology, got {obj!r}")


# ---------------------------------------------------------------------------
# Routing-kernel protocol
# ---------------------------------------------------------------------------


class RegionView(NamedTuple):
    """Non-clairvoyant per-region state handed to the ``route`` hook.

    ``home`` is the region whose job clock fired (where the job physically
    arrived); routing elsewhere models cross-region dispatch.  All vectors
    are indexed by region *position* (permute with the topology).
    """

    home: jax.Array  # () i32   arrival region of the current job
    price: jax.Array  # (R,) f32 region prices c_r
    hazard: jax.Array  # (R,) f32 preemption hazards h_r
    notice: jax.Array  # (R,) f32 notice windows
    rate: jax.Array  # (R,) f32  spot slot rates (scaled)
    job_rate: jax.Array  # (R,) f32 job arrival rates (scaled)
    qlen_region: jax.Array  # (R,) i32 queued jobs per region
    free_slots: jax.Array  # (R,) i32 remaining capacity rmax_r − qlen_r


def choose_region(choice: str, view: RegionView, params,
                  key: jax.Array) -> jax.Array:
    """Static routing rules shared by :class:`RoutingKernel` instances.

    ``home`` keeps the job where it arrived; ``cheapest`` / ``fastest`` /
    ``least_loaded`` are deterministic argmins over the region vectors
    (label-independent when the decided-on values are distinct); ``uniform``
    draws uniformly; ``weighted`` Gumbel-samples from traced
    ``params["region_logits"]`` so the routing distribution itself can be
    swept or learned on-device — the exact shape of
    :func:`repro.core.market.choose_pool`, one level up.
    """
    n = view.price.shape[0]
    if choice == "home":
        return view.home
    if choice == "cheapest":
        return jnp.argmin(view.price).astype(jnp.int32)
    if choice == "fastest":
        return jnp.argmax(view.rate).astype(jnp.int32)
    if choice == "least_loaded":
        return jnp.argmin(view.qlen_region).astype(jnp.int32)
    if choice == "uniform":
        return jax.random.randint(key, (), 0, n, jnp.int32)
    if choice == "weighted":
        g = jax.random.gumbel(key, (n,), jnp.float32)
        return jnp.argmax(params["region_logits"] + g).astype(jnp.int32)
    raise ValueError(f"unknown routing rule {choice!r}")


def choose_region_u(choice: str, view: RegionView, params,
                    u: jax.Array) -> jax.Array:
    """Slab-stream twin of :func:`choose_region` (pre-drawn uniforms
    instead of a key; ``repro.core.clocks.choice_cols`` widths) — the
    routing analogue of :func:`repro.core.market.choose_pool_u`."""
    n = view.price.shape[0]
    if choice == "uniform":
        return jnp.minimum((u[0] * n).astype(jnp.int32), n - 1)
    if choice == "weighted":
        g = gumbel_from_u(u[:n])
        return jnp.argmax(params["region_logits"] + g).astype(jnp.int32)
    return choose_region(choice, view, params, key=None)


def host_route(choice: str, *, prices, rates, qlens, home: int = 0,
               alive=None) -> int:
    """Host-scalar twin of the deterministic :func:`choose_region` rules.

    The cluster orchestrator routes one live job at a time; an un-jitted
    jnp round-trip costs ~1 ms per call (same dual-backend reasoning as
    ``three_phase_admit_prob``).  Randomized rules (uniform/weighted) stay
    on the traced path — the host consumer passes its own rng draw instead.

    ``alive`` (optional bool mask) restricts every rule to live regions —
    the host twin of :class:`repro.core.market.PanicKernel`'s failover: a
    dead ``home`` falls back to the cheapest alive region, and argmin/argmax
    rules never pick a dead one.  All-dead raises ``RuntimeError`` (the
    orchestrator's cue to run the job on-demand).
    """
    prices = np.asarray(prices, np.float64)
    rates = np.asarray(rates, np.float64)
    qlens = np.asarray(qlens, np.float64)
    if alive is not None:
        alive = np.asarray(alive, bool)
        if not alive.any():
            raise RuntimeError("host_route: no region alive")
        dead = ~alive
        if choice == "home" and dead[int(home)]:
            choice = "cheapest"  # failover: home is dark
        prices = np.where(dead, np.inf, prices)
        rates = np.where(dead, -np.inf, rates)
        qlens = np.where(dead, np.inf, qlens)
    if choice == "home":
        return int(home)
    if choice == "cheapest":
        return int(np.argmin(prices))
    if choice == "fastest":
        return int(np.argmax(rates))
    if choice == "least_loaded":
        return int(np.argmin(qlens))
    raise ValueError(f"unknown host routing rule {choice!r}")


@dataclasses.dataclass(frozen=True)
class RoutingKernel:
    """Adapt any engine kernel to the multi-region protocol with a rule.

    Admission (and wait budgets, and market/preemption hooks if the base
    has them) delegate to ``base``, evaluated against the *target* region's
    queue length; the target comes from :func:`choose_region`.  Mirrors
    PR-2's :class:`repro.core.market.PoolChoiceKernel`, one level up: wrap
    ``ThreePhaseKernel`` / ``SingleSlotKernel`` / ``NoticeAwareKernel`` and
    each region runs its own per-region instance of the paper's policy.

    Note on blackouts: the region loop's slot→region map is STATIC, so
    jobs already queued in a region that goes dark cannot be re-tagged
    (the market loop's ``PanicKernel(drain_dead=True)`` repair has no
    region analogue) — stranded region jobs drain through their wait
    budgets / the deadline path.  Routing only protects NEW admissions.
    """

    base: object  # any PolicyKernel / MarketPolicyKernel
    choice: str = "cheapest"

    def route(self, params, qlens, region_state: RegionView, key):
        del qlens  # already carried by region_state.qlen_region
        return choose_region(self.choice, region_state, params, key)

    def slab_cols(self, hook, n):
        if hook == "route":
            return choice_cols(self.choice, n)
        base_cols = getattr(object.__getattribute__(self, "base"),
                            "slab_cols", None)
        return base_cols(hook, n) if base_cols is not None else None

    def route_u(self, params, qlens, region_state: RegionView, u):
        del qlens
        return choose_region_u(self.choice, region_state, params, u)

    def __getattr__(self, name):
        # delegate the admission/preemption hooks the base actually has, so
        # the engine's hasattr dispatch sees exactly the base's protocol
        # (key-based hooks and their slab-stream ``*_u`` twins alike)
        if name in ("admit", "admit_market", "on_preempt", "init_params",
                    "admit_u", "admit_market_u", "on_preempt_u"):
            return getattr(object.__getattribute__(self, "base"), name)
        raise AttributeError(name)
