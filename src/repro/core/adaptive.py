"""Algorithm 1 — Adaptive Admission Control Policy — as one jit'd scan.

The learner runs the Theorem-4 three-phase policy at the current knob ``r``,
measures the empirical average delay d(r) over a window of events, and takes
a projected gradient step on the slack penalty L(r) = ½(d(r) − δ)²:

    r ← clip(r − η·(d(r) − δ), 0, r_max)

exactly as the paper's Algorithm 1 (the sign of ∂d/∂r is absorbed into η > 0
since d(r) is increasing in r).  The outer window loop and the inner event
loop are both ``lax.scan``s, so the full learning trajectory is one XLA
program: deterministic given a PRNG key and cheap enough to run *on-device*
next to a training loop.

Beyond-paper (recorded in EXPERIMENTS.md): an optional 1/√n step-size decay
(``eta_decay``) suppresses the stationary oscillation of constant-η SGD; and
the window statistic optionally includes immediate on-demand dispatches
(delay 0) exactly as the paper's d(r) does.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.simulator import (
    WindowStats,
    init_queue_carry,
    run_queue_window,
)


class AdaptiveTrace(NamedTuple):
    """Per-window trajectory (stacked over windows)."""

    r: jax.Array  # knob before the window's update
    window_delay: jax.Array  # d(r) measured in the window
    window_cost: jax.Array  # average cost of jobs completed in the window
    jobs: jax.Array
    completed: jax.Array
    spot_served: jax.Array
    cost_sum: jax.Array
    delay_sum: jax.Array
    time: jax.Array
    spot_arrivals: jax.Array
    spot_found_empty: jax.Array


@functools.partial(
    jax.jit,
    static_argnames=(
        "job", "spot", "k_cost", "rmax", "window_events", "n_windows",
    ),
)
def _adaptive_jit(job, spot, k_cost, rmax, window_events, n_windows,
                  delta, eta, eta_decay, r0, r_max, key):
    carry0 = init_queue_carry(key, job, spot, rmax)

    def outer(state, idx):
        carry, r = state
        carry, s = run_queue_window(
            job, spot, k_cost, rmax, carry, r, window_events
        )
        completed = jnp.maximum(s.jobs_completed, 1).astype(jnp.float32)
        d = s.delay_sum / completed
        c = s.cost_sum / completed
        step = eta / jnp.sqrt(1.0 + eta_decay * idx.astype(jnp.float32))
        r_new = jnp.clip(r - step * (d - delta), 0.0, r_max)
        trace = AdaptiveTrace(
            r=r,
            window_delay=d,
            window_cost=c,
            jobs=s.jobs_arrived,
            completed=s.jobs_completed,
            spot_served=s.spot_served,
            cost_sum=s.cost_sum,
            delay_sum=s.delay_sum,
            time=s.time_elapsed,
            spot_arrivals=s.spot_arrivals,
            spot_found_empty=s.spot_found_empty,
        )
        return (carry, r_new), trace

    (carry, r_final), traces = jax.lax.scan(
        outer, (carry0, jnp.float32(r0)), jnp.arange(n_windows)
    )
    return r_final, traces


def adaptive_admission_control(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    *,
    k: float = 10.0,
    delta: float,
    eta: float = 0.05,
    eta_decay: float = 0.0,
    r0: float = 0.0,
    r_max: float = 16.0,
    window_events: int = 2048,
    n_windows: int = 400,
    rmax_slots: int = 64,
    key: jax.Array,
) -> dict:
    """Run Algorithm 1; return the trajectory and running averages (float64).

    Returns a dict with per-window arrays: ``r`` (knob), ``window_delay``,
    ``window_cost``, and running averages ``running_cost`` / ``running_delay``
    (cumulative, matching the paper's C(r(n)) and d(r(n)) plots), plus the
    final knob ``r_star`` and Theorem-1 cross-check fields.
    """
    r_final, tr = _adaptive_jit(
        job, spot, float(k), rmax_slots, window_events, n_windows,
        jnp.float32(delta), jnp.float32(eta), jnp.float32(eta_decay),
        jnp.float32(r0), jnp.float32(r_max), key,
    )
    t = jax.tree.map(lambda x: np.asarray(x, np.float64), tr)
    cum_completed = np.maximum(np.cumsum(t.completed), 1.0)
    running_cost = np.cumsum(t.cost_sum) / cum_completed
    running_delay = np.cumsum(t.delay_sum) / cum_completed
    spot_arr = np.maximum(np.cumsum(t.spot_arrivals), 1.0)
    pi0_spot = np.cumsum(t.spot_found_empty) / spot_arr
    return {
        "r": t.r,
        "r_star": float(r_final),
        "window_delay": t.window_delay,
        "window_cost": t.window_cost,
        "running_cost": running_cost,
        "running_delay": running_delay,
        "pi0_spot": pi0_spot,
        "final_cost": float(running_cost[-1]),
        "final_delay": float(running_delay[-1]),
        "final_pi0": float(pi0_spot[-1]),
        "jobs_total": float(np.sum(t.jobs)),
        "time_total": float(np.sum(t.time)),
    }
