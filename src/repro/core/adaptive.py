"""Algorithm 1 — Adaptive Admission Control — on the market sweep engine.

The learner runs the Theorem-4 three-phase policy at the current knob ``r``,
measures the empirical average delay d(r) over a window of events, and takes
a projected gradient step on the slack penalty L(r) = ½(d(r) − δ)²:

    r ← clip(r − η·(d(r) − δ), 0, r_max)

exactly as the paper's Algorithm 1 (the sign of ∂d/∂r is absorbed into η > 0
since d(r) is increasing in r).  The event window is the engine's
:func:`repro.core.engine.run_market_window`: since PR 2 the learner runs on
the **spot-market subsystem** (heterogeneous pools, preemption with notice —
:mod:`repro.core.market`), so fleets can be trained against revocation-prone
multi-pool markets on-device.  A plain :class:`~repro.core.arrivals
.ArrivalProcess` is wrapped as the degenerate one-pool market, which
reproduces the PR-1 engine bit-for-bit — pre-market learner trajectories
are unchanged.

:func:`adaptive_admission_control_batched` vmaps the whole learner over
arrays of (δ, η, η-decay, r₀, r_max, k): a fleet of learners — e.g. one per
delay target, or the paper's two far-apart initializations — advances in ONE
jitted scan instead of one Python call per learner.

Beyond-paper (recorded in EXPERIMENTS.md): an optional 1/√n step-size decay
(``eta_decay``) suppresses the stationary oscillation of constant-η SGD; and
the window statistic includes immediate on-demand dispatches (delay 0)
exactly as the paper's d(r) does.  Under preemption the window delay d(r)
averages *legs* (a checkpointed job contributes its pre-revocation wait as
one leg) — the same accounting as the host orchestrator.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.engine import (_check_env, _env_params, _rebase_order,
                               _rebase_order_env, init_market_state,
                               run_market_window)
from repro.core.env import init_env_state
from repro.core.market import NoticeAwareKernel, SpotMarket, as_market
from repro.core.policies import ThreePhaseKernel
from repro.obs.timing import annotate

_THREE_PHASE = ThreePhaseKernel()


def _default_kernel(market: SpotMarket):
    """Legacy kernel on the degenerate market (bit-for-bit with PR 1);
    notice-aware three-phase everywhere else."""
    if market.n_pools == 1 and not market.preemptible:
        return _THREE_PHASE
    return NoticeAwareKernel()


class AdaptiveTrace(NamedTuple):
    """Per-window trajectory (stacked over windows)."""

    r: jax.Array  # knob before the window's update
    window_delay: jax.Array  # d(r) measured in the window
    window_cost: jax.Array  # average cost of jobs completed in the window
    jobs: jax.Array
    completed: jax.Array
    spot_served: jax.Array
    cost_sum: jax.Array
    delay_sum: jax.Array
    time: jax.Array
    spot_arrivals: jax.Array
    spot_found_empty: jax.Array
    preemptions: jax.Array
    resumed: jax.Array


def _adaptive_core(job, market, kernel, rmax, window_events, n_windows,
                   k_cost, delta, eta, eta_decay, r0, r_max, key, ep=None,
                   max_step=None, shock_reset=False):
    """One learner's full trajectory (vmap-able over every traced arg).

    ``ep`` threads the environment-timeline axis through every window
    (non-stationary prices/hazards/availability); ``max_step`` clamps the
    per-window knob excursion and zeroes non-finite updates (poisoned
    windows can't fling ``r``); ``shock_reset`` restarts the knob at
    ``r0`` whenever a window crosses into a shock segment.  All three
    default off, compiling the identical pre-env program.
    """
    mp = market.params()
    preempt_on = market.preemptible
    state0 = init_market_state(key, job, market, rmax, mp, preempt_on,
                               ep=ep)
    if ep is not None:
        state0 = (state0, init_env_state(ep))

    def outer(sc, idx):
        state, r = sc
        state, s = run_market_window(job, market, kernel, rmax, preempt_on,
                                     state, {"r": r}, mp, k_cost,
                                     window_events, ep=ep)
        if ep is not None:
            s, es = s
        # learner horizons are unbounded (windows × events); rebase the
        # int32 join-sequence counters every window so they never wrap
        state = _rebase_order(state) if ep is None else _rebase_order_env(
            state)
        completed = jnp.maximum(s.jobs_completed, 1).astype(jnp.float32)
        d = s.delay_sum / completed
        c = s.cost_sum / completed
        step = eta / jnp.sqrt(1.0 + eta_decay * idx.astype(jnp.float32))
        upd = step * (d - delta)
        if max_step is not None:
            # guardrail: bound the excursion; a poisoned window (NaN/inf
            # delay) contributes a zero step instead of destroying r
            upd = jnp.clip(upd, -max_step, max_step)
            upd = jnp.where(jnp.isfinite(upd), upd, 0.0)
        r_new = jnp.clip(r - upd, 0.0, r_max)
        if shock_reset and ep is not None:
            # regime flip: the learned knob is stale under a new supply
            # regime — restart from r0 when the window entered a shock
            flipped = (es.storms_entered + es.blackouts_entered
                       + es.spikes_entered) > 0
            r_new = jnp.where(flipped, jnp.asarray(r0, jnp.float32), r_new)
        trace = AdaptiveTrace(
            r=r,
            window_delay=d,
            window_cost=c,
            jobs=s.jobs_arrived,
            completed=s.jobs_completed,
            spot_served=s.spot_served,
            cost_sum=s.cost_sum,
            delay_sum=s.delay_sum,
            time=s.time_elapsed,
            spot_arrivals=s.spot_arrivals,
            spot_found_empty=s.spot_found_empty,
            preemptions=jnp.sum(s.pool_preempted),
            resumed=s.resumed,
        )
        return (state, r_new), trace

    (_, r_final), traces = jax.lax.scan(
        outer, (state0, jnp.float32(r0)), jnp.arange(n_windows)
    )
    return r_final, traces


@functools.partial(
    jax.jit,
    static_argnames=("job", "market", "kernel", "rmax", "window_events",
                     "n_windows", "max_step", "shock_reset"),
)
def _adaptive_jit(job, market, kernel, rmax, window_events, n_windows,
                  k_cost, delta, eta, eta_decay, r0, r_max, key, ep=None,
                  max_step=None, shock_reset=False):
    return _adaptive_core(job, market, kernel, rmax, window_events,
                          n_windows, k_cost, delta, eta, eta_decay, r0,
                          r_max, key, ep=ep, max_step=max_step,
                          shock_reset=shock_reset)


@functools.partial(
    jax.jit,
    static_argnames=("job", "market", "kernel", "rmax", "window_events",
                     "n_windows", "max_step", "shock_reset"),
)
def _adaptive_batched_jit(job, market, kernel, rmax, window_events,
                          n_windows, k_cost, delta, eta, eta_decay, r0,
                          r_max, keys, ep=None, max_step=None,
                          shock_reset=False):
    one = functools.partial(_adaptive_core, job, market, kernel, rmax,
                            window_events, n_windows)

    def learner(kc, de, et, ed, r0_, rm, ky):
        # ep and the guardrail knobs are shared across the fleet (closed
        # over, not vmapped)
        return one(kc, de, et, ed, r0_, rm, ky, ep=ep, max_step=max_step,
                   shock_reset=shock_reset)

    return jax.vmap(learner)(k_cost, delta, eta, eta_decay, r0, r_max, keys)


def _assemble(tr, r_final) -> dict:
    """Host-side float64 running averages from a (stacked) trace.

    Works for a single learner (arrays shaped ``(n_windows,)``) and a batch
    (arrays ``(batch, n_windows)``): the window axis is the last one.
    """
    t = jax.tree.map(lambda x: np.asarray(x, np.float64), tr)
    cum_completed = np.maximum(np.cumsum(t.completed, axis=-1), 1.0)
    running_cost = np.cumsum(t.cost_sum, axis=-1) / cum_completed
    running_delay = np.cumsum(t.delay_sum, axis=-1) / cum_completed
    spot_arr = np.maximum(np.cumsum(t.spot_arrivals, axis=-1), 1.0)
    pi0_spot = np.cumsum(t.spot_found_empty, axis=-1) / spot_arr
    r_star = np.asarray(r_final, np.float64)
    return {
        "r": t.r,
        "r_star": r_star if r_star.ndim else float(r_star),
        "window_delay": t.window_delay,
        "window_cost": t.window_cost,
        "running_cost": running_cost,
        "running_delay": running_delay,
        "pi0_spot": pi0_spot,
        "final_cost": _last(running_cost),
        "final_delay": _last(running_delay),
        "final_pi0": _last(pi0_spot),
        "jobs_total": _reduce(np.sum, t.jobs),
        "time_total": _reduce(np.sum, t.time),
        "preemptions_total": _reduce(np.sum, t.preemptions),
        "resumed_total": _reduce(np.sum, t.resumed),
    }


def _last(x: np.ndarray):
    v = x[..., -1]
    return float(v) if v.ndim == 0 else v


def _reduce(fn, x: np.ndarray):
    v = fn(x, axis=-1)
    return float(v) if v.ndim == 0 else v


def adaptive_admission_control(
    job: ArrivalProcess,
    spot,
    *,
    k: float = 10.0,
    delta: float,
    eta: float = 0.05,
    eta_decay: float = 0.0,
    r0: float = 0.0,
    r_max: float = 16.0,
    window_events: int = 2048,
    n_windows: int = 400,
    rmax_slots: int = 64,
    key: jax.Array,
    kernel=None,
    env=None,
    max_step: float | None = None,
    shock_reset: bool = False,
) -> dict:
    """Run Algorithm 1; return the trajectory and running averages (float64).

    ``spot`` may be a plain :class:`ArrivalProcess` (degenerate one-pool
    market — PR-1 behaviour, bit-for-bit) or a :class:`SpotMarket` to train
    the learner against heterogeneous pools and preemption-with-notice.
    ``kernel`` overrides the policy kernel (default: shared three-phase on a
    degenerate market, :class:`NoticeAwareKernel` otherwise); it must read
    the knob from ``params["r"]``.

    Robustness knobs (all off by default, compiling the identical
    program): ``env`` trains against a non-stationary
    :class:`repro.core.env.EnvTimeline`; ``max_step`` clamps each window's
    knob update to ``±max_step`` and zeroes non-finite updates;
    ``shock_reset`` restarts the knob at ``r0`` whenever a window enters a
    storm/blackout/spike segment.

    Returns a dict with per-window arrays: ``r`` (knob), ``window_delay``,
    ``window_cost``, and running averages ``running_cost`` / ``running_delay``
    (cumulative, matching the paper's C(r(n)) and d(r(n)) plots), plus the
    final knob ``r_star`` and Theorem-1 cross-check fields.
    """
    market = as_market(spot)
    kernel = _default_kernel(market) if kernel is None else kernel
    _check_env(env)
    ep = _env_params(env, market.n_pools)
    with annotate("repro.adaptive_admission_control"):
        r_final, tr = _adaptive_jit(
            job, market, kernel, rmax_slots, window_events, n_windows,
            jnp.float32(k), jnp.float32(delta), jnp.float32(eta),
            jnp.float32(eta_decay), jnp.float32(r0), jnp.float32(r_max),
            key, ep=ep,
            max_step=None if max_step is None else float(max_step),
            shock_reset=bool(shock_reset),
        )
    return _assemble(tr, r_final)


def adaptive_admission_control_batched(
    job: ArrivalProcess,
    spot,
    *,
    k: float = 10.0,
    delta,
    eta=0.05,
    eta_decay=0.0,
    r0=0.0,
    r_max=16.0,
    window_events: int = 2048,
    n_windows: int = 400,
    rmax_slots: int = 64,
    key: jax.Array,
    independent_keys: bool = False,
    kernel=None,
    env=None,
    max_step: float | None = None,
    shock_reset: bool = False,
) -> dict:
    """Run a fleet of Algorithm-1 learners in ONE jitted scan.

    ``delta``/``eta``/``eta_decay``/``r0``/``r_max``/``k`` broadcast to a
    common 1-D batch shape — e.g. ``delta=jnp.linspace(2, 30, 16)`` trains 16
    multi-δ learners at once, or ``r0=jnp.array([0.05, 4.0])`` reproduces the
    paper's two-initialization convergence plots in a single call.  By
    default every learner sees the same event stream (common random numbers,
    so trajectories differ only through the policy); pass
    ``independent_keys=True`` to fold a per-learner offset into the key.
    ``spot`` may be a :class:`SpotMarket` (see
    :func:`adaptive_admission_control`) to train the fleet on a preemptible
    multi-pool market.

    Returns the same dict as :func:`adaptive_admission_control` with a
    leading batch axis on every array (and on the ``final_*``/``r_star``
    scalars).
    """
    market = as_market(spot)
    kernel = _default_kernel(market) if kernel is None else kernel
    _check_env(env)
    ep = _env_params(env, market.n_pools)
    args = [jnp.asarray(x, jnp.float32)
            for x in (k, delta, eta, eta_decay, r0, r_max)]
    batch = jnp.broadcast_shapes(*(a.shape for a in args), (1,))
    n = int(np.prod(batch))
    args = [jnp.broadcast_to(a, batch).reshape(-1) for a in args]
    keys = (jax.random.split(key, n) if independent_keys
            else jnp.repeat(key[None], n, axis=0))
    with annotate("repro.adaptive_admission_control_batched"):
        r_final, tr = _adaptive_batched_jit(
            job, market, kernel, rmax_slots, window_events, n_windows,
            *args, keys, ep=ep,
            max_step=None if max_step is None else float(max_step),
            shock_reset=bool(shock_reset),
        )
    # restore multi-dimensional batch shapes (e.g. a delta × r0 meshgrid)
    r_final = r_final.reshape(batch)
    tr = jax.tree.map(lambda x: x.reshape(batch + x.shape[1:]), tr)
    return _assemble(tr, r_final)
