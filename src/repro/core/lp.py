"""LP oracles for the paper's two optimization problems (+ market extension).

1. :func:`knapsack_lp` — the abstract steady-state LP (eqs. 9-11):
       max Σ π_n   s.t.  Σ n·π_n ≤ λδ,  Σ π_n ≤ 1,  π ≥ 0.
   The knapsack structure (all objective coefficients equal, constraint
   coefficients increasing in n) makes the greedy fill lowest-n-first
   optimal; we also solve it exactly by enumeration to *prove* the greedy.

2. :func:`waittime_lp` — the discretized Theorem-3 LP over the maximal-wait
   density:
       max Σ f_i F_μ(w_i)  s.t.  Σ f_i = 1,  Σ f_i H(w_i) = δ/(1−λδ),  f ≥ 0
   with H(w) = ∫₀ʷ G_μ.  An LP with two equality constraints has a basic
   optimal solution supported on ≤ 2 grid points, so exact enumeration over
   support pairs is the (scipy-free) solver.

3. :func:`market_knapsack_lp` — the heterogeneous-pool generalization.
   With pool utilizations u_p = P(a pool-p slot finds an eligible job)
   (per-pool 1 − π₀), the market Theorem-1 identity gives

       E[C] = k − Σ_p (k − c_p) (μ_p/λ) u_p,

   the per-pool occupancy bound u_p = P(N_p ≥ 1) ≤ E[N_p] plus Little's
   law Σ_p E[N_p] ≤ λδ gives Σ_p u_p ≤ λδ, and u_p ≤ 1.  Relaxing the
   shared-queue coupling (a relaxation only loosens a lower bound) leaves a
   fractional knapsack,

       max Σ_p s_p u_p,  s_p = (k − c_p)(μ_p/λ),  Σ u_p ≤ λδ,  0 ≤ u_p ≤ 1,

   whose greedy best-savings-first fill is exactly optimal.  With one unit
   pool this is the paper's min(1, λδ) bound.  ``include_preemption``
   prices in revocation: a pool with hazard h_p completes a leg with
   probability μ_p/(μ_p+h_p), so each completion pays for (μ_p+h_p)/μ_p
   legs — effective price c_p (1 + h_p/μ_p).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.arrivals import ArrivalProcess, int_G_mu


def knapsack_lp(lam: float, delta: float, n_max: int = 64) -> dict:
    """Solve eqs. (9)-(11) exactly; return greedy and enumerated optima."""
    budget = lam * delta
    # Greedy: fill π_1 first (cheapest per unit of objective), then π_2, ...
    pis = np.zeros(n_max + 1)
    remaining_mass, remaining_budget = 1.0, budget
    for n in range(1, n_max + 1):
        take = min(remaining_mass, remaining_budget / n)
        pis[n] = take
        remaining_mass -= take
        remaining_budget -= take * n
        if remaining_mass <= 1e-15 or remaining_budget <= 1e-15:
            break
    greedy_obj = float(pis.sum())
    # For this LP the optimum is min(1, λδ) and is achieved entirely at n=1.
    analytic_obj = min(1.0, budget)
    return {
        "pi": pis,
        "objective": greedy_obj,
        "analytic_objective": analytic_obj,
        "support": np.nonzero(pis)[0].tolist(),
    }


def market_knapsack_lp(k: float, lam: float, delta: float, market, *,
                       include_preemption: bool = False) -> dict:
    """Greedy-optimal fractional knapsack over heterogeneous spot pools.

    ``market`` is any object with ``rates()``/``prices()``/``hazards()``
    (a :class:`repro.core.market.SpotMarket`).  Returns per-pool
    utilizations ``u`` (bound on per-pool 1−π₀), job fractions ``sigma``
    (= (μ_p/λ)·u_p), the implied cost lower bound ``objective``, the fill
    order, and the effective prices used.
    """
    rates = np.asarray(market.rates(), np.float64)
    prices = np.asarray(market.prices(), np.float64)
    hazards = np.asarray(market.hazards(), np.float64)
    eff = prices * (1.0 + hazards / rates) if include_preemption else prices
    savings = (k - eff) * rates / lam  # objective coefficient of u_p
    budget = lam * delta
    u = np.zeros_like(rates)
    order = np.argsort(-savings, kind="stable")
    support = []
    for p in order:
        if savings[p] <= 0.0 or budget <= 1e-15:
            break  # a pool pricier than on-demand is never worth filling
        u[p] = min(1.0, budget)
        budget -= u[p]
        support.append(int(p))
    sigma = rates / lam * u
    return {
        "u": u,
        "sigma": sigma,
        "objective": float(k - np.sum(savings * u)),
        "support": support,
        "effective_prices": eff,
    }


def region_knapsack_lp(k: float, delta: float, topology, *,
                       include_preemption: bool = False) -> dict:
    """Pooled multi-region knapsack: the cost floor WITH cross-region routing.

    With routing at admission, any job can be served by any region's spot
    supply, so the supply side of a :class:`repro.core.regions.RegionTopology`
    is formally a pool market over the *total* demand rate λ = Σ_r λ_r:
    region r's slot rate μ_r, price c_r, and hazard h_r fill the
    :func:`market_knapsack_lp` greedy exactly (the topology's host views
    ``rates()``/``prices()``/``hazards()`` are deliberately pool-shaped).
    The home-only counterpart — each region its own closed single-queue
    problem — is :func:`repro.core.cost.region_cost_lower_bound` with
    ``routed=False``; the gap between the two is the value of routing.
    """
    lam_total = float(topology.total_job_rate())
    return market_knapsack_lp(k, lam_total, delta, topology,
                              include_preemption=include_preemption)


@dataclasses.dataclass
class WaitTimeLPResult:
    support: np.ndarray  # (≤2,) wait values
    masses: np.ndarray  # (≤2,) probabilities
    objective: float  # P(X > S_μ) attained
    grid: np.ndarray
    f_weights: np.ndarray  # F_μ on the grid
    h_weights: np.ndarray  # H on the grid


def waittime_lp(
    spot: ArrivalProcess,
    lam: float,
    delta: float,
    *,
    grid_points: int = 1200,
    w_max: float | None = None,
) -> WaitTimeLPResult:
    """Exact discretized Theorem-3 LP via ≤2-point support enumeration."""
    target = delta / (1.0 - lam * delta)
    if w_max is None:
        su = spot.support_upper()
        w_max = su * 1.5 if np.isfinite(su) else spot.mean() * 20.0
    w = np.linspace(0.0, w_max, grid_points)
    F = spot.cdf(w)  # objective weights
    H = int_G_mu(spot, w)  # constraint weights

    # Single-point solutions: H_i == target.
    best_obj, best_support, best_masses = -np.inf, None, None
    close = np.abs(H - target) < 1e-12
    if close.any():
        i = int(np.argmax(np.where(close, F, -np.inf)))
        best_obj, best_support, best_masses = (
            float(F[i]),
            np.array([w[i]]),
            np.array([1.0]),
        )

    # Two-point solutions: fi·Hi + fj·Hj = target, fi + fj = 1, 0 ≤ fi ≤ 1.
    Hi = H[:, None]
    Hj = H[None, :]
    denom = Hi - Hj
    with np.errstate(divide="ignore", invalid="ignore"):
        fi = (target - Hj) / denom
        valid = np.isfinite(fi) & (fi >= 0.0) & (fi <= 1.0)
        obj = np.where(
            valid,
            np.nan_to_num(fi) * F[:, None]
            + (1.0 - np.nan_to_num(fi)) * F[None, :],
            -np.inf,
        )
    ij = np.unravel_index(np.argmax(obj), obj.shape)
    if obj[ij] > best_obj:
        i, j = int(ij[0]), int(ij[1])
        best_obj = float(obj[ij])
        best_support = np.array([w[i], w[j]])
        best_masses = np.array([float(fi[i, j]), 1.0 - float(fi[i, j])])

    if best_support is None:
        raise ValueError("wait-time LP infeasible on the given grid")
    order = np.argsort(best_support)
    return WaitTimeLPResult(
        support=best_support[order],
        masses=best_masses[order],
        objective=best_obj,
        grid=w,
        f_weights=F,
        h_weights=H,
    )


def waittime_lp_cost(k: float, lam: float, delta: float,
                     result: WaitTimeLPResult) -> float:
    """E[C] implied by an LP solution via eq. (2):
    E[C] = k − (k−1)(1−λδ)·P(X > S_μ)."""
    return k - (k - 1.0) * (1.0 - lam * delta) * result.objective
