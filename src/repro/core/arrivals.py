"""Renewal arrival processes for jobs and spot instances.

The paper models both the job stream and the spot-slot stream as renewal
processes with IID inter-arrival times.  Each process here is a small static
descriptor whose ``sample(key)`` builds traced JAX sampling code, so a
process can be closed over inside ``lax.scan`` bodies (the distribution type
is static at trace time; only its parameters are traced).

Implemented families (paper §V):
  * ``Exponential(rate)``           — Poisson process.
  * ``Gamma(shape, scale)``         — paper's Gamma(1/λ, 1) job arrivals.
  * ``Uniform(low, high)``          — finite-support spot model (Corollary 1/2).
  * ``Deterministic(value)``        — degenerate renewal process.
  * ``BathtubGCP(A, tau1, tau2, b)``— Kadupitige et al. [27] preemptible-GCP
    spot availability: a fraction ``A`` of slots arrive almost immediately
    (Exp(tau1) head) and ``1-A`` arrive near the ~24 h preemption deadline
    (reversed-Exp(tau2) spike at ``b``).

Note on the bathtub CDF: the paper prints
``F_S(t) = A(1 - exp(-t/τ1) + exp((t-b)/τ2) 1{t<=τ2})`` which is degenerate as
written (the second term is ~e^{-30} on its support).  We use the mixture form
of [27] that the printed formula garbles,

    F_S(t) = A (1 - e^{-t/τ1}) + (1 - A) e^{(t-b)/τ2},   t in [0, b],

whose density is the intended bathtub (mass near 0 and near b≈24 h), and whose
mean with the paper's parameter ranges (A≈0.5, τ1≈1, τ2≈0.8, b≈24) is ≈12 h,
matching the paper's "μ ≈ 1/12".
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clocks import exp_from_u as _exp_from_u


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Base renewal process; subclasses define sampling and moments.

    Two traceable sampling backends: :meth:`sample` draws from a PRNG key
    (the engine's ``rng="split"`` stream), while :meth:`sample_u` transforms
    ``u_dim`` pre-drawn float32 uniforms — the ``rng="slab"`` stream, where
    the engine hands the event body slab columns instead of keys (see
    :mod:`repro.core.clocks`).  The two backends are equal in distribution,
    not bitwise.
    """

    #: uniform draws :meth:`sample_u` consumes (None = no slab sampler;
    #: the engine's ``rng="slab"`` raises and points at ``rng="split"``).
    #: A ClassVar, not a dataclass field, so frozen subclasses keep their
    #: positional constructors.
    u_dim: ClassVar[int | None] = None

    def sample(self, key: jax.Array) -> jax.Array:
        """Draw one inter-arrival time (scalar, float32). Traceable."""
        raise NotImplementedError

    def sample_u(self, u: jax.Array) -> jax.Array:
        """Transform ``u[:u_dim]`` float32 uniforms into one draw."""
        raise NotImplementedError

    def mean(self) -> float:
        raise NotImplementedError

    def rate(self) -> float:
        return 1.0 / self.mean()

    def cdf(self, t: np.ndarray) -> np.ndarray:
        """Numpy CDF on a grid (used for analytics, not in traced code)."""
        raise NotImplementedError

    def support_upper(self) -> float:
        """Finite upper support L if any, else +inf."""
        return math.inf


@dataclasses.dataclass(frozen=True)
class Exponential(ArrivalProcess):
    rate_: float

    u_dim: ClassVar[int] = 1

    def sample(self, key):
        return jax.random.exponential(key, dtype=jnp.float32) / self.rate_

    def sample_u(self, u):
        return _exp_from_u(u[0]) / jnp.float32(self.rate_)

    def mean(self):
        return 1.0 / self.rate_

    def cdf(self, t):
        t = np.asarray(t, np.float64)
        return np.where(t >= 0, 1.0 - np.exp(-self.rate_ * t), 0.0)


@dataclasses.dataclass(frozen=True)
class Gamma(ArrivalProcess):
    shape: float
    scale: float = 1.0

    @property
    def u_dim(self):
        # Gamma(n, scale) with integer n is a sum of n unit exponentials —
        # a fixed-draw-count sampler.  Non-integer shapes need jax's
        # rejection sampler (unbounded draws), which the slab stream cannot
        # drive; u_dim=None routes those configs to rng="split".
        n = round(self.shape)
        return n if (n > 0 and math.isclose(n, self.shape)) else None

    def sample(self, key):
        return jax.random.gamma(key, self.shape, dtype=jnp.float32) * self.scale

    def sample_u(self, u):
        n = self.u_dim
        return -jnp.sum(jnp.log1p(-u[:n])) * jnp.float32(self.scale)

    def mean(self):
        return self.shape * self.scale

    def cdf(self, t):
        # Regularized lower incomplete gamma via series/continued fraction-free
        # route: use numpy-compatible igamma from jax on host.
        t = np.asarray(t, np.float64)
        vals = jax.scipy.special.gammainc(self.shape, np.maximum(t, 0.0) / self.scale)
        return np.asarray(vals)


@dataclasses.dataclass(frozen=True)
class Uniform(ArrivalProcess):
    low: float
    high: float

    u_dim: ClassVar[int] = 1

    def sample(self, key):
        return jax.random.uniform(
            key, dtype=jnp.float32, minval=self.low, maxval=self.high
        )

    def sample_u(self, u):
        return jnp.float32(self.low) + u[0] * jnp.float32(self.high - self.low)

    def mean(self):
        return 0.5 * (self.low + self.high)

    def cdf(self, t):
        t = np.asarray(t, np.float64)
        return np.clip((t - self.low) / (self.high - self.low), 0.0, 1.0)

    def support_upper(self):
        return self.high


@dataclasses.dataclass(frozen=True)
class Deterministic(ArrivalProcess):
    value: float

    u_dim: ClassVar[int] = 0

    def sample(self, key):
        del key
        return jnp.asarray(self.value, jnp.float32)

    def sample_u(self, u):
        del u
        return jnp.asarray(self.value, jnp.float32)

    def mean(self):
        return self.value

    def cdf(self, t):
        t = np.asarray(t, np.float64)
        return (t >= self.value).astype(np.float64)

    def support_upper(self):
        return self.value


@dataclasses.dataclass(frozen=True)
class BathtubGCP(ArrivalProcess):
    """Kadupitige-et-al. bathtub model of preemptible-GCP spot availability."""

    A: float = 0.5
    tau1: float = 1.0
    tau2: float = 0.8
    b: float = 24.0

    u_dim: ClassVar[int] = 3

    def sample(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        pick_head = jax.random.uniform(k1) < self.A
        head = jnp.minimum(
            jax.random.exponential(k2, dtype=jnp.float32) * self.tau1, self.b
        )
        tail = jnp.maximum(
            self.b - jax.random.exponential(k3, dtype=jnp.float32) * self.tau2, 0.0
        )
        return jnp.where(pick_head, head, tail)

    def sample_u(self, u):
        pick_head = u[0] < jnp.float32(self.A)
        head = jnp.minimum(_exp_from_u(u[1]) * jnp.float32(self.tau1),
                           jnp.float32(self.b))
        tail = jnp.maximum(
            jnp.float32(self.b) - _exp_from_u(u[2]) * jnp.float32(self.tau2),
            0.0)
        return jnp.where(pick_head, head, tail)

    def mean(self):
        # E[min(Exp(tau1), b)] = tau1 (1 - e^{-b/tau1}); E[max(b - Exp(tau2), 0)]
        # = b - tau2 (1 - e^{-b/tau2}).
        head = self.tau1 * (1.0 - math.exp(-self.b / self.tau1))
        tail = self.b - self.tau2 * (1.0 - math.exp(-self.b / self.tau2))
        return self.A * head + (1.0 - self.A) * tail

    def cdf(self, t):
        t = np.asarray(t, np.float64)
        head = np.where(t >= self.b, 1.0, 1.0 - np.exp(-np.maximum(t, 0) / self.tau1))
        tail = np.where(
            t >= self.b, 1.0, np.exp(np.minimum(t - self.b, 0.0) / self.tau2)
        )
        out = self.A * head + (1.0 - self.A) * tail
        return np.where(t < 0, 0.0, out)

    def support_upper(self):
        return self.b


def prob_A_le_S(
    job: ArrivalProcess, spot: ArrivalProcess, grid_points: int = 200_000
) -> float:
    """P(A <= S) via numeric integration: ∫ P(S >= t) dF_A(t).

    Used for the Theorem-2 regime boundary δ <= P(A <= S_μ)/λ.
    """
    upper = min(
        max(job.mean(), spot.mean()) * 40.0,
        max(
            job.support_upper() if math.isfinite(job.support_upper()) else math.inf,
            spot.support_upper() if math.isfinite(spot.support_upper()) else math.inf,
        )
        if (math.isfinite(job.support_upper()) or math.isfinite(spot.support_upper()))
        else max(job.mean(), spot.mean()) * 40.0,
    )
    if not math.isfinite(upper):
        upper = max(job.mean(), spot.mean()) * 40.0
    t = np.linspace(0.0, upper, grid_points)
    fa = np.gradient(job.cdf(t), t)  # density of A on the grid
    gs = 1.0 - spot.cdf(t)  # survival of S
    return float(np.trapezoid(fa * gs, t))


def int_G_mu(spot: ArrivalProcess, w: np.ndarray) -> np.ndarray:
    """H(w) = ∫_0^w G_μ(y) dy on a grid (Theorem-3 constraint weight)."""
    w = np.asarray(w, np.float64)
    hi = float(np.max(w)) if w.size else 1.0
    grid = np.linspace(0.0, max(hi, 1e-9), 200_000)
    g = 1.0 - spot.cdf(grid)
    cum = np.concatenate([[0.0], np.cumsum((g[1:] + g[:-1]) * 0.5 * np.diff(grid))])
    return np.interp(w, grid, cum)
