"""SpotMarket — heterogeneous spot pools with preemption-with-notice.

The paper (and PR 1's engine) models ONE spot arrival process and never
revokes work.  Real spot markets are many *pools* (instance type × zone)
with distinct prices and availability, and instances are reclaimed with an
advance-notice window.  This module is the static descriptor layer of the
on-device market subsystem:

  * :class:`SpotPool`   — one pool: traced arrival process, price ``c_p``,
    preemption hazard ``h_p`` (Poisson revocation clock), notice window.
  * :class:`SpotMarket` — a static, hashable tuple of pools.  The engine
    (:mod:`repro.core.engine`) carries a small *vector* of per-pool
    ``next_spot``/``next_preempt`` clocks merged into its renewal event loop;
    pool events join the existing spot > deadline > job tie order (preempt
    slots in after spot: spot > preempt > deadline > job).
  * market policy kernels — the engine protocol gains a pool-choice hook::

        admit_market(params, qlen, pool_state, key) -> (admit?, budget, pool)

    plus a preemption hook consulted when a pool revokes a running job::

        on_preempt(params, age, notice, qlen, key) -> resume?

    Legacy two-tuple kernels (``admit(params, qlen, key)``) still work —
    the engine routes them to pool 0 and defects on preemption, which is
    exactly the degenerate market.
  * :func:`checkpoint_within_notice` — the one notice law, shared by the
    traced :class:`NoticeAwareKernel` and the host cluster orchestrator
    (same dual host/traced backend pattern as ``three_phase_admit_prob``).

Model semantics (recorded in EXPERIMENTS.md):

  * A queued job tagged pool ``p`` *is running on a pool-p spot instance*;
    the pool's spot event is its service completion (cost ``c_p``).
  * Pool ``p``'s preempt event revokes the FIFO-oldest pool-p job (the
    longest-running instance).  The partial leg is paid (``c_p``), then the
    kernel decides: **checkpoint within the notice window and re-enter the
    queue** (age resets, the job re-joins FIFO order on the same pool — the
    orchestrator's leg accounting) or **defect to on-demand** (cost ``k``,
    delay = its age).  A zero-hazard pool never fires; its clock stays at
    INF and the engine statically removes the whole preemption path, which
    is how the degenerate 1-pool market reproduces the PR-1 engine
    bit-for-bit.
  * Per-pool PRNG streams are keyed by ``fold_in(key, pool.tag)`` — a
    *label-independent* identity — so relabeling (permuting) pools with
    their tags leaves every sampled stream, and therefore π₀ and the cost
    accounting, exactly invariant (tie-breaks between pools are by position
    but ties are measure-zero for continuous samplers).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.clocks import choice_cols, gumbel_from_u, kernel_slab_cols
from repro.core.policies import three_phase_admit_prob

_INF = np.float32(3e38)  # np scalar: inlines as a literal in kernel traces


# ---------------------------------------------------------------------------
# Descriptors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpotPool:
    """One spot pool: arrival process + price + preemption hazard/notice.

    ``tag`` is the pool's stable PRNG-stream identity (defaults to its index
    in the market); keep tags fixed when permuting pools to get bitwise
    relabel-invariance.
    """

    arrival: ArrivalProcess
    price: float = 1.0
    hazard: float = 0.0  # preemption events per unit time on the running job
    notice: float = 0.0  # advance-notice window length
    tag: int | None = None

    def rate(self) -> float:
        return self.arrival.rate()


@dataclasses.dataclass(frozen=True)
class SpotMarket:
    """P heterogeneous spot pools as one static, hashable descriptor."""

    pools: tuple[SpotPool, ...]

    def __post_init__(self):
        if not self.pools:
            raise ValueError("a SpotMarket needs at least one pool")
        tagged = tuple(
            dataclasses.replace(p, tag=i) if p.tag is None else p
            for i, p in enumerate(self.pools)
        )
        tags = [p.tag for p in tagged]
        if len(set(tags)) != len(tags):
            raise ValueError(f"pool tags must be unique, got {tags}")
        object.__setattr__(self, "pools", tagged)

    # ------------------------------------------------------------- structure
    @property
    def n_pools(self) -> int:
        return len(self.pools)

    @property
    def preemptible(self) -> bool:
        """Static: does any pool carry a preemption hazard?"""
        return any(p.hazard > 0.0 for p in self.pools)

    @property
    def is_degenerate(self) -> bool:
        """1 pool, unit price, zero hazard — the PR-1 engine, bit-for-bit."""
        p = self.pools[0]
        return self.n_pools == 1 and p.hazard == 0.0 and p.price == 1.0

    # ------------------------------------------------------------ host views
    def prices(self) -> np.ndarray:
        return np.array([p.price for p in self.pools], np.float64)

    def hazards(self) -> np.ndarray:
        return np.array([p.hazard for p in self.pools], np.float64)

    def notices(self) -> np.ndarray:
        return np.array([p.notice for p in self.pools], np.float64)

    def rates(self) -> np.ndarray:
        return np.array([p.rate() for p in self.pools], np.float64)

    def total_rate(self) -> float:
        return float(self.rates().sum())

    # --------------------------------------------------------- traced params
    def params(self) -> dict:
        """Traced pools-config pytree consumed by the engine event loop.

        ``spot_scale`` multiplies pool inter-arrival times (scale > 1 =
        scarcer slots) — a distribution-generic availability axis that a
        sweep can trace without retracing the arrival family.  ``rate`` is
        the raw (unscaled) per-pool slot rate; it rides in the traced
        params rather than being materialized inside the event body so the
        body stays constant-capture-free under the Pallas kernel trace.
        """
        return {
            "price": jnp.asarray(self.prices(), jnp.float32),
            "hazard": jnp.asarray(self.hazards(), jnp.float32),
            "notice": jnp.asarray(self.notices(), jnp.float32),
            "spot_scale": jnp.ones((self.n_pools,), jnp.float32),
            "rate": jnp.asarray(self.rates(), jnp.float32),
        }

    # ------------------------------------------------------------- utilities
    @staticmethod
    def single(spot: ArrivalProcess, *, price: float = 1.0,
               hazard: float = 0.0, notice: float = 0.0) -> "SpotMarket":
        """A one-pool market (``hazard=0`` is the PR-1 degenerate case)."""
        return SpotMarket(pools=(SpotPool(arrival=spot, price=price,
                                          hazard=hazard, notice=notice,
                                          tag=0),))

    def relabel(self, perm: Sequence[int]) -> "SpotMarket":
        """Permute pool positions, keeping each pool's tag (PRNG identity)."""
        if sorted(perm) != list(range(self.n_pools)):
            raise ValueError(f"not a permutation of {self.n_pools} pools")
        return SpotMarket(pools=tuple(self.pools[i] for i in perm))


def as_market(spot) -> SpotMarket:
    """Coerce an :class:`ArrivalProcess` (or a market) to a SpotMarket."""
    if isinstance(spot, SpotMarket):
        return spot
    if isinstance(spot, ArrivalProcess):
        return SpotMarket.single(spot)
    raise TypeError(f"expected ArrivalProcess or SpotMarket, got {spot!r}")


# ---------------------------------------------------------------------------
# The notice law (one source, host + traced — like three_phase_admit_prob)
# ---------------------------------------------------------------------------


def checkpoint_within_notice(checkpoint_time, notice):
    """Can a revoked job checkpoint before its instance disappears?

    Host scalars take the pure-Python path (the cluster orchestrator calls
    this once per live preemption); traced inputs take the jnp path the
    engine kernel scans over.
    """
    if not (isinstance(checkpoint_time, jax.Array)
            or isinstance(notice, jax.Array)):
        return checkpoint_time <= notice
    return jnp.asarray(checkpoint_time, jnp.float32) <= jnp.asarray(
        notice, jnp.float32)


# ---------------------------------------------------------------------------
# Market policy-kernel protocol
# ---------------------------------------------------------------------------


class PoolState(NamedTuple):
    """Non-clairvoyant per-pool state handed to ``admit_market``."""

    price: jax.Array  # (P,) f32  current pool prices c_p
    hazard: jax.Array  # (P,) f32 preemption hazards h_p
    notice: jax.Array  # (P,) f32 notice windows
    rate: jax.Array  # (P,) f32  slot arrival rates (scaled)
    qlen_pool: jax.Array  # (P,) i32 queued jobs per pool


@runtime_checkable
class MarketPolicyKernel(Protocol):
    """Pool-aware policy kernel (superset of the PR-1 two-tuple protocol)."""

    def admit_market(self, params, qlen: jax.Array, pool_state: PoolState,
                     key: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Return (admit?, wait budget, pool index) for an arriving job."""
        ...

    def on_preempt(self, params, age: jax.Array, notice: jax.Array,
                   qlen: jax.Array, key: jax.Array) -> jax.Array:
        """Revoked job: True = checkpoint + re-enter queue, False = defect."""
        ...


def choose_pool(choice: str, pool_state: PoolState, params,
                key: jax.Array) -> jax.Array:
    """Static pool-choice rules shared by the market kernels.

    ``cheapest`` / ``fastest`` / ``least_loaded`` are deterministic argmins;
    ``uniform`` draws uniformly; ``weighted`` Gumbel-samples from traced
    ``params["pool_logits"]`` so the pool distribution itself can be swept
    or learned on-device.
    """
    n = pool_state.price.shape[0]
    if choice == "cheapest":
        return jnp.argmin(pool_state.price).astype(jnp.int32)
    if choice == "fastest":
        return jnp.argmax(pool_state.rate).astype(jnp.int32)
    if choice == "least_loaded":
        return jnp.argmin(pool_state.qlen_pool).astype(jnp.int32)
    if choice == "uniform":
        return jax.random.randint(key, (), 0, n, jnp.int32)
    if choice == "weighted":
        g = jax.random.gumbel(key, (n,), jnp.float32)
        return jnp.argmax(params["pool_logits"] + g).astype(jnp.int32)
    raise ValueError(f"unknown pool choice rule {choice!r}")


def choose_pool_u(choice: str, pool_state: PoolState, params,
                  u: jax.Array) -> jax.Array:
    """Slab-stream twin of :func:`choose_pool`: draws come from pre-drawn
    float32 uniforms instead of a key (``repro.core.clocks.choice_cols``
    says how many).  Deterministic rules consume nothing; ``uniform`` one
    column; ``weighted`` Gumbel-samples from ``n`` columns.  Equal in
    distribution to the key path, not bitwise.
    """
    n = pool_state.price.shape[0]
    if choice == "uniform":
        return jnp.minimum((u[0] * n).astype(jnp.int32), n - 1)
    if choice == "weighted":
        g = gumbel_from_u(u[:n])
        return jnp.argmax(params["pool_logits"] + g).astype(jnp.int32)
    return choose_pool(choice, pool_state, params, key=None)


@dataclasses.dataclass(frozen=True)
class PoolChoiceKernel:
    """Adapt any legacy kernel to the market protocol with a choice rule.

    Admission and wait budgets come from ``base.admit``; the pool comes from
    :func:`choose_pool`.  Preempted jobs always defect to on-demand (use
    :class:`NoticeAwareKernel` for checkpoint-aware recovery).
    """

    base: object  # legacy PolicyKernel
    choice: str = "cheapest"

    def admit_market(self, params, qlen, pool_state, key):
        k_adm, k_choice = jax.random.split(key)
        admit, budget = self.base.admit(params, qlen, k_adm)
        return admit, budget, choose_pool(self.choice, pool_state, params,
                                          k_choice)

    def on_preempt(self, params, age, notice, qlen, key):
        del params, age, notice, qlen, key
        return jnp.zeros((), jnp.bool_)

    def slab_cols(self, hook, n):
        if hook == "admit_market":
            base_cols = kernel_slab_cols(self.base, "admit", n)
            if base_cols is None:  # legacy base: whole hook falls back
                return None
            return base_cols + choice_cols(self.choice, n)
        if hook == "on_preempt":
            return 0  # always defects — draws nothing
        return None

    def admit_market_u(self, params, qlen, pool_state, u):
        base_cols = kernel_slab_cols(self.base, "admit",
                                     pool_state.price.shape[0])
        admit, budget = self.base.admit_u(params, qlen, u[:base_cols])
        return admit, budget, choose_pool_u(self.choice, pool_state, params,
                                            u[base_cols:])

    def on_preempt_u(self, params, age, notice, qlen, u):
        del params, age, notice, qlen, u
        return jnp.zeros((), jnp.bool_)


@dataclasses.dataclass(frozen=True)
class NoticeAwareKernel:
    """Three-phase admission + pool choice + checkpoint-within-notice.

    Matches the host orchestrator's preemption model: a revoked job
    checkpoints iff its checkpoint takes no longer than the pool's notice
    window (:func:`checkpoint_within_notice`), then re-enters admission
    under the same Theorem-4 law (``three_phase_admit_prob`` at the current
    queue length) — recovery *is* the admission policy.  Failing either
    test it defects to on-demand.

    Params: ``{"r": f32}`` (+ optional traced ``"ckpt"`` overriding the
    static ``checkpoint_time``, so checkpoint cost can be swept in-jit).
    """

    checkpoint_time: float = 0.05
    choice: str = "cheapest"

    def init_params(self, r: float, ckpt: float | None = None) -> dict:
        p = {"r": jnp.float32(r)}
        if ckpt is not None:
            p["ckpt"] = jnp.float32(ckpt)
        return p

    def admit_market(self, params, qlen, pool_state, key):
        k_adm, k_choice = jax.random.split(key)
        p = three_phase_admit_prob(qlen, params["r"])
        admit = jax.random.uniform(k_adm) < p
        pool = choose_pool(self.choice, pool_state, params, k_choice)
        return admit, _INF, pool

    def on_preempt(self, params, age, notice, qlen, key):
        del age
        ckpt = params.get("ckpt", jnp.float32(self.checkpoint_time))
        within = checkpoint_within_notice(ckpt, notice)
        readmit = jax.random.uniform(key) < three_phase_admit_prob(
            qlen, params["r"])
        return within & readmit

    def slab_cols(self, hook, n):
        if hook == "admit_market":
            return 1 + choice_cols(self.choice, n)  # admission draw + rule
        if hook == "on_preempt":
            return 1  # the re-admission draw
        return None

    def admit_market_u(self, params, qlen, pool_state, u):
        p = three_phase_admit_prob(qlen, params["r"])
        admit = u[0] < p
        pool = choose_pool_u(self.choice, pool_state, params, u[1:])
        return admit, _INF, pool

    def on_preempt_u(self, params, age, notice, qlen, u):
        del age
        ckpt = params.get("ckpt", jnp.float32(self.checkpoint_time))
        within = checkpoint_within_notice(ckpt, notice)
        readmit = u[0] < three_phase_admit_prob(qlen, params["r"])
        return within & readmit


def _failover_alive(target, alive, price):
    """Re-target a dead loc to the cheapest alive one (identity when the
    chosen loc is alive; position 0 when nothing is — callers gate on
    ``jnp.any(alive)``)."""
    cheapest_alive = jnp.argmin(jnp.where(alive, price, _INF)).astype(
        jnp.int32)
    return jnp.where(alive[target], jnp.asarray(target, jnp.int32),
                     cheapest_alive)


@dataclasses.dataclass(frozen=True)
class PanicKernel:
    """Blackout-failover wrapper: degrade gracefully when supply goes dark.

    A blacked-out pool/region's slot rate is exactly zero (the environment
    timeline multiplies rates by availability before the kernel sees them),
    so ``rate > 0`` is the kernel-visible liveness signal.  PanicKernel
    delegates every decision to ``base`` and then repairs it:

      * an admission targeting a dead pool is re-routed to the cheapest
        alive pool;
      * when EVERY pool is dark the job is rejected outright, falling back
        to on-demand at cost ``k`` — the engine's degraded mode;
      * region routing re-targets dead regions the same way (wrapping a
        routing base repairs its rule; wrapping a non-routing base adds a
        home-unless-dead rule, so any kernel becomes blackout-tolerant).

    The failover consumes no randomness — slab layouts are the base
    kernel's — and with no blackout in the timeline ``alive`` is all-True,
    making every repair the identity: stats are bitwise the base kernel's
    (frozen in tests/test_env.py).

    ``drain_dead=True`` additionally repairs jobs ALREADY QUEUED on a pool
    that goes dark mid-wait: the market event body re-tags every occupied
    slot whose pool has zero availability to the cheapest alive pool
    (the stranded-job caveat — without it those jobs pin ``qlen`` until
    their wait budgets expire).  Opt-in because re-tagging changes which
    slot the next spot arrival serves; identity whenever no blackout is
    active.  Market loop only: the region loop's slot→region map is
    static, so stranded REGION jobs still drain via the deadline path.
    """

    base: object  # any PolicyKernel / MarketPolicyKernel / routing kernel
    drain_dead: bool = False  # re-queue jobs stranded on a dead pool

    # --------------------------------------------------------- admission
    def admit_market(self, params, qlen, pool_state, key):
        if hasattr(self.base, "admit_market"):
            admit, budget, pool = self.base.admit_market(
                params, qlen, pool_state, key)
        else:  # legacy two-tuple kernel: engine would pin it to pool 0
            admit, budget = self.base.admit(params, qlen, key)
            pool = jnp.zeros((), jnp.int32)
        alive = pool_state.rate > 0.0
        pool = _failover_alive(pool, alive, pool_state.price)
        return admit & jnp.any(alive), budget, pool

    def on_preempt(self, params, age, notice, qlen, key):
        if hasattr(self.base, "on_preempt"):
            return self.base.on_preempt(params, age, notice, qlen, key)
        return jnp.zeros((), jnp.bool_)

    # ----------------------------------------------------------- routing
    def route(self, params, qlens, region_state, key):
        if hasattr(self.base, "route"):
            target = self.base.route(params, qlens, region_state, key)
        else:
            target = region_state.home
        alive = region_state.rate > 0.0
        return _failover_alive(target, alive, region_state.price)

    # -------------------------------------------------- slab-stream twins
    def slab_cols(self, hook, n):
        if hook == "route":
            if not hasattr(self.base, "route"):
                return 0  # home fallback draws nothing
            return kernel_slab_cols(self.base, "route", n)
        if hook == "admit_market" and not hasattr(self.base, "admit_market"):
            return kernel_slab_cols(self.base, "admit", n)
        if hook == "on_preempt" and not hasattr(self.base, "on_preempt"):
            return 0  # defect fallback draws nothing
        return kernel_slab_cols(self.base, hook, n)

    def admit_market_u(self, params, qlen, pool_state, u):
        if hasattr(self.base, "admit_market"):
            admit, budget, pool = self.base.admit_market_u(
                params, qlen, pool_state, u)
        else:
            admit, budget = self.base.admit_u(params, qlen, u)
            pool = jnp.zeros((), jnp.int32)
        alive = pool_state.rate > 0.0
        pool = _failover_alive(pool, alive, pool_state.price)
        return admit & jnp.any(alive), budget, pool

    def on_preempt_u(self, params, age, notice, qlen, u):
        if hasattr(self.base, "on_preempt"):
            return self.base.on_preempt_u(params, age, notice, qlen, u)
        return jnp.zeros((), jnp.bool_)

    def route_u(self, params, qlens, region_state, u):
        if hasattr(self.base, "route"):
            target = self.base.route_u(params, qlens, region_state, u)
        else:
            target = region_state.home
        alive = region_state.rate > 0.0
        return _failover_alive(target, alive, region_state.price)

    def __getattr__(self, name):
        # delegate the hooks the wrapper doesn't repair, so the engine's
        # hasattr dispatch sees the base's protocol for them
        if name in ("admit", "admit_u", "init_params"):
            return getattr(object.__getattribute__(self, "base"), name)
        raise AttributeError(name)
