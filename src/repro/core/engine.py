"""Policy-generic, vmap-batched G/G/1+spot sweep engine.

One merged-renewal event loop replaces the two near-duplicate simulators the
seed carried (``run_queue_sim`` / ``run_single_slot_sim``): the loop is
parameterized by a traced **policy kernel** and the two paper policies become
small kernel implementations (:class:`repro.core.policies.ThreePhaseKernel`,
:class:`repro.core.policies.SingleSlotKernel`).

Policy-kernel protocol
----------------------
(Full reference: docs/kernels.md — all four hooks, tie order, worked
example.)  A kernel is a hashable (frozen-dataclass) static object with
one traced hook::

    admit(params, qlen, key) -> (admit: bool[], budget: f32[])

called once per merged event with the *pre-event* queue length and a fresh
PRNG subkey.  On a job-arrival event the engine admits the job iff
``admit & (qlen < rmax)`` and stamps it with the returned *wait budget*
(``on_join``): the maximal time the job will wait for a spot slot.  A budget
of :data:`INF` means "wait indefinitely" (Theorem 4); a finite budget arms a
**defect-on-deadline** event — when it expires the job leaves the queue for
an on-demand instance (cost ``k``, delay = its age), exactly the Theorems-2/3
maximal-wait semantics.  ``params`` is an arbitrary traced pytree (the
admission knob ``r``, wait-time parameters, …) so a whole parameter grid can
be ``vmap``-ed without retracing.

Queue representation
--------------------
A slot-mask ring: ``ages``/``budgets``/``order`` arrays of static size
``rmax`` plus an occupancy mask.  Spot slots serve the FIFO-oldest occupied
slot (min join ``order``); deadlines fire on the slot with the smallest
remaining budget.  This is O(rmax) per event — the same as the seed's ring
buffer — but supports out-of-order departures, which a head/tail ring cannot.
``order``/``next_seq`` are int32, rebased to the oldest occupied sequence at
every window boundary (:func:`_rebase_order`), so admission counts are
unbounded.

Event-time ties (measure-zero for continuous samplers) resolve
spot > deadline > job, matching the seed's single-slot simulator.

Numerics
--------
Ages are relative (incremented by the inter-event gap ``dt``), never absolute
event times, so float32 precision does not degrade over long horizons.  Sums
are accumulated in float32 **per chunk** (:func:`run_chunked` re-zeros the
accumulator every ``chunk_events`` events) and assembled in float64 on the
host by :func:`summarize` — a multi-billion-event horizon loses no more
precision than its last chunk.  With a single chunk the engine reproduces the
seed simulators bit-for-bit per seed (verified in tests/test_core_engine.py
against frozen reference copies of the seed event bodies).

Batched sweeps
--------------
:func:`run_sweep` broadcasts a params pytree + cost ratio ``k`` to a common
grid shape, pairs it with ``n_seeds`` common-random-number seeds, and runs
the whole (grid × seeds) fleet as ONE jitted nested-``vmap`` program — no
per-point Python dispatch, no retracing.  Cost accounting (paper §II): spot
service costs 1, an on-demand dispatch costs ``k``; π₀ is tracked both
time-averaged and as the fraction of spot arrivals finding the queue empty
(the quantity Theorem 1's proof uses).

Executors (``impl=``)
---------------------
Every entry point dispatches between executors sharing the same traced
event bodies: ``impl="xla"`` is the nested-vmap ``lax.scan`` program above;
``impl="pallas"`` hands the fleet to the batched-event kernel in
:mod:`repro.kernels.sweep` — engine state laid out as (tile, rmax) VMEM
blocks (market clocks as (tile, n_pools)) resident across a whole float32
window of events, with the clock merge, slot reductions, and one-hot
updates fused into one kernel body instead of N width-``rmax`` HLO selects
re-read from HBM per event; ``impl="ref"`` is the kernel's pure-JAX scan
reference on the identical lane layout.  Bit-for-bit contract
(tests/test_sweep_kernel.py): pallas == ref to the last bit on every
config and tile size; against the ``"xla"`` executor, integer event
accounting is bitwise identical and float32 window sums match to ~1 ulp
(the XLA executor keeps a broadcast-nested batch layout that is ~2.5×
faster on CPU but whose transcendental codegen can round an ulp apart —
see EXPERIMENTS.md).  ``interpret=None`` auto-falls back to the Pallas
interpreter off-TPU, so tier-1 stays green everywhere.

Randomness (``rng=``)
---------------------
Every entry point also dispatches between two PRNG streams (PR 5; full
story in EXPERIMENTS.md §"Event-loop RNG" and :mod:`repro.core.clocks`):
``rng="split"`` (default) is the frozen per-event split/fold_in ladder the
seed wrappers and every bitwise contract are pinned to; ``rng="slab"``
generates one ``(window_events, n_cols)`` uint32 slab per float32 window
with a single counter-based threefry call and has the event body consume
draws by static column index — no per-event key arithmetic, the per-pool/
per-region Poisson preemption clock vectors collapsed to one scalar clock
at the superposed total hazard (exact, by the superposition theorem), and,
in the Pallas executor, the slab arriving as a plain VMEM input block per
window (zero in-kernel RNG).  The slab stream holds the pallas == ref ==
xla integer-accounting ledger on its own terms; slab-vs-split equivalence
is distributional (KS-tested in tests/test_event_rng.py), so ``"slab"`` is
the stream for new sweeps and ``"split"`` the compatibility stream.

Telemetry (``telemetry=``)
--------------------------
Every entry point dispatches a third static axis (PR 7; full story in
docs/observability.md and :mod:`repro.obs`): ``telemetry=None`` (default)
compiles exactly today's program — the telemetry branch of every event
body is statically absent, so the off path is *bitwise* the pre-telemetry
engine on all three loops × all three executors (frozen in
tests/test_obs.py).  With a :class:`repro.obs.Telemetry` descriptor the
stats pytree becomes a ``(base, telemetry)`` pair riding through the same
scanners/kernels (both are generic over the stats pytree), and the event
bodies additionally fold each event into streaming log-binned wait/cost
histograms (mergeable quantile sketches → P50/P99 per grid point),
event-type counters, per-pool/per-region defect/resume counters, and —
with ``trace_cap > 0`` — a bounded per-window event ring exportable to
Chrome/Perfetto JSON (:mod:`repro.obs.trace`).  The base statistics are
accumulated by the untouched expressions, so telemetry-on primary stats
equal telemetry-off stats exactly; the summaries only gain new fields.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.clocks import (SlabLayout, build_slab_layout, hazard_clock,
                               lane_window_slabs, process_udim,
                               sample_clock_vector, sample_hazard_clocks,
                               split_event_keys, synth_key, tagged_keys,
                               thinning_pick, window_slab)
from repro.core.env import (EnvState, EnvTimeline, clock_rescale, env_row,
                            init_env_state, inv_avail)
from repro.core.market import (PoolState, SpotMarket, as_market,
                               checkpoint_within_notice)
from repro.core.policies import deadline_slack
from repro.core.regions import RegionTopology, RegionView, as_topology
from repro.core.work import WorkModel, WorkState, init_work_state
from repro.distributed.sharding import (lane_mesh, lane_spec, pad_lanes,
                                        shard_map_1d)
from repro.obs.shocks import env_update, env_zeros, summarize_env
from repro.obs.survival import (summarize_survival, survival_update,
                                survival_zeros)
from repro.kernels.sweep import (batched_events, batched_event_windows_ref,
                                 default_interpret)
from repro.obs.stats import (Telemetry, summarize_telemetry,
                             telemetry_update, telemetry_zeros)
from repro.obs.timing import annotate

# numpy (not jnp) scalars: they inline as jaxpr literals, so the event
# bodies stay capture-free inside the Pallas kernel trace (device-array
# constants would be hoisted as consts, which pallas_call rejects)
INF = np.float32(3e38)
_ORDER_MAX = np.int32(2**31 - 1)

#: One chunk_events default for every entry point (run_sim, run_sweep,
#: run_market_sim, run_market_sweep): float32 window sums are re-zeroed
#: every 2**16 events and assembled in float64 by :func:`summarize`, so the
#: precision behavior of a horizon does not depend on which entry point ran
#: it.  Horizons ≤ DEFAULT_CHUNK_EVENTS still accumulate in a single window
#: (chunks are clamped to ``n_events``), which keeps the seed's bit-for-bit
#: contract for short runs; pass ``chunk_events=None`` to force one window
#: at any horizon.
DEFAULT_CHUNK_EVENTS = 1 << 16


@runtime_checkable
class PolicyKernel(Protocol):
    """Static, hashable policy plugged into the engine's event loop."""

    def admit(self, params, qlen: jax.Array, key: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
        """Return (admit?, wait budget) for a job arriving at ``qlen``."""
        ...


class WindowStats(NamedTuple):
    """Per-window accumulators (float32 sums / int32 counts)."""

    jobs_arrived: jax.Array
    jobs_completed: jax.Array
    spot_served: jax.Array
    ondemand: jax.Array
    cost_sum: jax.Array
    delay_sum: jax.Array
    time_elapsed: jax.Array
    empty_time: jax.Array
    spot_arrivals: jax.Array
    spot_found_empty: jax.Array

    @staticmethod
    def zeros() -> "WindowStats":
        z = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        return WindowStats(zi, zi, zi, zi, z, z, z, z, zi, zi)


class EngineState(NamedTuple):
    key: jax.Array
    next_job: jax.Array  # time until next job arrival
    next_spot: jax.Array  # time until next spot-slot arrival
    ages: jax.Array  # (rmax,) time each queued job has waited
    budgets: jax.Array  # (rmax,) remaining wait budget (INF = wait forever)
    occ: jax.Array  # (rmax,) bool occupancy mask
    order: jax.Array  # (rmax,) int32 join sequence number
    next_seq: jax.Array  # int32 next join sequence number
    qlen: jax.Array  # int32 number of queued jobs


def init_engine_state(key: jax.Array, job: ArrivalProcess,
                      spot: ArrivalProcess, rmax: int,
                      ep: dict | None = None) -> EngineState:
    kj, ks, kc = jax.random.split(key, 3)
    next_job = job.sample(kj)
    next_spot = spot.sample(ks)
    if ep is not None:
        # initial spot clock runs under segment 0's availability
        next_spot = next_spot * inv_avail(ep["avail"][0])[0]
    return EngineState(
        key=kc,
        next_job=next_job,
        next_spot=next_spot,
        ages=jnp.zeros((rmax,), jnp.float32),
        budgets=jnp.full((rmax,), INF, jnp.float32),
        occ=jnp.zeros((rmax,), jnp.bool_),
        order=jnp.zeros((rmax,), jnp.int32),
        next_seq=jnp.zeros((), jnp.int32),
        qlen=jnp.zeros((), jnp.int32),
    )


def _admit_slab(kernel, params, qlen, layout: SlabLayout, x):
    """Slab-mode admission: a slab-aware kernel consumes its own uniform
    columns (``admit_u``); a legacy kernel gets a key synthesized from two
    raw columns and draws in-body (the compatibility path)."""
    if layout.admit_mode == "u":
        return kernel.admit_u(params, qlen, layout.uniforms(x, layout.admit))
    return kernel.admit(params, qlen, synth_key(layout.bits(x, layout.admit)))


def _engine_event(job: ArrivalProcess, spot: ArrivalProcess,
                  kernel: PolicyKernel, rmax: int,
                  layout: SlabLayout | None, carry: EngineState,
                  stats: WindowStats, params, k_cost: jax.Array,
                  x: jax.Array | None = None, tel: Telemetry | None = None,
                  ep: dict | None = None, work: WorkModel | None = None,
                  wk: dict | None = None
                  ) -> tuple[EngineState, WindowStats]:
    """Process one merged event (job arrival / spot slot / wait deadline).

    Per-slot updates are dense one-hot selects rather than scatter/gather:
    under ``vmap`` a traced-index ``.at[i].set`` lowers to a scatter, which
    is far slower on CPU/TPU than the width-``rmax`` selects used here (and
    the selects are numerically identical).

    ``layout=None`` is the frozen ``rng="split"`` stream (per-event key
    ladder); with a :class:`SlabLayout`, ``x`` is this event's uint32 slab
    row and the body performs no key arithmetic at all.

    ``tel`` (static) switches ``stats`` to a ``(base, telemetry)`` pair;
    the base expressions are untouched, the telemetry fold is a pure
    appendage over locals the body already computed (the module
    docstring's zero-cost-off / primary-stats-unchanged contract).

    ``ep`` (traced; see :mod:`repro.core.env`) switches ``carry`` to an
    ``(EngineState, EnvState)`` pair and ``stats`` to an outermost
    ``(stats, EnvWindowStats)`` pair: segment boundaries join the clock
    race as a highest-priority event, current-segment multipliers scale
    the spot price and supply, and survived clocks are rescaled exactly
    at each crossing.  A single open-ended segment reproduces the
    ``ep=None`` arithmetic bit-for-bit (every mask statically False-
    valued, every multiplier exactly 1.0).

    ``work`` (static :class:`~repro.core.work.WorkModel`) + ``wk`` (its
    traced params dict) switch ``carry`` to an *outermost*
    ``(carry, WorkState)`` pair and ``stats`` to an outermost
    ``(stats, SurvivalWindowStats)`` pair: every served unit pays down
    restart-overhead debt before making progress, a serve completes the
    job only when its remaining total clears, and the survival ledger
    gains job-level admission/finish/deadline-miss accounting.  The
    single-queue loop has no preemption, so rollback never fires here;
    the identity model (``WorkModel()``) makes every serve final and the
    base statistics bit-for-bit today's.
    """
    if work is not None:
        carry, wk_c = carry
        stats, wstats = stats
    if ep is not None:
        carry, env_c = carry
        stats, estats = stats
        seg = env_c.seg
        avail_row = env_row(ep["avail"], seg)
    if tel is not None:
        stats, tstats = stats
    if layout is None:
        key, k_job, k_spot, k_pol, _, _ = split_event_keys(carry.key)
    else:
        key = carry.key  # advanced once per window by the slab generator
    iota = jax.lax.iota(jnp.int32, rmax)

    budgets_masked = jnp.where(carry.occ, carry.budgets, INF)
    if work is not None and getattr(kernel, "safety_net", False):
        # can't-be-late watchdog: a job's panic time is the latest instant
        # still compatible with finishing on demand by its deadline
        # (deadline_slack); merging it into the budget race reuses the
        # defect-on-deadline machinery wholesale, so a panic IS a
        # defection to on-demand — just one forced early enough to land
        # on time.  Clamped at 0: an already-doomed job defects at the
        # next event rather than arming a negative clock.
        buf = np.float32(getattr(kernel, "slack_buffer", 0.0))
        rem_tot_all = wk_c.oh + jnp.maximum(wk["total_work"] - wk_c.prog,
                                            0.0)
        panic_at = jnp.maximum(
            deadline_slack(wk["deadline"], wk_c.life, rem_tot_all,
                           wk["od_time"], buf), 0.0)
        panic_at = jnp.where(carry.occ, panic_at, INF)
        panic_armed = panic_at < budgets_masked
        budgets_masked = jnp.minimum(budgets_masked, panic_at)
    else:
        panic_armed = None
    deadline = jnp.min(budgets_masked)
    defect_slot = jnp.argmin(budgets_masked)

    dt = jnp.minimum(jnp.minimum(carry.next_job, carry.next_spot), deadline)
    is_spot = carry.next_spot <= jnp.minimum(carry.next_job, deadline)
    is_deadline = (~is_spot) & (deadline <= carry.next_job)
    is_job = (~is_spot) & (~is_deadline)
    if ep is not None:
        # boundary-as-event: the segment boundary wins the race outright
        # (no queue activity; clocks age by dt), so dt never spans
        # segments.  With one open-ended segment next_boundary is 3e38:
        # is_boundary is identically False and dt is unchanged bitwise.
        is_boundary = env_c.next_boundary <= dt
        dt = jnp.minimum(dt, env_c.next_boundary)
        not_b = ~is_boundary
        is_spot = is_spot & not_b
        is_deadline = is_deadline & not_b
        is_job = is_job & not_b

    ages = carry.ages + dt
    budgets = jnp.where(carry.occ, carry.budgets - dt, INF)

    # ---- job arrival: ask the policy kernel ----
    if layout is None:
        admit_raw, budget = kernel.admit(params, carry.qlen, k_pol)
    else:
        admit_raw, budget = _admit_slab(kernel, params, carry.qlen, layout, x)
    admit = is_job & admit_raw & (carry.qlen < rmax)
    od_now = is_job & (~admit)  # rejected -> immediate on-demand, delay 0
    join_slot = jnp.argmin(carry.occ.astype(jnp.int32))  # first free slot

    # ---- spot slot: serve the FIFO-oldest job ----
    serve_slot = jnp.argmin(jnp.where(carry.occ, carry.order, _ORDER_MAX))
    has_job = carry.qlen > 0
    served = is_spot & has_job
    wait_served = jnp.sum(jnp.where(iota == serve_slot, ages, 0.0))

    if work is not None:
        # one unit of service pays down restart-overhead debt first and
        # spills the remainder into real progress; the serve *completes*
        # the job only when it clears the remaining total.  A partial
        # serve keeps the slot occupied with its join order (the FIFO
        # argmin keeps picking it), pays the spot price, and counts as a
        # leg in the base stats — the paper's renewal accounting is
        # untouched; job-level truth lives in the survival ledger.
        serve_vec = served & (iota == serve_slot)
        rem_tot = wk_c.oh + (wk["total_work"] - wk_c.prog)
        rem_serve = jnp.sum(jnp.where(iota == serve_slot, rem_tot, 0.0))
        oh_new = jnp.where(serve_vec, jnp.maximum(wk_c.oh - 1.0, 0.0),
                           wk_c.oh)
        spill = jnp.maximum(1.0 - wk_c.oh, 0.0)
        prog_new = jnp.where(
            serve_vec, jnp.minimum(wk_c.prog + spill, wk["total_work"]),
            wk_c.prog)
        done_inc = jnp.sum(jnp.where(serve_vec, prog_new - wk_c.prog, 0.0))
        if work.ckpt == "periodic":
            take_vec = (serve_vec & (rem_tot > 1.0)
                        & (prog_new - wk_c.ckpt >= wk["ckpt_period"]))
            ckpt_new = jnp.where(take_vec, prog_new, wk_c.ckpt)
            oh_new = oh_new + jnp.where(take_vec, wk["ckpt_cost"], 0.0)
            ckpt_taken = jnp.any(take_vec)
        else:
            ckpt_new = wk_c.ckpt
            ckpt_taken = jnp.zeros((), jnp.bool_)
        complete_serve = served & (rem_serve <= 1.0)
    else:
        complete_serve = served

    # ---- deadline: the minimal-budget job defects to on-demand ----
    defected = is_deadline  # deadline < INF implies an occupied slot
    age_defect = jnp.sum(jnp.where(iota == defect_slot, ages, 0.0))

    leave = complete_serve | defected
    leave_slot = jnp.where(served, serve_slot, defect_slot)

    join_mask = admit & (iota == join_slot)
    leave_mask = leave & (iota == leave_slot)
    ages = jnp.where(join_mask, 0.0, ages)
    budgets = jnp.where(join_mask, budget, budgets)
    occ = (carry.occ | join_mask) & (~leave_mask)
    order = jnp.where(join_mask, carry.next_seq, carry.order)
    if work is not None:
        life_new = jnp.where(join_mask, 0.0, wk_c.life + dt)
        prog_new = jnp.where(join_mask, 0.0, prog_new)
        oh_new = jnp.where(join_mask, 0.0, oh_new)
        ckpt_new = jnp.where(join_mask, 0.0, ckpt_new)

    if layout is None:
        job_draw = job.sample(k_job)
        spot_draw = spot.sample(k_spot)
    else:
        job_draw = job.sample_u(layout.uniforms(x, layout.job))
        spot_draw = spot.sample_u(layout.uniforms(x, layout.spot))
    next_job = jnp.where(is_job, job_draw, carry.next_job - dt)
    next_spot = jnp.where(is_spot, spot_draw, carry.next_spot - dt)
    if ep is not None:
        # supply side: the spot clock runs at rate·avail, represented as
        # base-draw × 1/avail (blackouts inflate by BLACKOUT_SCALE, kept
        # finite).  Fresh draws use the post-event segment; a boundary
        # re-expresses the survived clock under the new rate — in this
        # representation a uniform × inv_new/inv_old, valid through
        # blackouts in either direction.  Demand (the job clock) is not
        # modulated.  All factors are exactly 1.0 on a constant timeline.
        seg_new = seg + is_boundary.astype(jnp.int32)
        inv_old = inv_avail(avail_row)[0]
        inv_new = inv_avail(env_row(ep["avail"], seg_new))[0]
        next_spot = jnp.where(is_spot, spot_draw * inv_new, next_spot)
        next_spot = jnp.where(is_boundary, next_spot * (inv_new / inv_old),
                              next_spot)
    new_carry = EngineState(
        key=key,
        next_job=next_job,
        next_spot=next_spot,
        ages=ages,
        budgets=budgets,
        occ=occ,
        order=order,
        next_seq=carry.next_seq + jnp.where(admit, 1, 0),
        qlen=carry.qlen + jnp.where(admit, 1, 0) - jnp.where(leave, 1, 0),
    )
    if ep is None:
        # deferred so the op traces at its original position inside the
        # stats constructor (the frozen-lowering contract is byte-exact)
        cost_served = lambda: jnp.where(served, 1.0, 0.0)  # noqa: E731
    else:
        # spot price modulation: serves pay price_mult(seg) per unit;
        # the on-demand premium k_cost is the stable fallback price and
        # is NOT spiked (spikes are a spot-market phenomenon)
        cost_served = lambda: jnp.where(  # noqa: E731
            served, env_row(ep["price"], seg)[0], 0.0)
    new_stats = WindowStats(
        jobs_arrived=stats.jobs_arrived + is_job.astype(jnp.int32),
        jobs_completed=stats.jobs_completed
        + (od_now | served | defected).astype(jnp.int32),
        spot_served=stats.spot_served + served.astype(jnp.int32),
        ondemand=stats.ondemand + (od_now | defected).astype(jnp.int32),
        cost_sum=stats.cost_sum
        + cost_served()
        + jnp.where(od_now | defected, k_cost, 0.0),
        delay_sum=stats.delay_sum
        + jnp.where(served, wait_served, 0.0)
        + jnp.where(defected, age_defect, 0.0),
        time_elapsed=stats.time_elapsed + dt,
        empty_time=stats.empty_time + jnp.where(carry.qlen == 0, dt, 0.0),
        spot_arrivals=stats.spot_arrivals + is_spot.astype(jnp.int32),
        spot_found_empty=stats.spot_found_empty
        + (is_spot & (~has_job)).astype(jnp.int32),
    )
    if tel is not None:
        false = jnp.zeros((), jnp.bool_)
        tstats = telemetry_update(
            tel, tstats, t=new_stats.time_elapsed, is_job=is_job,
            is_spot=is_spot, is_pre=false, is_deadline=is_deadline,
            served=served, resume=false, defected=defected, od_now=od_now,
            wait_sample=jnp.where(served, wait_served, age_defect),
            wait_valid=served | defected,
            cost_inc=jnp.where(served, np.float32(1.0), k_cost),
            cost_valid=served | od_now | defected,
            loc=jnp.zeros((), jnp.int32), n_locs=1, qlen=new_carry.qlen)
    out_stats = (new_stats, tstats) if tel is not None else new_stats
    out_carry = new_carry
    if ep is not None:
        estats = env_update(
            estats, is_boundary=is_boundary,
            kind_prev=env_row(ep["kind"], seg),
            kind_next=env_row(ep["kind"], seg_new), dt=dt, is_job=is_job,
            od_now=od_now, served=served, resumed=jnp.zeros((), jnp.bool_))
        new_env = EnvState(
            next_boundary=jnp.where(
                is_boundary,
                env_row(ep["t_end"], seg_new) - env_row(ep["t_end"], seg),
                env_c.next_boundary - dt),
            seg=seg_new)
        out_carry = (new_carry, new_env)
        out_stats = (out_stats, estats)
    if work is not None:
        life_def = jnp.sum(jnp.where(iota == defect_slot, wk_c.life + dt,
                                     0.0))
        rem_def = jnp.sum(jnp.where(iota == defect_slot, rem_tot, 0.0))
        life_srv = jnp.sum(jnp.where(iota == serve_slot, wk_c.life + dt,
                                     0.0))
        od = wk["od_time"]
        # hard deadline-miss accounting: a job finishes at its last served
        # unit, or when it migrates to on-demand (od finish time = life at
        # migration + remaining work × od_time — live migration, the
        # can't-be-late convention)
        miss = ((od_now & (wk["total_work"] * od > wk["deadline"]))
                | (defected & (life_def + rem_def * od > wk["deadline"]))
                | (complete_serve & (life_srv > wk["deadline"])))
        panic = (defected & jnp.any((iota == defect_slot) & panic_armed)
                 if panic_armed is not None else jnp.zeros((), jnp.bool_))
        zf = jnp.zeros((), jnp.float32)
        wstats = survival_update(
            wstats, admitted=is_job,
            finished=od_now | complete_serve | defected, missed=miss,
            checkpoint=ckpt_taken, panic=panic, work_done=done_inc,
            work_lost=zf, work_recomputed=zf, overhead_paid=zf)
        return (out_carry, WorkState(prog=prog_new, oh=oh_new,
                                     ckpt=ckpt_new, life=life_new)), \
            (out_stats, wstats)
    return out_carry, out_stats


def _rebase_order(state):
    """Rebase join sequence numbers to the oldest occupied slot.

    ``order``/``next_seq`` are int32 and grow by one per admission; an
    unbounded counter wraps after ~2.1e9 admissions (well inside a long
    adaptive horizon), turning the FIFO ``argmin`` against ``_ORDER_MAX``
    into newest-first.  Subtracting the minimum *occupied* sequence (or
    ``next_seq`` itself when the queue is empty) at every window boundary
    keeps the counter below window-events + rmax forever.  The shift is
    uniform across occupied slots, so every order comparison — and therefore
    every statistic — is bitwise unchanged; works on any state carrying
    ``occ``/``order``/``next_seq`` (EngineState and MarketState).
    """
    base = jnp.min(jnp.where(state.occ, state.order, state.next_seq))
    return state._replace(
        order=jnp.where(state.occ, state.order - base, 0),
        next_seq=state.next_seq - base,
    )


def _rebase_order_env(state):
    """:func:`_rebase_order` for an ``(engine-state, EnvState)`` pair —
    the window-boundary epilogue when the env axis is on (the timeline
    cursor crosses windows untouched)."""
    base, env_c = state
    return (_rebase_order(base), env_c)


def _rebase_order_any(state):
    """:func:`_rebase_order` through arbitrary ``((state, env?), work?)``
    nesting — the window-boundary epilogue when the work axis is on (env
    cursor and work structure cross windows untouched)."""
    if hasattr(state, "occ"):
        return _rebase_order(state)
    return (_rebase_order_any(state[0]),) + tuple(state[1:])


def _rebase_for(ep, work):
    """Window-boundary rebase epilogue for the active (env, work) axes.

    Returns the exact pre-work function objects when ``work`` is off, so
    the ``work=None`` program is the identical jaxpr it always was."""
    if work is not None:
        return _rebase_order_any
    return _rebase_order if ep is None else _rebase_order_env


def _base_key_state(state):
    """Innermost engine state of an arbitrarily wrapped (env/work) pair."""
    while not hasattr(state, "key"):
        state = state[0]
    return state


def _replace_base_key(state, key):
    """Swap the lane key on the innermost engine state, preserving the
    surrounding (env/work) tuple nesting."""
    if hasattr(state, "key"):
        return state._replace(key=key)
    return (_replace_base_key(state[0], key),) + tuple(state[1:])


def _scan_window(step, zeros, state, n_events: int):
    """Scan ``step`` for ``n_events`` events from fresh window accumulators.

    Generic over the (state, stats) pytree pair — the PR-1 single-spot loop
    and the market loop share this scanner (and :func:`_scan_chunked`), so
    the chunked float32-window numerics are identical across both paths.
    """

    def body(sc, _):
        c, s = step(sc[0], sc[1])
        return (c, s), None

    (state, stats), _ = jax.lax.scan(body, (state, zeros), None,
                                     length=n_events)
    return state, stats


def _scan_chunked(step, zeros, state, n_events: int, chunk_events: int,
                  rebase=_rebase_order):
    """Run exactly ``n_events`` events as stacked float32 chunk windows.

    Every window boundary rebases the join-sequence counters
    (:func:`_rebase_order` — or :func:`_rebase_order_env` when the state
    is an env pair) so int32 ``order``/``next_seq`` never wrap on long
    horizons; the Pallas kernel path applies the same epilogue, so the
    two impls carry bitwise-identical state between windows.
    """
    n_chunks, rem = divmod(n_events, chunk_events)

    def chunk(c, _):
        c, s = _scan_window(step, zeros, c, chunk_events)
        return rebase(c), s

    state, stats = jax.lax.scan(chunk, state, None, length=n_chunks)
    if rem:
        state, tail = _scan_window(step, zeros, state, rem)
        state = rebase(state)
        stats = jax.tree.map(
            lambda s, t: jnp.concatenate([s, t[None]]), stats,
            jax.tree.map(jnp.asarray, tail),
        )
    return state, stats


def _scan_window_slab(step, zeros, state, n_events: int, n_cols: int,
                      paired: bool = False):
    """Slab-stream window: ONE counter-based bits call generates the whole
    window's ``(n_events, n_cols)`` uint32 slab, the event scan consumes it
    row by row as ``xs``, and the lane key advances once per window (not
    per event).  :func:`repro.core.clocks.lane_window_slabs` walks the same
    ladder with the same shapes, so the Pallas/ref executors consume
    bitwise-identical slabs.

    ``paired`` flags a tuple-wrapped state — ``(engine, EnvState)`` when
    the env axis is on, and/or an outermost ``(state, WorkState)`` when
    the work axis is on (NamedTuples are tuples, so this cannot be
    sniffed) — the slab ladder walks the innermost engine state's key
    either way."""
    if paired:
        key, slab = window_slab(_base_key_state(state).key, n_events, n_cols)
        state = _replace_base_key(state, key)
    else:
        key, slab = window_slab(state.key, n_events, n_cols)
        state = state._replace(key=key)

    def body(sc, x):
        c, s = step(sc[0], sc[1], x)
        return (c, s), None

    (state, stats), _ = jax.lax.scan(body, (state, zeros), slab)
    return state, stats


def _scan_chunked_slab(step, zeros, state, n_events: int, chunk_events: int,
                       n_cols: int, paired: bool = False,
                       rebase=_rebase_order):
    """Slab-stream twin of :func:`_scan_chunked` (same chunk plan, same
    per-window order rebase)."""
    n_chunks, rem = divmod(n_events, chunk_events)

    def chunk(c, _):
        c, s = _scan_window_slab(step, zeros, c, chunk_events, n_cols,
                                 paired=paired)
        return rebase(c), s

    state, stats = jax.lax.scan(chunk, state, None, length=n_chunks)
    if rem:
        state, tail = _scan_window_slab(step, zeros, state, rem, n_cols,
                                        paired=paired)
        state = rebase(state)
        stats = jax.tree.map(
            lambda s, t: jnp.concatenate([s, t[None]]), stats,
            jax.tree.map(jnp.asarray, tail),
        )
    return state, stats


def _window_plan(n_events: int, chunk_events: int,
                 burn_in: int) -> tuple[int, ...]:
    """Static per-window event counts: [burn-in?] + full chunks + [tail?]."""
    full, rem = divmod(n_events, chunk_events)
    return (((burn_in,) if burn_in else ()) + (chunk_events,) * full
            + ((rem,) if rem else ()))


def _raw_keys(keys: jax.Array) -> jax.Array:
    """Typed PRNG keys -> raw uint32 key data (Pallas refs carry raw words);
    threefry on the raw words is bitwise the typed-key stream."""
    if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(keys)
    return keys


def _engine_layout(job: ArrivalProcess, spot: ArrivalProcess,
                   kernel) -> SlabLayout:
    """Slab column map for the single-queue loop (built at trace time)."""
    return build_slab_layout(kernel, job_udim=process_udim(job),
                             spot_udim=process_udim(spot))


def _with_zeros(zeros, tel: Telemetry | None, n_locs: int,
                env: bool = False, work: bool = False):
    """Pair base window zeros with telemetry zeros when that axis is on,
    then with shock-counter zeros when the env axis is on, then
    (outermost) with survival-ledger zeros when the work axis is on."""
    if tel is not None:
        zeros = (zeros, telemetry_zeros(tel, n_locs))
    if env:
        zeros = (zeros, env_zeros())
    if work:
        zeros = (zeros, survival_zeros())
    return zeros


def run_window(job: ArrivalProcess, spot: ArrivalProcess,
               kernel: PolicyKernel, rmax: int, state: EngineState, params,
               k_cost: jax.Array, n_events: int,
               layout: SlabLayout | None = None,
               tel: Telemetry | None = None, ep: dict | None = None,
               work: WorkModel | None = None, wk: dict | None = None
               ) -> tuple[EngineState, WindowStats]:
    """Run ``n_events`` merged events; return state + one window of sums."""
    step = functools.partial(_engine_event, job, spot, kernel, rmax, layout,
                             params=params, k_cost=k_cost, tel=tel, ep=ep,
                             work=work, wk=wk)
    zeros = _with_zeros(WindowStats.zeros(), tel, 1, env=ep is not None,
                        work=work is not None)
    if layout is None:
        return _scan_window(lambda c, s: step(c, s), zeros, state, n_events)
    return _scan_window_slab(lambda c, s, x: step(c, s, x=x), zeros, state,
                             n_events, layout.n_cols,
                             paired=(ep is not None) or (work is not None))


def run_chunked(job: ArrivalProcess, spot: ArrivalProcess,
                kernel: PolicyKernel, rmax: int, state: EngineState, params,
                k_cost: jax.Array, n_events: int, chunk_events: int,
                layout: SlabLayout | None = None,
                tel: Telemetry | None = None, ep: dict | None = None,
                work: WorkModel | None = None, wk: dict | None = None
                ) -> tuple[EngineState, WindowStats]:
    """Run exactly ``n_events`` events as stacked float32 chunk windows.

    Returns stats with a leading chunk axis; :func:`summarize` reduces it in
    float64 so long horizons do not hit float32 sum saturation.
    """
    step = functools.partial(_engine_event, job, spot, kernel, rmax, layout,
                             params=params, k_cost=k_cost, tel=tel, ep=ep,
                             work=work, wk=wk)
    zeros = _with_zeros(WindowStats.zeros(), tel, 1, env=ep is not None,
                        work=work is not None)
    rebase = _rebase_for(ep, work)
    if layout is None:
        return _scan_chunked(lambda c, s: step(c, s), zeros, state,
                             n_events, chunk_events, rebase=rebase)
    return _scan_chunked_slab(lambda c, s, x: step(c, s, x=x), zeros, state,
                              n_events, chunk_events, layout.n_cols,
                              paired=(ep is not None) or (work is not None),
                              rebase=rebase)


@functools.partial(
    jax.jit,
    static_argnames=("job", "spot", "kernel", "rmax", "n_events",
                     "chunk_events", "burn_in", "rng", "tel", "work"),
)
def _run_sim_jit(job, spot, kernel, rmax, n_events, chunk_events, burn_in,
                 rng, params, k_cost, key, tel=None, ep=None, work=None,
                 wk=None):
    """Single-point entry, compiled once per static signature at module scope
    (the seed re-jitted its burn-in path on every call).

    ``ep`` is traced (an env-params dict, or None — a leafless pytree, so
    the ``env=None`` program is the same jaxpr as before the axis);
    ``work``/``wk`` are the static/traced halves of the work axis, with
    the same leafless-when-off property."""
    layout = _engine_layout(job, spot, kernel) if rng == "slab" else None
    state = init_engine_state(key, job, spot, rmax, ep=ep)
    if ep is not None:
        state = (state, init_env_state(ep))
    if work is not None:
        state = (state, init_work_state(rmax))
    if burn_in:
        state, _ = run_window(job, spot, kernel, rmax, state, params, k_cost,
                              burn_in, layout=layout, tel=tel, ep=ep,
                              work=work, wk=wk)
        state = _rebase_for(ep, work)(state)
    return run_chunked(job, spot, kernel, rmax, state, params, k_cost,
                       n_events, chunk_events, layout=layout, tel=tel, ep=ep,
                       work=work, wk=wk)


def _check_rng(rng: str) -> None:
    if rng not in ("split", "slab"):
        raise ValueError(f"unknown rng {rng!r} (expected 'split'|'slab')")


def _check_telemetry(telemetry) -> None:
    if telemetry is not None and not isinstance(telemetry, Telemetry):
        raise TypeError(
            f"telemetry must be a repro.obs.Telemetry or None, got "
            f"{telemetry!r}")


def _check_env(env) -> None:
    if env is not None and not isinstance(env, EnvTimeline):
        raise TypeError(
            f"env must be a repro.core.env.EnvTimeline or None, got "
            f"{env!r}")


def _env_params(env: EnvTimeline | None, n_locs: int):
    return None if env is None else env.params(n_locs)


def _check_work(work, kernel) -> None:
    if work is not None and not isinstance(work, WorkModel):
        raise TypeError(
            f"work must be a repro.core.work.WorkModel or None, got "
            f"{work!r}")
    if work is None and getattr(kernel, "safety_net", False):
        raise ValueError(
            "a safety-net kernel (CantBeLateKernel) tracks per-job slack "
            "and needs the work axis: pass work=WorkModel(...)")


def _check_run_shape(name: str, n_events: int, burn_in: int) -> None:
    """Actionable errors for the host-side run plan (every entry point)."""
    if n_events <= 0:
        raise ValueError(
            f"{name}: n_events must be a positive event count, got "
            f"{n_events}")
    if burn_in < 0:
        raise ValueError(
            f"{name}: burn_in must be >= 0 events, got {burn_in}")


def _check_loc_overrides(name: str, n_locs: int, what: str, **arrays) -> None:
    """Actionable errors for per-pool/per-region override grids: every
    given array must be a scalar (fills every loc) or have a last axis
    broadcastable to the scenario's loc count, and price/hazard/notice
    values must be non-negative and finite."""
    for field, arr in arrays.items():
        if arr is None:
            continue
        a = np.asarray(arr)
        if a.ndim > 0 and a.shape[-1] not in (1, n_locs):
            raise ValueError(
                f"{name}: {field} must be scalar or have last-axis length "
                f"{n_locs} (one per {what}), got shape {a.shape}")
        if not np.all(np.isfinite(a)):
            raise ValueError(
                f"{name}: {field} contains non-finite values")
        if np.any(a < 0):
            raise ValueError(
                f"{name}: {field} must be non-negative, got min "
                f"{a.min()}")


class NonFiniteStatsError(ValueError):
    """Raised by :func:`summarize` when a reduced statistic is NaN/inf —
    poisoned windows fail loudly at the host boundary instead of leaking
    silent NaN averages into sweeps and learners."""


def _check_finite_stats(s) -> None:
    for field in ("cost_sum", "delay_sum", "time_elapsed"):
        v = getattr(s, field)
        if not np.all(np.isfinite(v)):
            raise NonFiniteStatsError(
                f"summarize: window statistic {field!r} is non-finite "
                f"(NaN/inf) — the run diverged (bad params, non-finite "
                f"prices/hazards, or a poisoned window)")


def _flat_lane_args(params_trees, k_cost, keys):
    """Flatten a (grid × seeds) product to grid-major lanes (seed fastest).

    The Pallas executor's lane layout: params/k repeat per seed, raw seed
    keys tile per grid point — the kernel operates on materialized per-lane
    state/params tiles.  The XLA executor deliberately does NOT share this
    layout: its nested vmap with broadcast (``in_axes=None``) arguments
    compiles ~2.5× faster on CPU than any materialized-lane variant (the
    batching rules keep grid-constant operands symbolically unbatched).
    Per-lane arithmetic is the same traced event body either way; see
    EXPERIMENTS.md ("Engine kernel") for the ulp-level float caveat this
    split implies on CPU.
    """
    g, s = k_cost.shape[0], keys.shape[0]
    rep = lambda x: jnp.repeat(x, s, axis=0)
    return ([jax.tree.map(rep, t) for t in params_trees], rep(k_cost),
            jnp.tile(keys, (g, 1)))


def _unflatten_lanes(stats, g: int, s: int):
    """(lanes, windows, ...) stats leaves back to (grid, seeds, ...)."""
    return jax.tree.map(lambda x: x.reshape((g, s) + x.shape[1:]), stats)


@functools.partial(
    jax.jit,
    static_argnames=("job", "spot", "kernel", "rmax", "n_events",
                     "chunk_events", "burn_in", "rng", "tel", "work"),
)
def _run_sweep_jit(job, spot, kernel, rmax, n_events, chunk_events, burn_in,
                   rng, params, k_cost, keys, tel=None, ep=None, work=None,
                   wk=None):
    """(grid × seeds) fleet as one nested-vmap XLA program (broadcast
    ``in_axes`` — see :func:`_flat_lane_args` for why not flat lanes).
    ``ep``/``wk`` are closed over by ``one`` (grid-constant, so the nested
    vmap keeps them symbolically unbatched)."""
    layout = _engine_layout(job, spot, kernel) if rng == "slab" else None

    def one(p, kc, key):
        state = init_engine_state(key, job, spot, rmax, ep=ep)
        if ep is not None:
            state = (state, init_env_state(ep))
        if work is not None:
            state = (state, init_work_state(rmax))
        if burn_in:
            state, _ = run_window(job, spot, kernel, rmax, state, p, kc,
                                  burn_in, layout=layout, tel=tel, ep=ep,
                                  work=work, wk=wk)
            state = _rebase_for(ep, work)(state)
        _, stats = run_chunked(job, spot, kernel, rmax, state, p, kc,
                               n_events, chunk_events, layout=layout,
                               tel=tel, ep=ep, work=work, wk=wk)
        return stats

    per_seeds = jax.vmap(one, in_axes=(None, None, 0))
    return jax.vmap(per_seeds, in_axes=(0, 0, None))(params, k_cost, keys)


def _lane_slabs(state0, plan, layout: SlabLayout) -> jax.Array:
    """All lanes' per-window slabs, (lanes, n_windows, max_ev, n_cols) —
    generated OUTSIDE the kernel from each lane's initial key, so the
    Pallas executor sees the slab as a plain per-window input block and
    performs zero in-kernel RNG.  Values consumed per window are bitwise
    the scan executor's (:func:`_scan_window_slab`)."""
    return jax.vmap(
        lambda k: lane_window_slabs(k, plan, layout.n_cols))(state0.key)


def _env_lane_blocks(ep: dict, lanes: int):
    """Per-lane env inputs for the batched-event executors: the segment
    tables broadcast per lane (they become VMEM-resident param blocks,
    exactly like the PR-5 slab rides as an input block) plus each lane's
    initial :class:`EnvState` cursor."""
    ep_b = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (lanes,) + a.shape), ep)
    es0 = EnvState(
        next_boundary=jnp.broadcast_to(ep["t_end"][0], (lanes,)),
        seg=jnp.zeros((lanes,), jnp.int32))
    return ep_b, es0


@functools.partial(
    jax.jit,
    static_argnames=("job", "spot", "kernel", "rmax", "n_events",
                     "chunk_events", "burn_in", "tile", "interpret",
                     "executor", "rng", "tel", "work"),
)
def _run_sweep_pallas_jit(job, spot, kernel, rmax, n_events, chunk_events,
                          burn_in, tile, interpret, params, k_cost, keys,
                          executor="pallas", rng="split", tel=None, ep=None,
                          work=None, wk=None):
    """The (grid × seeds) fleet as ONE Pallas batched-event kernel call.

    Lanes are grid-major (seed fastest; :func:`_flat_lane_args`); per-lane
    arithmetic is the same traced :func:`_engine_event` the XLA executor
    scans.  Burn-in runs as a leading window through the same kernel and
    its stats row is dropped.  ``executor="ref"`` swaps the kernel for its
    pure-JAX scan reference on the identical lane layout — the bit-for-bit
    oracle the equivalence tests freeze the kernel against.
    """
    g, s = k_cost.shape[0], keys.shape[0]
    (params_f,), k_f, keys_f = _flat_lane_args((params,), k_cost, keys)
    params_b = {"params": params_f, "k": k_f}
    state0 = jax.vmap(
        lambda key: init_engine_state(key, job, spot, rmax, ep=ep))(keys_f)
    plan = _window_plan(n_events, chunk_events, burn_in)

    if rng == "slab":
        layout = _engine_layout(job, spot, kernel)
        xs = _lane_slabs(state0, plan, layout)
    else:
        layout, xs = None, None
    if ep is not None:
        # slabs above walk the bare engine key ladder; only now does the
        # lane state become the (engine, env-cursor) pair
        params_b["ep"], es0 = _env_lane_blocks(ep, keys_f.shape[0])
        state0 = (state0, es0)
    if work is not None:
        # work params ride as per-lane VMEM blocks like ep; the work
        # structure wraps outermost, after any env pairing
        params_b["wk"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (keys_f.shape[0],)), wk)
        state0 = (state0, init_work_state(rmax, keys_f.shape[0]))

    if rng == "slab":
        def step(carry, stats, p, x):
            return _engine_event(job, spot, kernel, rmax, layout, carry,
                                 stats, p["params"], p["k"], x=x, tel=tel,
                                 ep=p.get("ep"), work=work, wk=p.get("wk"))
    else:
        def step(carry, stats, p):
            return _engine_event(job, spot, kernel, rmax, None, carry,
                                 stats, p["params"], p["k"], tel=tel,
                                 ep=p.get("ep"), work=work, wk=p.get("wk"))

    zeros = _with_zeros(WindowStats.zeros(), tel, 1, env=ep is not None,
                        work=work is not None)
    epilogue = _rebase_for(ep, work)
    if executor == "ref":
        _, stats = batched_event_windows_ref(
            step, state0, params_b, zeros, plan, xs=xs,
            epilogue=epilogue)
    else:
        _, stats = batched_events(
            step, state0, params_b, zeros, plan, xs=xs,
            tile=tile, interpret=interpret, epilogue=epilogue)
    if burn_in:
        stats = jax.tree.map(lambda x: x[:, 1:], stats)
    return _unflatten_lanes(stats, g, s)


def _check_shard(name: str, shard: str, mesh) -> None:
    """Actionable errors for the ``shard=`` axis (every sweep entry point)."""
    if shard not in ("none", "lanes"):
        raise ValueError(
            f"{name}: unknown shard {shard!r} (expected 'none'|'lanes')")
    if mesh is not None:
        if shard == "none":
            raise ValueError(
                f"{name}: mesh= requires shard='lanes' (shard='none' runs "
                f"unsharded)")
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"{name}: lane sharding needs a 1-D mesh, got axes "
                f"{mesh.axis_names}")


def _pad_count(lanes: int, mesh) -> int:
    """Lanes to add so the flat lane axis divides the mesh evenly."""
    return -lanes % mesh.size


def _sweep_lanes(job, spot, kernel, rmax, n_events, chunk_events, burn_in,
                 tile, interpret, params_f, k_f, keys_f, *, executor, rng,
                 tel=None, ep=None, work=None, wk=None):
    """One shard's worth of flat lanes through the requested executor.

    The per-shard body of the ``shard="lanes"`` dispatch: arguments are
    already flat lane-leading (grid-major, seed fastest — the
    :func:`_flat_lane_args` layout; ``keys_f`` are raw uint32 key words),
    and the returned stats leaves are ``(lanes, windows, ...)``.  The
    ``"pallas"``/``"ref"`` branches mirror :func:`_run_sweep_pallas_jit`'s
    body op-for-op, so per-lane trajectories are bitwise the unsharded
    ones.  The ``"xla"`` branch runs the same per-lane program as
    :func:`_run_sweep_jit`'s ``one`` but under a single flat vmap —
    materialized lanes instead of broadcast nesting, which keeps integer
    stats bitwise and float sums within ~ulp of the unsharded nested-vmap
    program (the PR-3 layout caveat; see :func:`_flat_lane_args`).
    """
    layout = _engine_layout(job, spot, kernel) if rng == "slab" else None
    if executor == "xla":
        def one(p, kc, key):
            state = init_engine_state(key, job, spot, rmax, ep=ep)
            if ep is not None:
                state = (state, init_env_state(ep))
            if work is not None:
                state = (state, init_work_state(rmax))
            if burn_in:
                state, _ = run_window(job, spot, kernel, rmax, state, p, kc,
                                      burn_in, layout=layout, tel=tel, ep=ep,
                                      work=work, wk=wk)
                state = _rebase_for(ep, work)(state)
            _, stats = run_chunked(job, spot, kernel, rmax, state, p, kc,
                                   n_events, chunk_events, layout=layout,
                                   tel=tel, ep=ep, work=work, wk=wk)
            return stats

        return jax.vmap(one)(params_f, k_f, keys_f)

    params_b = {"params": params_f, "k": k_f}
    state0 = jax.vmap(
        lambda key: init_engine_state(key, job, spot, rmax, ep=ep))(keys_f)
    plan = _window_plan(n_events, chunk_events, burn_in)
    xs = _lane_slabs(state0, plan, layout) if layout is not None else None
    if ep is not None:
        params_b["ep"], es0 = _env_lane_blocks(ep, keys_f.shape[0])
        state0 = (state0, es0)
    if work is not None:
        params_b["wk"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (keys_f.shape[0],)), wk)
        state0 = (state0, init_work_state(rmax, keys_f.shape[0]))

    if layout is not None:
        def step(carry, stats, p, x):
            return _engine_event(job, spot, kernel, rmax, layout, carry,
                                 stats, p["params"], p["k"], x=x, tel=tel,
                                 ep=p.get("ep"), work=work, wk=p.get("wk"))
    else:
        def step(carry, stats, p):
            return _engine_event(job, spot, kernel, rmax, None, carry,
                                 stats, p["params"], p["k"], tel=tel,
                                 ep=p.get("ep"), work=work, wk=p.get("wk"))

    zeros = _with_zeros(WindowStats.zeros(), tel, 1, env=ep is not None,
                        work=work is not None)
    epilogue = _rebase_for(ep, work)
    if executor == "ref":
        _, stats = batched_event_windows_ref(
            step, state0, params_b, zeros, plan, xs=xs, epilogue=epilogue)
    else:
        _, stats = batched_events(
            step, state0, params_b, zeros, plan, xs=xs, tile=tile,
            interpret=interpret, epilogue=epilogue)
    if burn_in:
        stats = jax.tree.map(lambda x: x[:, 1:], stats)
    return stats


@functools.partial(
    jax.jit,
    static_argnames=("job", "spot", "kernel", "rmax", "n_events",
                     "chunk_events", "burn_in", "tile", "interpret", "mesh",
                     "executor", "rng", "tel", "work"),
)
def _run_sweep_sharded_jit(job, spot, kernel, rmax, n_events, chunk_events,
                           burn_in, tile, interpret, mesh, params, k_cost,
                           keys, executor="xla", rng="split", tel=None,
                           ep=None, work=None, wk=None):
    """The (grid × seeds) fleet lane-partitioned across a 1-D device mesh.

    Flatten to grid-major lanes, pad to a mesh-size multiple with copies
    of lane 0 (:func:`repro.distributed.sharding.pad_lanes`), run
    :func:`_sweep_lanes` per shard under ``shard_map`` (env tables ride
    replicated), slice the pad lanes off, and unflatten.  No cross-lane
    communication exists in the event loop — lane keys are independent in
    both rng streams — so each shard's trajectories are the unsharded
    ones by construction; the host-side summaries then reduce int32
    windows with integer addition (no float reduction-order hazard on the
    ledger's exact set).
    """
    g, s = k_cost.shape[0], keys.shape[0]
    (params_f,), k_f, keys_f = _flat_lane_args((params,), k_cost, keys)
    lanes = g * s
    params_f, k_f, keys_f = pad_lanes((params_f, k_f, keys_f),
                                      _pad_count(lanes, mesh))
    spec, rspec = lane_spec(mesh), jax.sharding.PartitionSpec()

    def local(pf, kf, keysf, ep_, wk_):
        return _sweep_lanes(job, spot, kernel, rmax, n_events, chunk_events,
                            burn_in, tile, interpret, pf, kf, keysf,
                            executor=executor, rng=rng, tel=tel, ep=ep_,
                            work=work, wk=wk_)

    stats = shard_map_1d(local, mesh=mesh,
                         in_specs=(spec, spec, spec, rspec, rspec),
                         out_specs=spec)(params_f, k_f, keys_f, ep, wk)
    if lanes != keys_f.shape[0]:
        stats = jax.tree.map(lambda x: x[:lanes], stats)
    return _unflatten_lanes(stats, g, s)


#: Statistics that count events (int32 window accumulators and their
#: per-pool variants).  Event *decisions* never differ between executors,
#: so these are bitwise identical across impl="xla"/"pallas"/"ref" on any
#: config — the exact-comparison set used by the equivalence tests,
#: benches, and examples (float sums get the ~ulp contract instead; see
#: the module docstring).
INT_STATS = ("jobs_arrived", "jobs_completed", "spot_served", "ondemand",
             "preemptions", "resumed", "pool_served", "pool_spot_arrivals",
             "pool_preempted", "routed_home", "region_served",
             "region_spot_arrivals", "region_preempted", "region_jobs",
             "region_routed")


def _merge_telemetry(out: dict, telemetry: Telemetry, tstats,
                     time_elapsed) -> dict:
    """Append the telemetry summary (new fields only — base keys are never
    touched) plus the per-window durations the trace exporter needs to
    place each window's ring on a global clock."""
    tout = summarize_telemetry(telemetry, tstats)
    if "trace" in tout:
        tout["trace"]["time_windows"] = np.asarray(time_elapsed, np.float64)
    out.update(tout)
    return out


def summarize(stats: WindowStats, telemetry: Telemetry | None = None,
              env=None, work=None) -> dict:
    """Reduce chunked (…, n_chunks) sums in float64; derive long-run stats.

    Leading batch axes (grid, seeds) pass through: every value in the
    returned dict is a numpy array of the batch shape (0-d for a single run).
    With ``telemetry``, ``stats`` is the engine's ``(base, telemetry)``
    pair and the dict gains the :func:`repro.obs.summarize_telemetry`
    fields (P50/P99 wait, event counters, …) — base keys unchanged.
    With ``env`` (truthy), ``stats`` is additionally wrapped in an
    outermost ``(stats, EnvWindowStats)`` pair and the dict gains the
    :func:`repro.obs.summarize_env` shock/degradation counters.
    With ``work`` (truthy), the outermost pair is
    ``(stats, SurvivalWindowStats)`` and the dict gains the
    :func:`repro.obs.summarize_survival` job-level ledger.
    Raises :class:`NonFiniteStatsError` when a reduced base statistic is
    NaN/inf (silent poisoned stats fail loudly at the host boundary).
    """
    wstats = None
    if work is not None:
        stats, wstats = stats
    estats = None
    if env is not None:
        stats, estats = stats
    tstats = None
    if telemetry is not None:
        stats, tstats = stats
    s = jax.tree.map(lambda x: np.asarray(x, np.float64).sum(axis=-1), stats)
    _check_finite_stats(s)
    completed = np.maximum(s.jobs_completed, 1.0)
    arrived = np.maximum(s.jobs_arrived, 1.0)
    time = np.maximum(s.time_elapsed, 1e-12)
    spot_arr = np.maximum(s.spot_arrivals, 1.0)
    out = {
        "jobs_arrived": s.jobs_arrived,
        "jobs_completed": s.jobs_completed,
        "spot_served": s.spot_served,
        "ondemand": s.ondemand,
        "avg_cost": s.cost_sum / completed,
        "avg_delay": s.delay_sum / completed,
        "time": s.time_elapsed,
        "pi0_time": s.empty_time / time,
        "pi0_spot": s.spot_found_empty / spot_arr,
        "spot_utilization": (s.spot_arrivals - s.spot_found_empty) / spot_arr,
        "arrival_rate": arrived / time,
    }
    if telemetry is not None:
        out = _merge_telemetry(out, telemetry, tstats, stats.time_elapsed)
    if estats is not None:
        out.update(summarize_env(estats))
    if wstats is not None:
        out.update(summarize_survival(wstats))
    return out


def _scalar_or_array(v):
    """Single-run host conversion: 0-d → float (the frozen sim contract),
    arrays stay arrays (per-pool/per-region/histogram fields), the trace
    dict passes through."""
    if isinstance(v, dict):
        return v
    return float(v) if np.ndim(v) == 0 else np.asarray(v)


def _reshape_sweep(out: dict, grid_shape: tuple, n_seeds: int) -> dict:
    """Reshape flat ``(grid_points, n_seeds, ...)`` summary values back to
    ``grid_shape + (n_seeds,) + trailing`` — generic over scalar,
    per-pool/per-region, histogram, and (nested) trace-dict fields."""
    def _r(v):
        v = np.asarray(v)
        return v.reshape(grid_shape + (n_seeds,) + v.shape[2:])

    return {name: ({key: _r(x) for key, x in v.items()}
                   if isinstance(v, dict) else _r(v))
            for name, v in out.items()}


def run_sim(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    kernel: PolicyKernel,
    params=None,
    *,
    k: float = 10.0,
    n_events: int,
    key: jax.Array,
    rmax: int = 64,
    burn_in: int = 0,
    chunk_events: int | None = DEFAULT_CHUNK_EVENTS,
    impl: str = "xla",
    rng: str = "split",
    tile: int = 256,
    interpret: bool | None = None,
    telemetry: Telemetry | None = None,
    env: EnvTimeline | None = None,
    work: WorkModel | None = None,
) -> dict:
    """Run one policy at one parameter point; return long-run scalar stats.

    ``chunk_events`` defaults to :data:`DEFAULT_CHUNK_EVENTS` like the sweep
    entry points (chunks clamp to ``n_events``, so horizons within one chunk
    still accumulate in a single float32 window — the seed simulators'
    bit-for-bit behaviour); ``None`` forces a single window at any horizon.
    ``impl="pallas"`` runs the horizon as a one-lane batched-event kernel
    call — bit-for-bit the ``"ref"`` scan oracle; see :func:`run_sweep`
    and the module docstring for the cross-executor equality contract.
    ``rng="slab"`` selects the fast slab PRNG stream (module docstring,
    "Randomness").  ``telemetry`` (a :class:`repro.obs.Telemetry`) adds
    streaming P50/P99 wait/cost sketches, event counters, and optionally
    an event trace to the returned dict (module docstring, "Telemetry").
    ``env`` (a :class:`repro.core.env.EnvTimeline`) runs the horizon
    through a piecewise-constant environment — price/hazard/availability
    segments, storms, blackouts — and adds the shock counters to the
    returned dict (module docstring of :mod:`repro.core.env`).
    ``work`` (a :class:`repro.core.work.WorkModel`) gives every job a
    work structure — multi-unit service, restart overhead, checkpoints,
    deadlines — and adds the survival ledger to the returned dict
    (module docstring of :mod:`repro.core.work`).
    """
    params = {} if params is None else params
    _check_rng(rng)
    _check_telemetry(telemetry)
    _check_env(env)
    _check_work(work, kernel)
    _check_run_shape("run_sim", n_events, burn_in)
    ep = _env_params(env, 1)
    wk = None if work is None else work.params()
    chunk = n_events if chunk_events is None else min(chunk_events, n_events)
    with annotate(f"repro.run_sim[{impl}]"):
        if impl in ("pallas", "ref"):
            stats = _run_sweep_pallas_jit(
                job, spot, kernel, rmax, n_events, chunk, burn_in, tile,
                default_interpret() if interpret is None else interpret,
                jax.tree.map(lambda x: jnp.asarray(x)[None], params),
                jnp.float32(k)[None], _raw_keys(key)[None], executor=impl,
                rng=rng, tel=telemetry, ep=ep, work=work, wk=wk)
            stats = jax.tree.map(lambda x: x[0, 0], stats)
        elif impl == "xla":
            _, stats = _run_sim_jit(job, spot, kernel, rmax, n_events, chunk,
                                    burn_in, rng, params, jnp.float32(k),
                                    key, tel=telemetry, ep=ep, work=work,
                                    wk=wk)
        else:
            raise ValueError(
                f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
    return {name: _scalar_or_array(v)
            for name, v in summarize(stats, telemetry, env=env,
                                     work=work).items()}


def run_sweep(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    kernel: PolicyKernel,
    params=None,
    *,
    k: float | np.ndarray | jax.Array = 10.0,
    n_events: int,
    key: jax.Array,
    n_seeds: int = 1,
    rmax: int = 64,
    burn_in: int = 0,
    chunk_events: int | None = DEFAULT_CHUNK_EVENTS,
    impl: str = "xla",
    rng: str = "split",
    tile: int = 256,
    interpret: bool | None = None,
    telemetry: Telemetry | None = None,
    env: EnvTimeline | None = None,
    work: WorkModel | None = None,
    shard: str = "none",
    mesh=None,
) -> dict:
    """Run a whole policy grid × seed fleet as ONE jitted call.

    ``params`` is a pytree whose leaves, together with ``k``, broadcast to a
    common grid shape (e.g. ``{"r": jnp.linspace(0, 4, 32)}``, or a 2-D
    meshgrid over ``r`` × ``k``).  Seeds use common random numbers across the
    grid (same ``n_seeds`` subkeys at every point), which cancels sampling
    noise out of cross-grid comparisons.

    ``impl`` selects the executor: ``"xla"`` is the nested-vmap
    ``lax.scan`` program; ``"pallas"`` runs the fleet through the batched
    -event kernel (:mod:`repro.kernels.sweep`) — engine state resident in
    VMEM as (tile, rmax) blocks for a whole float32 window of events;
    ``"ref"`` is the kernel's pure-JAX scan reference (the bit-for-bit
    oracle; see the module docstring for the exact cross-executor
    equality contract).  ``tile`` is lanes per kernel instance;
    ``interpret=None`` auto-selects compiled Mosaic on TPU and the Pallas
    interpreter elsewhere (the CPU fallback).  ``rng="slab"`` selects the
    fast slab PRNG stream (module docstring, "Randomness") — recommended
    for new sweeps; the default ``"split"`` is the frozen seed-compatible
    stream.

    ``shard="lanes"`` partitions the flattened (grid × seeds) lane axis
    across a 1-D device mesh with ``shard_map`` (``mesh`` defaults to
    :func:`repro.distributed.sharding.lane_mesh` over every local device);
    uneven lane counts pad with copies of lane 0 and mask the pad off.
    Lane trajectories are unchanged by construction — integer stats and
    telemetry histograms match the unsharded run bitwise, float sums to
    ~ulp (the sharding-equivalence ledger, tests/test_fleet.py; see
    docs/scaling.md).

    Returns :func:`summarize`'s dict with every value shaped
    ``grid_shape + (n_seeds,)``.
    """
    params = {} if params is None else params
    _check_rng(rng)
    _check_telemetry(telemetry)
    _check_env(env)
    _check_work(work, kernel)
    _check_shard("run_sweep", shard, mesh)
    _check_run_shape("run_sweep", n_events, burn_in)
    ep = _env_params(env, 1)
    wk = None if work is None else work.params()
    params = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), params)
    k = jnp.asarray(k, jnp.float32)
    grid_shape = jnp.broadcast_shapes(
        k.shape, *(x.shape for x in jax.tree.leaves(params))
    )
    flat = lambda x: jnp.broadcast_to(x, grid_shape).reshape(-1)
    params_flat = jax.tree.map(flat, params)
    k_flat = flat(k)
    keys = jax.random.split(key, n_seeds)
    chunk = n_events if chunk_events is None else min(chunk_events, n_events)
    with annotate(f"repro.run_sweep[{impl}]"):
        if shard == "lanes":
            if impl not in ("xla", "pallas", "ref"):
                raise ValueError(
                    f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
            stats = _run_sweep_sharded_jit(
                job, spot, kernel, rmax, n_events, chunk, burn_in, tile,
                default_interpret() if interpret is None else interpret,
                lane_mesh() if mesh is None else mesh, params_flat, k_flat,
                _raw_keys(keys), executor=impl, rng=rng, tel=telemetry,
                ep=ep, work=work, wk=wk)
        elif impl in ("pallas", "ref"):
            stats = _run_sweep_pallas_jit(
                job, spot, kernel, rmax, n_events, chunk, burn_in, tile,
                default_interpret() if interpret is None else interpret,
                params_flat, k_flat, _raw_keys(keys), executor=impl,
                rng=rng, tel=telemetry, ep=ep, work=work, wk=wk)
        elif impl == "xla":
            stats = _run_sweep_jit(job, spot, kernel, rmax, n_events, chunk,
                                   burn_in, rng, params_flat, k_flat, keys,
                                   tel=telemetry, ep=ep, work=work, wk=wk)
        else:
            raise ValueError(
                f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
    # values shaped (grid_points, n_seeds)
    out = summarize(stats, telemetry, env=env, work=work)
    return _reshape_sweep(out, grid_shape, n_seeds)


# ===========================================================================
# SpotMarket: P heterogeneous pools + preemption-with-notice
# ===========================================================================
#
# The market event loop is the PR-1 loop with the scalar ``next_spot`` clock
# widened to per-pool vectors ``next_spot``/``next_preempt`` (see
# repro.core.market for the descriptors and model semantics).  Event-time
# ties resolve spot > preempt > deadline > job; ties *between* pools resolve
# by position (argmin), measure-zero for continuous samplers.
#
# With a degenerate market (1 pool, zero hazard, unit price) every branch
# below reduces bitwise to the PR-1 expressions: the preemption machinery is
# statically removed (4-way key split, untouched INF preempt clock), the
# single-pool min/argmin are exact identities, and the extra stat terms add
# literal +0.0 to non-negative float32 sums.  tests/test_core_market.py
# freezes that contract against run_sim/run_sweep.


class MarketWindowStats(NamedTuple):
    """Per-window accumulators for the market loop.

    The first ten fields mirror :class:`WindowStats` exactly (same order,
    same accumulation semantics); the tail adds preemption and per-pool
    counters.  Under preemption, completions count *legs* — a checkpointed
    job contributes one completed leg at revocation and another when it
    finally finishes, matching the host orchestrator's accounting.
    """

    jobs_arrived: jax.Array
    jobs_completed: jax.Array
    spot_served: jax.Array
    ondemand: jax.Array
    cost_sum: jax.Array
    delay_sum: jax.Array
    time_elapsed: jax.Array
    empty_time: jax.Array
    spot_arrivals: jax.Array
    spot_found_empty: jax.Array
    resumed: jax.Array  # i32: preempted legs that checkpointed + re-queued
    spot_cost: jax.Array  # f32: cost paid to spot pools (incl. partial legs)
    pool_served: jax.Array  # (P,) i32 completions per pool
    pool_spot_arrivals: jax.Array  # (P,) i32 slot arrivals per pool
    pool_preempted: jax.Array  # (P,) i32 preemption hits per pool

    @staticmethod
    def zeros(n_pools: int) -> "MarketWindowStats":
        z = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        zp = jnp.zeros((n_pools,), jnp.int32)
        return MarketWindowStats(zi, zi, zi, zi, z, z, z, z, zi, zi,
                                 zi, z, zp, zp, zp)


_POOL_FIELDS = frozenset({"pool_served", "pool_spot_arrivals",
                          "pool_preempted"})


class MarketState(NamedTuple):
    key: jax.Array
    next_job: jax.Array  # time until next job arrival
    next_spot: jax.Array  # (P,) per-pool spot-slot clocks
    next_preempt: jax.Array  # (P,) per-pool preemption clocks (INF = never)
    ages: jax.Array  # (rmax,)
    budgets: jax.Array  # (rmax,)
    occ: jax.Array  # (rmax,) bool
    pool: jax.Array  # (rmax,) int32 pool tag of each queued job
    order: jax.Array  # (rmax,) int32 join sequence number
    next_seq: jax.Array
    qlen: jax.Array


def _market_tags(market: SpotMarket) -> tuple:
    return tuple(p.tag for p in market.pools)


def _sample_spot_clocks(market: SpotMarket, k_spot: jax.Array,
                        mp: dict) -> jax.Array:
    """Per-pool spot clock refresh (split stream): tag-folded keys via the
    shared :func:`repro.core.clocks.sample_clock_vector` plumbing — the
    1-pool market uses ``k_spot`` directly (the PR-1 key layout), so the
    degenerate engine is bit-for-bit the PR-1 engine."""
    return sample_clock_vector(tuple(p.arrival for p in market.pools),
                               _market_tags(market), k_spot,
                               mp["spot_scale"])


def _slab_spot_clocks(procs: tuple, u: jax.Array,
                      scale: jax.Array) -> jax.Array:
    """Slab-stream clock-vector refresh: every process transforms the SAME
    shared uniforms (only the firing entry's sample is ever consumed, so
    sharing the columns is distributionally exact) — zero key arithmetic,
    O(P) cheap transforms."""
    return jnp.stack([p.sample_u(u) for p in procs]) * scale


def init_market_state(key: jax.Array, job: ArrivalProcess,
                      market: SpotMarket, rmax: int, mp: dict,
                      preempt_on: bool,
                      scalar_preempt: bool = False,
                      ep: dict | None = None) -> MarketState:
    """``scalar_preempt`` (the ``rng="slab"`` representation) carries ONE
    superposed preemption clock instead of the (P,) vector: the min of the
    per-pool init draws — exactly ``Exp(Σ h_p)``, the superposition law.
    ``ep`` places the initial clocks under segment 0's effective hazard
    and availability (exact ×1.0 no-ops on a constant timeline)."""
    kj, ks, kc = jax.random.split(key, 3)
    n = market.n_pools
    hazard0 = (mp["hazard"] if ep is None
               else mp["hazard"] * ep["hazard"][0])
    if preempt_on:
        next_preempt = sample_hazard_clocks(
            _market_tags(market), jax.random.fold_in(ks, 2**31 - 1),
            hazard0)
        if scalar_preempt:
            next_preempt = jnp.min(next_preempt, keepdims=True)
    else:
        next_preempt = jnp.full((1 if scalar_preempt else n,), INF,
                                jnp.float32)
    next_job = job.sample(kj)
    next_spot = _sample_spot_clocks(market, ks, mp)
    if ep is not None:
        next_spot = next_spot * inv_avail(ep["avail"][0])
    return MarketState(
        key=kc,
        next_job=next_job,
        next_spot=next_spot,
        next_preempt=next_preempt,
        ages=jnp.zeros((rmax,), jnp.float32),
        budgets=jnp.full((rmax,), INF, jnp.float32),
        occ=jnp.zeros((rmax,), jnp.bool_),
        pool=jnp.zeros((rmax,), jnp.int32),
        order=jnp.zeros((rmax,), jnp.int32),
        next_seq=jnp.zeros((), jnp.int32),
        qlen=jnp.zeros((), jnp.int32),
    )


def _kernel_admit(kernel, params, qlen, pool_state, key):
    """Route market-aware kernels to admit_market; legacy kernels to pool 0
    with the PR-1 key layout (degenerate bit-for-bit)."""
    if hasattr(kernel, "admit_market"):
        admit, budget, pool = kernel.admit_market(params, qlen, pool_state,
                                                  key)
        return admit, budget, jnp.asarray(pool, jnp.int32)
    admit, budget = kernel.admit(params, qlen, key)
    return admit, budget, jnp.zeros((), jnp.int32)


def _kernel_admit_slab(kernel, params, qlen, pool_state, layout: SlabLayout,
                       x):
    """Slab-stream twin of :func:`_kernel_admit`: slab-aware kernels own
    their uniform columns; legacy hooks get a synthesized key."""
    if layout.market_admit:
        if layout.admit_mode == "u":
            admit, budget, pool = kernel.admit_market_u(
                params, qlen, pool_state, layout.uniforms(x, layout.admit))
        else:
            admit, budget, pool = kernel.admit_market(
                params, qlen, pool_state,
                synth_key(layout.bits(x, layout.admit)))
        return admit, budget, jnp.asarray(pool, jnp.int32)
    admit, budget = _admit_slab(kernel, params, qlen, layout, x)
    return admit, budget, jnp.zeros((), jnp.int32)


def _kernel_on_preempt(kernel, params, age, notice, qlen, key):
    if hasattr(kernel, "on_preempt"):
        return kernel.on_preempt(params, age, notice, qlen, key)
    return jnp.zeros((), jnp.bool_)  # legacy kernels defect on revocation


def _kernel_on_preempt_slab(kernel, params, age, notice, qlen,
                            layout: SlabLayout, x):
    if layout.on_preempt_mode == "u":
        return kernel.on_preempt_u(params, age, notice, qlen,
                                   layout.uniforms(x, layout.on_preempt))
    if layout.on_preempt_mode == "key":
        return kernel.on_preempt(params, age, notice, qlen,
                                 synth_key(layout.bits(x, layout.on_preempt)))
    return jnp.zeros((), jnp.bool_)  # legacy kernels defect on revocation


def _market_event(job: ArrivalProcess, market: SpotMarket, kernel, rmax: int,
                  preempt_on: bool, layout: SlabLayout | None,
                  carry: MarketState, stats: MarketWindowStats, params,
                  mp: dict, k_cost: jax.Array,
                  x: jax.Array | None = None, tel: Telemetry | None = None,
                  ep: dict | None = None, work: WorkModel | None = None,
                  wk: dict | None = None
                  ) -> tuple[MarketState, MarketWindowStats]:
    """One merged event: job arrival / pool spot slot / pool preemption /
    wait deadline.  Same dense one-hot-select style as :func:`_engine_event`
    (see the note there on scatter vs select under vmap).

    ``layout=None`` is the frozen split stream; with a :class:`SlabLayout`
    the body consumes slab row ``x`` instead — and the (P,) preemption
    clock vector is ONE superposed clock at total hazard plus a thinning
    pick of the firing pool (exact; see :mod:`repro.core.clocks`).
    ``tel`` appends the telemetry fold exactly as in :func:`_engine_event`
    (base expressions untouched); the event locus is the firing pool.
    ``ep`` threads the environment-timeline axis exactly as in
    :func:`_engine_event`, here with per-pool multiplier rows: effective
    price/hazard = base × segment row, spot supply scaled by per-pool
    availability (0 = blackout, clocks inflated finite), and the kernel's
    :class:`PoolState` sees the *effective* market — a zero ``rate`` entry
    is the blackout signal failover kernels key on.
    ``work``/``wk`` thread the work axis exactly as in
    :func:`_engine_event`; here preemption makes it bite — a resumed job
    rolls back to its checkpoint and owes the restart overhead, and the
    ledger prices every rollback.
    """
    n_pools = market.n_pools
    if work is not None:
        carry, wk_c = carry
        stats, wstats = stats
    if ep is not None:
        carry, env_c = carry
        stats, estats = stats
        seg = env_c.seg
        avail_row = env_row(ep["avail"], seg)
        eff_hazard = mp["hazard"] * env_row(ep["hazard"], seg)
        eff_price = mp["price"] * env_row(ep["price"], seg)
    else:
        eff_hazard = mp["hazard"]
        eff_price = mp["price"]
    if tel is not None:
        stats, tstats = stats
    if layout is None:
        key, k_job, k_spot, k_pol, k_pre, _ = split_event_keys(
            carry.key, preempt_on)
    else:
        key = carry.key
    iota = jax.lax.iota(jnp.int32, rmax)
    iota_p = jax.lax.iota(jnp.int32, n_pools)

    budgets_masked = jnp.where(carry.occ, carry.budgets, INF)
    if work is not None and getattr(kernel, "safety_net", False):
        # can't-be-late watchdog (see _engine_event): the panic clock
        # joins the budget race, so a panic is a forced-early defection
        # to on-demand through the existing deadline machinery
        buf = np.float32(getattr(kernel, "slack_buffer", 0.0))
        rem_tot_all = wk_c.oh + jnp.maximum(wk["total_work"] - wk_c.prog,
                                            0.0)
        panic_at = jnp.maximum(
            deadline_slack(wk["deadline"], wk_c.life, rem_tot_all,
                           wk["od_time"], buf), 0.0)
        panic_at = jnp.where(carry.occ, panic_at, INF)
        panic_armed = panic_at < budgets_masked
        budgets_masked = jnp.minimum(budgets_masked, panic_at)
    else:
        panic_armed = None
    deadline = jnp.min(budgets_masked)
    defect_slot = jnp.argmin(budgets_masked)

    min_spot = jnp.min(carry.next_spot)
    spot_pool = jnp.argmin(carry.next_spot).astype(jnp.int32)
    if preempt_on:
        if layout is None:
            min_pre = jnp.min(carry.next_preempt)
            pre_pool = jnp.argmin(carry.next_preempt).astype(jnp.int32)
        else:
            min_pre = carry.next_preempt[0]
            pre_pool = thinning_pick(eff_hazard,
                                     layout.uniforms(x, layout.preempt)[1])
        dt = jnp.minimum(jnp.minimum(carry.next_job, min_spot),
                         jnp.minimum(deadline, min_pre))
        is_spot = min_spot <= jnp.minimum(carry.next_job,
                                          jnp.minimum(deadline, min_pre))
        is_pre = (~is_spot) & (min_pre <= jnp.minimum(carry.next_job,
                                                      deadline))
        is_deadline = (~is_spot) & (~is_pre) & (deadline <= carry.next_job)
        is_job = (~is_spot) & (~is_pre) & (~is_deadline)
    else:
        pre_pool = jnp.zeros((), jnp.int32)
        dt = jnp.minimum(jnp.minimum(carry.next_job, min_spot), deadline)
        is_spot = min_spot <= jnp.minimum(carry.next_job, deadline)
        is_pre = jnp.zeros((), jnp.bool_)
        is_deadline = (~is_spot) & (deadline <= carry.next_job)
        is_job = (~is_spot) & (~is_deadline)
    if ep is not None:
        # boundary-as-event (see _engine_event): the crossing outranks
        # every queue clock, so dt never spans segments
        is_boundary = env_c.next_boundary <= dt
        dt = jnp.minimum(dt, env_c.next_boundary)
        not_b = ~is_boundary
        is_spot = is_spot & not_b
        is_pre = is_pre & not_b
        is_deadline = is_deadline & not_b
        is_job = is_job & not_b

    ages = carry.ages + dt
    budgets = jnp.where(carry.occ, carry.budgets - dt, INF)

    if ep is not None and getattr(kernel, "drain_dead", False):
        # PanicKernel drain: re-tag jobs stranded on a blacked-out pool to
        # the cheapest alive pool (the host orchestrator's re-queue step,
        # on device) so they stop pinning qlen — the PR-7 stranded-job
        # caveat.  Availability is recomputed here instead of hoisting the
        # `rates` expression below, so the drain-off program keeps its
        # original op order (CSE merges the duplicate).
        alive_p = (mp["rate"] / mp["spot_scale"]) * avail_row > 0
        cheapest = jnp.argmin(
            jnp.where(alive_p, eff_price, INF)).astype(jnp.int32)
        alive_slot = jnp.sum(
            jnp.where(carry.pool[:, None] == iota_p[None, :],
                      alive_p[None, :].astype(jnp.int32), 0), axis=1) > 0
        retag = carry.occ & (~alive_slot) & jnp.any(alive_p)
        carry = carry._replace(pool=jnp.where(retag, cheapest, carry.pool))

    # ---- job arrival: ask the policy kernel (admission + pool choice) ----
    qlen_pool = jnp.sum(
        (carry.occ[:, None] & (carry.pool[:, None] == iota_p[None, :]))
        .astype(jnp.int32), axis=0)
    rates = mp["rate"] / mp["spot_scale"]
    if ep is not None:
        rates = rates * avail_row  # 0 on blacked-out pools: the signal
    pool_state = PoolState(price=eff_price, hazard=eff_hazard,
                           notice=mp["notice"], rate=rates,
                           qlen_pool=qlen_pool)
    if layout is None:
        admit_raw, budget, pool_choice = _kernel_admit(kernel, params,
                                                       carry.qlen,
                                                       pool_state, k_pol)
    else:
        admit_raw, budget, pool_choice = _kernel_admit_slab(
            kernel, params, carry.qlen, pool_state, layout, x)
    admit = is_job & admit_raw & (carry.qlen < rmax)
    od_now = is_job & (~admit)
    join_slot = jnp.argmin(carry.occ.astype(jnp.int32))

    # ---- pool spot slot: serve the FIFO-oldest job tagged to that pool ----
    eligible_s = carry.occ & (carry.pool == spot_pool)
    serve_slot = jnp.argmin(jnp.where(eligible_s, carry.order, _ORDER_MAX))
    has_elig = jnp.any(eligible_s)
    served = is_spot & has_elig
    wait_served = jnp.sum(jnp.where(iota == serve_slot, ages, 0.0))
    price_s = eff_price[spot_pool]

    if work is not None:
        # one unit of service: overhead debt first, spill into progress;
        # final only when the remaining total clears (see _engine_event)
        serve_vec = served & (iota == serve_slot)
        rem_tot = wk_c.oh + (wk["total_work"] - wk_c.prog)
        rem_serve = jnp.sum(jnp.where(iota == serve_slot, rem_tot, 0.0))
        oh_new = jnp.where(serve_vec, jnp.maximum(wk_c.oh - 1.0, 0.0),
                           wk_c.oh)
        spill = jnp.maximum(1.0 - wk_c.oh, 0.0)
        prog_new = jnp.where(
            serve_vec, jnp.minimum(wk_c.prog + spill, wk["total_work"]),
            wk_c.prog)
        done_inc = jnp.sum(jnp.where(serve_vec, prog_new - wk_c.prog, 0.0))
        if work.ckpt == "periodic":
            take_vec = (serve_vec & (rem_tot > 1.0)
                        & (prog_new - wk_c.ckpt >= wk["ckpt_period"]))
            ckpt_new = jnp.where(take_vec, prog_new, wk_c.ckpt)
            oh_new = oh_new + jnp.where(take_vec, wk["ckpt_cost"], 0.0)
            ckpt_taken = jnp.any(take_vec)
        else:
            ckpt_new = wk_c.ckpt
            ckpt_taken = jnp.zeros((), jnp.bool_)
        complete_serve = served & (rem_serve <= 1.0)
    else:
        complete_serve = served

    # ---- pool preemption: revoke the FIFO-oldest job on that pool ----
    if preempt_on:
        eligible_p = carry.occ & (carry.pool == pre_pool)
        pre_slot = jnp.argmin(jnp.where(eligible_p, carry.order, _ORDER_MAX))
        pre_hit = is_pre & jnp.any(eligible_p)
        age_pre = jnp.sum(jnp.where(iota == pre_slot, ages, 0.0))
        # re-admission sees the queue WITHOUT the revoked job (the host
        # orchestrator pops it before consulting the admission law)
        qlen_wo = jnp.maximum(carry.qlen - 1, 0)
        if layout is None:
            resume_raw = _kernel_on_preempt(kernel, params, age_pre,
                                            mp["notice"][pre_pool], qlen_wo,
                                            k_pre)
        else:
            resume_raw = _kernel_on_preempt_slab(kernel, params, age_pre,
                                                 mp["notice"][pre_pool],
                                                 qlen_wo, layout, x)
        resume = pre_hit & resume_raw
        defect_pre = pre_hit & (~resume)
        price_p = eff_price[pre_pool]
    else:
        pre_slot = jnp.zeros((), jnp.int32)
        pre_hit = jnp.zeros((), jnp.bool_)
        age_pre = jnp.zeros((), jnp.float32)
        resume = jnp.zeros((), jnp.bool_)
        defect_pre = jnp.zeros((), jnp.bool_)
        price_p = jnp.zeros((), jnp.float32)

    if work is not None and preempt_on:
        # rollback: the resumed job restarts from its checkpoint and owes
        # the restart overhead before progress resumes.  In notice mode
        # the checkpoint saves current progress iff it fits the firing
        # pool's notice window — the PR-2 law, now priced in lost work.
        if work.ckpt == "notice":
            saved = resume & checkpoint_within_notice(
                wk["ckpt_time"], mp["notice"][pre_pool])
        else:
            saved = jnp.zeros((), jnp.bool_)
        prog_p = jnp.sum(jnp.where(iota == pre_slot, prog_new, 0.0))
        ckpt_p = jnp.sum(jnp.where(iota == pre_slot, ckpt_new, 0.0))
        ckpt_val = jnp.where(saved, jnp.maximum(ckpt_p, prog_p), ckpt_p)
        resume_vec = resume & (iota == pre_slot)
        prog_new = jnp.where(resume_vec, ckpt_val, prog_new)
        oh_new = jnp.where(resume_vec, wk["restart_overhead"], oh_new)
        ckpt_new = jnp.where(resume_vec, ckpt_val, ckpt_new)
        lost = jnp.where(resume, jnp.maximum(prog_p - ckpt_val, 0.0), 0.0)
        oh_inc = jnp.where(resume, wk["restart_overhead"], 0.0)
        ckpt_taken = ckpt_taken | (resume & saved)
    elif work is not None:
        lost = jnp.zeros((), jnp.float32)
        oh_inc = jnp.zeros((), jnp.float32)

    # ---- deadline: the minimal-budget job defects to on-demand ----
    defected = is_deadline
    age_defect = jnp.sum(jnp.where(iota == defect_slot, ages, 0.0))

    leave = complete_serve | defected | defect_pre
    leave_slot = jnp.where(served, serve_slot,
                           jnp.where(defected, defect_slot, pre_slot))

    join_mask = admit & (iota == join_slot)
    leave_mask = leave & (iota == leave_slot)
    resume_mask = resume & (iota == pre_slot)
    ages = jnp.where(join_mask | resume_mask, 0.0, ages)
    budgets = jnp.where(join_mask, budget,
                        jnp.where(resume_mask, INF, budgets))
    occ = (carry.occ | join_mask) & (~leave_mask)
    pool = jnp.where(join_mask, pool_choice, carry.pool)
    order = jnp.where(join_mask | resume_mask, carry.next_seq, carry.order)
    if work is not None:
        life_new = jnp.where(join_mask, 0.0, wk_c.life + dt)
        prog_new = jnp.where(join_mask, 0.0, prog_new)
        oh_new = jnp.where(join_mask, 0.0, oh_new)
        ckpt_new = jnp.where(join_mask, 0.0, ckpt_new)

    fire_s = is_spot & (iota_p == spot_pool)
    if layout is None:
        spot_draws = _sample_spot_clocks(market, k_spot, mp)
        job_draw = job.sample(k_job)
    else:
        spot_draws = _slab_spot_clocks(
            tuple(p.arrival for p in market.pools),
            layout.uniforms(x, layout.spot), mp["spot_scale"])
        job_draw = job.sample_u(layout.uniforms(x, layout.job))
    if ep is not None:
        # refresh draws live under the POST-event segment; boundary
        # crossings rescale the survived clocks exactly (memorylessness)
        seg_new = seg + is_boundary.astype(jnp.int32)
        inv_old = inv_avail(avail_row)
        inv_new = inv_avail(env_row(ep["avail"], seg_new))
        eff_hazard_new = mp["hazard"] * env_row(ep["hazard"], seg_new)
        spot_draws = spot_draws * inv_new
    else:
        eff_hazard_new = mp["hazard"]
    next_spot = jnp.where(fire_s, spot_draws, carry.next_spot - dt)
    if ep is not None:
        next_spot = jnp.where(is_boundary, next_spot * (inv_new / inv_old),
                              next_spot)
    if not preempt_on:
        next_preempt = carry.next_preempt
    elif layout is None:
        fire_p = is_pre & (iota_p == pre_pool)
        next_preempt = jnp.where(
            fire_p, sample_hazard_clocks(_market_tags(market), k_pre,
                                         eff_hazard_new),
            carry.next_preempt - dt)
        if ep is not None:
            next_preempt = jnp.where(
                is_boundary,
                next_preempt * clock_rescale(eff_hazard, eff_hazard_new),
                next_preempt)
    else:
        # scalar superposed clock: refresh Exp(Σ h_p) whenever ANY pool
        # fires (memorylessness makes the non-firing residuals fresh draws)
        next_preempt = jnp.where(
            is_pre, hazard_clock(eff_hazard_new,
                                 layout.uniforms(x, layout.preempt)[0]),
            carry.next_preempt - dt)
        if ep is not None:
            next_preempt = jnp.where(
                is_boundary,
                next_preempt * clock_rescale(jnp.sum(eff_hazard),
                                             jnp.sum(eff_hazard_new)),
                next_preempt)

    new_carry = MarketState(
        key=key,
        next_job=jnp.where(is_job, job_draw, carry.next_job - dt),
        next_spot=next_spot,
        next_preempt=next_preempt,
        ages=ages,
        budgets=budgets,
        occ=occ,
        pool=pool,
        order=order,
        next_seq=carry.next_seq + jnp.where(admit | resume, 1, 0),
        qlen=carry.qlen + jnp.where(admit, 1, 0) - jnp.where(leave, 1, 0),
    )
    completed = od_now | served | defected | defect_pre | resume
    new_stats = MarketWindowStats(
        jobs_arrived=stats.jobs_arrived + is_job.astype(jnp.int32),
        jobs_completed=stats.jobs_completed + completed.astype(jnp.int32),
        spot_served=stats.spot_served + served.astype(jnp.int32),
        ondemand=stats.ondemand
        + (od_now | defected | defect_pre).astype(jnp.int32),
        cost_sum=stats.cost_sum
        + jnp.where(served, price_s, 0.0)
        + jnp.where(od_now | defected | defect_pre, k_cost, 0.0)
        + jnp.where(pre_hit, price_p, 0.0),
        delay_sum=stats.delay_sum
        + jnp.where(served, wait_served, 0.0)
        + jnp.where(defected, age_defect, 0.0)
        + jnp.where(pre_hit, age_pre, 0.0),
        time_elapsed=stats.time_elapsed + dt,
        empty_time=stats.empty_time + jnp.where(carry.qlen == 0, dt, 0.0),
        spot_arrivals=stats.spot_arrivals + is_spot.astype(jnp.int32),
        spot_found_empty=stats.spot_found_empty
        + (is_spot & (~has_elig)).astype(jnp.int32),
        resumed=stats.resumed + resume.astype(jnp.int32),
        spot_cost=stats.spot_cost
        + jnp.where(served, price_s, 0.0)
        + jnp.where(pre_hit, price_p, 0.0),
        pool_served=stats.pool_served
        + (fire_s & served).astype(jnp.int32),
        pool_spot_arrivals=stats.pool_spot_arrivals
        + fire_s.astype(jnp.int32),
        pool_preempted=stats.pool_preempted
        + (pre_hit & (iota_p == pre_pool)).astype(jnp.int32),
    )
    if tel is not None:
        defect_pool = jnp.sum(jnp.where(iota == defect_slot, carry.pool, 0))
        loc = jnp.where(is_spot, spot_pool,
                        jnp.where(is_pre, pre_pool,
                                  jnp.where(is_deadline, defect_pool,
                                            pool_choice)))
        tstats = telemetry_update(
            tel, tstats, t=new_stats.time_elapsed, is_job=is_job,
            is_spot=is_spot, is_pre=is_pre, is_deadline=is_deadline,
            served=served, resume=resume, defected=defected, od_now=od_now,
            wait_sample=jnp.where(served, wait_served,
                                  jnp.where(defected, age_defect, age_pre)),
            wait_valid=served | defected | pre_hit,
            cost_inc=jnp.where(served, price_s, 0.0)
            + jnp.where(od_now | defected | defect_pre, k_cost, 0.0)
            + jnp.where(pre_hit, price_p, 0.0),
            cost_valid=served | od_now | defected | pre_hit,
            loc=loc, n_locs=n_pools, qlen=new_carry.qlen)
    out_stats = (new_stats, tstats) if tel is not None else new_stats
    out_carry = new_carry
    if ep is not None:
        estats = env_update(
            estats, is_boundary=is_boundary,
            kind_prev=env_row(ep["kind"], seg),
            kind_next=env_row(ep["kind"], seg_new), dt=dt, is_job=is_job,
            od_now=od_now, served=served, resumed=resume)
        new_env = EnvState(
            next_boundary=jnp.where(
                is_boundary,
                env_row(ep["t_end"], seg_new) - env_row(ep["t_end"], seg),
                env_c.next_boundary - dt),
            seg=seg_new)
        out_carry = (new_carry, new_env)
        out_stats = (out_stats, estats)
    if work is not None:
        life_def = jnp.sum(jnp.where(iota == defect_slot, wk_c.life + dt,
                                     0.0))
        rem_def = jnp.sum(jnp.where(iota == defect_slot, rem_tot, 0.0))
        life_pre = jnp.sum(jnp.where(iota == pre_slot, wk_c.life + dt, 0.0))
        rem_pre = jnp.sum(jnp.where(iota == pre_slot, rem_tot, 0.0))
        life_srv = jnp.sum(jnp.where(iota == serve_slot, wk_c.life + dt,
                                     0.0))
        od = wk["od_time"]
        # a job finishes at its last served unit or when it migrates to
        # on-demand; od finish time = life at migration + remaining work
        # × od_time (live migration — the preempted job's remaining work
        # is its PRE-rollback remainder, it does not re-lose progress by
        # leaving the spot market)
        miss = ((od_now & (wk["total_work"] * od > wk["deadline"]))
                | (defected & (life_def + rem_def * od > wk["deadline"]))
                | (defect_pre & (life_pre + rem_pre * od > wk["deadline"]))
                | (complete_serve & (life_srv > wk["deadline"])))
        panic = (defected & jnp.any((iota == defect_slot) & panic_armed)
                 if panic_armed is not None else jnp.zeros((), jnp.bool_))
        wstats = survival_update(
            wstats, admitted=is_job,
            finished=od_now | complete_serve | defected | defect_pre,
            missed=miss, checkpoint=ckpt_taken, panic=panic,
            work_done=done_inc, work_lost=lost,
            work_recomputed=lost + oh_inc, overhead_paid=oh_inc)
        return (out_carry, WorkState(prog=prog_new, oh=oh_new,
                                     ckpt=ckpt_new, life=life_new)), \
            (out_stats, wstats)
    return out_carry, out_stats


def _market_layout(job: ArrivalProcess, market: SpotMarket, kernel,
                   preempt_on: bool) -> SlabLayout:
    """Slab column map for the market loop: the spot span is the max
    ``u_dim`` across pools (all pools transform the same shared
    uniforms)."""
    return build_slab_layout(
        kernel, job_udim=process_udim(job),
        spot_udim=max(process_udim(p.arrival) for p in market.pools),
        n=market.n_pools, preempt_on=preempt_on, market=True)


def run_market_window(job: ArrivalProcess, market: SpotMarket, kernel,
                      rmax: int, preempt_on: bool, state: MarketState,
                      params, mp: dict, k_cost: jax.Array, n_events: int,
                      layout: SlabLayout | None = None,
                      tel: Telemetry | None = None, ep: dict | None = None,
                      work: WorkModel | None = None, wk: dict | None = None
                      ) -> tuple[MarketState, MarketWindowStats]:
    """Run ``n_events`` merged market events; one window of float32 sums."""
    step = functools.partial(_market_event, job, market, kernel, rmax,
                             preempt_on, layout, params=params, mp=mp,
                             k_cost=k_cost, tel=tel, ep=ep, work=work, wk=wk)
    zeros = _with_zeros(MarketWindowStats.zeros(market.n_pools), tel,
                        market.n_pools, env=ep is not None,
                        work=work is not None)
    if layout is None:
        return _scan_window(step, zeros, state, n_events)
    return _scan_window_slab(lambda c, s, x: step(c, s, x=x), zeros, state,
                             n_events, layout.n_cols,
                             paired=(ep is not None) or (work is not None))


def run_market_chunked(job: ArrivalProcess, market: SpotMarket, kernel,
                       rmax: int, preempt_on: bool, state: MarketState,
                       params, mp: dict, k_cost: jax.Array, n_events: int,
                       chunk_events: int, layout: SlabLayout | None = None,
                       tel: Telemetry | None = None, ep: dict | None = None,
                       work: WorkModel | None = None, wk: dict | None = None
                       ) -> tuple[MarketState, MarketWindowStats]:
    step = functools.partial(_market_event, job, market, kernel, rmax,
                             preempt_on, layout, params=params, mp=mp,
                             k_cost=k_cost, tel=tel, ep=ep, work=work, wk=wk)
    zeros = _with_zeros(MarketWindowStats.zeros(market.n_pools), tel,
                        market.n_pools, env=ep is not None,
                        work=work is not None)
    rebase = _rebase_for(ep, work)
    if layout is None:
        return _scan_chunked(step, zeros, state, n_events, chunk_events,
                             rebase=rebase)
    return _scan_chunked_slab(lambda c, s, x: step(c, s, x=x), zeros, state,
                              n_events, chunk_events, layout.n_cols,
                              paired=(ep is not None) or (work is not None),
                              rebase=rebase)


@functools.partial(
    jax.jit,
    static_argnames=("job", "market", "kernel", "rmax", "preempt_on",
                     "n_events", "chunk_events", "burn_in", "rng", "tel",
                     "work"),
)
def _run_market_sim_jit(job, market, kernel, rmax, preempt_on, n_events,
                        chunk_events, burn_in, rng, params, mp, k_cost, key,
                        tel=None, ep=None, work=None, wk=None):
    layout = (_market_layout(job, market, kernel, preempt_on)
              if rng == "slab" else None)
    state = init_market_state(key, job, market, rmax, mp, preempt_on,
                              scalar_preempt=layout is not None, ep=ep)
    if ep is not None:
        state = (state, init_env_state(ep))
    if work is not None:
        state = (state, init_work_state(rmax))
    if burn_in:
        state, _ = run_market_window(job, market, kernel, rmax, preempt_on,
                                     state, params, mp, k_cost, burn_in,
                                     layout=layout, tel=tel, ep=ep,
                                     work=work, wk=wk)
        state = _rebase_for(ep, work)(state)
    return run_market_chunked(job, market, kernel, rmax, preempt_on, state,
                              params, mp, k_cost, n_events, chunk_events,
                              layout=layout, tel=tel, ep=ep, work=work,
                              wk=wk)


@functools.partial(
    jax.jit,
    static_argnames=("job", "market", "kernel", "rmax", "preempt_on",
                     "n_events", "chunk_events", "burn_in", "rng", "tel",
                     "work"),
)
def _run_market_sweep_jit(job, market, kernel, rmax, preempt_on, n_events,
                          chunk_events, burn_in, rng, params, mp, k_cost,
                          keys, tel=None, ep=None, work=None, wk=None):
    """(grid × pools-config × seeds) fleet as one nested-vmap XLA program
    (broadcast ``in_axes``; see :func:`_flat_lane_args`)."""
    layout = (_market_layout(job, market, kernel, preempt_on)
              if rng == "slab" else None)

    def one(p, m, kc, key):
        state = init_market_state(key, job, market, rmax, m, preempt_on,
                                  scalar_preempt=layout is not None, ep=ep)
        if ep is not None:
            state = (state, init_env_state(ep))
        if work is not None:
            state = (state, init_work_state(rmax))
        if burn_in:
            state, _ = run_market_window(job, market, kernel, rmax,
                                         preempt_on, state, p, m, kc,
                                         burn_in, layout=layout, tel=tel,
                                         ep=ep, work=work, wk=wk)
            state = _rebase_for(ep, work)(state)
        _, stats = run_market_chunked(job, market, kernel, rmax, preempt_on,
                                      state, p, m, kc, n_events,
                                      chunk_events, layout=layout, tel=tel,
                                      ep=ep, work=work, wk=wk)
        return stats

    per_seeds = jax.vmap(one, in_axes=(None, None, None, 0))
    return jax.vmap(per_seeds, in_axes=(0, 0, 0, None))(params, mp, k_cost,
                                                        keys)


@functools.partial(
    jax.jit,
    static_argnames=("job", "market", "kernel", "rmax", "preempt_on",
                     "n_events", "chunk_events", "burn_in", "tile",
                     "interpret", "executor", "rng", "tel", "work"),
)
def _run_market_sweep_pallas_jit(job, market, kernel, rmax, preempt_on,
                                 n_events, chunk_events, burn_in, tile,
                                 interpret, params, mp, k_cost, keys,
                                 executor="pallas", rng="split", tel=None,
                                 ep=None, work=None, wk=None):
    """The market fleet through the same batched-event kernel family: the
    per-pool ``next_spot``/``next_preempt`` clock vectors become
    (tile, n_pools) VMEM blocks and :func:`_market_event` is the vmap-ed
    kernel body — bit-for-bit the ``executor="ref"`` scan oracle; integer
    stats bitwise / float sums to ~ulp vs :func:`_run_market_sweep_jit`
    (see the module docstring).  Under ``rng="slab"`` the slab arrives as
    a (tile, 1, window_events, n_cols) input block per window and the
    kernel performs no RNG at all."""
    g, s = k_cost.shape[0], keys.shape[0]
    (params_f, mp_f), k_f, keys_f = _flat_lane_args((params, mp), k_cost,
                                                    keys)
    params_b = {"params": params_f, "mp": mp_f, "k": k_f}
    layout = (_market_layout(job, market, kernel, preempt_on)
              if rng == "slab" else None)
    state0 = jax.vmap(
        lambda key, m: init_market_state(
            key, job, market, rmax, m, preempt_on,
            scalar_preempt=layout is not None,
            ep=ep))(keys_f, mp_f)
    plan = _window_plan(n_events, chunk_events, burn_in)

    if layout is not None:
        xs = _lane_slabs(state0, plan, layout)
    else:
        xs = None
    if ep is not None:
        params_b["ep"], es0 = _env_lane_blocks(ep, keys_f.shape[0])
        state0 = (state0, es0)
    if work is not None:
        params_b["wk"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (keys_f.shape[0],)), wk)
        state0 = (state0, init_work_state(rmax, keys_f.shape[0]))

    if layout is not None:
        def step(carry, stats, p, x):
            return _market_event(job, market, kernel, rmax, preempt_on,
                                 layout, carry, stats, p["params"], p["mp"],
                                 p["k"], x=x, tel=tel, ep=p.get("ep"),
                                 work=work, wk=p.get("wk"))
    else:
        def step(carry, stats, p):
            return _market_event(job, market, kernel, rmax, preempt_on,
                                 None, carry, stats, p["params"], p["mp"],
                                 p["k"], tel=tel, ep=p.get("ep"),
                                 work=work, wk=p.get("wk"))

    zeros = _with_zeros(MarketWindowStats.zeros(market.n_pools), tel,
                        market.n_pools, env=ep is not None,
                        work=work is not None)
    epilogue = _rebase_for(ep, work)
    if executor == "ref":
        _, stats = batched_event_windows_ref(
            step, state0, params_b, zeros, plan, xs=xs,
            epilogue=epilogue)
    else:
        _, stats = batched_events(
            step, state0, params_b, zeros, plan, xs=xs, tile=tile,
            interpret=interpret, epilogue=epilogue)
    if burn_in:
        stats = jax.tree.map(lambda x: x[:, 1:], stats)
    return _unflatten_lanes(stats, g, s)


def _market_sweep_lanes(job, market, kernel, rmax, preempt_on, n_events,
                        chunk_events, burn_in, tile, interpret, params_f,
                        mp_f, k_f, keys_f, *, executor, rng, tel=None,
                        ep=None, work=None, wk=None):
    """One shard of flat market lanes through any executor (cf.
    :func:`_sweep_lanes`; the pools-config tree ``mp_f`` is a per-lane
    grid axis exactly as in :func:`_run_market_sweep_pallas_jit`)."""
    layout = (_market_layout(job, market, kernel, preempt_on)
              if rng == "slab" else None)
    if executor == "xla":
        def one(p, m, kc, key):
            state = init_market_state(key, job, market, rmax, m, preempt_on,
                                      scalar_preempt=layout is not None,
                                      ep=ep)
            if ep is not None:
                state = (state, init_env_state(ep))
            if work is not None:
                state = (state, init_work_state(rmax))
            if burn_in:
                state, _ = run_market_window(job, market, kernel, rmax,
                                             preempt_on, state, p, m, kc,
                                             burn_in, layout=layout, tel=tel,
                                             ep=ep, work=work, wk=wk)
                state = _rebase_for(ep, work)(state)
            _, stats = run_market_chunked(job, market, kernel, rmax,
                                          preempt_on, state, p, m, kc,
                                          n_events, chunk_events,
                                          layout=layout, tel=tel, ep=ep,
                                          work=work, wk=wk)
            return stats

        return jax.vmap(one)(params_f, mp_f, k_f, keys_f)

    params_b = {"params": params_f, "mp": mp_f, "k": k_f}
    state0 = jax.vmap(
        lambda key, m: init_market_state(
            key, job, market, rmax, m, preempt_on,
            scalar_preempt=layout is not None, ep=ep))(keys_f, mp_f)
    plan = _window_plan(n_events, chunk_events, burn_in)
    xs = _lane_slabs(state0, plan, layout) if layout is not None else None
    if ep is not None:
        params_b["ep"], es0 = _env_lane_blocks(ep, keys_f.shape[0])
        state0 = (state0, es0)
    if work is not None:
        params_b["wk"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (keys_f.shape[0],)), wk)
        state0 = (state0, init_work_state(rmax, keys_f.shape[0]))

    if layout is not None:
        def step(carry, stats, p, x):
            return _market_event(job, market, kernel, rmax, preempt_on,
                                 layout, carry, stats, p["params"], p["mp"],
                                 p["k"], x=x, tel=tel, ep=p.get("ep"),
                                 work=work, wk=p.get("wk"))
    else:
        def step(carry, stats, p):
            return _market_event(job, market, kernel, rmax, preempt_on,
                                 None, carry, stats, p["params"], p["mp"],
                                 p["k"], tel=tel, ep=p.get("ep"),
                                 work=work, wk=p.get("wk"))

    zeros = _with_zeros(MarketWindowStats.zeros(market.n_pools), tel,
                        market.n_pools, env=ep is not None,
                        work=work is not None)
    epilogue = _rebase_for(ep, work)
    if executor == "ref":
        _, stats = batched_event_windows_ref(
            step, state0, params_b, zeros, plan, xs=xs, epilogue=epilogue)
    else:
        _, stats = batched_events(
            step, state0, params_b, zeros, plan, xs=xs, tile=tile,
            interpret=interpret, epilogue=epilogue)
    if burn_in:
        stats = jax.tree.map(lambda x: x[:, 1:], stats)
    return stats


@functools.partial(
    jax.jit,
    static_argnames=("job", "market", "kernel", "rmax", "preempt_on",
                     "n_events", "chunk_events", "burn_in", "tile",
                     "interpret", "mesh", "executor", "rng", "tel", "work"),
)
def _run_market_sweep_sharded_jit(job, market, kernel, rmax, preempt_on,
                                  n_events, chunk_events, burn_in, tile,
                                  interpret, mesh, params, mp, k_cost, keys,
                                  executor="xla", rng="split", tel=None,
                                  ep=None, work=None, wk=None):
    """The market fleet lane-partitioned across a 1-D device mesh (cf.
    :func:`_run_sweep_sharded_jit`)."""
    g, s = k_cost.shape[0], keys.shape[0]
    (params_f, mp_f), k_f, keys_f = _flat_lane_args((params, mp), k_cost,
                                                    keys)
    lanes = g * s
    params_f, mp_f, k_f, keys_f = pad_lanes((params_f, mp_f, k_f, keys_f),
                                            _pad_count(lanes, mesh))
    spec, rspec = lane_spec(mesh), jax.sharding.PartitionSpec()

    def local(pf, mf, kf, keysf, ep_, wk_):
        return _market_sweep_lanes(job, market, kernel, rmax, preempt_on,
                                   n_events, chunk_events, burn_in, tile,
                                   interpret, pf, mf, kf, keysf,
                                   executor=executor, rng=rng, tel=tel,
                                   ep=ep_, work=work, wk=wk_)

    stats = shard_map_1d(local, mesh=mesh,
                         in_specs=(spec, spec, spec, spec, rspec, rspec),
                         out_specs=spec)(params_f, mp_f, k_f, keys_f, ep, wk)
    if lanes != keys_f.shape[0]:
        stats = jax.tree.map(lambda x: x[:lanes], stats)
    return _unflatten_lanes(stats, g, s)


def summarize_market(stats: MarketWindowStats,
                     telemetry: Telemetry | None = None,
                     env: EnvTimeline | None = None, work=None) -> dict:
    """Float64 chunk reduction + market-specific derived statistics.

    Extends :func:`summarize`'s dict with preemption counters, spot spend,
    and per-pool served/arrival/utilization arrays (trailing pool axis).
    The chunk axis is the last axis for scalar accumulators and the
    second-to-last for per-pool vectors.  With ``telemetry``, ``stats`` is
    the ``(base, telemetry)`` pair and the telemetry fields are appended
    (base keys unchanged; see :func:`summarize`).  With ``env``, the env
    block rides outermost and the shock counters are appended.  With
    ``work``, the survival ledger rides outermost of all and its job-level
    fields are appended.
    """
    wstats = None
    if work is not None:
        stats, wstats = stats
    estats = None
    if env is not None:
        stats, estats = stats
    tstats = None
    if telemetry is not None:
        stats, tstats = stats
    n_common = len(WindowStats._fields)
    out = summarize(WindowStats(*stats[:n_common]))

    def _red(name):
        x = getattr(stats, name)
        axis = -2 if name in _POOL_FIELDS else -1
        return np.asarray(x, np.float64).sum(axis=axis)

    resumed = _red("resumed")
    spot_cost = _red("spot_cost")
    pool_served = _red("pool_served")
    pool_arrivals = _red("pool_spot_arrivals")
    pool_preempted = _red("pool_preempted")
    # per-JOB statistics: jobs_completed counts *legs* under preemption (a
    # checkpointed revocation closes one leg; the retry completes later),
    # which is the right window statistic for Algorithm 1 but not the
    # paper's E[C].  Jobs leave the system only via spot service or
    # on-demand, so dividing the same cost/delay totals by final
    # completions gives true per-job averages (identical when resumed = 0).
    cost_sum = _red("cost_sum")
    delay_sum = _red("delay_sum")
    final = np.maximum(_red("spot_served") + _red("ondemand"), 1.0)
    out.update({
        "preemptions": pool_preempted.sum(axis=-1),
        "resumed": resumed,
        "spot_cost": spot_cost,
        "avg_cost_job": cost_sum / final,
        "avg_delay_job": delay_sum / final,
        "pool_served": pool_served,
        "pool_spot_arrivals": pool_arrivals,
        "pool_preempted": pool_preempted,
        "pool_utilization": pool_served / np.maximum(pool_arrivals, 1.0),
    })
    if telemetry is not None:
        out = _merge_telemetry(out, telemetry, tstats, stats.time_elapsed)
    if estats is not None:
        out.update(summarize_env(estats))
    if wstats is not None:
        out.update(summarize_survival(wstats))
    return out


def _broadcast_config_params(n: int, cfg: dict, overrides: dict,
                             grid_shape: tuple) -> dict:
    """Merge config overrides into a traced per-pool/per-region params dict.

    Each override broadcasts to ``grid_shape + (n,)``: scalars fill every
    entry, ``(n,)`` vectors fix a config, ``grid_shape + (n,)`` arrays sweep
    the configuration itself.  Shared by the market (pools axis) and region
    (regions axis) sweep entry points; non-overridden keys keep their dtype
    (the region config carries an int32 ``rmax`` vector).
    """
    for name, val in overrides.items():
        if val is None:
            continue
        v = jnp.asarray(val, jnp.float32)
        if v.ndim == 0:
            v = jnp.broadcast_to(v, (n,))
        cfg[name] = v
    return {name: jnp.broadcast_to(v, grid_shape + (n,))
            .reshape((-1, n)) for name, v in cfg.items()}


def _broadcast_market_params(market: SpotMarket, mp_overrides: dict,
                             grid_shape: tuple) -> dict:
    """Pools-config overrides → flat traced market params (see
    :func:`_broadcast_config_params`)."""
    return _broadcast_config_params(market.n_pools, market.params(),
                                    mp_overrides, grid_shape)


def run_market_sim(
    job: ArrivalProcess,
    market: SpotMarket,
    kernel,
    params=None,
    *,
    k: float = 10.0,
    n_events: int,
    key: jax.Array,
    rmax: int = 64,
    burn_in: int = 0,
    chunk_events: int | None = DEFAULT_CHUNK_EVENTS,
    impl: str = "xla",
    rng: str = "split",
    tile: int = 256,
    interpret: bool | None = None,
    telemetry: Telemetry | None = None,
    env: EnvTimeline | None = None,
    work: WorkModel | None = None,
) -> dict:
    """Run one market policy at one parameter point; scalar long-run stats.

    A degenerate market (:meth:`SpotMarket.is_degenerate`) with a legacy
    kernel reproduces :func:`run_sim` bit-for-bit per seed.  ``chunk_events``
    / ``impl`` / ``rng`` behave exactly as in :func:`run_sim`; ``env``
    attaches an :class:`~repro.core.env.EnvTimeline` (per-pool price /
    hazard / availability segments) exactly as in :func:`run_sim`;
    ``work`` (a :class:`repro.core.work.WorkModel`) attaches the work
    structure — checkpoint-priced recovery, restart overhead, deadlines —
    and the survival ledger (module docstring of :mod:`repro.core.work`).
    """
    market = as_market(market)
    params = {} if params is None else params
    _check_rng(rng)
    _check_telemetry(telemetry)
    _check_env(env)
    _check_work(work, kernel)
    _check_run_shape("run_market_sim", n_events, burn_in)
    mp = market.params()
    ep = _env_params(env, market.n_pools)
    wk = None if work is None else work.params()
    chunk = n_events if chunk_events is None else min(chunk_events, n_events)
    with annotate(f"repro.run_market_sim[{impl}]"):
        if impl in ("pallas", "ref"):
            stats = _run_market_sweep_pallas_jit(
                job, market, kernel, rmax, market.preemptible, n_events,
                chunk, burn_in, tile,
                default_interpret() if interpret is None else interpret,
                jax.tree.map(lambda x: jnp.asarray(x)[None], params),
                jax.tree.map(lambda x: jnp.asarray(x)[None], mp),
                jnp.float32(k)[None], _raw_keys(key)[None], executor=impl,
                rng=rng, tel=telemetry, ep=ep, work=work, wk=wk)
            stats = jax.tree.map(lambda x: x[0, 0], stats)
        elif impl == "xla":
            _, stats = _run_market_sim_jit(job, market, kernel, rmax,
                                           market.preemptible, n_events,
                                           chunk, burn_in, rng, params, mp,
                                           jnp.float32(k), key,
                                           tel=telemetry, ep=ep, work=work,
                                           wk=wk)
        else:
            raise ValueError(
                f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
    return {name: _scalar_or_array(v)
            for name, v in summarize_market(stats, telemetry, env=env,
                                            work=work).items()}


def run_market_sweep(
    job: ArrivalProcess,
    market: SpotMarket,
    kernel,
    params=None,
    *,
    k: float | np.ndarray | jax.Array = 10.0,
    prices=None,
    hazards=None,
    notices=None,
    spot_scales=None,
    n_events: int,
    key: jax.Array,
    n_seeds: int = 1,
    rmax: int = 64,
    burn_in: int = 0,
    chunk_events: int | None = DEFAULT_CHUNK_EVENTS,
    impl: str = "xla",
    rng: str = "split",
    tile: int = 256,
    interpret: bool | None = None,
    telemetry: Telemetry | None = None,
    env: EnvTimeline | None = None,
    work: WorkModel | None = None,
    shard: str = "none",
    mesh=None,
) -> dict:
    """Run a (params × k × pools-config × seeds) grid as ONE jitted call.

    ``params`` leaves and ``k`` broadcast to a common grid shape exactly as
    in :func:`run_sweep`.  ``prices``/``hazards``/``notices``/``spot_scales``
    optionally override the market's static pool configuration per grid
    point: a scalar applies to every pool, a ``(P,)`` vector fixes one
    config, and a ``grid_shape + (P,)`` array sweeps the pool configuration
    inside the same compiled program (the pools-config axis of the grid).

    ``impl``/``tile``/``interpret`` select the executor exactly as in
    :func:`run_sweep`; the Pallas path widens the VMEM-resident state tile
    with the (tile, n_pools) clock vectors — bit-for-bit the ``"ref"``
    oracle, integer stats bitwise / float sums to ~ulp vs ``"xla"`` (see
    the module docstring's executor contract).  ``shard="lanes"``
    partitions the flattened lane axis across a 1-D device mesh exactly
    as in :func:`run_sweep` (pools-config lanes ride along).

    Returns :func:`summarize_market`'s dict; scalar statistics are shaped
    ``grid_shape + (n_seeds,)`` and per-pool statistics
    ``grid_shape + (n_seeds, P)``.
    """
    market = as_market(market)
    n = market.n_pools
    params = {} if params is None else params
    _check_rng(rng)
    _check_telemetry(telemetry)
    _check_env(env)
    _check_work(work, kernel)
    _check_shard("run_market_sweep", shard, mesh)
    _check_run_shape("run_market_sweep", n_events, burn_in)
    _check_loc_overrides("run_market_sweep", n, "pool", prices=prices,
                         hazards=hazards, notices=notices,
                         spot_scales=spot_scales)
    ep = _env_params(env, n)
    wk = None if work is None else work.params()
    params = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), params)
    k = jnp.asarray(k, jnp.float32)
    overrides = {"price": prices, "hazard": hazards, "notice": notices,
                 "spot_scale": spot_scales}
    override_shapes = [jnp.asarray(v).shape[:-1]
                       for v in overrides.values()
                       if v is not None and jnp.asarray(v).ndim > 1]
    grid_shape = jnp.broadcast_shapes(
        k.shape, *(x.shape for x in jax.tree.leaves(params)),
        *override_shapes,
    )
    flat = lambda x: jnp.broadcast_to(x, grid_shape).reshape(-1)
    params_flat = jax.tree.map(flat, params)
    k_flat = flat(k)
    mp_flat = _broadcast_market_params(market, overrides, grid_shape)
    preempt_on = market.preemptible or hazards is not None
    keys = jax.random.split(key, n_seeds)
    chunk = n_events if chunk_events is None else min(chunk_events, n_events)
    with annotate(f"repro.run_market_sweep[{impl}]"):
        if shard == "lanes":
            if impl not in ("xla", "pallas", "ref"):
                raise ValueError(
                    f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
            stats = _run_market_sweep_sharded_jit(
                job, market, kernel, rmax, preempt_on, n_events, chunk,
                burn_in, tile,
                default_interpret() if interpret is None else interpret,
                lane_mesh() if mesh is None else mesh, params_flat, mp_flat,
                k_flat, _raw_keys(keys), executor=impl, rng=rng,
                tel=telemetry, ep=ep, work=work, wk=wk)
        elif impl in ("pallas", "ref"):
            stats = _run_market_sweep_pallas_jit(
                job, market, kernel, rmax, preempt_on, n_events, chunk,
                burn_in, tile,
                default_interpret() if interpret is None else interpret,
                params_flat, mp_flat, k_flat, _raw_keys(keys), executor=impl,
                rng=rng, tel=telemetry, ep=ep, work=work, wk=wk)
        elif impl == "xla":
            stats = _run_market_sweep_jit(job, market, kernel, rmax,
                                          preempt_on, n_events, chunk,
                                          burn_in, rng, params_flat, mp_flat,
                                          k_flat, keys, tel=telemetry, ep=ep,
                                          work=work, wk=wk)
        else:
            raise ValueError(
                f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
    out = summarize_market(stats, telemetry, env=env, work=work)
    return _reshape_sweep(out, grid_shape, n_seeds)


# ===========================================================================
# Multi-region routing: N queues, per-region clocks, routing at admission
# ===========================================================================
#
# Third traversal of the event-loop architecture (PR 4).  The region loop
# widens the market loop one more level: the scalar job clock becomes a
# per-region ``next_job`` vector, the pool clock vectors become per-region
# supply clocks (one pool per region — exactly the PR-2 market clocks,
# re-indexed), and the single ``(rmax,)`` queue becomes N per-region
# ``(rmax_r,)`` partitions packed as one ``(sum rmax_r,)`` slot array with a
# *static* slot→region map.  The kernel protocol gains a routing hook
# (``route(params, qlens, region_state, key) -> region``, see
# repro.core.regions); the admission law then runs against the TARGET
# region's queue length, so each region runs a per-region instance of the
# paper's policy.
#
# Event-time ties resolve spot > preempt > deadline > job (the PR-2 order);
# ties between regions resolve by position (argmin), measure-zero for
# continuous samplers.
#
# With a degenerate topology (1 region, zero hazard, unit price) and a
# kernel without a ``route`` hook, every expression below reduces bitwise
# to the market loop's (and hence, by the PR-2 ledger, to the PR-1 engine):
# the routing machinery is statically removed (no extra key split, target =
# home = 0), the per-region min/argmin over length-1 vectors are exact
# identities, the static all-zero slot→region map makes every eligibility
# mask equal the occupancy mask, and the extra stat terms accumulate into
# separate fields.  tests/test_core_regions.py freezes that contract
# against run_sim/run_sweep AND run_market_sim/run_market_sweep under all
# three executors.


class RegionWindowStats(NamedTuple):
    """Per-window accumulators for the region loop.

    The first ten fields mirror :class:`WindowStats` exactly (same order,
    same accumulation semantics); ``resumed``/``spot_cost`` mirror the
    market tail; the per-region counters close the set.  ``region_jobs``
    counts arrivals by HOME region; ``region_routed`` counts admissions by
    TARGET region — their difference is the cross-region flow the routing
    hook created (``routed_home`` tracks the non-crossing admissions).
    """

    jobs_arrived: jax.Array
    jobs_completed: jax.Array
    spot_served: jax.Array
    ondemand: jax.Array
    cost_sum: jax.Array
    delay_sum: jax.Array
    time_elapsed: jax.Array
    empty_time: jax.Array
    spot_arrivals: jax.Array
    spot_found_empty: jax.Array
    resumed: jax.Array  # i32: preempted legs that checkpointed + re-queued
    spot_cost: jax.Array  # f32: spend on region spot (incl. partial legs)
    routed_home: jax.Array  # i32: admissions whose target == home region
    region_served: jax.Array  # (R,) i32 completions per region
    region_spot_arrivals: jax.Array  # (R,) i32 slot arrivals per region
    region_preempted: jax.Array  # (R,) i32 preemption hits per region
    region_jobs: jax.Array  # (R,) i32 job arrivals per HOME region
    region_routed: jax.Array  # (R,) i32 admissions per TARGET region

    @staticmethod
    def zeros(n_regions: int) -> "RegionWindowStats":
        z = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        zr = jnp.zeros((n_regions,), jnp.int32)
        return RegionWindowStats(zi, zi, zi, zi, z, z, z, z, zi, zi,
                                 zi, z, zi, zr, zr, zr, zr, zr)


_REGION_FIELDS = frozenset({"region_served", "region_spot_arrivals",
                            "region_preempted", "region_jobs",
                            "region_routed"})


class RegionState(NamedTuple):
    key: jax.Array
    next_job: jax.Array  # (R,) per-region job-arrival clocks
    next_spot: jax.Array  # (R,) per-region spot-slot clocks
    next_preempt: jax.Array  # (R,) per-region preemption clocks (INF = never)
    ages: jax.Array  # (S,) packed slots, S = sum rmax_r
    budgets: jax.Array  # (S,)
    occ: jax.Array  # (S,) bool
    order: jax.Array  # (S,) int32 join sequence number
    next_seq: jax.Array
    qlen: jax.Array  # (R,) int32 queued jobs per region


def _slot_region_iota(topo: RegionTopology, iota_s: jax.Array) -> jax.Array:
    """The static slot→region map as ops on an iota (no array constants:
    inline jnp constants would be hoisted as consts, which pallas_call
    rejects — same rule as the module-level np scalars)."""
    reg = jnp.zeros_like(iota_s)
    for off in topo.slot_offsets()[1:]:
        reg = reg + (iota_s >= np.int32(off)).astype(jnp.int32)
    return reg


def _region_tags(topo: RegionTopology) -> tuple:
    return tuple(r.tag for r in topo.regions)


def _sample_job_clocks(topo: RegionTopology, k_job: jax.Array,
                       rp: dict) -> jax.Array:
    """Per-region job clock refresh via the shared tag-folded plumbing
    (:func:`repro.core.clocks.sample_clock_vector`): the 1-region topology
    uses ``k_job`` directly, the PR-1/PR-2 key layout, so the degenerate
    engine is bit-for-bit the PR-3 engine."""
    return sample_clock_vector(tuple(r.job for r in topo.regions),
                               _region_tags(topo), k_job, rp["job_scale"])


def _sample_region_spot_clocks(topo: RegionTopology, k_spot: jax.Array,
                               rp: dict) -> jax.Array:
    return sample_clock_vector(tuple(r.spot for r in topo.regions),
                               _region_tags(topo), k_spot, rp["spot_scale"])


def init_region_state(key: jax.Array, topo: RegionTopology, rp: dict,
                      preempt_on: bool,
                      scalar_preempt: bool = False,
                      ep: dict | None = None) -> RegionState:
    """``scalar_preempt`` (the ``rng="slab"`` representation) carries ONE
    superposed preemption clock — min of the per-region init draws, exactly
    ``Exp(Σ h_r)``; see :func:`init_market_state`.  ``ep`` places the
    initial supply clocks under segment 0 (exact no-op on a constant
    timeline); job clocks are never modulated."""
    kj, ks, kc = jax.random.split(key, 3)
    n, s = topo.n_regions, topo.total_slots
    hazard0 = (rp["hazard"] if ep is None
               else rp["hazard"] * ep["hazard"][0])
    if preempt_on:
        next_preempt = sample_hazard_clocks(
            _region_tags(topo), jax.random.fold_in(ks, 2**31 - 1),
            hazard0)
        if scalar_preempt:
            next_preempt = jnp.min(next_preempt, keepdims=True)
    else:
        next_preempt = jnp.full((1 if scalar_preempt else n,), INF,
                                jnp.float32)
    next_job = _sample_job_clocks(topo, kj, rp)
    next_spot = _sample_region_spot_clocks(topo, ks, rp)
    if ep is not None:
        next_spot = next_spot * inv_avail(ep["avail"][0])
    return RegionState(
        key=kc,
        next_job=next_job,
        next_spot=next_spot,
        next_preempt=next_preempt,
        ages=jnp.zeros((s,), jnp.float32),
        budgets=jnp.full((s,), INF, jnp.float32),
        occ=jnp.zeros((s,), jnp.bool_),
        order=jnp.zeros((s,), jnp.int32),
        next_seq=jnp.zeros((), jnp.int32),
        qlen=jnp.zeros((n,), jnp.int32),
    )


def _kernel_region_admit(kernel, params, qlen_t, view: RegionView, key):
    """Run the admission law against the target region's queue length.

    Market-aware kernels (``admit_market``) see the regions as their pools
    (one supply pool per region — the :class:`PoolState` vectors ARE the
    region vectors); their pool choice is ignored in favour of the routing
    decision.  Legacy kernels call ``admit`` with the PR-1 key layout.
    """
    if hasattr(kernel, "admit_market"):
        ps = PoolState(price=view.price, hazard=view.hazard,
                       notice=view.notice, rate=view.rate,
                       qlen_pool=view.qlen_region)
        admit, budget, _pool = kernel.admit_market(params, qlen_t, ps, key)
        return admit, budget
    return kernel.admit(params, qlen_t, key)


def _kernel_region_admit_slab(kernel, params, qlen_t, view: RegionView,
                              layout: SlabLayout, x):
    """Slab-stream twin of :func:`_kernel_region_admit`."""
    if layout.market_admit:
        ps = PoolState(price=view.price, hazard=view.hazard,
                       notice=view.notice, rate=view.rate,
                       qlen_pool=view.qlen_region)
        if layout.admit_mode == "u":
            admit, budget, _pool = kernel.admit_market_u(
                params, qlen_t, ps, layout.uniforms(x, layout.admit))
        else:
            admit, budget, _pool = kernel.admit_market(
                params, qlen_t, ps, synth_key(layout.bits(x, layout.admit)))
        return admit, budget
    return _admit_slab(kernel, params, qlen_t, layout, x)


def _kernel_route_slab(kernel, params, qlens, view: RegionView,
                       layout: SlabLayout, x):
    if layout.route_mode == "u":
        return kernel.route_u(params, qlens, view,
                              layout.uniforms(x, layout.route))
    return kernel.route(params, qlens, view,
                        synth_key(layout.bits(x, layout.route)))


def _region_event(topo: RegionTopology, kernel, preempt_on: bool,
                  layout: SlabLayout | None, carry: RegionState,
                  stats: RegionWindowStats, params, rp: dict,
                  k_cost: jax.Array, x: jax.Array | None = None,
                  tel: Telemetry | None = None, ep: dict | None = None,
                  work: WorkModel | None = None, wk: dict | None = None
                  ) -> tuple[RegionState, RegionWindowStats]:
    """One merged event: job arrival (in some region) / region spot slot /
    region preemption / wait deadline.  Same dense one-hot-select style as
    :func:`_engine_event` (see the note there on scatter vs select under
    vmap); expression structure deliberately mirrors :func:`_market_event`
    so the degenerate reduction is auditable term by term — including the
    slab stream's superposed scalar preemption clock (``layout`` not None).
    ``tel`` appends the telemetry fold exactly as in :func:`_engine_event`
    (base expressions untouched); the event locus is the firing region.
    ``ep`` threads the environment timeline exactly as in
    :func:`_market_event` (regions are the locations; the demand-side
    ``next_job`` clocks are deliberately NOT modulated — supply shocks
    perturb the market, not the workload).
    ``work``/``wk`` thread the work axis exactly as in
    :func:`_market_event` (the packed slot array carries the work
    structure; rollbacks price the region's notice window).
    """
    n_regions, n_slots = topo.n_regions, topo.total_slots
    has_route = hasattr(kernel, "route")
    if work is not None:
        carry, wk_c = carry
        stats, wstats = stats
    if ep is not None:
        carry, env_c = carry
        stats, estats = stats
        seg = env_c.seg
        avail_row = env_row(ep["avail"], seg)
        eff_hazard = rp["hazard"] * env_row(ep["hazard"], seg)
        eff_price = rp["price"] * env_row(ep["price"], seg)
    else:
        eff_hazard = rp["hazard"]
        eff_price = rp["price"]
    if tel is not None:
        stats, tstats = stats
    if layout is None:
        key, k_job, k_spot, k_pol, k_pre, k_rt = split_event_keys(
            carry.key, preempt_on, has_route)
    else:
        key = carry.key
    iota_s = jax.lax.iota(jnp.int32, n_slots)
    iota_r = jax.lax.iota(jnp.int32, n_regions)
    slot_region = _slot_region_iota(topo, iota_s)

    budgets_masked = jnp.where(carry.occ, carry.budgets, INF)
    if work is not None and getattr(kernel, "safety_net", False):
        # can't-be-late watchdog (see _engine_event): the panic clock
        # joins the budget race, so a panic is a forced-early defection
        # to on-demand through the existing deadline machinery
        buf = np.float32(getattr(kernel, "slack_buffer", 0.0))
        rem_tot_all = wk_c.oh + jnp.maximum(wk["total_work"] - wk_c.prog,
                                            0.0)
        panic_at = jnp.maximum(
            deadline_slack(wk["deadline"], wk_c.life, rem_tot_all,
                           wk["od_time"], buf), 0.0)
        panic_at = jnp.where(carry.occ, panic_at, INF)
        panic_armed = panic_at < budgets_masked
        budgets_masked = jnp.minimum(budgets_masked, panic_at)
    else:
        panic_armed = None
    deadline = jnp.min(budgets_masked)
    defect_slot = jnp.argmin(budgets_masked)

    min_job = jnp.min(carry.next_job)
    home = jnp.argmin(carry.next_job).astype(jnp.int32)
    min_spot = jnp.min(carry.next_spot)
    spot_region = jnp.argmin(carry.next_spot).astype(jnp.int32)
    if preempt_on:
        if layout is None:
            min_pre = jnp.min(carry.next_preempt)
            pre_region = jnp.argmin(carry.next_preempt).astype(jnp.int32)
        else:
            min_pre = carry.next_preempt[0]
            pre_region = thinning_pick(
                eff_hazard, layout.uniforms(x, layout.preempt)[1])
        dt = jnp.minimum(jnp.minimum(min_job, min_spot),
                         jnp.minimum(deadline, min_pre))
        is_spot = min_spot <= jnp.minimum(min_job,
                                          jnp.minimum(deadline, min_pre))
        is_pre = (~is_spot) & (min_pre <= jnp.minimum(min_job, deadline))
        is_deadline = (~is_spot) & (~is_pre) & (deadline <= min_job)
        is_job = (~is_spot) & (~is_pre) & (~is_deadline)
    else:
        pre_region = jnp.zeros((), jnp.int32)
        dt = jnp.minimum(jnp.minimum(min_job, min_spot), deadline)
        is_spot = min_spot <= jnp.minimum(min_job, deadline)
        is_pre = jnp.zeros((), jnp.bool_)
        is_deadline = (~is_spot) & (deadline <= min_job)
        is_job = (~is_spot) & (~is_deadline)

    if ep is not None:
        # segment boundary joins the race with highest priority: no queue
        # activity, clocks age by dt, the segment index advances
        is_boundary = env_c.next_boundary <= dt
        dt = jnp.minimum(dt, env_c.next_boundary)
        not_b = ~is_boundary
        is_spot = is_spot & not_b
        is_pre = is_pre & not_b
        is_deadline = is_deadline & not_b
        is_job = is_job & not_b

    ages = carry.ages + dt
    budgets = jnp.where(carry.occ, carry.budgets - dt, INF)

    # ---- job arrival in region `home`: route, then ask the admission law --
    rates = rp["rate"] / rp["spot_scale"]
    if ep is not None:
        rates = rates * avail_row  # rate == 0 marks a blacked-out region
    view = RegionView(
        home=home,
        price=eff_price, hazard=eff_hazard, notice=rp["notice"],
        rate=rates,
        job_rate=rp["job_rate"] / rp["job_scale"],
        qlen_region=carry.qlen,
        free_slots=jnp.maximum(rp["rmax"] - carry.qlen, 0),
    )
    if not has_route:
        target = home
    elif layout is None:
        target = jnp.asarray(kernel.route(params, carry.qlen, view, k_rt),
                             jnp.int32)
    else:
        target = jnp.asarray(
            _kernel_route_slab(kernel, params, carry.qlen, view, layout, x),
            jnp.int32)
    qlen_t = jnp.sum(jnp.where(iota_r == target, carry.qlen, 0))
    rmax_t = jnp.sum(jnp.where(iota_r == target, rp["rmax"], 0))
    if layout is None:
        admit_raw, budget = _kernel_region_admit(kernel, params, qlen_t,
                                                 view, k_pol)
    else:
        admit_raw, budget = _kernel_region_admit_slab(kernel, params, qlen_t,
                                                      view, layout, x)
    admit = is_job & admit_raw & (qlen_t < rmax_t)
    od_now = is_job & (~admit)
    target_mask = slot_region == target
    join_slot = jnp.argmin(jnp.where(target_mask,
                                     carry.occ.astype(jnp.int32), 2))

    # ---- region spot slot: serve the FIFO-oldest job queued there --------
    eligible_s = carry.occ & (slot_region == spot_region)
    serve_slot = jnp.argmin(jnp.where(eligible_s, carry.order, _ORDER_MAX))
    has_elig = jnp.any(eligible_s)
    served = is_spot & has_elig
    wait_served = jnp.sum(jnp.where(iota_s == serve_slot, ages, 0.0))
    price_s = eff_price[spot_region]

    if work is not None:
        # one unit of service: overhead debt first, spill into progress;
        # final only when the remaining total clears (see _engine_event)
        serve_vec = served & (iota_s == serve_slot)
        rem_tot = wk_c.oh + (wk["total_work"] - wk_c.prog)
        rem_serve = jnp.sum(jnp.where(iota_s == serve_slot, rem_tot, 0.0))
        oh_new = jnp.where(serve_vec, jnp.maximum(wk_c.oh - 1.0, 0.0),
                           wk_c.oh)
        spill = jnp.maximum(1.0 - wk_c.oh, 0.0)
        prog_new = jnp.where(
            serve_vec, jnp.minimum(wk_c.prog + spill, wk["total_work"]),
            wk_c.prog)
        done_inc = jnp.sum(jnp.where(serve_vec, prog_new - wk_c.prog, 0.0))
        if work.ckpt == "periodic":
            take_vec = (serve_vec & (rem_tot > 1.0)
                        & (prog_new - wk_c.ckpt >= wk["ckpt_period"]))
            ckpt_new = jnp.where(take_vec, prog_new, wk_c.ckpt)
            oh_new = oh_new + jnp.where(take_vec, wk["ckpt_cost"], 0.0)
            ckpt_taken = jnp.any(take_vec)
        else:
            ckpt_new = wk_c.ckpt
            ckpt_taken = jnp.zeros((), jnp.bool_)
        complete_serve = served & (rem_serve <= 1.0)
    else:
        complete_serve = served

    # ---- region preemption: revoke the FIFO-oldest job in that region ----
    if preempt_on:
        eligible_p = carry.occ & (slot_region == pre_region)
        pre_slot = jnp.argmin(jnp.where(eligible_p, carry.order, _ORDER_MAX))
        pre_hit = is_pre & jnp.any(eligible_p)
        age_pre = jnp.sum(jnp.where(iota_s == pre_slot, ages, 0.0))
        # re-admission sees the region's queue WITHOUT the revoked job (the
        # host orchestrator pops it before consulting the admission law)
        qlen_p = jnp.sum(jnp.where(iota_r == pre_region, carry.qlen, 0))
        qlen_wo = jnp.maximum(qlen_p - 1, 0)
        if layout is None:
            resume_raw = _kernel_on_preempt(kernel, params, age_pre,
                                            rp["notice"][pre_region],
                                            qlen_wo, k_pre)
        else:
            resume_raw = _kernel_on_preempt_slab(kernel, params, age_pre,
                                                 rp["notice"][pre_region],
                                                 qlen_wo, layout, x)
        resume = pre_hit & resume_raw
        defect_pre = pre_hit & (~resume)
        price_p = eff_price[pre_region]
    else:
        pre_slot = jnp.zeros((), jnp.int32)
        pre_hit = jnp.zeros((), jnp.bool_)
        age_pre = jnp.zeros((), jnp.float32)
        resume = jnp.zeros((), jnp.bool_)
        defect_pre = jnp.zeros((), jnp.bool_)
        price_p = jnp.zeros((), jnp.float32)

    if work is not None and preempt_on:
        # rollback (see _market_event): resume restarts from the last
        # checkpoint and owes the restart overhead; notice mode saves
        # current progress iff it fits the firing REGION's notice window
        if work.ckpt == "notice":
            saved = resume & checkpoint_within_notice(
                wk["ckpt_time"], rp["notice"][pre_region])
        else:
            saved = jnp.zeros((), jnp.bool_)
        prog_p = jnp.sum(jnp.where(iota_s == pre_slot, prog_new, 0.0))
        ckpt_p = jnp.sum(jnp.where(iota_s == pre_slot, ckpt_new, 0.0))
        ckpt_val = jnp.where(saved, jnp.maximum(ckpt_p, prog_p), ckpt_p)
        resume_vec = resume & (iota_s == pre_slot)
        prog_new = jnp.where(resume_vec, ckpt_val, prog_new)
        oh_new = jnp.where(resume_vec, wk["restart_overhead"], oh_new)
        ckpt_new = jnp.where(resume_vec, ckpt_val, ckpt_new)
        lost = jnp.where(resume, jnp.maximum(prog_p - ckpt_val, 0.0), 0.0)
        oh_inc = jnp.where(resume, wk["restart_overhead"], 0.0)
        ckpt_taken = ckpt_taken | (resume & saved)
    elif work is not None:
        lost = jnp.zeros((), jnp.float32)
        oh_inc = jnp.zeros((), jnp.float32)

    # ---- deadline: the minimal-budget job defects to on-demand ----
    defected = is_deadline
    age_defect = jnp.sum(jnp.where(iota_s == defect_slot, ages, 0.0))

    leave = complete_serve | defected | defect_pre
    leave_slot = jnp.where(served, serve_slot,
                           jnp.where(defected, defect_slot, pre_slot))
    leave_region = jnp.sum(jnp.where(iota_s == leave_slot, slot_region, 0))

    join_mask = admit & (iota_s == join_slot)
    leave_mask = leave & (iota_s == leave_slot)
    resume_mask = resume & (iota_s == pre_slot)
    ages = jnp.where(join_mask | resume_mask, 0.0, ages)
    budgets = jnp.where(join_mask, budget,
                        jnp.where(resume_mask, INF, budgets))
    occ = (carry.occ | join_mask) & (~leave_mask)
    order = jnp.where(join_mask | resume_mask, carry.next_seq, carry.order)
    if work is not None:
        life_new = jnp.where(join_mask, 0.0, wk_c.life + dt)
        prog_new = jnp.where(join_mask, 0.0, prog_new)
        oh_new = jnp.where(join_mask, 0.0, oh_new)
        ckpt_new = jnp.where(join_mask, 0.0, ckpt_new)

    fire_j = is_job & (iota_r == home)
    fire_s = is_spot & (iota_r == spot_region)
    if layout is None:
        job_draws = _sample_job_clocks(topo, k_job, rp)
        spot_draws = _sample_region_spot_clocks(topo, k_spot, rp)
    else:
        job_draws = _slab_spot_clocks(tuple(r.job for r in topo.regions),
                                      layout.uniforms(x, layout.job),
                                      rp["job_scale"])
        spot_draws = _slab_spot_clocks(tuple(r.spot for r in topo.regions),
                                       layout.uniforms(x, layout.spot),
                                       rp["spot_scale"])
    if ep is not None:
        # availability scales fresh supply draws; on a boundary, survived
        # spot clocks are rescaled by the availability ratio and survived
        # hazard clocks by the hazard ratio — exact by memorylessness.
        # Demand (job) clocks are deliberately untouched.
        seg_new = seg + is_boundary.astype(jnp.int32)
        inv_old = inv_avail(avail_row)
        inv_new = inv_avail(env_row(ep["avail"], seg_new))
        eff_hazard_new = rp["hazard"] * env_row(ep["hazard"], seg_new)
        spot_draws = spot_draws * inv_new
    else:
        eff_hazard_new = rp["hazard"]
    next_job = jnp.where(fire_j, job_draws, carry.next_job - dt)
    next_spot = jnp.where(fire_s, spot_draws, carry.next_spot - dt)
    if ep is not None:
        next_spot = jnp.where(is_boundary, next_spot * (inv_new / inv_old),
                              next_spot)
    if not preempt_on:
        next_preempt = carry.next_preempt
    elif layout is None:
        fire_p = is_pre & (iota_r == pre_region)
        next_preempt = jnp.where(
            fire_p, sample_hazard_clocks(_region_tags(topo), k_pre,
                                         eff_hazard_new),
            carry.next_preempt - dt)
        if ep is not None:
            next_preempt = jnp.where(
                is_boundary,
                next_preempt * clock_rescale(eff_hazard, eff_hazard_new),
                next_preempt)
    else:
        # superposed scalar clock (see _market_event)
        next_preempt = jnp.where(
            is_pre, hazard_clock(eff_hazard_new,
                                 layout.uniforms(x, layout.preempt)[0]),
            carry.next_preempt - dt)
        if ep is not None:
            next_preempt = jnp.where(
                is_boundary,
                next_preempt * clock_rescale(jnp.sum(eff_hazard),
                                             jnp.sum(eff_hazard_new)),
                next_preempt)

    new_carry = RegionState(
        key=key,
        next_job=next_job,
        next_spot=next_spot,
        next_preempt=next_preempt,
        ages=ages,
        budgets=budgets,
        occ=occ,
        order=order,
        next_seq=carry.next_seq + jnp.where(admit | resume, 1, 0),
        qlen=(carry.qlen
              + jnp.where(admit & (iota_r == target), 1, 0)
              - jnp.where(leave & (iota_r == leave_region), 1, 0)),
    )
    completed = od_now | served | defected | defect_pre | resume
    new_stats = RegionWindowStats(
        jobs_arrived=stats.jobs_arrived + is_job.astype(jnp.int32),
        jobs_completed=stats.jobs_completed + completed.astype(jnp.int32),
        spot_served=stats.spot_served + served.astype(jnp.int32),
        ondemand=stats.ondemand
        + (od_now | defected | defect_pre).astype(jnp.int32),
        cost_sum=stats.cost_sum
        + jnp.where(served, price_s, 0.0)
        + jnp.where(od_now | defected | defect_pre, k_cost, 0.0)
        + jnp.where(pre_hit, price_p, 0.0),
        delay_sum=stats.delay_sum
        + jnp.where(served, wait_served, 0.0)
        + jnp.where(defected, age_defect, 0.0)
        + jnp.where(pre_hit, age_pre, 0.0),
        time_elapsed=stats.time_elapsed + dt,
        empty_time=stats.empty_time
        + jnp.where(jnp.sum(carry.qlen) == 0, dt, 0.0),
        spot_arrivals=stats.spot_arrivals + is_spot.astype(jnp.int32),
        spot_found_empty=stats.spot_found_empty
        + (is_spot & (~has_elig)).astype(jnp.int32),
        resumed=stats.resumed + resume.astype(jnp.int32),
        spot_cost=stats.spot_cost
        + jnp.where(served, price_s, 0.0)
        + jnp.where(pre_hit, price_p, 0.0),
        routed_home=stats.routed_home
        + (admit & (target == home)).astype(jnp.int32),
        region_served=stats.region_served
        + (fire_s & served).astype(jnp.int32),
        region_spot_arrivals=stats.region_spot_arrivals
        + fire_s.astype(jnp.int32),
        region_preempted=stats.region_preempted
        + (pre_hit & (iota_r == pre_region)).astype(jnp.int32),
        region_jobs=stats.region_jobs + fire_j.astype(jnp.int32),
        region_routed=stats.region_routed
        + (admit & (iota_r == target)).astype(jnp.int32),
    )
    if tel is not None:
        defect_region = jnp.sum(jnp.where(iota_s == defect_slot,
                                          slot_region, 0))
        loc = jnp.where(is_spot, spot_region,
                        jnp.where(is_pre, pre_region,
                                  jnp.where(is_deadline, defect_region,
                                            target)))
        tstats = telemetry_update(
            tel, tstats, t=new_stats.time_elapsed, is_job=is_job,
            is_spot=is_spot, is_pre=is_pre, is_deadline=is_deadline,
            served=served, resume=resume, defected=defected, od_now=od_now,
            wait_sample=jnp.where(served, wait_served,
                                  jnp.where(defected, age_defect, age_pre)),
            wait_valid=served | defected | pre_hit,
            cost_inc=jnp.where(served, price_s, 0.0)
            + jnp.where(od_now | defected | defect_pre, k_cost, 0.0)
            + jnp.where(pre_hit, price_p, 0.0),
            cost_valid=served | od_now | defected | pre_hit,
            loc=loc, n_locs=n_regions, qlen=jnp.sum(new_carry.qlen))
        out_stats = (new_stats, tstats)
    else:
        out_stats = new_stats
    out_carry = new_carry
    if ep is not None:
        estats = env_update(
            estats, is_boundary=is_boundary,
            kind_prev=env_row(ep["kind"], seg),
            kind_next=env_row(ep["kind"], seg_new), dt=dt, is_job=is_job,
            od_now=od_now, served=served, resumed=resume)
        new_env = EnvState(
            next_boundary=jnp.where(
                is_boundary,
                env_row(ep["t_end"], seg_new) - env_row(ep["t_end"], seg),
                env_c.next_boundary - dt),
            seg=seg_new)
        out_carry = (new_carry, new_env)
        out_stats = (out_stats, estats)
    if work is not None:
        life_def = jnp.sum(jnp.where(iota_s == defect_slot, wk_c.life + dt,
                                     0.0))
        rem_def = jnp.sum(jnp.where(iota_s == defect_slot, rem_tot, 0.0))
        life_pre = jnp.sum(jnp.where(iota_s == pre_slot, wk_c.life + dt,
                                     0.0))
        rem_pre = jnp.sum(jnp.where(iota_s == pre_slot, rem_tot, 0.0))
        life_srv = jnp.sum(jnp.where(iota_s == serve_slot, wk_c.life + dt,
                                     0.0))
        od = wk["od_time"]
        # finish/miss accounting exactly as in _market_event (live
        # migration: a preempted defector's od remainder is its
        # PRE-rollback remaining total)
        miss = ((od_now & (wk["total_work"] * od > wk["deadline"]))
                | (defected & (life_def + rem_def * od > wk["deadline"]))
                | (defect_pre & (life_pre + rem_pre * od > wk["deadline"]))
                | (complete_serve & (life_srv > wk["deadline"])))
        panic = (defected & jnp.any((iota_s == defect_slot) & panic_armed)
                 if panic_armed is not None else jnp.zeros((), jnp.bool_))
        wstats = survival_update(
            wstats, admitted=is_job,
            finished=od_now | complete_serve | defected | defect_pre,
            missed=miss, checkpoint=ckpt_taken, panic=panic,
            work_done=done_inc, work_lost=lost,
            work_recomputed=lost + oh_inc, overhead_paid=oh_inc)
        return (out_carry, WorkState(prog=prog_new, oh=oh_new,
                                     ckpt=ckpt_new, life=life_new)), \
            (out_stats, wstats)
    return out_carry, out_stats


def _region_layout(topo: RegionTopology, kernel,
                   preempt_on: bool) -> SlabLayout:
    """Slab column map for the region loop: job/spot spans are the max
    ``u_dim`` across regions (shared uniforms, see
    :func:`_slab_spot_clocks`)."""
    return build_slab_layout(
        kernel, job_udim=max(process_udim(r.job) for r in topo.regions),
        spot_udim=max(process_udim(r.spot) for r in topo.regions),
        n=topo.n_regions, preempt_on=preempt_on,
        has_route=hasattr(kernel, "route"), market=True)


def run_region_window(topo: RegionTopology, kernel, preempt_on: bool,
                      state: RegionState, params, rp: dict,
                      k_cost: jax.Array, n_events: int,
                      layout: SlabLayout | None = None,
                      tel: Telemetry | None = None, ep: dict | None = None,
                      work: WorkModel | None = None, wk: dict | None = None
                      ) -> tuple[RegionState, RegionWindowStats]:
    """Run ``n_events`` merged region events; one window of float32 sums."""
    step = functools.partial(_region_event, topo, kernel, preempt_on, layout,
                             params=params, rp=rp, k_cost=k_cost, tel=tel,
                             ep=ep, work=work, wk=wk)
    zeros = _with_zeros(RegionWindowStats.zeros(topo.n_regions), tel,
                        topo.n_regions, env=ep is not None,
                        work=work is not None)
    if layout is None:
        return _scan_window(step, zeros, state, n_events)
    return _scan_window_slab(lambda c, s, x: step(c, s, x=x), zeros, state,
                             n_events, layout.n_cols,
                             paired=(ep is not None) or (work is not None))


def run_region_chunked(topo: RegionTopology, kernel, preempt_on: bool,
                       state: RegionState, params, rp: dict,
                       k_cost: jax.Array, n_events: int, chunk_events: int,
                       layout: SlabLayout | None = None,
                       tel: Telemetry | None = None, ep: dict | None = None,
                       work: WorkModel | None = None, wk: dict | None = None
                       ) -> tuple[RegionState, RegionWindowStats]:
    step = functools.partial(_region_event, topo, kernel, preempt_on, layout,
                             params=params, rp=rp, k_cost=k_cost, tel=tel,
                             ep=ep, work=work, wk=wk)
    zeros = _with_zeros(RegionWindowStats.zeros(topo.n_regions), tel,
                        topo.n_regions, env=ep is not None,
                        work=work is not None)
    rebase = _rebase_for(ep, work)
    if layout is None:
        return _scan_chunked(step, zeros, state, n_events, chunk_events,
                             rebase=rebase)
    return _scan_chunked_slab(lambda c, s, x: step(c, s, x=x), zeros, state,
                              n_events, chunk_events, layout.n_cols,
                              paired=(ep is not None) or (work is not None),
                              rebase=rebase)


@functools.partial(
    jax.jit,
    static_argnames=("topo", "kernel", "preempt_on", "n_events",
                     "chunk_events", "burn_in", "rng", "tel", "work"),
)
def _run_region_sim_jit(topo, kernel, preempt_on, n_events, chunk_events,
                        burn_in, rng, params, rp, k_cost, key, tel=None,
                        ep=None, work=None, wk=None):
    layout = (_region_layout(topo, kernel, preempt_on)
              if rng == "slab" else None)
    state = init_region_state(key, topo, rp, preempt_on,
                              scalar_preempt=layout is not None, ep=ep)
    if ep is not None:
        state = (state, init_env_state(ep))
    if work is not None:
        state = (state, init_work_state(topo.total_slots))
    if burn_in:
        state, _ = run_region_window(topo, kernel, preempt_on, state, params,
                                     rp, k_cost, burn_in, layout=layout,
                                     tel=tel, ep=ep, work=work, wk=wk)
        state = _rebase_for(ep, work)(state)
    return run_region_chunked(topo, kernel, preempt_on, state, params, rp,
                              k_cost, n_events, chunk_events, layout=layout,
                              tel=tel, ep=ep, work=work, wk=wk)


@functools.partial(
    jax.jit,
    static_argnames=("topo", "kernel", "preempt_on", "n_events",
                     "chunk_events", "burn_in", "rng", "tel", "work"),
)
def _run_region_sweep_jit(topo, kernel, preempt_on, n_events, chunk_events,
                          burn_in, rng, params, rp, k_cost, keys, tel=None,
                          ep=None, work=None, wk=None):
    """(grid × regions-config × seeds) fleet as one nested-vmap XLA program
    (broadcast ``in_axes``; see :func:`_flat_lane_args`)."""
    layout = (_region_layout(topo, kernel, preempt_on)
              if rng == "slab" else None)

    def one(p, r, kc, key):
        state = init_region_state(key, topo, r, preempt_on,
                                  scalar_preempt=layout is not None, ep=ep)
        if ep is not None:
            state = (state, init_env_state(ep))
        if work is not None:
            state = (state, init_work_state(topo.total_slots))
        if burn_in:
            state, _ = run_region_window(topo, kernel, preempt_on, state, p,
                                         r, kc, burn_in, layout=layout,
                                         tel=tel, ep=ep, work=work, wk=wk)
            state = _rebase_for(ep, work)(state)
        _, stats = run_region_chunked(topo, kernel, preempt_on, state, p, r,
                                      kc, n_events, chunk_events,
                                      layout=layout, tel=tel, ep=ep,
                                      work=work, wk=wk)
        return stats

    per_seeds = jax.vmap(one, in_axes=(None, None, None, 0))
    return jax.vmap(per_seeds, in_axes=(0, 0, 0, None))(params, rp, k_cost,
                                                        keys)


@functools.partial(
    jax.jit,
    static_argnames=("topo", "kernel", "preempt_on", "n_events",
                     "chunk_events", "burn_in", "tile", "interpret",
                     "executor", "rng", "tel", "work"),
)
def _run_region_sweep_pallas_jit(topo, kernel, preempt_on, n_events,
                                 chunk_events, burn_in, tile, interpret,
                                 params, rp, k_cost, keys,
                                 executor="pallas", rng="split", tel=None,
                                 ep=None, work=None, wk=None):
    """The region fleet through the same batched-event kernel family: the
    engine-state blocks grow a region axis — (tile, R) clock vectors,
    (tile, sum rmax_r) packed slot arrays — and :func:`_region_event` is
    the vmap-ed kernel body.  Bit-for-bit the ``executor="ref"`` scan
    oracle; integer stats bitwise / float sums to ~ulp vs
    :func:`_run_region_sweep_jit` (see the module docstring).  Under
    ``rng="slab"`` the slab is a per-window input block and the kernel
    performs no RNG at all."""
    g, s = k_cost.shape[0], keys.shape[0]
    (params_f, rp_f), k_f, keys_f = _flat_lane_args((params, rp), k_cost,
                                                    keys)
    params_b = {"params": params_f, "rp": rp_f, "k": k_f}
    layout = (_region_layout(topo, kernel, preempt_on)
              if rng == "slab" else None)
    state0 = jax.vmap(
        lambda key, r: init_region_state(
            key, topo, r, preempt_on,
            scalar_preempt=layout is not None, ep=ep))(keys_f, rp_f)
    plan = _window_plan(n_events, chunk_events, burn_in)

    if layout is not None:
        xs = _lane_slabs(state0, plan, layout)
    else:
        xs = None
    if ep is not None:
        params_b["ep"], es0 = _env_lane_blocks(ep, keys_f.shape[0])
        state0 = (state0, es0)
    if work is not None:
        params_b["wk"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (keys_f.shape[0],)), wk)
        state0 = (state0, init_work_state(topo.total_slots,
                                          keys_f.shape[0]))

    if layout is not None:
        def step(carry, stats, p, x):
            return _region_event(topo, kernel, preempt_on, layout, carry,
                                 stats, p["params"], p["rp"], p["k"], x=x,
                                 tel=tel, ep=p.get("ep"), work=work,
                                 wk=p.get("wk"))
    else:
        def step(carry, stats, p):
            return _region_event(topo, kernel, preempt_on, None, carry,
                                 stats, p["params"], p["rp"], p["k"],
                                 tel=tel, ep=p.get("ep"), work=work,
                                 wk=p.get("wk"))

    zeros = _with_zeros(RegionWindowStats.zeros(topo.n_regions), tel,
                        topo.n_regions, env=ep is not None,
                        work=work is not None)
    epilogue = _rebase_for(ep, work)
    if executor == "ref":
        _, stats = batched_event_windows_ref(
            step, state0, params_b, zeros, plan, xs=xs,
            epilogue=epilogue)
    else:
        _, stats = batched_events(
            step, state0, params_b, zeros, plan, xs=xs, tile=tile,
            interpret=interpret, epilogue=epilogue)
    if burn_in:
        stats = jax.tree.map(lambda x: x[:, 1:], stats)
    return _unflatten_lanes(stats, g, s)


def _region_sweep_lanes(topo, kernel, preempt_on, n_events, chunk_events,
                        burn_in, tile, interpret, params_f, rp_f, k_f,
                        keys_f, *, executor, rng, tel=None, ep=None,
                        work=None, wk=None):
    """One shard of flat region lanes through any executor (cf.
    :func:`_sweep_lanes`; the regions-config tree ``rp_f`` is a per-lane
    grid axis exactly as in :func:`_run_region_sweep_pallas_jit`)."""
    layout = (_region_layout(topo, kernel, preempt_on)
              if rng == "slab" else None)
    if executor == "xla":
        def one(p, r, kc, key):
            state = init_region_state(key, topo, r, preempt_on,
                                      scalar_preempt=layout is not None,
                                      ep=ep)
            if ep is not None:
                state = (state, init_env_state(ep))
            if work is not None:
                state = (state, init_work_state(topo.total_slots))
            if burn_in:
                state, _ = run_region_window(topo, kernel, preempt_on, state,
                                             p, r, kc, burn_in, layout=layout,
                                             tel=tel, ep=ep, work=work,
                                             wk=wk)
                state = _rebase_for(ep, work)(state)
            _, stats = run_region_chunked(topo, kernel, preempt_on, state, p,
                                          r, kc, n_events, chunk_events,
                                          layout=layout, tel=tel, ep=ep,
                                          work=work, wk=wk)
            return stats

        return jax.vmap(one)(params_f, rp_f, k_f, keys_f)

    params_b = {"params": params_f, "rp": rp_f, "k": k_f}
    state0 = jax.vmap(
        lambda key, r: init_region_state(
            key, topo, r, preempt_on,
            scalar_preempt=layout is not None, ep=ep))(keys_f, rp_f)
    plan = _window_plan(n_events, chunk_events, burn_in)
    xs = _lane_slabs(state0, plan, layout) if layout is not None else None
    if ep is not None:
        params_b["ep"], es0 = _env_lane_blocks(ep, keys_f.shape[0])
        state0 = (state0, es0)
    if work is not None:
        params_b["wk"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (keys_f.shape[0],)), wk)
        state0 = (state0, init_work_state(topo.total_slots,
                                          keys_f.shape[0]))

    if layout is not None:
        def step(carry, stats, p, x):
            return _region_event(topo, kernel, preempt_on, layout, carry,
                                 stats, p["params"], p["rp"], p["k"], x=x,
                                 tel=tel, ep=p.get("ep"), work=work,
                                 wk=p.get("wk"))
    else:
        def step(carry, stats, p):
            return _region_event(topo, kernel, preempt_on, None, carry,
                                 stats, p["params"], p["rp"], p["k"],
                                 tel=tel, ep=p.get("ep"), work=work,
                                 wk=p.get("wk"))

    zeros = _with_zeros(RegionWindowStats.zeros(topo.n_regions), tel,
                        topo.n_regions, env=ep is not None,
                        work=work is not None)
    epilogue = _rebase_for(ep, work)
    if executor == "ref":
        _, stats = batched_event_windows_ref(
            step, state0, params_b, zeros, plan, xs=xs, epilogue=epilogue)
    else:
        _, stats = batched_events(
            step, state0, params_b, zeros, plan, xs=xs, tile=tile,
            interpret=interpret, epilogue=epilogue)
    if burn_in:
        stats = jax.tree.map(lambda x: x[:, 1:], stats)
    return stats


@functools.partial(
    jax.jit,
    static_argnames=("topo", "kernel", "preempt_on", "n_events",
                     "chunk_events", "burn_in", "tile", "interpret", "mesh",
                     "executor", "rng", "tel", "work"),
)
def _run_region_sweep_sharded_jit(topo, kernel, preempt_on, n_events,
                                  chunk_events, burn_in, tile, interpret,
                                  mesh, params, rp, k_cost, keys,
                                  executor="xla", rng="split", tel=None,
                                  ep=None, work=None, wk=None):
    """The region fleet lane-partitioned across a 1-D device mesh (cf.
    :func:`_run_sweep_sharded_jit`)."""
    g, s = k_cost.shape[0], keys.shape[0]
    (params_f, rp_f), k_f, keys_f = _flat_lane_args((params, rp), k_cost,
                                                    keys)
    lanes = g * s
    params_f, rp_f, k_f, keys_f = pad_lanes((params_f, rp_f, k_f, keys_f),
                                            _pad_count(lanes, mesh))
    spec, rspec = lane_spec(mesh), jax.sharding.PartitionSpec()

    def local(pf, rf, kf, keysf, ep_, wk_):
        return _region_sweep_lanes(topo, kernel, preempt_on, n_events,
                                   chunk_events, burn_in, tile, interpret,
                                   pf, rf, kf, keysf, executor=executor,
                                   rng=rng, tel=tel, ep=ep_, work=work,
                                   wk=wk_)

    stats = shard_map_1d(local, mesh=mesh,
                         in_specs=(spec, spec, spec, spec, rspec, rspec),
                         out_specs=spec)(params_f, rp_f, k_f, keys_f, ep, wk)
    if lanes != keys_f.shape[0]:
        stats = jax.tree.map(lambda x: x[:lanes], stats)
    return _unflatten_lanes(stats, g, s)


def summarize_region(stats: RegionWindowStats,
                     telemetry: Telemetry | None = None,
                     env: EnvTimeline | None = None,
                     work: WorkModel | None = None) -> dict:
    """Float64 chunk reduction + region-specific derived statistics.

    Extends :func:`summarize`'s dict with preemption counters, spot spend,
    per-job statistics (leg vs job accounting as in
    :func:`summarize_market`), per-region served/arrival/utilization
    arrays (trailing region axis), and the routing flow:
    ``region_jobs`` (arrivals by home region), ``region_routed``
    (admissions by target region), and ``cross_region_frac`` (the fraction
    of admitted jobs the routing hook sent away from home).  With
    ``telemetry``, ``stats`` is the ``(base, telemetry)`` pair and the
    telemetry fields are appended (base keys unchanged; :func:`summarize`).
    With ``env``, the env block rides outermost and the shock counters are
    appended.  With ``work``, the survival ledger rides outermost of all
    and its job-level counters are appended (:func:`summarize_survival`).
    """
    wstats = None
    if work is not None:
        stats, wstats = stats
    estats = None
    if env is not None:
        stats, estats = stats
    tstats = None
    if telemetry is not None:
        stats, tstats = stats
    n_common = len(WindowStats._fields)
    out = summarize(WindowStats(*stats[:n_common]))

    def _red(name):
        x = getattr(stats, name)
        axis = -2 if name in _REGION_FIELDS else -1
        return np.asarray(x, np.float64).sum(axis=axis)

    resumed = _red("resumed")
    spot_cost = _red("spot_cost")
    routed_home = _red("routed_home")
    region_served = _red("region_served")
    region_arrivals = _red("region_spot_arrivals")
    region_preempted = _red("region_preempted")
    region_jobs = _red("region_jobs")
    region_routed = _red("region_routed")
    cost_sum = _red("cost_sum")
    delay_sum = _red("delay_sum")
    final = np.maximum(_red("spot_served") + _red("ondemand"), 1.0)
    admitted = region_routed.sum(axis=-1)
    cross = np.where(admitted > 0,
                     1.0 - routed_home / np.maximum(admitted, 1.0), 0.0)
    out.update({
        "preemptions": region_preempted.sum(axis=-1),
        "resumed": resumed,
        "spot_cost": spot_cost,
        "avg_cost_job": cost_sum / final,
        "avg_delay_job": delay_sum / final,
        "routed_home": routed_home,
        "cross_region_frac": cross,
        "region_served": region_served,
        "region_spot_arrivals": region_arrivals,
        "region_preempted": region_preempted,
        "region_jobs": region_jobs,
        "region_routed": region_routed,
        "region_utilization": region_served / np.maximum(region_arrivals,
                                                         1.0),
    })
    if telemetry is not None:
        out = _merge_telemetry(out, telemetry, tstats, stats.time_elapsed)
    if estats is not None:
        out.update(summarize_env(estats))
    if wstats is not None:
        out.update(summarize_survival(wstats))
    return out


def run_region_sim(
    topology: RegionTopology,
    kernel,
    params=None,
    *,
    k: float = 10.0,
    n_events: int,
    key: jax.Array,
    burn_in: int = 0,
    chunk_events: int | None = DEFAULT_CHUNK_EVENTS,
    impl: str = "xla",
    rng: str = "split",
    tile: int = 256,
    interpret: bool | None = None,
    telemetry: Telemetry | None = None,
    env: EnvTimeline | None = None,
    work: WorkModel | None = None,
) -> dict:
    """Run one routing policy on one topology point; scalar long-run stats.

    A degenerate topology (:attr:`RegionTopology.is_degenerate`) with a
    non-routing kernel reproduces :func:`run_sim` (and the 1-pool
    :func:`run_market_sim`) bit-for-bit per seed.  ``chunk_events`` /
    ``impl`` / ``rng`` behave exactly as in :func:`run_sim`; ``env``
    attaches an :class:`~repro.core.env.EnvTimeline` (per-region price /
    hazard / availability segments) exactly as in :func:`run_sim`;
    ``work`` attaches the work structure and survival ledger exactly as
    in :func:`run_market_sim`.
    """
    topology = as_topology(topology)
    params = {} if params is None else params
    _check_rng(rng)
    _check_telemetry(telemetry)
    _check_env(env)
    _check_work(work, kernel)
    _check_run_shape("run_region_sim", n_events, burn_in)
    rp = topology.params()
    ep = _env_params(env, topology.n_regions)
    wk = None if work is None else work.params()
    chunk = n_events if chunk_events is None else min(chunk_events, n_events)
    with annotate(f"repro.run_region_sim[{impl}]"):
        if impl in ("pallas", "ref"):
            stats = _run_region_sweep_pallas_jit(
                topology, kernel, topology.preemptible, n_events, chunk,
                burn_in, tile,
                default_interpret() if interpret is None else interpret,
                jax.tree.map(lambda x: jnp.asarray(x)[None], params),
                jax.tree.map(lambda x: jnp.asarray(x)[None], rp),
                jnp.float32(k)[None], _raw_keys(key)[None], executor=impl,
                rng=rng, tel=telemetry, ep=ep, work=work, wk=wk)
            stats = jax.tree.map(lambda x: x[0, 0], stats)
        elif impl == "xla":
            _, stats = _run_region_sim_jit(topology, kernel,
                                           topology.preemptible, n_events,
                                           chunk, burn_in, rng, params, rp,
                                           jnp.float32(k), key,
                                           tel=telemetry, ep=ep, work=work,
                                           wk=wk)
        else:
            raise ValueError(
                f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
    return {name: _scalar_or_array(v)
            for name, v in summarize_region(stats, telemetry, env=env,
                                            work=work).items()}


def run_region_sweep(
    topology: RegionTopology,
    kernel,
    params=None,
    *,
    k: float | np.ndarray | jax.Array = 10.0,
    vector_params=None,
    prices=None,
    hazards=None,
    notices=None,
    spot_scales=None,
    job_scales=None,
    n_events: int,
    key: jax.Array,
    n_seeds: int = 1,
    burn_in: int = 0,
    chunk_events: int | None = DEFAULT_CHUNK_EVENTS,
    impl: str = "xla",
    rng: str = "split",
    tile: int = 256,
    interpret: bool | None = None,
    telemetry: Telemetry | None = None,
    env: EnvTimeline | None = None,
    work: WorkModel | None = None,
    shard: str = "none",
    mesh=None,
) -> dict:
    """Run a (params × k × regions-config × seeds) grid as ONE jitted call.

    ``params`` leaves and ``k`` broadcast to a common grid shape exactly as
    in :func:`run_sweep`.  ``vector_params`` is a dict of *vector-valued*
    kernel parameters whose LAST axis is carried into every grid point
    instead of being swept: an ``(m,)`` leaf fixes one vector for the whole
    grid, a ``grid_shape + (m,)`` leaf sweeps the vector itself (e.g.
    ``{"region_logits": logits}`` for ``choice="weighted"`` routing — the
    logits stay ``(R,)`` per point while ``r`` sweeps).  ``prices``/
    ``hazards``/``notices``/
    ``spot_scales``/``job_scales`` optionally override the topology's
    static region configuration per grid point: a scalar applies to every
    region, an ``(R,)`` vector fixes one config, and a ``grid_shape + (R,)``
    array sweeps the region configuration inside the same compiled program
    (the regions-config axis of the grid — ``job_scales`` sweeps *demand*
    per region, the axis the market engine does not have).

    ``impl``/``tile``/``interpret`` select the executor exactly as in
    :func:`run_sweep`; the Pallas path widens the VMEM-resident state tile
    with the (tile, R) clock vectors and the (tile, sum rmax_r) packed slot
    partition — bit-for-bit the ``"ref"`` oracle, integer stats bitwise /
    float sums to ~ulp vs ``"xla"`` (the module docstring's executor
    contract).  ``shard="lanes"`` partitions the flattened lane axis
    across a 1-D device mesh exactly as in :func:`run_sweep`
    (regions-config and vector-param lanes ride along).

    Returns :func:`summarize_region`'s dict; scalar statistics are shaped
    ``grid_shape + (n_seeds,)`` and per-region statistics
    ``grid_shape + (n_seeds, R)``.
    """
    topology = as_topology(topology)
    n = topology.n_regions
    params = {} if params is None else params
    _check_rng(rng)
    _check_telemetry(telemetry)
    _check_env(env)
    _check_work(work, kernel)
    _check_shard("run_region_sweep", shard, mesh)
    _check_run_shape("run_region_sweep", n_events, burn_in)
    _check_loc_overrides("run_region_sweep", n, "region", prices=prices,
                         hazards=hazards, notices=notices,
                         spot_scales=spot_scales, job_scales=job_scales)
    ep = _env_params(env, n)
    wk = None if work is None else work.params()
    params = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), params)
    vparams = {} if vector_params is None else jax.tree.map(
        lambda x: jnp.asarray(x, jnp.float32), dict(vector_params))
    if vparams and not isinstance(params, dict):
        raise TypeError("vector_params requires params to be a dict")
    k = jnp.asarray(k, jnp.float32)
    overrides = {"price": prices, "hazard": hazards, "notice": notices,
                 "spot_scale": spot_scales, "job_scale": job_scales}
    override_shapes = [jnp.asarray(v).shape[:-1]
                       for v in overrides.values()
                       if v is not None and jnp.asarray(v).ndim > 1]
    grid_shape = jnp.broadcast_shapes(
        k.shape, *(x.shape for x in jax.tree.leaves(params)),
        *(x.shape[:-1] for x in jax.tree.leaves(vparams)),
        *override_shapes,
    )
    flat = lambda x: jnp.broadcast_to(x, grid_shape).reshape(-1)
    vflat = lambda x: jnp.broadcast_to(
        x, grid_shape + x.shape[-1:]).reshape((-1,) + x.shape[-1:])
    params_flat = {**jax.tree.map(flat, params),
                   **jax.tree.map(vflat, vparams)} if vparams \
        else jax.tree.map(flat, params)
    k_flat = flat(k)
    rp_flat = _broadcast_config_params(n, topology.params(), overrides,
                                       grid_shape)
    preempt_on = topology.preemptible or hazards is not None
    keys = jax.random.split(key, n_seeds)
    chunk = n_events if chunk_events is None else min(chunk_events, n_events)
    with annotate(f"repro.run_region_sweep[{impl}]"):
        if shard == "lanes":
            if impl not in ("xla", "pallas", "ref"):
                raise ValueError(
                    f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
            stats = _run_region_sweep_sharded_jit(
                topology, kernel, preempt_on, n_events, chunk, burn_in, tile,
                default_interpret() if interpret is None else interpret,
                lane_mesh() if mesh is None else mesh, params_flat, rp_flat,
                k_flat, _raw_keys(keys), executor=impl, rng=rng,
                tel=telemetry, ep=ep, work=work, wk=wk)
        elif impl in ("pallas", "ref"):
            stats = _run_region_sweep_pallas_jit(
                topology, kernel, preempt_on, n_events, chunk, burn_in, tile,
                default_interpret() if interpret is None else interpret,
                params_flat, rp_flat, k_flat, _raw_keys(keys), executor=impl,
                rng=rng, tel=telemetry, ep=ep, work=work, wk=wk)
        elif impl == "xla":
            stats = _run_region_sweep_jit(topology, kernel, preempt_on,
                                          n_events, chunk, burn_in, rng,
                                          params_flat, rp_flat, k_flat, keys,
                                          tel=telemetry, ep=ep, work=work,
                                          wk=wk)
        else:
            raise ValueError(
                f"unknown impl {impl!r} (expected 'xla'|'pallas'|'ref')")
    out = summarize_region(stats, telemetry, env=env, work=work)
    return _reshape_sweep(out, grid_shape, n_seeds)
