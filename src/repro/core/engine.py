"""Policy-generic, vmap-batched G/G/1+spot sweep engine.

One merged-renewal event loop replaces the two near-duplicate simulators the
seed carried (``run_queue_sim`` / ``run_single_slot_sim``): the loop is
parameterized by a traced **policy kernel** and the two paper policies become
small kernel implementations (:class:`repro.core.policies.ThreePhaseKernel`,
:class:`repro.core.policies.SingleSlotKernel`).

Policy-kernel protocol
----------------------
A kernel is a hashable (frozen-dataclass) static object with one traced hook::

    admit(params, qlen, key) -> (admit: bool[], budget: f32[])

called once per merged event with the *pre-event* queue length and a fresh
PRNG subkey.  On a job-arrival event the engine admits the job iff
``admit & (qlen < rmax)`` and stamps it with the returned *wait budget*
(``on_join``): the maximal time the job will wait for a spot slot.  A budget
of :data:`INF` means "wait indefinitely" (Theorem 4); a finite budget arms a
**defect-on-deadline** event — when it expires the job leaves the queue for
an on-demand instance (cost ``k``, delay = its age), exactly the Theorems-2/3
maximal-wait semantics.  ``params`` is an arbitrary traced pytree (the
admission knob ``r``, wait-time parameters, …) so a whole parameter grid can
be ``vmap``-ed without retracing.

Queue representation
--------------------
A slot-mask ring: ``ages``/``budgets``/``order`` arrays of static size
``rmax`` plus an occupancy mask.  Spot slots serve the FIFO-oldest occupied
slot (min join ``order``); deadlines fire on the slot with the smallest
remaining budget.  This is O(rmax) per event — the same as the seed's ring
buffer — but supports out-of-order departures, which a head/tail ring cannot.
``order`` is int32: the engine supports ~2×10⁹ admissions per run.

Event-time ties (measure-zero for continuous samplers) resolve
spot > deadline > job, matching the seed's single-slot simulator.

Numerics
--------
Ages are relative (incremented by the inter-event gap ``dt``), never absolute
event times, so float32 precision does not degrade over long horizons.  Sums
are accumulated in float32 **per chunk** (:func:`run_chunked` re-zeros the
accumulator every ``chunk_events`` events) and assembled in float64 on the
host by :func:`summarize` — a multi-billion-event horizon loses no more
precision than its last chunk.  With a single chunk the engine reproduces the
seed simulators bit-for-bit per seed (verified in tests/test_core_engine.py
against frozen reference copies of the seed event bodies).

Batched sweeps
--------------
:func:`run_sweep` broadcasts a params pytree + cost ratio ``k`` to a common
grid shape, pairs it with ``n_seeds`` common-random-number seeds, and runs
the whole (grid × seeds) fleet as ONE jitted nested-``vmap`` program — no
per-point Python dispatch, no retracing.  Cost accounting (paper §II): spot
service costs 1, an on-demand dispatch costs ``k``; π₀ is tracked both
time-averaged and as the fraction of spot arrivals finding the queue empty
(the quantity Theorem 1's proof uses).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess

INF = jnp.float32(3e38)
_ORDER_MAX = jnp.int32(2**31 - 1)


@runtime_checkable
class PolicyKernel(Protocol):
    """Static, hashable policy plugged into the engine's event loop."""

    def admit(self, params, qlen: jax.Array, key: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
        """Return (admit?, wait budget) for a job arriving at ``qlen``."""
        ...


class WindowStats(NamedTuple):
    """Per-window accumulators (float32 sums / int32 counts)."""

    jobs_arrived: jax.Array
    jobs_completed: jax.Array
    spot_served: jax.Array
    ondemand: jax.Array
    cost_sum: jax.Array
    delay_sum: jax.Array
    time_elapsed: jax.Array
    empty_time: jax.Array
    spot_arrivals: jax.Array
    spot_found_empty: jax.Array

    @staticmethod
    def zeros() -> "WindowStats":
        z = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        return WindowStats(zi, zi, zi, zi, z, z, z, z, zi, zi)


class EngineState(NamedTuple):
    key: jax.Array
    next_job: jax.Array  # time until next job arrival
    next_spot: jax.Array  # time until next spot-slot arrival
    ages: jax.Array  # (rmax,) time each queued job has waited
    budgets: jax.Array  # (rmax,) remaining wait budget (INF = wait forever)
    occ: jax.Array  # (rmax,) bool occupancy mask
    order: jax.Array  # (rmax,) int32 join sequence number
    next_seq: jax.Array  # int32 next join sequence number
    qlen: jax.Array  # int32 number of queued jobs


def init_engine_state(key: jax.Array, job: ArrivalProcess,
                      spot: ArrivalProcess, rmax: int) -> EngineState:
    kj, ks, kc = jax.random.split(key, 3)
    return EngineState(
        key=kc,
        next_job=job.sample(kj),
        next_spot=spot.sample(ks),
        ages=jnp.zeros((rmax,), jnp.float32),
        budgets=jnp.full((rmax,), INF, jnp.float32),
        occ=jnp.zeros((rmax,), jnp.bool_),
        order=jnp.zeros((rmax,), jnp.int32),
        next_seq=jnp.zeros((), jnp.int32),
        qlen=jnp.zeros((), jnp.int32),
    )


def _engine_event(job: ArrivalProcess, spot: ArrivalProcess,
                  kernel: PolicyKernel, rmax: int, carry: EngineState,
                  stats: WindowStats, params,
                  k_cost: jax.Array) -> tuple[EngineState, WindowStats]:
    """Process one merged event (job arrival / spot slot / wait deadline).

    Per-slot updates are dense one-hot selects rather than scatter/gather:
    under ``vmap`` a traced-index ``.at[i].set`` lowers to a scatter, which
    is far slower on CPU/TPU than the width-``rmax`` selects used here (and
    the selects are numerically identical).
    """
    key, k_job, k_spot, k_pol = jax.random.split(carry.key, 4)
    iota = jax.lax.iota(jnp.int32, rmax)

    budgets_masked = jnp.where(carry.occ, carry.budgets, INF)
    deadline = jnp.min(budgets_masked)
    defect_slot = jnp.argmin(budgets_masked)

    dt = jnp.minimum(jnp.minimum(carry.next_job, carry.next_spot), deadline)
    is_spot = carry.next_spot <= jnp.minimum(carry.next_job, deadline)
    is_deadline = (~is_spot) & (deadline <= carry.next_job)
    is_job = (~is_spot) & (~is_deadline)

    ages = carry.ages + dt
    budgets = jnp.where(carry.occ, carry.budgets - dt, INF)

    # ---- job arrival: ask the policy kernel ----
    admit_raw, budget = kernel.admit(params, carry.qlen, k_pol)
    admit = is_job & admit_raw & (carry.qlen < rmax)
    od_now = is_job & (~admit)  # rejected -> immediate on-demand, delay 0
    join_slot = jnp.argmin(carry.occ.astype(jnp.int32))  # first free slot

    # ---- spot slot: serve the FIFO-oldest job ----
    serve_slot = jnp.argmin(jnp.where(carry.occ, carry.order, _ORDER_MAX))
    has_job = carry.qlen > 0
    served = is_spot & has_job
    wait_served = jnp.sum(jnp.where(iota == serve_slot, ages, 0.0))

    # ---- deadline: the minimal-budget job defects to on-demand ----
    defected = is_deadline  # deadline < INF implies an occupied slot
    age_defect = jnp.sum(jnp.where(iota == defect_slot, ages, 0.0))

    leave = served | defected
    leave_slot = jnp.where(served, serve_slot, defect_slot)

    join_mask = admit & (iota == join_slot)
    leave_mask = leave & (iota == leave_slot)
    ages = jnp.where(join_mask, 0.0, ages)
    budgets = jnp.where(join_mask, budget, budgets)
    occ = (carry.occ | join_mask) & (~leave_mask)
    order = jnp.where(join_mask, carry.next_seq, carry.order)

    new_carry = EngineState(
        key=key,
        next_job=jnp.where(is_job, job.sample(k_job), carry.next_job - dt),
        next_spot=jnp.where(is_spot, spot.sample(k_spot),
                            carry.next_spot - dt),
        ages=ages,
        budgets=budgets,
        occ=occ,
        order=order,
        next_seq=carry.next_seq + jnp.where(admit, 1, 0),
        qlen=carry.qlen + jnp.where(admit, 1, 0) - jnp.where(leave, 1, 0),
    )
    new_stats = WindowStats(
        jobs_arrived=stats.jobs_arrived + is_job.astype(jnp.int32),
        jobs_completed=stats.jobs_completed
        + (od_now | served | defected).astype(jnp.int32),
        spot_served=stats.spot_served + served.astype(jnp.int32),
        ondemand=stats.ondemand + (od_now | defected).astype(jnp.int32),
        cost_sum=stats.cost_sum
        + jnp.where(served, 1.0, 0.0)
        + jnp.where(od_now | defected, k_cost, 0.0),
        delay_sum=stats.delay_sum
        + jnp.where(served, wait_served, 0.0)
        + jnp.where(defected, age_defect, 0.0),
        time_elapsed=stats.time_elapsed + dt,
        empty_time=stats.empty_time + jnp.where(carry.qlen == 0, dt, 0.0),
        spot_arrivals=stats.spot_arrivals + is_spot.astype(jnp.int32),
        spot_found_empty=stats.spot_found_empty
        + (is_spot & (~has_job)).astype(jnp.int32),
    )
    return new_carry, new_stats


def run_window(job: ArrivalProcess, spot: ArrivalProcess,
               kernel: PolicyKernel, rmax: int, state: EngineState, params,
               k_cost: jax.Array,
               n_events: int) -> tuple[EngineState, WindowStats]:
    """Run ``n_events`` merged events; return state + one window of sums."""

    def body(sc, _):
        c, s = sc
        c, s = _engine_event(job, spot, kernel, rmax, c, s, params, k_cost)
        return (c, s), None

    (state, stats), _ = jax.lax.scan(
        body, (state, WindowStats.zeros()), None, length=n_events
    )
    return state, stats


def run_chunked(job: ArrivalProcess, spot: ArrivalProcess,
                kernel: PolicyKernel, rmax: int, state: EngineState, params,
                k_cost: jax.Array, n_events: int,
                chunk_events: int) -> tuple[EngineState, WindowStats]:
    """Run exactly ``n_events`` events as stacked float32 chunk windows.

    Returns stats with a leading chunk axis; :func:`summarize` reduces it in
    float64 so long horizons do not hit float32 sum saturation.
    """
    n_chunks, rem = divmod(n_events, chunk_events)

    def chunk(c, _):
        c, s = run_window(job, spot, kernel, rmax, c, params, k_cost,
                          chunk_events)
        return c, s

    state, stats = jax.lax.scan(chunk, state, None, length=n_chunks)
    if rem:
        state, tail = run_window(job, spot, kernel, rmax, state, params,
                                 k_cost, rem)
        stats = jax.tree.map(
            lambda s, t: jnp.concatenate([s, t[None]]), stats,
            jax.tree.map(jnp.asarray, tail),
        )
    return state, stats


@functools.partial(
    jax.jit,
    static_argnames=("job", "spot", "kernel", "rmax", "n_events",
                     "chunk_events", "burn_in"),
)
def _run_sim_jit(job, spot, kernel, rmax, n_events, chunk_events, burn_in,
                 params, k_cost, key):
    """Single-point entry, compiled once per static signature at module scope
    (the seed re-jitted its burn-in path on every call)."""
    state = init_engine_state(key, job, spot, rmax)
    if burn_in:
        state, _ = run_window(job, spot, kernel, rmax, state, params, k_cost,
                              burn_in)
    return run_chunked(job, spot, kernel, rmax, state, params, k_cost,
                       n_events, chunk_events)


@functools.partial(
    jax.jit,
    static_argnames=("job", "spot", "kernel", "rmax", "n_events",
                     "chunk_events", "burn_in"),
)
def _run_sweep_jit(job, spot, kernel, rmax, n_events, chunk_events, burn_in,
                   params, k_cost, keys):
    """(grid × seeds) fleet as one nested-vmap XLA program."""

    def one(p, kc, key):
        state = init_engine_state(key, job, spot, rmax)
        if burn_in:
            state, _ = run_window(job, spot, kernel, rmax, state, p, kc,
                                  burn_in)
        _, stats = run_chunked(job, spot, kernel, rmax, state, p, kc,
                               n_events, chunk_events)
        return stats

    per_seeds = jax.vmap(one, in_axes=(None, None, 0))
    return jax.vmap(per_seeds, in_axes=(0, 0, None))(params, k_cost, keys)


def summarize(stats: WindowStats) -> dict:
    """Reduce chunked (…, n_chunks) sums in float64; derive long-run stats.

    Leading batch axes (grid, seeds) pass through: every value in the
    returned dict is a numpy array of the batch shape (0-d for a single run).
    """
    s = jax.tree.map(lambda x: np.asarray(x, np.float64).sum(axis=-1), stats)
    completed = np.maximum(s.jobs_completed, 1.0)
    arrived = np.maximum(s.jobs_arrived, 1.0)
    time = np.maximum(s.time_elapsed, 1e-12)
    spot_arr = np.maximum(s.spot_arrivals, 1.0)
    return {
        "jobs_arrived": s.jobs_arrived,
        "jobs_completed": s.jobs_completed,
        "spot_served": s.spot_served,
        "ondemand": s.ondemand,
        "avg_cost": s.cost_sum / completed,
        "avg_delay": s.delay_sum / completed,
        "time": s.time_elapsed,
        "pi0_time": s.empty_time / time,
        "pi0_spot": s.spot_found_empty / spot_arr,
        "spot_utilization": (s.spot_arrivals - s.spot_found_empty) / spot_arr,
        "arrival_rate": arrived / time,
    }


def run_sim(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    kernel: PolicyKernel,
    params=None,
    *,
    k: float = 10.0,
    n_events: int,
    key: jax.Array,
    rmax: int = 64,
    burn_in: int = 0,
    chunk_events: int | None = None,
) -> dict:
    """Run one policy at one parameter point; return long-run scalar stats.

    ``chunk_events=None`` accumulates the whole horizon in a single float32
    window (the seed simulators' behaviour, kept as the bit-for-bit default
    for short runs); pass e.g. ``1 << 16`` for multi-million-event horizons.
    """
    params = {} if params is None else params
    chunk = n_events if chunk_events is None else min(chunk_events, n_events)
    _, stats = _run_sim_jit(job, spot, kernel, rmax, n_events, chunk,
                            burn_in, params, jnp.float32(k), key)
    return {name: float(v) for name, v in summarize(stats).items()}


def run_sweep(
    job: ArrivalProcess,
    spot: ArrivalProcess,
    kernel: PolicyKernel,
    params=None,
    *,
    k: float | np.ndarray | jax.Array = 10.0,
    n_events: int,
    key: jax.Array,
    n_seeds: int = 1,
    rmax: int = 64,
    burn_in: int = 0,
    chunk_events: int | None = 1 << 16,
) -> dict:
    """Run a whole policy grid × seed fleet as ONE jitted call.

    ``params`` is a pytree whose leaves, together with ``k``, broadcast to a
    common grid shape (e.g. ``{"r": jnp.linspace(0, 4, 32)}``, or a 2-D
    meshgrid over ``r`` × ``k``).  Seeds use common random numbers across the
    grid (same ``n_seeds`` subkeys at every point), which cancels sampling
    noise out of cross-grid comparisons.

    Returns :func:`summarize`'s dict with every value shaped
    ``grid_shape + (n_seeds,)``.
    """
    params = {} if params is None else params
    params = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), params)
    k = jnp.asarray(k, jnp.float32)
    grid_shape = jnp.broadcast_shapes(
        k.shape, *(x.shape for x in jax.tree.leaves(params))
    )
    flat = lambda x: jnp.broadcast_to(x, grid_shape).reshape(-1)
    params_flat = jax.tree.map(flat, params)
    k_flat = flat(k)
    keys = jax.random.split(key, n_seeds)
    chunk = n_events if chunk_events is None else min(chunk_events, n_events)
    stats = _run_sweep_jit(job, spot, kernel, rmax, n_events, chunk, burn_in,
                           params_flat, k_flat, keys)
    out = summarize(stats)  # values shaped (grid_points, n_seeds)
    return {name: v.reshape(grid_shape + (n_seeds,)) for name, v in
            out.items()}
