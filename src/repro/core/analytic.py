"""Closed-form results: Theorem 2 (strong-delay optimum) and Theorem 5
(M/M/1/N cost & delay), plus the M/M/1/N stationary distribution used to
cross-validate the event simulator.
"""
from __future__ import annotations

import numpy as np

from repro.core.arrivals import ArrivalProcess, prob_A_le_S


def theorem2_cost(k: float, mu: float, delta: float) -> float:
    """Optimal cost in the strong-delay regime: E[C*] = k − (k−1)·μ·δ."""
    return k - (k - 1.0) * mu * delta


def theorem2_delta_max(job: ArrivalProcess, spot: ArrivalProcess) -> float:
    """Upper edge of the strong-delay regime: P(A ≤ S_μ)/λ."""
    return prob_A_le_S(job, spot) / job.rate()


def mm1n_pi(lam: float, mu: float, n_max: int) -> np.ndarray:
    """Stationary distribution of the M/M/1/N spot queue (birth-death).

    Arrivals Poisson(λ) join while queue < N; spot slots Poisson(μ) serve the
    head.  π_n ∝ ρ^n with ρ = λ/μ, truncated at N.
    """
    rho = lam / mu
    pis = np.array([rho**n for n in range(n_max + 1)], np.float64)
    return pis / pis.sum()


def theorem5_cost(k: float, lam: float, mu: float, n_max: int) -> float:
    """E[C_N] = k − (k−1)(μ/λ)(1 − (λ/μ − 1)/((λ/μ)^{N+1} − 1))."""
    rho = lam / mu
    if abs(rho - 1.0) < 1e-12:
        # limit ρ→1: 1−π₀ = N/(N+1)
        util = n_max / (n_max + 1.0)
    else:
        util = 1.0 - (rho - 1.0) / (rho ** (n_max + 1) - 1.0)
    return k - (k - 1.0) * (mu / lam) * util


def theorem5_delta(lam: float, mu: float, n_max: int) -> float:
    """δ_N lower bound: (1/λ)·Σ n·ρⁿ / (1 + Σ ρⁿ) = E[N]/λ (Little)."""
    rho = lam / mu
    num = sum(n * rho**n for n in range(1, n_max + 1))
    den = 1.0 + sum(rho**n for n in range(1, n_max + 1))
    return num / den / lam


def mm1n_expected_queue(lam: float, mu: float, n_max: int) -> float:
    pis = mm1n_pi(lam, mu, n_max)
    return float(np.dot(np.arange(n_max + 1), pis))


def mm1n_cost_from_pi(k: float, lam: float, mu: float, n_max: int) -> float:
    """Theorem 1 applied to the M/M/1/N chain — must equal theorem5_cost."""
    pis = mm1n_pi(lam, mu, n_max)
    return k - (k - 1.0) * (mu / lam) * (1.0 - float(pis[0]))
