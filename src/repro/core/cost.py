"""Cost laws (Theorem 1) and empirical cost accounting.

Theorem 1: for *any* scheduling policy over a G/G/1 spot queue in steady
state,

    E[C] = k − (k−1) · (E[A]/E[S_μ]) · (1 − π₀) = k − (k−1) · (μ/λ) · (1 − π₀)

where π₀ is the steady-state probability that a spot arrival finds the queue
empty.  The whole optimization therefore reduces to maximizing spot-slot
utilization (1 − π₀) subject to the delay constraint.
"""
from __future__ import annotations


def theorem1_cost(k: float, lam: float, mu: float, pi0: float) -> float:
    """E[C] from the empty-queue probability (Theorem 1)."""
    return k - (k - 1.0) * (mu / lam) * (1.0 - pi0)


def pi0_from_cost(k: float, lam: float, mu: float, cost: float) -> float:
    """Invert Theorem 1: recover π₀ implied by an observed average cost."""
    return 1.0 - (k - cost) / ((k - 1.0) * (mu / lam))


def spot_utilization_bound(lam: float, mu: float, delta: float) -> float:
    """Knapsack-LP bound on (1−π₀): min(1, λδ) (Section IV, eqs. 9-11).

    With Little's law E[N] = λ·E[T] ≤ λδ and π_n ≤ coefficients increasing
    in n, the abstract LP's optimum is Σπ_n = min(1, λδ).
    """
    return min(1.0, lam * delta)


def cost_lower_bound(k: float, lam: float, mu: float, delta: float) -> float:
    """Policy-independent lower bound on E[C] from Theorem 1 + the LP bound."""
    return k - (k - 1.0) * (mu / lam) * spot_utilization_bound(lam, mu, delta)


# ---------------------------------------------------------------------------
# Work-structured jobs (see repro.core.work)
# ---------------------------------------------------------------------------


def all_ondemand_cost(k: float, jobs: float, total_work: float = 1.0) -> float:
    """The all-on-demand cost floor for work-structured jobs.

    Sending every one of ``jobs`` jobs straight to on-demand costs
    ``k × total_work`` each — no spot savings, no preemption risk, and (by
    construction, for any feasible deadline ``total_work·od_time ≤ D``)
    zero deadline misses.  This is the safety baseline every
    checkpoint/safety-net kernel must beat on cost while matching on
    misses: the can't-be-late acceptance bar
    (``tests/test_work.py``, EXPERIMENTS.md §Checkpoint-priced recovery).
    """
    return float(k) * float(jobs) * float(total_work)


# ---------------------------------------------------------------------------
# Heterogeneous-pool market generalization (see repro.core.market)
# ---------------------------------------------------------------------------


def theorem1_market_cost(k: float, lam: float, rates, prices, utils) -> float:
    """Market Theorem 1: E[C] from per-pool slot utilizations.

    With pool slot rates μ_p, prices c_p, and utilizations
    u_p = P(a pool-p slot finds an eligible job) — the per-pool 1 − π₀ the
    engine reports as ``pool_utilization`` — the fraction of jobs served by
    pool p is (μ_p/λ)·u_p, so

        E[C] = k − Σ_p (k − c_p) (μ_p/λ) u_p.

    Preemption-free identity: revoked legs pay extra spot cost on top (the
    engine's ``spot_cost`` tracks it), so under preemption this is the cost
    of the *completed-leg* flow only.  One unit-price pool recovers
    :func:`theorem1_cost` exactly.
    """
    import numpy as np

    rates = np.asarray(rates, np.float64)
    prices = np.asarray(prices, np.float64)
    utils = np.asarray(utils, np.float64)
    return float(k - np.sum((k - prices) * rates / lam * utils))


def market_cost_lower_bound(k: float, lam: float, delta: float, market, *,
                            include_preemption: bool = False) -> float:
    """Policy-independent market bound: Theorem 1 + the multi-pool LP
    (:func:`repro.core.lp.market_knapsack_lp`)."""
    from repro.core.lp import market_knapsack_lp

    return market_knapsack_lp(k, lam, delta, market,
                              include_preemption=include_preemption)[
                                  "objective"]


# ---------------------------------------------------------------------------
# Multi-region generalization (see repro.core.regions)
# ---------------------------------------------------------------------------


def theorem1_region_cost(k: float, lam: float, rates, prices, utils) -> float:
    """Region Theorem 1: E[C] from per-region slot utilizations.

    Identical algebra to :func:`theorem1_market_cost` — under routing, a
    region's spot supply is a pool serving the pooled job stream:
    ``E[C] = k − Σ_r (k − c_r)(μ_r/λ)u_r`` with ``u_r`` the per-region slot
    utilization the engine reports as ``region_utilization`` and ``λ`` the
    *total* (all-region) job arrival rate.  Preemption-free identity, like
    its market twin.
    """
    return theorem1_market_cost(k, lam, rates, prices, utils)


def region_cost_lower_bound(k: float, delta: float, topology, *,
                            routed: bool = True,
                            include_preemption: bool = False) -> float:
    """Policy-independent multi-region bound on E[C].

    ``routed=True`` (default): cross-region routing pools all demand against
    all supply — the :func:`repro.core.lp.region_knapsack_lp` floor.
    ``routed=False``: no routing; region r is a closed single-queue problem
    at its own ``λ_r``, and the bound is the λ-weighted average of the
    per-region floors.  Pooling relaxes the per-region constraints, so
    routed ≤ home-only always; the gap is the value routing can capture
    (tested in tests/test_core_regions.py).
    """
    from repro.core.lp import market_knapsack_lp, region_knapsack_lp

    if routed:
        return region_knapsack_lp(k, delta, topology,
                                  include_preemption=include_preemption)[
                                      "objective"]
    lams = topology.job_rates()
    lam_total = float(lams.sum())
    total = 0.0
    for r, lam_r in zip(topology.regions, lams):
        view = _SingleRegionSupply(r)
        obj = market_knapsack_lp(k, float(lam_r), delta, view,
                                 include_preemption=include_preemption)[
                                     "objective"]
        total += (lam_r / lam_total) * obj
    return float(total)


class _SingleRegionSupply:
    """One region's supply as a 1-pool market view for the knapsack LP."""

    def __init__(self, region):
        self._r = region

    def rates(self):
        import numpy as np

        return np.array([self._r.spot_rate()], np.float64)

    def prices(self):
        import numpy as np

        return np.array([self._r.price], np.float64)

    def hazards(self):
        import numpy as np

        return np.array([self._r.hazard], np.float64)
