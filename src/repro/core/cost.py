"""Cost laws (Theorem 1) and empirical cost accounting.

Theorem 1: for *any* scheduling policy over a G/G/1 spot queue in steady
state,

    E[C] = k − (k−1) · (E[A]/E[S_μ]) · (1 − π₀) = k − (k−1) · (μ/λ) · (1 − π₀)

where π₀ is the steady-state probability that a spot arrival finds the queue
empty.  The whole optimization therefore reduces to maximizing spot-slot
utilization (1 − π₀) subject to the delay constraint.
"""
from __future__ import annotations


def theorem1_cost(k: float, lam: float, mu: float, pi0: float) -> float:
    """E[C] from the empty-queue probability (Theorem 1)."""
    return k - (k - 1.0) * (mu / lam) * (1.0 - pi0)


def pi0_from_cost(k: float, lam: float, mu: float, cost: float) -> float:
    """Invert Theorem 1: recover π₀ implied by an observed average cost."""
    return 1.0 - (k - cost) / ((k - 1.0) * (mu / lam))


def spot_utilization_bound(lam: float, mu: float, delta: float) -> float:
    """Knapsack-LP bound on (1−π₀): min(1, λδ) (Section IV, eqs. 9-11).

    With Little's law E[N] = λ·E[T] ≤ λδ and π_n ≤ coefficients increasing
    in n, the abstract LP's optimum is Σπ_n = min(1, λδ).
    """
    return min(1.0, lam * delta)


def cost_lower_bound(k: float, lam: float, mu: float, delta: float) -> float:
    """Policy-independent lower bound on E[C] from Theorem 1 + the LP bound."""
    return k - (k - 1.0) * (mu / lam) * spot_utilization_bound(lam, mu, delta)


# ---------------------------------------------------------------------------
# Heterogeneous-pool market generalization (see repro.core.market)
# ---------------------------------------------------------------------------


def theorem1_market_cost(k: float, lam: float, rates, prices, utils) -> float:
    """Market Theorem 1: E[C] from per-pool slot utilizations.

    With pool slot rates μ_p, prices c_p, and utilizations
    u_p = P(a pool-p slot finds an eligible job) — the per-pool 1 − π₀ the
    engine reports as ``pool_utilization`` — the fraction of jobs served by
    pool p is (μ_p/λ)·u_p, so

        E[C] = k − Σ_p (k − c_p) (μ_p/λ) u_p.

    Preemption-free identity: revoked legs pay extra spot cost on top (the
    engine's ``spot_cost`` tracks it), so under preemption this is the cost
    of the *completed-leg* flow only.  One unit-price pool recovers
    :func:`theorem1_cost` exactly.
    """
    import numpy as np

    rates = np.asarray(rates, np.float64)
    prices = np.asarray(prices, np.float64)
    utils = np.asarray(utils, np.float64)
    return float(k - np.sum((k - prices) * rates / lam * utils))


def market_cost_lower_bound(k: float, lam: float, delta: float, market, *,
                            include_preemption: bool = False) -> float:
    """Policy-independent market bound: Theorem 1 + the multi-pool LP
    (:func:`repro.core.lp.market_knapsack_lp`)."""
    from repro.core.lp import market_knapsack_lp

    return market_knapsack_lp(k, lam, delta, market,
                              include_preemption=include_preemption)[
                                  "objective"]
