"""Environment timelines: piecewise-constant non-stationary supply.

The engine through PR 6 holds every pool's price, preemption hazard,
and spot availability constant per run.  This module adds the traced
**environment-timeline axis**: a host-side descriptor
(:class:`EnvTimeline`) of piecewise-constant segments — per-pool or
per-region price multipliers, hazard multipliers, and availability —
plus a Markov-modulated regime generator and chaos injectors
(:func:`inject_storm` / :func:`inject_blackout` /
:func:`inject_price_spike`) for sweeps.

Device-side contract (how the engine consumes a timeline)
---------------------------------------------------------

``EnvTimeline.params(n_locs)`` lowers the descriptor to a small dict of
arrays (``ep``) that rides through every executor exactly like the PR-5
RNG slab: a plain traced input (broadcast per lane into the Pallas
VMEM param block), looked up per event with the capture-free one-hot
select :func:`env_row`.  The per-lane cursor is :class:`EnvState` — a
*countdown* ``next_boundary`` clock in the engine's relative-time
numerics plus the current segment index.

**Boundary-as-event.**  Segment boundaries join the merged-renewal race
as a fourth (highest-priority) clock: when ``next_boundary`` wins the
``dt`` race the event is a pure boundary crossing — no queue activity,
clocks age by ``dt``, the segment index advances, and the survived
exponential clocks are rescaled by the old/new rate ratio (exact by
memorylessness).  Because ``dt`` intervals therefore never span a
segment boundary, storm/blackout time attribution is exact.  A
single-segment timeline has ``next_boundary = 3e38``: the boundary
clock never wins, every mask stays identically ``False``, every
multiplier is exactly ``1.0`` — bit-for-bit the PR-6 engine (frozen
test), and ``env=None`` skips all of it at trace time (lowered HLO
byte-identical, like ``telemetry=None``).

Blackouts keep arithmetic finite: availability 0 maps to a
``BLACKOUT_SCALE``-inflated clock, not ``inf``, so recovery at the next
boundary is a well-defined rescale.  Storms are *multiplicative* on the
base hazard — a pool whose base hazard is 0 stays un-preemptible
through a storm (document, don't surprise).
"""
from __future__ import annotations

import dataclasses
import math

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

INF = np.float32(3e38)

# availability 0 inflates (not infinitizes) the spot clock: the clock
# stays finite so the next boundary's old/new-rate rescale is exact and
# recovery works; 1e15 × any draw still never wins a dt race
BLACKOUT_SCALE = np.float32(1e15)

SEG_NORMAL = 0
SEG_STORM = 1
SEG_BLACKOUT = 2
SEG_SPIKE = 3

_KINDS = (SEG_NORMAL, SEG_STORM, SEG_BLACKOUT, SEG_SPIKE)
_KIND_NAMES = {SEG_NORMAL: "normal", SEG_STORM: "storm",
               SEG_BLACKOUT: "blackout", SEG_SPIKE: "spike"}


def _norm_value(v, field, si):
    """Normalize one segment's value to a float scalar or per-loc tuple."""
    if isinstance(v, (list, tuple, np.ndarray)):
        vals = tuple(float(x) for x in np.asarray(v).reshape(-1))
        if not vals:
            raise ValueError(f"EnvTimeline.{field}[{si}] is empty")
        bad = [x for x in vals if not math.isfinite(x) or x < 0]
        if bad:
            raise ValueError(
                f"EnvTimeline.{field}[{si}] must be finite and >= 0, "
                f"got {bad}")
        return vals
    v = float(v)
    if not math.isfinite(v) or v < 0:
        raise ValueError(
            f"EnvTimeline.{field}[{si}] must be finite and >= 0, got {v}")
    return v


@dataclasses.dataclass(frozen=True)
class EnvTimeline:
    """Piecewise-constant environment: segment ``i`` covers
    ``[t_end[i-1], t_end[i])`` (with ``t_end[-1]`` open-ended at 3e38).

    ``price_mult`` / ``hazard_mult`` / ``avail`` hold one entry per
    segment, each a scalar (applies to every pool/region) or a per-loc
    tuple; ``kind`` tags each segment ``SEG_NORMAL`` / ``SEG_STORM`` /
    ``SEG_BLACKOUT`` / ``SEG_SPIKE`` for the `repro.obs` shock counters.
    Hashable (nested tuples only) so it can sit beside the other static
    descriptors, but the engine consumes only :meth:`params` — the
    timeline itself never becomes a static jit argument.
    """

    t_end: tuple
    price_mult: tuple = (1.0,)
    hazard_mult: tuple = (1.0,)
    avail: tuple = (1.0,)
    kind: tuple = (SEG_NORMAL,)

    def __post_init__(self):
        t_end = tuple(float(t) for t in self.t_end)
        if not t_end:
            raise ValueError("EnvTimeline needs at least one segment")
        if not (math.isinf(t_end[-1]) or t_end[-1] >= float(INF)):
            raise ValueError(
                "EnvTimeline's last segment must be open-ended: pass "
                f"t_end[-1]=float('inf'), got {t_end[-1]} (append a "
                "trailing segment holding the final regime)")
        t_end = t_end[:-1] + (float(INF),)
        for a, b in zip(t_end, t_end[1:]):
            if not a < b:
                raise ValueError(
                    f"EnvTimeline.t_end must be strictly increasing, "
                    f"got {a} before {b}")
        if t_end[0] <= 0:
            raise ValueError(
                f"EnvTimeline.t_end[0] must be > 0, got {t_end[0]}")
        s = len(t_end)
        fields = {}
        for name in ("price_mult", "hazard_mult", "avail"):
            vals = getattr(self, name)
            if not isinstance(vals, (list, tuple)):
                vals = (vals,) * s
            if len(vals) != s:
                raise ValueError(
                    f"EnvTimeline.{name} has {len(vals)} entries for "
                    f"{s} segments")
            fields[name] = tuple(
                _norm_value(v, name, i) for i, v in enumerate(vals))
        kind = self.kind
        if not isinstance(kind, (list, tuple)):
            kind = (kind,) * s
        if len(kind) != s:
            raise ValueError(
                f"EnvTimeline.kind has {len(kind)} entries for {s} segments")
        kind = tuple(int(k) for k in kind)
        bad = [k for k in kind if k not in _KINDS]
        if bad:
            raise ValueError(
                f"EnvTimeline.kind entries must be in {_KINDS} "
                f"(normal/storm/blackout/spike), got {bad}")
        object.__setattr__(self, "t_end", t_end)
        object.__setattr__(self, "kind", kind)
        for name, vals in fields.items():
            object.__setattr__(self, name, vals)

    # ---------------------------------------------------------------- host

    @property
    def n_segments(self) -> int:
        return len(self.t_end)

    @staticmethod
    def constant(price_mult=1.0, hazard_mult=1.0, avail=1.0) -> "EnvTimeline":
        """One open-ended segment (the stationary PR-6 world)."""
        return EnvTimeline(t_end=(float("inf"),), price_mult=(price_mult,),
                           hazard_mult=(hazard_mult,), avail=(avail,))

    def span(self) -> float:
        """Time of the last finite boundary (0.0 for a single segment)."""
        return 0.0 if self.n_segments == 1 else self.t_end[-2]

    def count(self, kind: int) -> int:
        return sum(1 for k in self.kind if k == kind)

    def count_storms(self) -> int:
        return self.count(SEG_STORM)

    def count_blackouts(self) -> int:
        return self.count(SEG_BLACKOUT)

    def count_spikes(self) -> int:
        return self.count(SEG_SPIKE)

    def segments(self):
        """Host iterator of (t_start, t_end, price, hazard, avail, kind)."""
        t0 = 0.0
        for i, t1 in enumerate(self.t_end):
            yield (t0, t1, self.price_mult[i], self.hazard_mult[i],
                   self.avail[i], self.kind[i])
            t0 = t1

    # -------------------------------------------------------------- device

    def params(self, n_locs: int) -> dict:
        """Lower to the traced ``ep`` dict consumed by the event loops.

        ``t_end (S,) f32``, ``kind (S,) i32``, and ``(S, n_locs) f32``
        grids for price / hazard / avail (scalars broadcast across locs).
        """
        def grid(vals, name):
            rows = []
            for si, v in enumerate(vals):
                if isinstance(v, tuple):
                    if len(v) != n_locs:
                        raise ValueError(
                            f"EnvTimeline.{name}[{si}] has {len(v)} "
                            f"per-loc entries but the scenario has "
                            f"{n_locs} pools/regions")
                    rows.append(np.asarray(v, np.float32))
                else:
                    rows.append(np.full((n_locs,), v, np.float32))
            return jnp.asarray(np.stack(rows))

        return {
            "t_end": jnp.asarray(np.asarray(self.t_end, np.float32)),
            "price": grid(self.price_mult, "price_mult"),
            "hazard": grid(self.hazard_mult, "hazard_mult"),
            "avail": grid(self.avail, "avail"),
            "kind": jnp.asarray(np.asarray(self.kind, np.int32)),
        }


class EnvState(NamedTuple):
    """Per-lane timeline cursor: countdown to the next boundary (the
    engine works in relative time; an absolute-t cursor would lose
    float32 precision as t grows) + current segment index."""

    next_boundary: jnp.ndarray   # f32, counts down with every dt
    seg: jnp.ndarray             # i32 segment index


def init_env_state(ep) -> EnvState:
    return EnvState(next_boundary=ep["t_end"][0], seg=jnp.int32(0))


def env_row(arr, seg):
    """Segment lookup as a capture-free one-hot reduce (Pallas-safe:
    no gather, no captured constants; works under vmap)."""
    onehot = jax.lax.iota(jnp.int32, arr.shape[0]) == seg
    if arr.ndim == 1:
        return jnp.sum(jnp.where(onehot, arr, jnp.zeros((), arr.dtype)))
    return jnp.sum(jnp.where(onehot[:, None], arr, jnp.zeros((), arr.dtype)),
                   axis=0)


def inv_avail(avail_row):
    """1/avail with blackout (avail == 0) mapped to BLACKOUT_SCALE.

    Spot inter-arrival clocks scale by this: avail 1 → exactly ×1.0
    (bitwise no-op), avail 0 → clocks too large to win any dt race but
    finite, so the boundary rescale back to avail > 0 is exact.
    """
    safe = jnp.where(avail_row > 0, avail_row, jnp.ones((), avail_row.dtype))
    return jnp.where(avail_row > 0, 1.0 / safe, BLACKOUT_SCALE)


def clock_rescale(old_rate_mult, new_rate_mult):
    """Exponential-clock ratio for a boundary crossing: a survived
    Exp(r_old) residual re-expressed under r_new is t·(r_old/r_new)
    (memorylessness).  Zero rates on either side leave the clock
    untouched — the inflated/zero-rate representation handles those."""
    both = (old_rate_mult > 0) & (new_rate_mult > 0)
    safe_new = jnp.where(both, new_rate_mult,
                         jnp.ones((), new_rate_mult.dtype))
    return jnp.where(both, old_rate_mult / safe_new,
                     jnp.ones((), new_rate_mult.dtype))


# --------------------------------------------------------------------------
# generators + chaos injectors (host-side; compose before .params())
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Regime:
    """One state of the Markov modulator."""

    price_mult: float = 1.0
    hazard_mult: float = 1.0
    avail: float = 1.0
    kind: int = SEG_NORMAL
    mean_hold: float = 1.0


def markov_timeline(regimes, *, horizon, seed=0, transition=None,
                    start=0) -> EnvTimeline:
    """Markov-modulated regime switching: exponential holding times per
    regime, jump matrix ``transition`` (row-stochastic; default uniform
    over the *other* regimes), truncated at ``horizon`` with the regime
    then active held open-ended."""
    regs = tuple(regimes)
    if len(regs) < 2:
        raise ValueError("markov_timeline needs >= 2 regimes")
    r = len(regs)
    if transition is None:
        transition = (np.ones((r, r)) - np.eye(r)) / (r - 1)
    transition = np.asarray(transition, float)
    if transition.shape != (r, r) or not np.allclose(
            transition.sum(axis=1), 1.0):
        raise ValueError(
            f"transition must be a row-stochastic ({r}, {r}) matrix")
    if horizon <= 0:
        raise ValueError(f"horizon must be > 0, got {horizon}")
    rng = np.random.default_rng(seed)
    t, cur = 0.0, int(start)
    t_end, pm, hm, av, kd = [], [], [], [], []
    while t < horizon:
        g = regs[cur]
        t = t + rng.exponential(g.mean_hold)
        t_end.append(min(t, float(horizon)) if t < horizon else float("inf"))
        pm.append(g.price_mult)
        hm.append(g.hazard_mult)
        av.append(g.avail)
        kd.append(g.kind)
        cur = int(rng.choice(r, p=transition[cur]))
    if not math.isinf(t_end[-1]):     # pragma: no cover - defensive
        t_end[-1] = float("inf")
    return EnvTimeline(t_end=tuple(t_end), price_mult=tuple(pm),
                       hazard_mult=tuple(hm), avail=tuple(av),
                       kind=tuple(kd))


def _edit_loc(value, loc, n_locs, fn):
    """Apply ``fn`` at one loc (expanding scalars) or everywhere."""
    if loc is None:
        if isinstance(value, tuple):
            return tuple(fn(v) for v in value)
        return fn(value)
    if not isinstance(value, tuple):
        if n_locs is None:
            raise ValueError(
                "loc-targeted injection on a scalar-valued timeline "
                "needs n_locs= to expand it to per-loc values")
        value = (value,) * n_locs
    if not 0 <= loc < len(value):
        raise ValueError(f"loc {loc} out of range for {len(value)} locs")
    return tuple(fn(v) if i == loc else v for i, v in enumerate(value))


def _splice(tl: EnvTimeline, t0: float, t1: float, kind: int,
            edit) -> EnvTimeline:
    """Cut ``[t0, t1)`` into the timeline and apply ``edit`` inside it."""
    if not (0 <= t0 < t1):
        raise ValueError(f"need 0 <= t0 < t1, got t0={t0}, t1={t1}")
    if not math.isfinite(t1):
        raise ValueError("injection windows must be finite (t1 < inf)")
    t_end, pm, hm, av, kd = [], [], [], [], []

    def emit(end, p, h, a, k):
        t_end.append(end)
        pm.append(p)
        hm.append(h)
        av.append(a)
        kd.append(k)

    for s0, s1, p, h, a, k in tl.segments():
        cuts = sorted({s1, *(c for c in (t0, t1) if s0 < c < s1)})
        lo = s0
        for hi in cuts:
            if t0 <= lo and hi <= t1:
                emit(hi, *edit(p, h, a), kind)
            else:
                emit(hi, p, h, a, k)
            lo = hi
    return EnvTimeline(t_end=tuple(t_end), price_mult=tuple(pm),
                       hazard_mult=tuple(hm), avail=tuple(av),
                       kind=tuple(kd))


def timeline_from_trace(times, avail, *, price=None, hazard=None
                        ) -> EnvTimeline:
    """Replay a recorded availability trace as an :class:`EnvTimeline`.

    ``times`` are segment END times (strictly increasing; the final
    segment is held open-ended past ``times[-1]``); ``avail`` holds one
    availability row per segment — a scalar or a per-pool/region tuple,
    with 0 marking a capacity blackout exactly as
    :func:`inject_blackout` would.  Optional ``price`` / ``hazard``
    rows ride along as multipliers.  This is the bridge from spot-market
    traces (e.g. the synthetic k80-style trace in
    ``tests/data/spot_trace_k80.json``) to the engine's traced
    environment axis, so checkpoint/safety-net kernels can be
    tournament-tested against adversarial recorded blackouts rather
    than only synthetic injections.

    Segments whose availability is zero in EVERY location are tagged
    ``SEG_BLACKOUT`` (feeding the `repro.obs` shock counters); all
    others are ``SEG_NORMAL``.
    """
    times = [float(t) for t in times]
    avail = list(avail)
    if len(times) != len(avail):
        raise ValueError(
            f"timeline_from_trace: {len(times)} times for "
            f"{len(avail)} avail rows")
    if not times:
        raise ValueError("timeline_from_trace needs at least one segment")

    def _row(v):
        return tuple(float(x) for x in v) if isinstance(
            v, (list, tuple, np.ndarray)) else float(v)

    def _opt(rows, name):
        if rows is None:
            return (1.0,) * (len(times) + 1)
        rows = list(rows)
        if len(rows) != len(times):
            raise ValueError(
                f"timeline_from_trace: {len(rows)} {name} rows for "
                f"{len(times)} segments")
        return tuple(_row(v) for v in rows) + (_row(rows[-1]),)

    av = tuple(_row(v) for v in avail)
    kind = tuple(
        SEG_BLACKOUT if (all(x == 0.0 for x in v) if isinstance(v, tuple)
                         else v == 0.0) else SEG_NORMAL
        for v in av)
    # hold the last recorded regime open-ended (EnvTimeline requires an
    # infinite final boundary)
    return EnvTimeline(
        t_end=tuple(times) + (float("inf"),),
        price_mult=_opt(price, "price"),
        hazard_mult=_opt(hazard, "hazard"),
        avail=av + (av[-1],),
        kind=kind + (kind[-1],),
    )


def inject_storm(tl: EnvTimeline, t0: float, t1: float, *,
                 hazard_mult: float = 10.0, loc=None,
                 n_locs=None) -> EnvTimeline:
    """Preemption storm: multiply the hazard by ``hazard_mult`` over
    ``[t0, t1)`` (at one loc, or everywhere) and tag it SEG_STORM.
    Multiplicative: a pool with base hazard 0 stays un-preemptible."""
    if hazard_mult <= 0:
        raise ValueError(f"hazard_mult must be > 0, got {hazard_mult}")
    return _splice(
        tl, t0, t1, SEG_STORM,
        lambda p, h, a: (p, _edit_loc(h, loc, n_locs,
                                      lambda v: v * hazard_mult), a))


def inject_blackout(tl: EnvTimeline, t0: float, t1: float, *, loc=None,
                    n_locs=None) -> EnvTimeline:
    """Capacity blackout: availability 0 over ``[t0, t1)`` (at one loc,
    or everywhere), tagged SEG_BLACKOUT."""
    return _splice(
        tl, t0, t1, SEG_BLACKOUT,
        lambda p, h, a: (p, h, _edit_loc(a, loc, n_locs, lambda v: 0.0)))


def inject_price_spike(tl: EnvTimeline, t0: float, t1: float, *,
                       price_mult: float = 3.0, loc=None,
                       n_locs=None) -> EnvTimeline:
    """Price spike: multiply spot price by ``price_mult`` over
    ``[t0, t1)``, tagged SEG_SPIKE."""
    if price_mult <= 0:
        raise ValueError(f"price_mult must be > 0, got {price_mult}")
    return _splice(
        tl, t0, t1, SEG_SPIKE,
        lambda p, h, a: (_edit_loc(p, loc, n_locs,
                                   lambda v: v * price_mult), h, a))
