"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every ``attn_every`` layers (weights shared, per-application KV
caches).  Sub-quadratic in sequence length between attention applications,
which is why this family runs the long_500k cell.

Structure: layers are partitioned into ``n_apps`` groups; each group is
[shared attention block] → scan over its Mamba2 layers.  The group loop is a
static Python loop (n_apps ≈ 7), so each application's KV cache is indexed
statically and the scan bodies stay deduplicated in HLO.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.attention import (
    attention_init,
    decode_attention,
    mix_sequence,
    out_project,
    qkv_project,
)
from repro.layers.mlp import mlp, mlp_init
from repro.layers.norms import rms_norm, rms_norm_init
from repro.layers.ssm import (
    SSMCache,
    dims_from_cfg,
    mamba_block,
    mamba_block_decode,
    ssm_init,
    ssm_init_cache,
)
from repro.models.base import (
    ParallelContext,
    cross_entropy_chunked,
    embed_init,
    lm_head_init,
    logits_for_tokens,
    remat_wrap,
)
from repro.models.config import ModelConfig


class HybridCache(NamedTuple):
    conv: jax.Array  # (L, B, W-1, C)
    state: jax.Array  # (L, B, H, P, N)
    attn_k: jax.Array  # (n_apps, B, S, KH, hd)
    attn_v: jax.Array
    index: jax.Array  # scalar int32


class HybridLM:
    def __init__(self, cfg: ModelConfig, ctx: Optional[ParallelContext] = None):
        self.cfg = cfg
        self.ctx = ctx or ParallelContext()
        self.dims = dims_from_cfg(cfg)
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.n_apps = -(-cfg.num_layers // cfg.attn_every)
        # group g covers mamba layers [bounds[g], bounds[g+1])
        self.bounds = [min(g * cfg.attn_every, cfg.num_layers)
                       for g in range(self.n_apps + 1)]

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kl, ka, km, kh = jax.random.split(key, 5)
        layer_keys = jax.random.split(kl, cfg.num_layers)

        def layer_init(k):
            return {"ln": rms_norm_init(cfg.d_model),
                    "ssm": ssm_init(k, self.dims, dtype=self.dtype)}

        return {
            "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, self.dtype),
            "layers": jax.vmap(layer_init)(layer_keys),
            "shared": {
                "ln1": rms_norm_init(cfg.d_model),
                "ln2": rms_norm_init(cfg.d_model),
                "attn": attention_init(
                    ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim, dtype=self.dtype),
                "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, dtype=self.dtype),
            },
            "final_norm": rms_norm_init(cfg.d_model),
            "lm_head": lm_head_init(kh, cfg.d_model, cfg.vocab_size,
                                    self.dtype),
        }

    def _group_params(self, params, g):
        lo, hi = self.bounds[g], self.bounds[g + 1]
        return jax.tree.map(lambda a: a[lo:hi], params["layers"])

    # ---------------------------------------------------------------- shared
    def _shared_block_seq(self, shared, x, positions, *, collect_kv: bool):
        cfg = self.cfg
        h = rms_norm(shared["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(shared["attn"], h)
        q = apply_rope_local(q, positions, cfg.rope_theta)
        k = apply_rope_local(k, positions, cfg.rope_theta)
        y = mix_sequence(cfg, q, k, v, causal=True)
        x = x + out_project(shared["attn"], y)
        h = rms_norm(shared["ln2"], x, cfg.norm_eps)
        x = x + mlp(shared["mlp"], h)
        return (x, (k, v)) if collect_kv else (x, None)

    def _mamba_group(self, group_params, x, *, collect_cache: bool):
        cfg, ctx = self.cfg, self.ctx
        impl = "pallas" if cfg.attn_impl == "pallas" else "chunked"

        def body(xc, p_layer):
            h = rms_norm(p_layer["ln"], xc, cfg.norm_eps)
            if collect_cache:
                y, c = mamba_block(p_layer["ssm"], self.dims, h,
                                   norm_eps=cfg.norm_eps, impl=impl,
                                   return_cache=True)
            else:
                y = mamba_block(p_layer["ssm"], self.dims, h,
                                norm_eps=cfg.norm_eps, impl=impl)
                c = None
            xc = ctx.constrain(xc + y, P(ctx.batch_spec_entry(), None, None))
            return xc, c

        body = remat_wrap(body, cfg)
        return jax.lax.scan(body, x, group_params)

    def _run_layers(self, params, x, positions, *, collect_cache: bool = False):
        shared = params["shared"]
        kvs, ssm_caches = [], []
        for g in range(self.n_apps):
            x, kv = self._shared_block_seq(shared, x, positions,
                                           collect_kv=collect_cache)
            x, c = self._mamba_group(self._group_params(params, g), x,
                                     collect_cache=collect_cache)
            if collect_cache:
                kvs.append(kv)
                ssm_caches.append(c)
        if not collect_cache:
            return x, None
        attn_k = jnp.stack([kv[0] for kv in kvs])  # (n_apps,B,S,KH,hd)
        attn_v = jnp.stack([kv[1] for kv in kvs])
        conv = jnp.concatenate([c.conv for c in ssm_caches])  # (L,...)
        state = jnp.concatenate([c.state for c in ssm_caches])
        return x, (attn_k, attn_v, conv, state)

    def loss(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = self.ctx.constrain(x, P(self.ctx.batch_spec_entry(), None, None))
        x, _ = self._run_layers(params, x, positions)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        ce = cross_entropy_chunked(x, params["lm_head"], batch["targets"])
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    # --------------------------------------------------------------- serving
    def init_cache(self, batch_size: int, max_len: int) -> HybridCache:
        cfg, d = self.cfg, self.dims
        c = ssm_init_cache(d, batch_size, self.dtype)
        L = cfg.num_layers
        return HybridCache(
            conv=jnp.broadcast_to(c.conv[None], (L,) + c.conv.shape).copy(),
            state=jnp.broadcast_to(c.state[None], (L,) + c.state.shape).copy(),
            attn_k=jnp.zeros((self.n_apps, batch_size, max_len,
                              cfg.num_kv_heads, cfg.resolved_head_dim),
                             self.dtype),
            attn_v=jnp.zeros((self.n_apps, batch_size, max_len,
                              cfg.num_kv_heads, cfg.resolved_head_dim),
                             self.dtype),
            index=jnp.zeros((), jnp.int32),
        )

    def prefill(self, params, batch, max_len: Optional[int] = None
                ) -> tuple[jax.Array, HybridCache]:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x, caches = self._run_layers(params, x, positions, collect_cache=True)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_for_tokens(x[:, -1:], params["lm_head"])
        attn_k, attn_v, conv, state = caches
        if max_len is not None and max_len > S:
            pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
            attn_k, attn_v = jnp.pad(attn_k, pad), jnp.pad(attn_v, pad)
        return logits, HybridCache(conv=conv, state=state, attn_k=attn_k,
                                   attn_v=attn_v,
                                   index=jnp.asarray(S, jnp.int32))

    def decode_step(self, params, batch, cache: HybridCache
                    ) -> tuple[jax.Array, HybridCache]:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]  # (B, 1, D)
        B = x.shape[0]
        idx = cache.index
        positions = jnp.broadcast_to(idx[None, None], (B, 1))
        shared = params["shared"]

        def shared_decode(xc, ak, av):
            h = rms_norm(shared["ln1"], xc, cfg.norm_eps)
            q, k, v = qkv_project(shared["attn"], h)
            q = apply_rope_local(q, positions, cfg.rope_theta)
            k = apply_rope_local(k, positions, cfg.rope_theta)
            ak = jax.lax.dynamic_update_slice_in_dim(ak, k, idx, axis=1)
            av = jax.lax.dynamic_update_slice_in_dim(av, v, idx, axis=1)
            y = decode_attention(q, ak, av, idx + 1)
            xc = xc + out_project(shared["attn"], y)
            h = rms_norm(shared["ln2"], xc, cfg.norm_eps)
            return xc + mlp(shared["mlp"], h), ak, av

        def mamba_decode_group(xc, group_params, conv_g, state_g):
            def body(xb, inputs):
                p_layer, conv_l, state_l = inputs
                h = rms_norm(p_layer["ln"], xb, cfg.norm_eps)
                y, new_c = mamba_block_decode(
                    p_layer["ssm"], self.dims, h,
                    SSMCache(conv=conv_l, state=state_l),
                    norm_eps=cfg.norm_eps)
                return xb + y, (new_c.conv, new_c.state)

            return jax.lax.scan(body, xc, (group_params, conv_g, state_g))

        ak_new, av_new, conv_new, state_new = [], [], [], []
        for g in range(self.n_apps):
            x, ak, av = shared_decode(x, cache.attn_k[g], cache.attn_v[g])
            lo, hi = self.bounds[g], self.bounds[g + 1]
            x, (conv_g, state_g) = mamba_decode_group(
                x, self._group_params(params, g),
                cache.conv[lo:hi], cache.state[lo:hi])
            ak_new.append(ak)
            av_new.append(av)
            conv_new.append(conv_g)
            state_new.append(state_g)

        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_for_tokens(x, params["lm_head"])
        return logits, HybridCache(
            conv=jnp.concatenate(conv_new), state=jnp.concatenate(state_new),
            attn_k=jnp.stack(ak_new), attn_v=jnp.stack(av_new),
            index=idx + 1)


def apply_rope_local(x, positions, theta):
    from repro.layers.rotary import apply_rope

    return apply_rope(x, positions, theta)
