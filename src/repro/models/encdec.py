"""Whisper-style encoder-decoder backbone (whisper-small).

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, S, d_model).  Positions are fixed
sinusoidal (added at embed time), matching Whisper's design more closely
than RoPE.  Decoder layers carry self-attention (causal, cached) and
cross-attention to the encoded frames (KV computed once at prefill).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.attention import (
    attention_init,
    decode_attention,
    mix_sequence,
    out_project,
    qkv_project,
)
from repro.layers.mlp import mlp, mlp_init
from repro.layers.norms import rms_norm, rms_norm_init
from repro.layers.rotary import sinusoidal_positions
from repro.models.base import (
    ParallelContext,
    cross_entropy_chunked,
    embed_init,
    lm_head_init,
    logits_for_tokens,
    remat_wrap,
)
from repro.models.config import ModelConfig


class EncDecCache(NamedTuple):
    self_k: jax.Array  # (L, B, S_dec, KH, hd)
    self_v: jax.Array
    cross_k: jax.Array  # (L, B, S_enc, KH, hd) — fixed after prefill
    cross_v: jax.Array
    index: jax.Array


class EncDecLM:
    def __init__(self, cfg: ModelConfig, ctx: Optional[ParallelContext] = None):
        self.cfg = cfg
        self.ctx = ctx or ParallelContext()
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def _enc_layer_init(self, key):
        cfg = self.cfg
        ka, km = jax.random.split(key)
        return {
            "ln1": rms_norm_init(cfg.d_model),
            "ln2": rms_norm_init(cfg.d_model),
            "attn": attention_init(ka, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.resolved_head_dim,
                                   dtype=self.dtype),
            "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, dtype=self.dtype,
                            variant=cfg.mlp_variant),
        }

    def _dec_layer_init(self, key):
        cfg = self.cfg
        ka, kx, km = jax.random.split(key, 3)
        return {
            "ln1": rms_norm_init(cfg.d_model),
            "ln_x": rms_norm_init(cfg.d_model),
            "ln2": rms_norm_init(cfg.d_model),
            "attn": attention_init(ka, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.resolved_head_dim,
                                   dtype=self.dtype),
            "cross": attention_init(kx, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim,
                                    dtype=self.dtype),
            "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, dtype=self.dtype,
                            variant=cfg.mlp_variant),
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kenc, kdec, kh = jax.random.split(key, 4)
        enc_keys = jax.random.split(kenc, cfg.num_encoder_layers)
        dec_keys = jax.random.split(kdec, cfg.num_layers)
        return {
            "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, self.dtype),
            "enc_layers": jax.vmap(self._enc_layer_init)(enc_keys),
            "enc_norm": rms_norm_init(cfg.d_model),
            "dec_layers": jax.vmap(self._dec_layer_init)(dec_keys),
            "final_norm": rms_norm_init(cfg.d_model),
            "lm_head": lm_head_init(kh, cfg.d_model, cfg.vocab_size,
                                    self.dtype),
        }

    # ---------------------------------------------------------------- encode
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames (B, S, D) stub embeddings -> encoded (B, S, D)."""
        cfg, ctx = self.cfg, self.ctx
        S = frames.shape[1]
        x = frames.astype(self.dtype) + sinusoidal_positions(
            S, cfg.d_model).astype(self.dtype)[None]
        x = ctx.constrain(x, P(ctx.batch_spec_entry(), None, None))

        def body(xc, p_layer):
            h = rms_norm(p_layer["ln1"], xc, cfg.norm_eps)
            q, k, v = qkv_project(p_layer["attn"], h)
            y = mix_sequence(cfg, q, k, v, causal=False)
            xc = xc + out_project(p_layer["attn"], y)
            h = rms_norm(p_layer["ln2"], xc, cfg.norm_eps)
            xc = ctx.constrain(xc + mlp(p_layer["mlp"], h),
                               P(ctx.batch_spec_entry(), None, None))
            return xc, None

        body = remat_wrap(body, cfg)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rms_norm(params["enc_norm"], x, cfg.norm_eps)

    # ---------------------------------------------------------------- decode
    def _decoder_seq(self, params, tokens, encoded, *, collect_cache: bool):
        cfg, ctx = self.cfg, self.ctx
        B, S = tokens.shape
        x = params["embed"][tokens] + sinusoidal_positions(
            S, cfg.d_model).astype(self.dtype)[None]
        x = ctx.constrain(x, P(ctx.batch_spec_entry(), None, None))

        def body(xc, p_layer):
            h = rms_norm(p_layer["ln1"], xc, cfg.norm_eps)
            q, k, v = qkv_project(p_layer["attn"], h)
            y = mix_sequence(cfg, q, k, v, causal=True)
            xc = xc + out_project(p_layer["attn"], y)
            # cross attention
            h = rms_norm(p_layer["ln_x"], xc, cfg.norm_eps)
            qx = jnp.einsum("bsd,dhk->bshk", h, p_layer["cross"]["wq"])
            kx = jnp.einsum("bsd,dhk->bshk", encoded, p_layer["cross"]["wk"])
            vx = jnp.einsum("bsd,dhk->bshk", encoded, p_layer["cross"]["wv"])
            yx = mix_sequence(cfg, qx, kx, vx, causal=False)
            xc = xc + out_project(p_layer["cross"], yx)
            h = rms_norm(p_layer["ln2"], xc, cfg.norm_eps)
            xc = ctx.constrain(xc + mlp(p_layer["mlp"], h),
                               P(ctx.batch_spec_entry(), None, None))
            out = (k, v, kx, vx) if collect_cache else None
            return xc, out

        body = remat_wrap(body, cfg)
        x, caches = jax.lax.scan(body, x, params["dec_layers"])
        return rms_norm(params["final_norm"], x, cfg.norm_eps), caches

    def loss(self, params, batch):
        encoded = self.encode(params, batch["frames"])
        x, _ = self._decoder_seq(params, batch["tokens"], encoded,
                                 collect_cache=False)
        ce = cross_entropy_chunked(x, params["lm_head"], batch["targets"])
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    def init_cache(self, batch_size: int, max_len: int) -> EncDecCache:
        cfg = self.cfg
        kv = (cfg.num_layers, batch_size, max_len, cfg.num_kv_heads,
              cfg.resolved_head_dim)
        return EncDecCache(
            self_k=jnp.zeros(kv, self.dtype), self_v=jnp.zeros(kv, self.dtype),
            cross_k=jnp.zeros(kv, self.dtype),
            cross_v=jnp.zeros(kv, self.dtype),
            index=jnp.zeros((), jnp.int32),
        )

    def prefill(self, params, batch, max_len=None
                ) -> tuple[jax.Array, EncDecCache]:
        encoded = self.encode(params, batch["frames"])
        x, (sk, sv, ck, cv) = self._decoder_seq(
            params, batch["tokens"], encoded, collect_cache=True)
        logits = logits_for_tokens(x[:, -1:], params["lm_head"])
        S = batch["tokens"].shape[1]
        if max_len is not None and max_len > S:
            pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
            sk, sv = jnp.pad(sk, pad), jnp.pad(sv, pad)
        return logits, EncDecCache(self_k=sk, self_v=sv, cross_k=ck,
                                   cross_v=cv,
                                   index=jnp.asarray(S, jnp.int32))

    def decode_step(self, params, batch, cache: EncDecCache
                    ) -> tuple[jax.Array, EncDecCache]:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        B = x.shape[0]
        idx = cache.index
        pos_table = sinusoidal_positions(cache.self_k.shape[2],
                                         cfg.d_model).astype(self.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pos_table, idx, 1, axis=0)[None]

        def body(carry, inputs):
            xc = carry
            p_layer, sk_l, sv_l, ck_l, cv_l = inputs
            h = rms_norm(p_layer["ln1"], xc, cfg.norm_eps)
            q, k, v = qkv_project(p_layer["attn"], h)
            sk_l = jax.lax.dynamic_update_slice_in_dim(sk_l, k, idx, axis=1)
            sv_l = jax.lax.dynamic_update_slice_in_dim(sv_l, v, idx, axis=1)
            y = decode_attention(q, sk_l, sv_l, idx + 1)
            xc = xc + out_project(p_layer["attn"], y)
            h = rms_norm(p_layer["ln_x"], xc, cfg.norm_eps)
            qx = jnp.einsum("bsd,dhk->bshk", h, p_layer["cross"]["wq"])
            yx = decode_attention(qx, ck_l, cv_l, ck_l.shape[1])
            xc = xc + out_project(p_layer["cross"], yx)
            h = rms_norm(p_layer["ln2"], xc, cfg.norm_eps)
            xc = xc + mlp(p_layer["mlp"], h)
            return xc, (sk_l, sv_l)

        x, (sk_new, sv_new) = jax.lax.scan(
            body, x,
            (params["dec_layers"], cache.self_k, cache.self_v,
             cache.cross_k, cache.cross_v),
        )
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_for_tokens(x, params["lm_head"])
        return logits, cache._replace(self_k=sk_new, self_v=sv_new,
                                      index=idx + 1)
