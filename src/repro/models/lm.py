"""Decoder-only transformer LM: dense, MoE and VLM-backbone variants.

One implementation covers granite-20b, qwen3-32b, internlm2-20b, qwen1.5-4b
(dense), qwen2-moe-a2.7b, arctic-480b (MoE) and qwen2-vl-72b (VLM backbone —
``input_mode="embeddings"`` with M-RoPE; the patch frontend is a stub that
supplies fused embeddings, per the assignment).

Layers are *stacked* and iterated with ``lax.scan`` (MaxText-style): HLO size
and compile time are O(1) in depth, and remat policy applies per layer.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.attention import (
    attention_init,
    decode_attention,
    mix_sequence,
    out_project,
    qkv_project,
)
from repro.layers.mlp import mlp, mlp_init
from repro.layers.moe import moe_apply_local, moe_apply_sharded, moe_init, \
    padded_experts
from repro.layers.norms import rms_norm, rms_norm_init
from repro.layers.rotary import apply_mrope, apply_rope
from repro.models.base import (
    ParallelContext,
    cross_entropy_chunked,
    embed_init,
    lm_head_init,
    logits_for_tokens,
    remat_wrap,
)
from repro.models.config import ModelConfig


class KVCache(NamedTuple):
    """Layer-stacked KV cache.

    K/V are stored as RAW 16-bit words (uint16 bitcast of bf16) and bitcast
    back at the point of use.  On TPU this is a no-op (same bits, bf16 is
    native); on CPU hosts it keeps the multi-GiB cache out of XLA's float-
    normalization pass, which otherwise shadows every bf16 buffer touched by
    a float op with an f32 copy (2× decode memory, measured).
    """

    k: jax.Array  # (L, B, S, KH, hd) uint16 (bf16 bits)
    v: jax.Array
    index: jax.Array  # scalar int32 — next write slot == #valid tokens


def kv_to_bits(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.uint16)


def kv_from_bits(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.bfloat16)


class TransformerLM:
    def __init__(self, cfg: ModelConfig, ctx: Optional[ParallelContext] = None):
        self.cfg = cfg
        self.ctx = ctx or ParallelContext()
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        if cfg.family == "moe":
            model_axis = (self.ctx.mesh.shape[self.ctx.model_axis]
                          if self.ctx.mesh is not None else 1)
            self.num_padded_experts = padded_experts(cfg.num_experts,
                                                     max(model_axis, 1))
        else:
            self.num_padded_experts = 0

    # ------------------------------------------------------------------ init
    def _layer_init(self, key) -> dict:
        cfg = self.cfg
        ka, km, ks, kd = jax.random.split(key, 4)
        p = {
            "ln1": rms_norm_init(cfg.d_model),
            "ln2": rms_norm_init(cfg.d_model),
            "attn": attention_init(
                ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias,
                qk_norm=cfg.qk_norm, dtype=self.dtype,
            ),
        }
        if cfg.family == "moe":
            p["moe"] = moe_init(km, cfg.d_model, cfg.moe_d_ff,
                                cfg.num_experts, self.num_padded_experts,
                                dtype=self.dtype)
            if cfg.num_shared_experts:
                p["shared_mlp"] = mlp_init(
                    ks, cfg.d_model,
                    cfg.num_shared_experts * cfg.moe_d_ff, dtype=self.dtype)
            if cfg.dense_residual:
                p["dense_mlp"] = mlp_init(kd, cfg.d_model, cfg.d_ff,
                                          dtype=self.dtype)
        else:
            p["mlp"] = mlp_init(km, cfg.d_model, cfg.d_ff, dtype=self.dtype,
                                variant=cfg.mlp_variant)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kl, kh = jax.random.split(key, 3)
        layer_keys = jax.random.split(kl, cfg.num_layers)
        params = {
            "layers": jax.vmap(self._layer_init)(layer_keys),
            "final_norm": rms_norm_init(cfg.d_model),
            "lm_head": lm_head_init(kh, cfg.d_model, cfg.vocab_size,
                                    self.dtype),
        }
        if cfg.input_mode == "tokens":
            params["embed"] = embed_init(ke, cfg.vocab_size, cfg.d_model,
                                         self.dtype)
        return params

    # ----------------------------------------------------------- core blocks
    def _ffn(self, p_layer, h):
        cfg, ctx = self.cfg, self.ctx
        if cfg.family != "moe":
            return mlp(p_layer["mlp"], h), jnp.zeros((), jnp.float32)
        if ctx.mesh is not None:
            y, aux = moe_apply_sharded(p_layer["moe"], h, cfg, ctx.mesh,
                                       ctx.batch_axes, ctx.model_axis)
        else:
            y, aux = moe_apply_local(p_layer["moe"], h, cfg)
        if "shared_mlp" in p_layer:
            y = y + mlp(p_layer["shared_mlp"], h)
        if "dense_mlp" in p_layer:
            y = y + mlp(p_layer["dense_mlp"], h)
        return y, aux

    def _rope(self, q, k, positions):
        cfg = self.cfg
        if cfg.mrope:
            return (apply_mrope(q, positions, cfg.rope_theta),
                    apply_mrope(k, positions, cfg.rope_theta))
        return (apply_rope(q, positions, cfg.rope_theta),
                apply_rope(k, positions, cfg.rope_theta))

    def _block_seq(self, p_layer, x, positions):
        """Full-sequence block (train / prefill). Returns (x, aux, (k, v))."""
        cfg, ctx = self.cfg, self.ctx
        h = rms_norm(p_layer["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(p_layer["attn"], h)
        q, k = self._rope(q, k, positions)
        y = mix_sequence(cfg, q, k, v, causal=True)
        y = out_project(p_layer["attn"], y)
        x = ctx.constrain(x + y, P(ctx.batch_spec_entry(), None, None))
        h = rms_norm(p_layer["ln2"], x, cfg.norm_eps)
        f, aux = self._ffn(p_layer, h)
        x = ctx.constrain(x + f, P(ctx.batch_spec_entry(), None, None))
        return x, aux, (k, v)

    def _run_layers(self, params, x, positions, *, collect_cache: bool):
        cfg = self.cfg

        def body(carry, p_layer):
            xc, aux = carry
            xc, a, kv = self._block_seq(p_layer, xc, positions)
            out = kv if collect_cache else None
            return (xc, aux + a), out

        body = remat_wrap(body, cfg)
        (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                     params["layers"])
        return x, aux, kvs

    # ------------------------------------------------------------------ train
    def loss(self, params, batch):
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x, aux, _ = self._run_layers(params, x, positions, collect_cache=False)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        ce = cross_entropy_chunked(x, params["lm_head"], batch["targets"])
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        if cfg.input_mode == "tokens":
            x = params["embed"][batch["tokens"]]
            B, S = batch["tokens"].shape
        else:
            x = batch["embeds"].astype(self.dtype)
            B, S = x.shape[0], x.shape[1]
        if cfg.mrope:
            positions = batch["positions"]  # (3, B, S)
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = self.ctx.constrain(x, P(self.ctx.batch_spec_entry(), None, None))
        return x, positions

    # ---------------------------------------------------------------- serving
    def init_cache(self, batch_size: int, max_len: int) -> KVCache:
        cfg = self.cfg
        shape = (cfg.num_layers, batch_size, max_len, cfg.num_kv_heads,
                 cfg.resolved_head_dim)
        return KVCache(
            k=jnp.zeros(shape, jnp.uint16),
            v=jnp.zeros(shape, jnp.uint16),
            index=jnp.zeros((), jnp.int32),
        )

    def prefill(self, params, batch, max_len: Optional[int] = None
                ) -> tuple[jax.Array, KVCache]:
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        S = x.shape[1]
        x, _, kvs = self._run_layers(params, x, positions, collect_cache=True)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_for_tokens(x[:, -1:], params["lm_head"])
        k, v = kvs
        if max_len is not None and max_len > S:
            pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache = KVCache(k=kv_to_bits(k), v=kv_to_bits(v),
                        index=jnp.asarray(S, jnp.int32))
        return logits, cache

    def decode_step(self, params, batch, cache: KVCache
                    ) -> tuple[jax.Array, KVCache]:
        """One token for every sequence.  batch: {"tokens": (B,1)} or embeds."""
        cfg, ctx = self.cfg, self.ctx
        if cfg.input_mode == "tokens":
            x = params["embed"][batch["tokens"]]
            B = batch["tokens"].shape[0]
        else:
            x = batch["embeds"].astype(self.dtype)
            B = x.shape[0]
        if cfg.mrope:
            positions = batch["positions"]  # (3, B, 1)
        else:
            positions = jnp.broadcast_to(cache.index[None, None], (B, 1))
        idx = cache.index

        # Memory discipline (measured on qwen1.5-4b decode_32k, 22.5 → 8.9
        # GiB/device):
        #  * the cache rides the scan as *uint16* xs — integer buffers are
        #    immune to backend float normalization (a bf16 cache in a while
        #    loop gets shadowed in f32), and the loop structure forces
        #    per-layer liveness of the upcast slices;
        #  * reads are immutable — the new token's own K/V folds into the
        #    online softmax (self_kv) — so there is no ys cache stack;
        #  * the write-back is a single uint16 DUS after the loop (pure data
        #    movement: in-place with donation).
        def body(xc, inputs):
            p_layer, k_bits, v_bits = inputs
            h = rms_norm(p_layer["ln1"], xc, cfg.norm_eps)
            q, k, v = qkv_project(p_layer["attn"], h)
            q, k = self._rope(q, k, positions)
            y = decode_attention(q, kv_from_bits(k_bits),
                                 kv_from_bits(v_bits), idx, self_kv=(k, v))
            y = out_project(p_layer["attn"], y)
            xc = xc + y
            h = rms_norm(p_layer["ln2"], xc, cfg.norm_eps)
            f, _ = self._ffn(p_layer, h)
            return xc + f, (k, v)

        x, (k_out, v_out) = jax.lax.scan(
            body, x, (params["layers"], cache.k, cache.v))
        k_steps = kv_to_bits(k_out.astype(jnp.bfloat16))
        v_steps = kv_to_bits(v_out.astype(jnp.bfloat16))
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_for_tokens(x, params["lm_head"])
        zero = jnp.zeros((), jnp.int32)
        # uint16 DUS: pure data movement — in-place with donation, immune to
        # backend float normalization
        k_new = jax.lax.dynamic_update_slice(
            cache.k, k_steps, (zero, zero, idx, zero, zero))
        v_new = jax.lax.dynamic_update_slice(
            cache.v, v_steps, (zero, zero, idx, zero, zero))
        return logits, KVCache(k=k_new, v=v_new, index=idx + 1)
