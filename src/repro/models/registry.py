"""Model factory + abstract input specs for every (arch × shape) cell."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ParallelContext
from repro.models.config import SHAPES, ModelConfig, ShapeCell
from repro.models.encdec import EncDecLM
from repro.models.hybrid_lm import HybridLM
from repro.models.lm import TransformerLM
from repro.models.mamba_lm import MambaLM

_FAMILY_CLS = {
    "dense": TransformerLM,
    "moe": TransformerLM,
    "vlm": TransformerLM,
    "ssm": MambaLM,
    "hybrid": HybridLM,
    "encdec": EncDecLM,
}


def build_model(cfg: ModelConfig, ctx: Optional[ParallelContext] = None):
    return _FAMILY_CLS[cfg.family](cfg, ctx)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for the step inputs of one cell.

    ``train``/``prefill`` specs feed loss/prefill; ``decode`` specs feed
    decode_step and include the KV/SSM cache at full sequence length
    (obtained via jax.eval_shape on init_cache — no allocation).
    """
    B, S = cell.global_batch, cell.seq_len
    D = cfg.d_model
    bf16, i32 = jnp.bfloat16, jnp.int32

    if cell.kind in ("train", "prefill"):
        batch = {}
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, S, D), bf16)
            batch["tokens"] = _sds((B, S), i32)
        elif cfg.input_mode == "embeddings":
            batch["embeds"] = _sds((B, S, D), bf16)
            if cfg.mrope:
                batch["positions"] = _sds((3, B, S), i32)
        else:
            batch["tokens"] = _sds((B, S), i32)
        if cell.kind == "train":
            batch["targets"] = _sds((B, S), i32)
        return {"batch": batch}

    # decode: one new token against a cache of length S
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = _sds((B, 1, D), bf16)
        if cfg.mrope:
            batch["positions"] = _sds((3, B, 1), i32)
    else:
        batch["tokens"] = _sds((B, 1), i32)
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {"batch": batch, "cache": cache}


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs via eval_shape — no allocation."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))
