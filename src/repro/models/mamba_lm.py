"""Mamba2 (attention-free SSM) language model — mamba2-780m."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.norms import rms_norm, rms_norm_init
from repro.layers.ssm import (
    SSMCache,
    dims_from_cfg,
    mamba_block,
    mamba_block_decode,
    ssm_init,
    ssm_init_cache,
)
from repro.models.base import (
    ParallelContext,
    cross_entropy_chunked,
    embed_init,
    lm_head_init,
    logits_for_tokens,
    remat_wrap,
)
from repro.models.config import ModelConfig


class MambaCache(NamedTuple):
    conv: jax.Array  # (L, B, W-1, C)
    state: jax.Array  # (L, B, H, P, N) fp32
    index: jax.Array  # scalar int32 (for API parity; recurrence is O(1))


class MambaLM:
    def __init__(self, cfg: ModelConfig, ctx: Optional[ParallelContext] = None):
        self.cfg = cfg
        self.ctx = ctx or ParallelContext()
        self.dims = dims_from_cfg(cfg)
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def _layer_init(self, key) -> dict:
        return {
            "ln": rms_norm_init(self.cfg.d_model),
            "ssm": ssm_init(key, self.dims, dtype=self.dtype),
        }

    def init(self, key) -> dict:
        ke, kl, kh = jax.random.split(key, 3)
        layer_keys = jax.random.split(kl, self.cfg.num_layers)
        return {
            "embed": embed_init(ke, self.cfg.vocab_size, self.cfg.d_model,
                                self.dtype),
            "layers": jax.vmap(self._layer_init)(layer_keys),
            "final_norm": rms_norm_init(self.cfg.d_model),
            "lm_head": lm_head_init(kh, self.cfg.d_model, self.cfg.vocab_size,
                                    self.dtype),
        }

    def _run_layers(self, params, x, *, collect_cache: bool = False):
        cfg, ctx = self.cfg, self.ctx
        impl = "pallas" if cfg.attn_impl == "pallas" else "chunked"

        def body(xc, p_layer):
            h = rms_norm(p_layer["ln"], xc, cfg.norm_eps)
            if collect_cache:
                y, cache = mamba_block(p_layer["ssm"], self.dims, h,
                                       norm_eps=cfg.norm_eps, impl=impl,
                                       return_cache=True)
            else:
                y = mamba_block(p_layer["ssm"], self.dims, h,
                                norm_eps=cfg.norm_eps, impl=impl)
                cache = None
            xc = ctx.constrain(xc + y, P(ctx.batch_spec_entry(), None, None))
            return xc, cache

        body = remat_wrap(body, cfg)
        x, caches = jax.lax.scan(body, x, params["layers"])
        return (x, caches) if collect_cache else x

    def loss(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        x = self.ctx.constrain(x, P(self.ctx.batch_spec_entry(), None, None))
        x = self._run_layers(params, x)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        ce = cross_entropy_chunked(x, params["lm_head"], batch["targets"])
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    def init_cache(self, batch_size: int, max_len: int) -> MambaCache:
        del max_len  # O(1) state
        d = self.dims
        c = ssm_init_cache(d, batch_size, self.dtype)
        L = self.cfg.num_layers
        return MambaCache(
            conv=jnp.broadcast_to(c.conv[None], (L,) + c.conv.shape).copy(),
            state=jnp.broadcast_to(c.state[None], (L,) + c.state.shape).copy(),
            index=jnp.zeros((), jnp.int32),
        )

    def prefill(self, params, batch, max_len=None) -> tuple[jax.Array, MambaCache]:
        """Prefill: run the sequence, emitting each layer's terminal
        recurrent state + conv window as the decode cache (O(1) size, so
        ``max_len`` is ignored)."""
        del max_len
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        B, S = batch["tokens"].shape
        x, caches = self._run_layers(params, x, collect_cache=True)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_for_tokens(x[:, -1:], params["lm_head"])
        cache = MambaCache(conv=caches.conv, state=caches.state,
                           index=jnp.asarray(S, jnp.int32))
        return logits, cache

    def decode_step(self, params, batch, cache: MambaCache
                    ) -> tuple[jax.Array, MambaCache]:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]  # (B, 1, D)

        def body(xc, inputs):
            p_layer, conv_l, state_l = inputs
            h = rms_norm(p_layer["ln"], xc, cfg.norm_eps)
            y, new_c = mamba_block_decode(
                p_layer["ssm"], self.dims, h,
                SSMCache(conv=conv_l, state=state_l), norm_eps=cfg.norm_eps,
            )
            return xc + y, (new_c.conv, new_c.state)

        x, (conv_new, state_new) = jax.lax.scan(
            body, x, (params["layers"], cache.conv, cache.state)
        )
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_for_tokens(x, params["lm_head"])
        return logits, MambaCache(conv=conv_new, state=state_new,
                                  index=cache.index + 1)
