"""Shared model plumbing: parallel context, embeddings, chunked CE loss."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """Mesh context threaded through models.

    ``mesh=None`` (default) → single-device: MoE uses the local path and
    sharding constraints are no-ops, so the same model code runs smoke tests
    and the production dry-run.
    """

    mesh: Optional[object] = None
    batch_axes: tuple = ("data",)
    model_axis: str = "model"

    def constrain(self, x: jax.Array, spec) -> jax.Array:
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding

        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    def batch_spec_entry(self):
        from jax.sharding import PartitionSpec as P

        return self.batch_axes if self.mesh is not None else None


def embed_init(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model)) * (1.0 / math.sqrt(d_model))
            ).astype(dtype)


def lm_head_init(key, d_model: int, vocab: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (d_model, vocab)) * (1.0 / math.sqrt(d_model))
            ).astype(dtype)


def cross_entropy_chunked(x: jax.Array, lm_head: jax.Array,
                          targets: jax.Array, *, num_chunks: int = 16,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Sequence-chunked CE: never materializes the full (B, S, V) logits.

    x (B, S, D); lm_head (D, V); targets (B, S) int32.  Chunks slice the
    *sequence* axis so the batch axis keeps its DP sharding (slicing the
    flattened token axis would cut across data shards and force GSPMD to
    all-gather the activations).  The chunk body is rematerialized in the
    backward pass (jax.checkpoint), so peak memory is one chunk of logits —
    the difference between fitting and OOM at 151k vocab × 1M tokens.
    """
    B, S, D = x.shape
    mask_full = jnp.ones((B, S), jnp.float32) if mask is None else mask
    num_chunks = max(1, min(num_chunks, S))
    while S % num_chunks:
        num_chunks -= 1
    C = S // num_chunks

    @jax.checkpoint
    def chunk_loss(xc, tc, mc):
        logits = jnp.einsum("bsd,dv->bsv", xc, lm_head,
                            preferred_element_type=jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=2)[..., 0]
        return jnp.sum((lse - gold) * mc)

    def body(acc, idx):
        xc = jax.lax.dynamic_slice_in_dim(x, idx * C, C, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, idx * C, C, axis=1)
        mc = jax.lax.dynamic_slice_in_dim(mask_full, idx * C, C, axis=1)
        return acc + chunk_loss(xc, tc, mc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(num_chunks))
    return total / jnp.maximum(jnp.sum(mask_full), 1.0)


def logits_for_tokens(x: jax.Array, lm_head: jax.Array) -> jax.Array:
    """Decode-time logits (small T): plain matmul, fp32."""
    return jnp.einsum("bsd,dv->bsv", x, lm_head,
                      preferred_element_type=jnp.float32)


def remat_wrap(fn, cfg):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    elif cfg.remat_policy == "none":
        policy = None
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)
