"""Model configuration — one dataclass covers all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention options
    qk_norm: bool = False  # qwen3
    qkv_bias: bool = False  # qwen1.5 / qwen2 family
    rope_theta: float = 10_000.0
    mrope: bool = False  # qwen2-vl 3-axis multimodal RoPE
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # hybrid (zamba2): shared attention block applied every `attn_every`
    # mamba layers (weights shared across applications)
    attn_every: int = 0

    # encoder-decoder (whisper)
    num_encoder_layers: int = 0

    # modality frontend: "tokens" (LM) | "embeddings" (audio/vlm stubs)
    input_mode: str = "tokens"

    # MLP
    mlp_variant: str = "swiglu"  # swiglu (3-matrix) | gelu (2-matrix)

    # numerics / perf knobs
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots | none
    optimizer: str = "adamw"  # adamw | adafactor
    attn_impl: str = "chunked"  # chunked | naive | pallas
    attn_chunk_q: int = 512
    attn_chunk_k: int = 1024
    causal_block_skip: bool = False  # perf: skip fully-masked KV blocks
    scan_layers: bool = True
    logits_softcap: float = 0.0

    # sharding knobs (hillclimb targets)
    shard_kv_seq: bool = False  # shard decode KV cache along sequence
    zero1_optimizer_sharding: bool = True  # shard opt state over data axis
    fsdp: bool = False  # additionally shard params over the data axis (ZeRO-3)
    train_accum: int = 1  # microbatch gradient-accumulation steps
    grad_accum_dtype: str = "float32"  # float32 | bfloat16 accumulator

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count N for MODEL_FLOPS = 6·N·D bookkeeping."""
        d, hd = self.d_model, self.resolved_head_dim
        mlp_mats = 3 if self.mlp_variant == "swiglu" else 2
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings and self.input_mode == "tokens":
            n += self.vocab_size * d  # lm head
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            attn = q + kv + o
            per_layer += attn
            if self.family == "moe":
                per_layer += 3 * d * self.moe_d_ff * self.num_experts
                per_layer += 3 * d * self.moe_d_ff * self.num_shared_experts
                per_layer += d * self.num_experts  # router
                if self.dense_residual:
                    per_layer += mlp_mats * d * self.d_ff
            else:
                per_layer += mlp_mats * d * self.d_ff
        elif self.family in ("ssm", "hybrid"):
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            proj_in = d * (2 * di + 2 * ns + nh)
            proj_out = di * d
            per_layer += proj_in + proj_out + (di + 2 * ns) * self.ssm_conv_width
        n += per_layer * self.num_layers
        if self.family == "hybrid" and self.attn_every:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            n += q + kv + o + 3 * d * self.d_ff  # one shared block
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            enc = (d * self.num_heads * hd * 2 + 2 * d * self.num_kv_heads * hd
                   + 3 * d * self.d_ff)
            n += enc * self.num_encoder_layers
            n += (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                  + self.num_heads * hd * d) * self.num_layers  # cross-attn
        return n

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only routed top-k experts."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dead = 3 * d * self.moe_d_ff * (
            self.num_experts - self.num_experts_per_tok
        ) * self.num_layers
        return self.param_count() - dead


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture × input-shape) dry-run cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
