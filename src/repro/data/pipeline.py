"""Deterministic synthetic token pipeline with checkpointable cursor.

Real training jobs need a data path that (a) shards across hosts, (b) is
exactly resumable after preemption (the spot-instance story), and (c) packs
variable-length documents into fixed training sequences.  This pipeline is
all three: batches are a pure function of (seed, step, host_shard), so a
restore from ``state()`` reproduces the exact token stream — tested in
tests/test_data.py.

Documents are synthesized as Zipf-ish token draws with EOS terminators and
greedily packed into seq_len windows (no cross-batch fragmentation state —
the cursor is just the step counter, which is what makes elastic re-sharding
trivial: a new host count re-partitions future steps without replay).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataPipeline:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    eos_id: int = 1
    step: int = 0

    def __post_init__(self):
        if self.global_batch % self.host_count:
            raise ValueError("global_batch must divide across hosts")
        self.host_batch = self.global_batch // self.host_count

    # ------------------------------------------------------------- sampling
    def _batch_for(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))
        B, S = self.host_batch, self.seq_len
        # Zipf-ish marginal over the vocab (heavier head, long tail)
        toks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
        toks = (toks - 1) % (self.vocab_size - 2) + 2  # reserve 0=pad, 1=eos
        # doc packing: terminate docs with EOS at random boundaries
        doc_len = rng.integers(32, max(self.seq_len, 64), size=(B,))
        pos = np.arange(S + 1)[None, :]
        is_eos = (pos % doc_len[:, None]) == (doc_len[:, None] - 1)
        toks = np.where(is_eos, self.eos_id, toks)
        tokens = toks[:, :-1].astype(np.int32)
        targets = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "targets": targets}

    def next(self) -> dict:
        batch = self._batch_for(self.step)
        self.step += 1
        return {k: jnp.asarray(v) for k, v in batch.items()}

    # ----------------------------------------------------------- checkpoint
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed,
                "host_count": self.host_count}

    def restore(self, state: dict, *, host_index: int = None,
                host_count: int = None):
        """Resume; host topology may change (elastic re-shard)."""
        self.step = int(state["step"])
        self.seed = int(state["seed"])
        if host_count is not None and host_count != self.host_count:
            self.host_count = host_count
            self.host_index = host_index or 0
            self.__post_init__()
        return self
