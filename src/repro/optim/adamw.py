"""AdamW with fp32 state, decoupled weight decay, global-norm clipping."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    params_new = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return params_new, AdamWState(m=m_new, v=v_new, count=count)
