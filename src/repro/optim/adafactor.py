"""Adafactor (factored second moment) for the ≥70B configs.

For a matrix parameter (…, R, C) the second moment is stored as row/column
running means (R,) + (C,) instead of the full (R, C) — the state for
arctic-480b drops from 2× fp32 param size to ~1/2000th, which is the
difference between 22 GB/chip (AdamW, does not fit v5e) and ~4 GB/chip.
Momentum is kept in bf16 (beta1 path), vectors fall back to full v.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    m: dict  # bf16 momentum (same shape as params)
    vr: dict  # row second-moment (or full v for rank<2)
    vc: dict  # col second-moment (or unused zeros(1))
    count: jax.Array


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def mk_m(p):
        return jnp.zeros(p.shape, jnp.bfloat16)

    def mk_vr(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def mk_vc(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(
        m=jax.tree.map(mk_m, params),
        vr=jax.tree.map(mk_vr, params),
        vc=jax.tree.map(mk_vc, params),
        count=jnp.zeros((), jnp.int32),
    )


def adafactor_update(grads, state: AdafactorState, params, *, lr,
                     b1: float = 0.9, decay: float = 0.99, eps: float = 1e-30,
                     weight_decay: float = 0.0):
    count = state.count + 1

    def upd(g, m, vr, vc, p):
        gf = g.astype(jnp.float32)
        g2 = jnp.square(gf) + eps
        if _factored(p):
            vr_new = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            vc_new = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            r = vr_new / jnp.maximum(
                jnp.mean(vr_new, axis=-1, keepdims=True), eps)
            precond = gf / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc_new)[..., None, :]
                            + 1e-8)
        else:
            vr_new = decay * vr + (1 - decay) * g2
            vc_new = vc
            precond = gf / (jnp.sqrt(vr_new) + 1e-8)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * precond
        step = m_new + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new.astype(jnp.bfloat16), vr_new, vc_new

    out = jax.tree.map(upd, grads, state.m, state.vr, state.vc, params)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), AdafactorState(m=pick(1), vr=pick(2), vc=pick(3),
                                   count=count)
