"""Optimizers + LR schedule; ``build_optimizer(cfg)`` picks per config."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.optim.adafactor import AdafactorState, adafactor_init, \
    adafactor_update
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, \
    clip_by_global_norm, global_norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, jnp.maximum(cos, 0.1 * base_lr))

    return lr


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (params, state)


def build_optimizer(cfg) -> Optimizer:
    if cfg.optimizer == "adafactor":
        return Optimizer(
            name="adafactor",
            init=adafactor_init,
            update=lambda g, s, p, lr: adafactor_update(g, s, p, lr=lr),
        )
    return Optimizer(
        name="adamw",
        init=adamw_init,
        update=lambda g, s, p, lr: adamw_update(g, s, p, lr=lr),
    )


__all__ = [
    "AdafactorState", "AdamWState", "Optimizer", "adafactor_init",
    "adafactor_update", "adamw_init", "adamw_update", "build_optimizer",
    "clip_by_global_norm", "cosine_schedule", "global_norm",
]
