"""Sharded, async, preemption-aware checkpointing with elastic restore.

Layout: <dir>/step_<N>/
  manifest.msgpack   — step, tree structure, dtypes/shapes, PartitionSpecs,
                       data-pipeline cursor, mesh shape
  <leaf_id>.npy      — one file per leaf (per-host shard in a real cluster;
                       single process here holds the full leaf)

Design points exercised by tests:
  * async save: device_get + file writes happen on a worker thread; training
    continues (``wait()`` joins before the next save or exit).
  * preemption flow: ``SpotOrchestrator`` (repro.cluster) fires an
    advance-notice callback → ``save(..., blocking=True)`` inside the notice
    window → job re-enters the admission queue (the paper's policy decides
    spot-wait vs on-demand).
  * elastic restore: ``restore(..., mesh=new_mesh, specs=...)`` re-shards
    leaves onto a *different* mesh via jax.device_put — DP width can shrink
    or grow between spot allocations.
  * integrity: manifest lists every leaf + sha1; partial checkpoints
    (killed mid-save) are detected and skipped by ``latest_step``.
"""
from __future__ import annotations

import hashlib
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16": ml_dtypes.bfloat16}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """np.save can't store bfloat16 — view as uint16 + record logical dtype."""
    name = str(arr.dtype)
    if name in _EXOTIC:
        return arr.view(np.uint16), name
    return arr, name


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name])
    return arr


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def write():
            items, _ = _flatten(host_tree)
            tmp = os.path.join(self.directory, f".tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": [], "extra": extra or {}}
            for i, (key, leaf) in enumerate(items):
                fn = f"leaf_{i:05d}.npy"
                savable, dtype_name = _to_savable(leaf)
                np.save(os.path.join(tmp, fn), savable)
                manifest["leaves"].append({
                    "key": key, "file": fn, "shape": list(leaf.shape),
                    "dtype": dtype_name,
                    "sha1": hashlib.sha1(leaf.tobytes()).hexdigest()[:16],
                })
            with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
                f.write(msgpack.packb(manifest))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                path = os.path.join(self.directory, name, "manifest.msgpack")
                if os.path.exists(path):  # complete checkpoints only
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, *, mesh=None, specs=None,
                verify: bool = False) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; optional elastic re-shard.

        ``like`` may be concrete or ShapeDtypeStructs; with ``mesh``+``specs``
        every leaf is placed with NamedSharding(mesh, spec) — re-sharding onto
        a different topology than the one that saved it.
        """
        self.wait()
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        leaves = []
        for meta in manifest["leaves"]:
            arr = _from_savable(np.load(os.path.join(d, meta["file"])),
                                meta["dtype"])
            if verify:
                got = hashlib.sha1(arr.tobytes()).hexdigest()[:16]
                if got != meta["sha1"]:
                    raise IOError(f"checksum mismatch for {meta['key']}")
            leaves.append(arr)
        _, treedef = _flatten(jax.tree.map(lambda x: 0, like))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding

            tree = jax.tree.map(
                lambda leaf, spec: jax.device_put(
                    leaf, NamedSharding(mesh, spec)), tree, specs)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return tree, manifest.get("extra", {})
