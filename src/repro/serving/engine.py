"""Batched serving engine with spot/on-demand request dispatch.

Continuous-batching decode over a fixed slot budget, with the paper's
admission controller deciding, per request, whether it queues for the cheap
*spot* decode pool (slots appear stochastically — shared preemptible
capacity) or goes to the dedicated on-demand pool at cost ``k``.

The engine drives a real model (prefill → slot → decode loop), so the same
code path serves the smoke-scale examples and the dry-run-lowered
production shapes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.orchestrator import OnlineAdmissionController
from repro.core.arrivals import ArrivalProcess


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrival_time: float = 0.0
    tokens_out: list = dataclasses.field(default_factory=list)
    pool: str = ""  # "spot" | "ondemand"
    delay: float = 0.0


class BatchedServer:
    """Slot-based continuous batching for one model replica."""

    def __init__(self, model, params, *, max_batch: int, max_len: int):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill, static_argnames=("max_len",))

    def generate(self, prompts: list[np.ndarray], max_new: int) -> list[list[int]]:
        """Greedy-decode a batch of equal-length prompts."""
        B = len(prompts)
        toks = jnp.asarray(np.stack(prompts))
        batch = {"tokens": toks}
        logits, cache = self._prefill(self.params, batch,
                                      max_len=toks.shape[1] + max_new)
        outs = [[] for _ in range(B)]
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for _ in range(max_new):
            for i in range(B):
                outs[i].append(int(cur[i]))
            logits, cache = self._decode(self.params,
                                         {"tokens": cur[:, None]}, cache)
            cur = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return outs


class SpotServingFrontend:
    """Request stream → paper-policy dispatch → spot/on-demand pools."""

    def __init__(self, server: BatchedServer, *,
                 spot_process: ArrivalProcess,
                 controller: OnlineAdmissionController,
                 k_cost: float = 10.0, batch_size: int = 4, seed: int = 0):
        self.server = server
        self.spots = spot_process
        self.ctl = controller
        self.k = k_cost
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []
        self.total_cost = 0.0
        self._t = 0.0

    def _sample_spot(self) -> float:
        key = jax.random.key(int(self.rng.integers(2**31)))
        return float(self.spots.sample(key))

    def submit(self, req: Request, now: float) -> None:
        req.arrival_time = now
        if self.ctl.admit(len(self.queue), self.rng):
            self.queue.append(req)
        else:
            self._serve([req], "ondemand", now)

    def spot_slot(self, now: float) -> None:
        """A spot decode slot became available: serve up to batch_size."""
        if not self.queue:
            return
        batch = []
        while self.queue and len(batch) < self.batch_size:
            batch.append(self.queue.popleft())
        self._serve(batch, "spot", now)

    def _serve(self, reqs: list[Request], pool: str, now: float) -> None:
        prompts = [r.prompt for r in reqs]
        outs = self.server.generate(prompts, reqs[0].max_new_tokens)
        for r, toks in zip(reqs, outs):
            r.tokens_out = toks
            r.pool = pool
            r.delay = now - r.arrival_time
            self.completed.append(r)
            self.total_cost += 1.0 if pool == "spot" else self.k
            self.ctl.on_job_complete(r.delay)

    # ------------------------------------------------------------ simulation
    def run_stream(self, job_process: ArrivalProcess, *, n_requests: int,
                   prompt_len: int, max_new: int, vocab: int) -> dict:
        next_req = 0.0
        next_spot = self._sample_spot()
        rid = 0
        while rid < n_requests:
            if next_req <= next_spot:
                self._t += next_req
                next_spot -= next_req
                key = jax.random.key(int(self.rng.integers(2**31)))
                next_req = float(job_process.sample(key))
                rid += 1
                prompt = self.rng.integers(
                    2, vocab, size=prompt_len).astype(np.int32)
                self.submit(Request(rid, prompt, max_new), self._t)
            else:
                self._t += next_spot
                next_req -= next_spot
                next_spot = self._sample_spot()
                self.spot_slot(self._t)
        # drain
        while self.queue:
            self._t += next_spot
            next_spot = self._sample_spot()
            self.spot_slot(self._t)
        n = max(len(self.completed), 1)
        return {
            "avg_cost": self.total_cost / n,
            "avg_delay": float(np.mean([r.delay for r in self.completed])),
            "spot_fraction": float(np.mean(
                [r.pool == "spot" for r in self.completed])),
            "r_star": self.ctl.r,
            "completed": len(self.completed),
        }
