"""Timing, provenance, and profiler scopes — the host half of ``repro.obs``.

Three small tools every measurement surface in the repo shares:

* :func:`time_compiled` — the bench harness's compile-vs-steady-state
  split (absorbed from ``benchmarks/_timing.py``, which now re-exports
  it).  The first call pays trace + XLA compile + one run; steady state
  is the mean of further calls blocked to completion.
* :func:`provenance` — the audit stamp every ``BENCH_*.json`` carries:
  git commit, jax version, backend/platform, python.  A BENCH number
  without its commit and backend is unfalsifiable; with them the BENCH
  trajectory across PRs is a real measurement series.
* :func:`annotate` — named ``jax.profiler`` trace scopes on the engine
  entry points, the adaptive learner, and the orchestrator's what-if
  sweeps, so an ``xprof``/``perfetto`` capture of a sweep attributes
  device time to the loop that spent it.  Compiles to nothing when no
  profiler is attached; falls back to a null context where the profiler
  API is unavailable (minimal CPU wheels).
"""
from __future__ import annotations

import contextlib
import platform as _platform
import subprocess
import sys
import time

import jax


def time_compiled(call, *, runs: int = 1):
    """Time ``call`` (a 0-arg closure returning a pytree) compile + steady.

    Returns ``(result, timing)`` with ``timing = {"t_first_s", "t_run_s",
    "t_compile_s"}``: the first call pays trace + compile + one run; the
    steady-state number is the mean of ``runs`` further calls, each blocked
    to completion.  ``t_compile_s`` is the difference, floored at zero.
    """
    t0 = time.perf_counter()
    out = jax.block_until_ready(call())
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(runs):
        out = jax.block_until_ready(call())
    t_run = (time.perf_counter() - t0) / runs
    return out, {"t_first_s": t_first, "t_run_s": t_run,
                 "t_compile_s": max(t_first - t_run, 0.0)}


def _git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, check=True).stdout.strip()
    except Exception:  # no git / not a checkout — the stamp still works
        return "unknown"


def provenance(**extra) -> dict:
    """The measurement-audit stamp for BENCH jsons (and anything else).

    Keyword args are merged in verbatim — benches pass ``seed=`` and
    ``telemetry=`` so a BENCH file records the exact configuration that
    produced its numbers.
    """
    stamp = {
        "git_commit": _git_commit(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "platform": _platform.platform(),
        "python": sys.version.split()[0],
    }
    stamp.update(extra)
    return stamp


def annotate(name: str):
    """A named profiler trace scope (``with annotate("run_sweep"): ...``).

    Uses ``jax.profiler.TraceAnnotation`` when available so the scope
    shows up on the device timeline of a profiler capture; otherwise a
    null context.  Zero overhead when no profiler session is active.
    """
    trace_annotation = getattr(jax.profiler, "TraceAnnotation", None)
    if trace_annotation is None:
        return contextlib.nullcontext()
    return trace_annotation(name)
