"""Event-trace capture and Chrome/Perfetto export.

Two producers, one format:

* **Device rings** — with ``Telemetry(trace_cap=K)`` the engine's event
  bodies record every merged event into a bounded per-window ring
  (:mod:`repro.obs.stats`).  ``summarize*(..., telemetry=...)`` returns
  the stacked rings under ``telemetry["trace"]``;
  :func:`device_trace_records` re-times them onto one global clock
  (window starts come from the base ``time_elapsed`` windows) and
  :func:`to_perfetto` turns records into Chrome trace JSON.
* **Host loops** — :class:`TraceRecorder` is the same record stream
  hand-fed from :mod:`repro.cluster.orchestrator`'s python event loops,
  so a cluster replay and a device sim export the identical schema.

The export is the classic Chrome ``traceEvents`` array (what
``ui.perfetto.dev`` and ``chrome://tracing`` both load): one instant
event (``"ph": "i"``) per sim event on a per-location track, plus a
``"ph": "C"`` counter track for queue length.  Sim time (hours) maps to
trace microseconds 1:1e6 so zooming works at event granularity.
``tools/trace_export.py`` is the CLI wrapper; ``tools/check_trace.py``
validates the schema in CI.
"""
from __future__ import annotations

import json
from typing import Iterable

import numpy as np

from .stats import EVENT_TYPES

#: Perfetto track (tid) per event type keeps the instant events readable.
_TYPE_TID = {name: i + 1 for i, name in enumerate(EVENT_TYPES)}
_QLEN_TID = len(EVENT_TYPES) + 1


def device_trace_records(trace: dict, time_windows, *,
                         lane: int = 0) -> list[dict]:
    """Flatten one lane's stacked window rings into global-time records.

    ``trace`` is ``telemetry["trace"]`` from a ``summarize*`` call: each
    field is ``(..., n_windows, cap)`` (``n`` is ``(..., n_windows)``).
    ``time_windows`` is the matching per-window ``time_elapsed`` stack —
    window k's records are offset by the duration of windows < k.  Rings
    wrap at ``cap``; wrapped (overwritten) slots are skipped and counted
    in the ``dropped`` field of the first record of that window.
    """
    def _lane(x):
        x = np.asarray(x)
        return x.reshape((-1,) + x.shape[-2:])[lane] if x.ndim > 2 else x

    t = _lane(trace["t"])
    ev_type = _lane(trace["type"])
    loc = _lane(trace["loc"])
    qlen = _lane(trace["qlen"])
    val = _lane(trace["val"])
    n = np.asarray(trace["n"]).reshape(-1, t.shape[0])[lane] \
        if np.asarray(trace["n"]).ndim > 1 else np.asarray(trace["n"])
    tw = np.asarray(time_windows, np.float64)
    tw = tw.reshape(-1, tw.shape[-1])[lane] if tw.ndim > 1 else tw
    starts = np.concatenate([[0.0], np.cumsum(tw)[:-1]])

    cap = t.shape[-1]
    records: list[dict] = []
    for w in range(t.shape[0]):
        kept = int(min(n[w], cap))
        dropped = int(max(n[w] - cap, 0))
        # on wrap the ring holds the LAST cap records, starting at n % cap
        order = (np.arange(kept) + (int(n[w]) % cap if dropped else 0)) % cap
        for j in order:
            rec = {
                "t": float(starts[w] + t[w, j]),
                "type": EVENT_TYPES[int(ev_type[w, j])],
                "loc": int(loc[w, j]),
                "qlen": int(qlen[w, j]),
            }
            if val[w, j] >= 0.0:
                rec["wait"] = float(val[w, j])
            records.append(rec)
        if dropped and records:
            records[-kept]["dropped"] = dropped
    return records


class TraceRecorder:
    """Host-side record stream — the orchestrator's per-event tap.

    ``record(t, type, loc, qlen, **fields)`` appends one record; bounded
    by ``cap`` (drops are counted, mirroring the device ring contract).
    """

    def __init__(self, cap: int = 100_000):
        self.cap = cap
        self.records: list[dict] = []
        self.dropped = 0

    def record(self, t: float, type: str, loc: int = 0, qlen: int = 0,
               **fields) -> None:
        if len(self.records) >= self.cap:
            self.dropped += 1
            return
        rec = {"t": float(t), "type": type, "loc": int(loc),
               "qlen": int(qlen)}
        rec.update(fields)
        self.records.append(rec)


def to_perfetto(records: Iterable[dict], *, pid: int = 1,
                label: str = "sim") -> dict:
    """Chrome/Perfetto ``traceEvents`` JSON from a record stream.

    One instant event per record on the event-type's track; a queue-
    length counter track alongside.  Sim hours → trace µs at 1:1e6.
    """
    events: list[dict] = [
        {"ph": "M", "pid": pid, "name": "process_name",
         "args": {"name": label}},
        {"ph": "M", "pid": pid, "tid": _QLEN_TID, "name": "thread_name",
         "args": {"name": "queue length"}},
    ]
    for name, tid in _TYPE_TID.items():
        events.append({"ph": "M", "pid": pid, "tid": tid,
                       "name": "thread_name", "args": {"name": name}})
    for rec in records:
        ts = rec["t"] * 1e6
        args = {"loc": rec["loc"], "qlen": rec["qlen"]}
        for key in ("wait", "dropped"):
            if key in rec:
                args[key] = rec[key]
        events.append({
            "ph": "i", "s": "t", "pid": pid,
            "tid": _TYPE_TID.get(rec["type"], len(_TYPE_TID) + 2),
            "ts": ts, "name": f"{rec['type']}@{rec['loc']}", "args": args,
        })
        events.append({
            "ph": "C", "pid": pid, "tid": _QLEN_TID, "ts": ts,
            "name": "qlen", "args": {"jobs": rec["qlen"]},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_perfetto(path: str, records: Iterable[dict], **kwargs) -> None:
    with open(path, "w") as f:
        json.dump(to_perfetto(records, **kwargs), f)
