"""On-device streaming telemetry: histograms, quantile sketches, counters.

The engine's window stats answer "what did it cost *on average*" — the
paper's delay constraint, and any SLO a real service quotes, needs the
*tail*: P50/P99 wait, per-pool preemption rates, defect rates.  This
module is the accumulator layer of the ``telemetry=`` engine axis
(:mod:`repro.core.engine`): a :class:`Telemetry` descriptor (static,
hashable — a jit cache key exactly like ``impl=``/``rng=``) plus a
:class:`TelemetryWindowStats` pytree that rides NEXT TO the engine's
existing ``WindowStats`` through every executor — the same float32 window
blocks, re-zeroed per chunk, stacked on the host.  ``telemetry=None``
never constructs any of this, so the compiled program is *identical* to
today's (the zero-cost-off contract, frozen in tests/test_obs.py).

Quantile sketch
---------------
Waits and costs accumulate into **log-spaced fixed-bin histograms**
(DDSketch-style: bin ``i`` covers ``[lo·γ^(i-1), lo·γ^i)`` with
``γ = (hi/lo)^(1/(n_bins-2))``, plus an underflow and an overflow bin).
A log-binned histogram is a mergeable quantile sketch with *bounded
relative error*: any quantile read off the cumulative counts is within
one bin of the truth, i.e. within a factor ``γ`` of the exact empirical
quantile — ``γ − 1`` ≈ 9% at the 64-bin default over six decades.
Merging across windows / seeds / shards is integer addition, which is
exactly what the sharded-sweep direction in ROADMAP.md needs.  Accuracy
is pinned in tests/test_obs.py against exact empirical quantiles
recovered from the event trace.

Counters
--------
Scalar per-window event counters close the visibility gaps the base
stats leave: ``preempts_fired`` counts hazard-clock firings (the base
``preemptions`` only counts *hits* on occupied pools), ``rejects``
splits admission rejections out of ``ondemand``, ``deadline_defects``
splits budget expiries, ``notices_honored`` mirrors ``resumed``.  The
``events`` vector counts merged events by type (job/spot/preempt/
deadline), and ``loc_defects``/``loc_resumed`` resolve defects and
recoveries per pool/region — the per-location defect-rate view.

Event trace
-----------
With ``trace_cap > 0`` a bounded per-lane ring buffer records every
merged event as ``(t, type, loc, qlen, val)`` — within-window time,
event-type code, pool/region index, post-event queue length, and the
wait sample (−1 when the event observed none).  The ring is drained per
window (it lives in the stats pytree, which the executors re-zero and
stack per chunk); :mod:`repro.obs.trace` turns the stacked windows into
Chrome/Perfetto trace JSON.  Records wrap at ``trace_cap`` per window —
``n`` keeps the true count so the exporter can report drops.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

#: Merged-event type codes (the ``events`` counter axis and the trace
#: ``type`` field).  Order matches the engine's tie-break priority.
EVENT_TYPES = ("job", "spot", "preempt", "deadline")
EV_JOB, EV_SPOT, EV_PREEMPT, EV_DEADLINE = range(4)


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Static telemetry descriptor — the ``telemetry=`` engine axis.

    Hashable (frozen dataclass), so it is a jit static argument exactly
    like the ``impl=``/``rng=`` axes.  ``n_bins`` log-spaced bins span
    ``[lo, hi)`` per histogram (first bin = underflow, last = overflow);
    the relative quantile error is ``γ − 1`` with
    ``γ = (hi/lo)^(1/(n_bins-2))``.  ``trace_cap`` > 0 additionally
    records a bounded per-lane, per-window event ring (see the module
    docstring); 0 keeps tracing compiled out.
    """

    n_bins: int = 64
    wait_lo: float = 1e-2
    wait_hi: float = 1e4
    cost_lo: float = 1e-2
    cost_hi: float = 1e3
    trace_cap: int = 0

    def wait_edges(self) -> np.ndarray:
        return _edges(self.wait_lo, self.wait_hi, self.n_bins)

    def cost_edges(self) -> np.ndarray:
        return _edges(self.cost_lo, self.cost_hi, self.n_bins)

    def rel_error(self) -> float:
        """The sketch's worst-case relative quantile error (γ − 1)."""
        gamma = (self.wait_hi / self.wait_lo) ** (1.0 / (self.n_bins - 2))
        return gamma - 1.0


class TelemetryWindowStats(NamedTuple):
    """Per-window telemetry accumulators (int32 counts, float32 rings).

    Rides next to the engine's base window stats as ``(base, telemetry)``
    — every executor (xla scan, pallas kernel, ref oracle) is generic
    over the stats pytree, so the pair threads through with zero
    executor changes.  Ring fields are ``None`` when tracing is off
    (``jax.tree`` drops ``None`` subtrees, so the compiled program
    carries no trace machinery at all).
    """

    wait_hist: jax.Array  # (n_bins,) i32 — wait samples, log-binned
    cost_hist: jax.Array  # (n_bins,) i32 — per-event cost increments
    events: jax.Array  # (4,) i32 — merged events by type code
    spot_starts: jax.Array  # () i32 — spot legs started (= served)
    preempts_fired: jax.Array  # () i32 — hazard clock firings (incl. idle)
    notices_honored: jax.Array  # () i32 — preempted legs that resumed
    deadline_defects: jax.Array  # () i32 — wait-budget expiries
    rejects: jax.Array  # () i32 — admission rejections (immediate OD)
    loc_defects: jax.Array  # (n_locs,) i32 — deadline defects per pool/region
    loc_resumed: jax.Array  # (n_locs,) i32 — notices honored per pool/region
    ring_t: jax.Array | None  # (cap,) f32 within-window event time
    ring_type: jax.Array | None  # (cap,) i32 event-type code
    ring_loc: jax.Array | None  # (cap,) i32 pool/region index
    ring_qlen: jax.Array | None  # (cap,) i32 post-event total queue length
    ring_val: jax.Array | None  # (cap,) f32 wait sample (-1 = none)
    ring_n: jax.Array | None  # () i32 true record count (ring wraps)


def telemetry_zeros(tel: Telemetry, n_locs: int) -> TelemetryWindowStats:
    """Unbatched zero accumulators for one window (cf. WindowStats.zeros)."""
    zi = jnp.zeros((), jnp.int32)
    zb = jnp.zeros((tel.n_bins,), jnp.int32)
    zl = jnp.zeros((n_locs,), jnp.int32)
    if tel.trace_cap:
        # all-zero (NOT sentinel-filled): every executor re-zeros window
        # accumulators with literal zeros, so any other fill would break
        # the pallas == ref == xla ledger.  Unwritten ring slots are never
        # exported (the drain reads min(n, cap) records).
        cap = tel.trace_cap
        ring = (jnp.zeros((cap,), jnp.float32), jnp.zeros((cap,), jnp.int32),
                jnp.zeros((cap,), jnp.int32), jnp.zeros((cap,), jnp.int32),
                jnp.zeros((cap,), jnp.float32), zi)
    else:
        ring = (None,) * 6
    return TelemetryWindowStats(zb, zb, jnp.zeros((4,), jnp.int32),
                                zi, zi, zi, zi, zi, zl, zl, *ring)


def _edges(lo: float, hi: float, n_bins: int) -> np.ndarray:
    """Host-side bin edges: [0, lo·γ⁰, …, lo·γ^(n_bins-2), inf]."""
    interior = lo * ((hi / lo) ** (np.arange(n_bins - 1)
                                   / (n_bins - 2))).astype(np.float64)
    return np.concatenate([[0.0], interior, [np.inf]])


def hist_bin(x: jax.Array, lo: float, hi: float, n_bins: int) -> jax.Array:
    """Traced log-spaced bin index of ``x`` (clamped; bin 0 underflow,
    bin ``n_bins-1`` overflow).  All constants are np scalars so the
    expression stays capture-free under the Pallas kernel trace."""
    log_lo = np.float32(np.log(lo))
    inv_log_gamma = np.float32((n_bins - 2) / np.log(hi / lo))
    safe = jnp.maximum(x, np.float32(1e-30))
    raw = (jnp.log(safe) - log_lo) * inv_log_gamma
    idx = jnp.floor(raw).astype(jnp.int32) + 1
    return jnp.clip(idx, 0, np.int32(n_bins - 1))


def _hist_add(hist: jax.Array, x: jax.Array, valid: jax.Array,
              lo: float, hi: float, n_bins: int) -> jax.Array:
    """One-hot histogram increment (dense select — the engine's idiom)."""
    b = hist_bin(x, lo, hi, n_bins)
    iota = jax.lax.iota(jnp.int32, n_bins)
    return hist + ((iota == b) & valid).astype(jnp.int32)


def telemetry_update(tel: Telemetry, ts: TelemetryWindowStats, *,
                     t: jax.Array, is_job: jax.Array, is_spot: jax.Array,
                     is_pre: jax.Array, is_deadline: jax.Array,
                     served: jax.Array, resume: jax.Array,
                     defected: jax.Array, od_now: jax.Array,
                     wait_sample: jax.Array, wait_valid: jax.Array,
                     cost_inc: jax.Array, cost_valid: jax.Array,
                     loc: jax.Array, n_locs: int,
                     qlen: jax.Array) -> TelemetryWindowStats:
    """Fold one merged event into the telemetry accumulators.

    Called from the engine event bodies ONLY under ``telemetry=``; every
    argument is a local the body already computed, so the update is a
    pure appendage — the base stats expressions are untouched (the
    primary-stats-bitwise contract).  ``loc`` is the event's pool/region
    locus (0 for the single-queue loop); ``t`` is the post-event
    within-window time; ``qlen`` the post-event total queue length.
    """
    iota4 = jax.lax.iota(jnp.int32, 4)
    ev_type = jnp.where(is_spot, EV_SPOT,
                        jnp.where(is_pre, EV_PREEMPT,
                                  jnp.where(is_deadline, EV_DEADLINE,
                                            EV_JOB))).astype(jnp.int32)
    iota_l = jax.lax.iota(jnp.int32, n_locs)
    loc_hit = iota_l == loc
    out = ts._replace(
        wait_hist=_hist_add(ts.wait_hist, wait_sample, wait_valid,
                            tel.wait_lo, tel.wait_hi, tel.n_bins),
        cost_hist=_hist_add(ts.cost_hist, cost_inc, cost_valid,
                            tel.cost_lo, tel.cost_hi, tel.n_bins),
        events=ts.events + (iota4 == ev_type).astype(jnp.int32),
        spot_starts=ts.spot_starts + served.astype(jnp.int32),
        preempts_fired=ts.preempts_fired + is_pre.astype(jnp.int32),
        notices_honored=ts.notices_honored + resume.astype(jnp.int32),
        deadline_defects=ts.deadline_defects + defected.astype(jnp.int32),
        rejects=ts.rejects + od_now.astype(jnp.int32),
        loc_defects=ts.loc_defects + (defected & loc_hit).astype(jnp.int32),
        loc_resumed=ts.loc_resumed + (resume & loc_hit).astype(jnp.int32),
    )
    if not tel.trace_cap:
        return out
    cap = tel.trace_cap
    iota_c = jax.lax.iota(jnp.int32, cap)
    slot = jnp.mod(ts.ring_n, np.int32(cap))
    hit = iota_c == slot
    val = jnp.where(wait_valid, wait_sample, np.float32(-1.0))
    return out._replace(
        ring_t=jnp.where(hit, t, ts.ring_t),
        ring_type=jnp.where(hit, ev_type, ts.ring_type),
        ring_loc=jnp.where(hit, jnp.asarray(loc, jnp.int32), ts.ring_loc),
        ring_qlen=jnp.where(hit, jnp.asarray(qlen, jnp.int32), ts.ring_qlen),
        ring_val=jnp.where(hit, val, ts.ring_val),
        ring_n=ts.ring_n + 1,
    )


_TRACE_FIELDS = ("ring_t", "ring_type", "ring_loc", "ring_qlen", "ring_val",
                 "ring_n")
#: Telemetry statistics carrying a trailing per-bin / per-type /
#: per-location axis in summaries (everything else is scalar per lane).
TEL_VECTOR_STATS = frozenset({"wait_hist", "cost_hist", "events",
                              "loc_defects", "loc_resumed"})
#: Integer telemetry statistics — event *decisions*, bitwise across
#: executors just like engine INT_STATS.  Histogram counts are excluded:
#: binning a float wait that differs by an ulp between batch layouts can
#: flip a boundary bin, so hists get the pallas==ref bitwise contract
#: only (see tests/test_obs.py).
TEL_INT_STATS = ("events", "spot_starts", "preempts_fired",
                 "notices_honored", "deadline_defects", "rejects",
                 "loc_defects", "loc_resumed")


_COUNTER_FIELDS = tuple(f for f in TelemetryWindowStats._fields
                        if f not in _TRACE_FIELDS)


def _check_no_rings(name: str, *blocks: TelemetryWindowStats) -> None:
    for ts in blocks:
        if any(getattr(ts, f) is not None for f in _TRACE_FIELDS):
            raise ValueError(
                f"{name}: trace rings are per-lane drains, not additive — "
                f"export them first (repro.obs.trace) and merge only the "
                f"histogram/counter block (ring fields must be None)")


def telemetry_merge(a: TelemetryWindowStats,
                    b: TelemetryWindowStats) -> TelemetryWindowStats:
    """Merge two telemetry accumulator blocks by integer addition.

    The shard-merge entry point: histograms and counters are int32 event
    counts, so merging lane partitions / shards / windows is exact —
    associative, commutative, and partition-invariant (the property tests
    in tests/test_fleet.py pin all three).  Works on numpy and jax
    arrays alike.  Trace rings are NOT mergeable (bounded per-lane
    drains); blocks carrying rings are rejected with the fix.
    """
    _check_no_rings("telemetry_merge", a, b)
    return TelemetryWindowStats(
        *(getattr(a, f) + getattr(b, f) for f in _COUNTER_FIELDS),
        *(None,) * len(_TRACE_FIELDS))


def telemetry_reduce(ts: TelemetryWindowStats,
                     axis: int = 0) -> TelemetryWindowStats:
    """Collapse one batch axis (lanes, shards, seeds, or stacked windows)
    of a telemetry block by integer addition — the n-way form of
    :func:`telemetry_merge`, e.g. reducing per-lane accumulators to one
    fleet-wide sketch before a :func:`sketch_quantile` read
    (docs/scaling.md shows the cross-shard P99 read)."""
    _check_no_rings("telemetry_reduce", ts)
    return TelemetryWindowStats(
        *(getattr(ts, f).sum(axis=axis) for f in _COUNTER_FIELDS),
        *(None,) * len(_TRACE_FIELDS))


def sketch_quantile(hist: np.ndarray, edges: np.ndarray,
                    q: float) -> np.ndarray:
    """Quantile estimate from (…, n_bins) log-binned counts.

    Linear interpolation of the cumulative mass inside the selected
    bin, against the geometric bin representative rule at the edges:
    within one bin of the exact empirical quantile by construction, i.e.
    relative error ≤ γ − 1.  Empty histograms return 0.0.
    """
    h = np.asarray(hist, np.float64)
    total = h.sum(axis=-1, keepdims=True)
    cum = np.cumsum(h, axis=-1)
    target = np.maximum(q * total, 1.0)
    idx = np.minimum((cum < target).sum(axis=-1), h.shape[-1] - 1)
    lo = edges[idx]
    hi = np.where(np.isfinite(edges[idx + 1]), edges[idx + 1], edges[idx])
    lo = np.where(idx == 0, 0.0, lo)
    in_bin = np.take_along_axis(h, idx[..., None], -1)[..., 0]
    below = np.take_along_axis(cum, idx[..., None], -1)[..., 0] - in_bin
    frac = np.where(in_bin > 0,
                    (target[..., 0] - below) / np.maximum(in_bin, 1.0), 0.0)
    est = lo + np.clip(frac, 0.0, 1.0) * (hi - lo)
    return np.where(total[..., 0] > 0, est, 0.0)


def summarize_telemetry(tel: Telemetry, ts: TelemetryWindowStats) -> dict:
    """Reduce stacked telemetry windows; derive quantiles.  Host-side.

    Mirrors :func:`repro.core.engine.summarize`: the window axis is the
    last axis for scalar counters and second-to-last for vector fields;
    leading batch axes (grid, seeds) pass through.  Ring fields are NOT
    reduced — they are per-window drains, returned under ``"trace"`` for
    :mod:`repro.obs.trace` (with per-window true counts).
    """
    def _red(name):
        x = getattr(ts, name)
        axis = -2 if name in TEL_VECTOR_STATS else -1
        return np.asarray(x, np.float64).sum(axis=axis)

    wait_hist = _red("wait_hist")
    cost_hist = _red("cost_hist")
    we, ce = tel.wait_edges(), tel.cost_edges()
    out = {
        "p50_wait": sketch_quantile(wait_hist, we, 0.50),
        "p90_wait": sketch_quantile(wait_hist, we, 0.90),
        "p99_wait": sketch_quantile(wait_hist, we, 0.99),
        "p50_cost": sketch_quantile(cost_hist, ce, 0.50),
        "p99_cost": sketch_quantile(cost_hist, ce, 0.99),
        "wait_hist": wait_hist,
        "cost_hist": cost_hist,
        "events": _red("events"),
        "spot_starts": _red("spot_starts"),
        "preempts_fired": _red("preempts_fired"),
        "notices_honored": _red("notices_honored"),
        "deadline_defects": _red("deadline_defects"),
        "rejects": _red("rejects"),
        "loc_defects": _red("loc_defects"),
        "loc_resumed": _red("loc_resumed"),
    }
    if tel.trace_cap:
        out["trace"] = {name[len("ring_"):]: np.asarray(getattr(ts, name))
                        for name in _TRACE_FIELDS}
    return out
