"""Shock accounting for the environment-timeline axis.

When an :class:`repro.core.env.EnvTimeline` rides along a run
(``env=``), every event loop additionally folds one
:class:`EnvWindowStats` — counters for boundary crossings, shock
segments entered (storms / blackouts / spikes), time spent inside
shocks, and the degradation ledger: arrivals that landed during a shock
segment, how many of those were served degraded (pushed to on-demand),
how many were still served on spot, and how many preempted jobs resumed
inside a shock window.  The pytree rides env-outermost next to the
engine's ``WindowStats`` through all three executors, exactly like the
PR-6 telemetry block, and is absent from the program when ``env=None``.

These counters are what makes resilience *measurable*: the frozen
identities in tests/test_env.py pin ``storms_observed`` against
``EnvTimeline.count_storms()`` (every injected shock is accounted for)
and ``degraded_admits <= shock_arrivals`` (degradation is bounded by
exposure).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

#: summary keys reported as python ints (counter identities are exact)
ENV_INT_STATS = ("env_boundaries", "storms_observed", "blackouts_observed",
                 "spikes_observed", "shock_arrivals", "degraded_admits",
                 "shock_served", "shock_resumed")


class EnvWindowStats(NamedTuple):
    """Per-window shock counters (i32) + shock dwell times (f32)."""

    boundaries: jnp.ndarray        # segment boundary crossings
    storms_entered: jnp.ndarray    # boundaries that entered a SEG_STORM
    blackouts_entered: jnp.ndarray
    spikes_entered: jnp.ndarray
    shock_arrivals: jnp.ndarray    # job arrivals inside any shock segment
    degraded_admits: jnp.ndarray   # of those, served on-demand (degraded)
    shock_served: jnp.ndarray      # spot serves inside a shock segment
    shock_resumed: jnp.ndarray     # preemption resumes inside a shock
    storm_time: jnp.ndarray        # time spent inside SEG_STORM segments
    blackout_time: jnp.ndarray     # time spent inside SEG_BLACKOUT


def env_zeros() -> EnvWindowStats:
    z = jnp.zeros((), jnp.int32)
    f = jnp.zeros((), jnp.float32)
    return EnvWindowStats(z, z, z, z, z, z, z, z, f, f)


def env_update(es: EnvWindowStats, *, is_boundary, kind_prev, kind_next,
               dt, is_job, od_now, served, resumed) -> EnvWindowStats:
    """Fold one merged event.  ``kind_prev`` is the segment the event's
    ``dt`` elapsed in; ``kind_next`` the segment in effect afterwards
    (they differ only on boundary events).  Because the boundary joins
    the clock race, ``dt`` never spans segments — the dwell-time
    attribution is exact, not approximate."""
    # deferred: repro.core.env triggers the repro.core package init, so a
    # module-level import would cycle for consumers that import repro.obs
    # first (engine itself imports this module); by trace time core is up
    from repro.core.env import (SEG_BLACKOUT, SEG_NORMAL, SEG_SPIKE,
                                SEG_STORM)

    def i32(b):
        return b.astype(jnp.int32)

    shock = kind_prev != SEG_NORMAL
    entered = lambda k: i32(is_boundary & (kind_next == k))  # noqa: E731
    return EnvWindowStats(
        boundaries=es.boundaries + i32(is_boundary),
        storms_entered=es.storms_entered + entered(SEG_STORM),
        blackouts_entered=es.blackouts_entered + entered(SEG_BLACKOUT),
        spikes_entered=es.spikes_entered + entered(SEG_SPIKE),
        shock_arrivals=es.shock_arrivals + i32(is_job & shock),
        degraded_admits=es.degraded_admits + i32(od_now & shock),
        shock_served=es.shock_served + i32(served & shock),
        shock_resumed=es.shock_resumed + i32(resumed & shock),
        storm_time=es.storm_time + jnp.where(kind_prev == SEG_STORM, dt, 0.0),
        blackout_time=es.blackout_time
        + jnp.where(kind_prev == SEG_BLACKOUT, dt, 0.0),
    )


def env_merge(a: EnvWindowStats, b: EnvWindowStats) -> EnvWindowStats:
    """Merge two shock-accounting blocks across a lane/shard partition.

    The eight counters are int32, so the merge is exact — associative,
    commutative, partition-invariant (pinned with the telemetry merge in
    tests/test_fleet.py).  The two dwell-time fields are float sums and
    carry the usual ~ulp reduction-order story; merge those in float64
    (as :func:`summarize_env` does) when exact partition invariance
    matters.  Works on numpy and jax arrays alike.
    """
    return EnvWindowStats(*(x + y for x, y in zip(a, b)))


def env_reduce(es: EnvWindowStats, axis: int = 0) -> EnvWindowStats:
    """Collapse one batch axis (lanes, shards, seeds, or stacked windows)
    by summation — the n-way form of :func:`env_merge`."""
    return EnvWindowStats(*(x.sum(axis=axis) for x in es))


def summarize_env(estats: EnvWindowStats) -> dict:
    """Reduce stacked env windows (window axis last, like
    :func:`repro.core.engine.summarize`); leading grid/seed axes pass
    through.  Counter keys come back as exact ints."""
    def _red(name):
        return np.asarray(getattr(estats, name), np.float64).sum(axis=-1)

    def _int(x):
        arr = x.astype(np.int64)
        return int(arr) if arr.ndim == 0 else arr

    return {
        "env_boundaries": _int(_red("boundaries")),
        "storms_observed": _int(_red("storms_entered")),
        "blackouts_observed": _int(_red("blackouts_entered")),
        "spikes_observed": _int(_red("spikes_entered")),
        "shock_arrivals": _int(_red("shock_arrivals")),
        "degraded_admits": _int(_red("degraded_admits")),
        "shock_served": _int(_red("shock_served")),
        "shock_resumed": _int(_red("shock_resumed")),
        "storm_time": _red("storm_time"),
        "blackout_time": _red("blackout_time"),
    }
