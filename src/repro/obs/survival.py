"""Survival ledger: job-level work/deadline accounting for the work axis.

The engine's base :class:`~repro.core.engine.WindowStats` counts *legs*
(every service attempt, defection, and resume is one completed leg — the
paper's renewal accounting).  Once jobs carry a work structure
(``work=`` on the entry points, :class:`repro.core.work.WorkModel`), the
job-level truth lives here instead: a job is *finished* only when its
last unit of work is served (or it migrates to on-demand), and a finished
job either met its deadline or *missed* it — hard accounting, not a soft
statistic.  The ledger also prices recovery: work lost to rollbacks, work
recomputed (lost progress + restart overhead), checkpoints taken, and
panic entries (safety-net defections forced by
:class:`~repro.core.work.CantBeLateKernel`).

Frozen counter identities (property-tested in ``tests/test_work.py``):

- ``jobs_ontime + deadline_misses == jobs_finished`` — every finished job
  is classified exactly once.
- ``jobs_admitted - jobs_finished == jobs_in_flight >= 0`` — misses +
  completions account for every admission, up to jobs still running.
- ``work_lost == work_recomputed`` under zero restart overhead.

Same float32-window / float64-host-reduction discipline as the rest of
``repro.obs``: the traced :func:`survival_update` fold adds one event into
a window block; :func:`summarize_survival` reduces the chunk axis in
float64 on the host.  Cross-shard merge helpers (:func:`survival_merge`,
:func:`survival_reduce`) mirror ``telemetry_merge``/``env_merge``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# Integer-valued summary keys (kept separate from the engine's INT_STATS:
# these exist only when `work=` is on, so callers iterating INT_STATS over
# a work=None summary stay correct).
SURVIVAL_INT_STATS = (
    "jobs_admitted",
    "jobs_finished",
    "deadline_misses",
    "jobs_ontime",
    "checkpoints_taken",
    "panic_entries",
    "jobs_in_flight",
)


class SurvivalWindowStats(NamedTuple):
    """One float32/int32 window block of job-level survival counters."""

    admitted: jnp.ndarray       # i32: job arrivals (admitted or sent od)
    finished: jnp.ndarray       # i32: jobs that reached their last unit
    misses: jnp.ndarray         # i32: finished jobs past their deadline
    ontime: jnp.ndarray         # i32: finished jobs within their deadline
    checkpoints: jnp.ndarray    # i32: checkpoints taken (periodic + notice)
    panics: jnp.ndarray         # i32: safety-net forced defections
    work_done: jnp.ndarray      # f32: units of real progress served
    work_lost: jnp.ndarray      # f32: progress rolled back on resume
    work_recomputed: jnp.ndarray  # f32: lost progress + restart overhead
    overhead_paid: jnp.ndarray  # f32: restart-overhead units charged


def survival_zeros() -> SurvivalWindowStats:
    zi = jnp.zeros((), jnp.int32)
    zf = jnp.zeros((), jnp.float32)
    return SurvivalWindowStats(zi, zi, zi, zi, zi, zi, zf, zf, zf, zf)


def survival_update(ws: SurvivalWindowStats, *, admitted, finished, missed,
                    checkpoint, panic, work_done, work_lost,
                    work_recomputed, overhead_paid) -> SurvivalWindowStats:
    """Fold one merged event into the ledger (traced; no data-dependent
    control flow).  ``missed`` is only counted for finished jobs; the
    on-time twin is derived here so the classification identity holds by
    construction."""
    fin = jnp.asarray(finished, jnp.bool_)
    miss = fin & jnp.asarray(missed, jnp.bool_)
    return SurvivalWindowStats(
        admitted=ws.admitted + jnp.asarray(admitted, jnp.int32),
        finished=ws.finished + fin.astype(jnp.int32),
        misses=ws.misses + miss.astype(jnp.int32),
        ontime=ws.ontime + (fin & (~miss)).astype(jnp.int32),
        checkpoints=ws.checkpoints + jnp.asarray(checkpoint,
                                                 jnp.int32),
        panics=ws.panics + jnp.asarray(panic, jnp.int32),
        work_done=ws.work_done + work_done,
        work_lost=ws.work_lost + work_lost,
        work_recomputed=ws.work_recomputed + work_recomputed,
        overhead_paid=ws.overhead_paid + overhead_paid,
    )


def survival_merge(a: SurvivalWindowStats,
                   b: SurvivalWindowStats) -> SurvivalWindowStats:
    """Merge two ledgers (cross-shard / cross-window; exact for ints)."""
    return SurvivalWindowStats(*(x + y for x, y in zip(a, b)))


def survival_reduce(ws: SurvivalWindowStats,
                    axis: int = 0) -> SurvivalWindowStats:
    """Sum the ledger along one axis (e.g. a lane or chunk axis)."""
    return SurvivalWindowStats(*(x.sum(axis=axis) for x in ws))


def summarize_survival(wstats: SurvivalWindowStats) -> dict:
    """Float64 chunk reduction + derived job-level statistics."""

    def _red(name):
        return np.asarray(getattr(wstats, name), np.float64).sum(axis=-1)

    def _int(x):
        arr = x.astype(np.int64)
        return int(arr) if arr.ndim == 0 else arr

    admitted = _red("admitted")
    finished = _red("finished")
    misses = _red("misses")
    return {
        "jobs_admitted": _int(admitted),
        "jobs_finished": _int(finished),
        "deadline_misses": _int(misses),
        "jobs_ontime": _int(_red("ontime")),
        "checkpoints_taken": _int(_red("checkpoints")),
        "panic_entries": _int(_red("panics")),
        "jobs_in_flight": _int(admitted - finished),
        "deadline_miss_rate": misses / np.maximum(finished, 1.0),
        "work_done": _red("work_done"),
        "work_lost": _red("work_lost"),
        "work_recomputed": _red("work_recomputed"),
        "restart_overhead_paid": _red("overhead_paid"),
    }
