"""``repro.obs`` — the observability subsystem.

Three layers, one axis: :class:`Telemetry` is the static descriptor the
engine entry points accept as ``telemetry=`` (the fifth dispatch axis,
after policy kernel / scenario loop / executor / RNG stream).  With it
set, sims and sweeps additionally return streaming wait/cost quantile
sketches, event-type counters, and per-pool/per-region defect/resume
counts per grid point — accumulated on-device in the same float32 window
blocks as the base stats, through all three executors.  ``telemetry=None``
(the default) compiles the identical program as before the axis existed:
zero cost, bitwise-reproduced stats (frozen in tests/test_obs.py).

* :mod:`repro.obs.stats` — device accumulators + host summaries.
* :mod:`repro.obs.shocks` — shock/degradation counters for the
  environment-timeline axis (``env=``): boundaries crossed, storms /
  blackouts / spikes entered, shock dwell times, degraded admissions.
* :mod:`repro.obs.survival` — the survival ledger for the work axis
  (``work=``): job-level finished/on-time/missed counters with frozen
  identities, work lost/recomputed to rollbacks, checkpoints taken,
  and safety-net panic entries.
* :mod:`repro.obs.trace` — event tracing (device rings / host recorder)
  and the Chrome/Perfetto exporter.
* :mod:`repro.obs.timing` — compile-vs-steady timing, BENCH provenance
  stamps, profiler trace scopes.
"""
from .shocks import (ENV_INT_STATS, EnvWindowStats, env_merge,
                     env_reduce, env_update, env_zeros, summarize_env)
from .survival import (SURVIVAL_INT_STATS, SurvivalWindowStats,
                       summarize_survival, survival_merge, survival_reduce,
                       survival_update, survival_zeros)
from .stats import (EVENT_TYPES, TEL_INT_STATS, Telemetry,
                    TelemetryWindowStats, sketch_quantile,
                    summarize_telemetry, telemetry_merge, telemetry_reduce,
                    telemetry_update, telemetry_zeros)
from .timing import annotate, provenance, time_compiled
from .trace import (TraceRecorder, device_trace_records, to_perfetto,
                    write_perfetto)

__all__ = [
    "ENV_INT_STATS",
    "EVENT_TYPES",
    "EnvWindowStats",
    "SURVIVAL_INT_STATS",
    "SurvivalWindowStats",
    "TEL_INT_STATS",
    "Telemetry",
    "TelemetryWindowStats",
    "TraceRecorder",
    "annotate",
    "device_trace_records",
    "env_merge",
    "env_reduce",
    "env_update",
    "env_zeros",
    "summarize_env",
    "summarize_survival",
    "survival_merge",
    "survival_reduce",
    "survival_update",
    "survival_zeros",
    "provenance",
    "sketch_quantile",
    "summarize_telemetry",
    "telemetry_merge",
    "telemetry_reduce",
    "telemetry_update",
    "telemetry_zeros",
    "time_compiled",
    "to_perfetto",
    "write_perfetto",
]
