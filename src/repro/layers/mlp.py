"""Dense MLP (SwiGLU) block."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16,
             variant: str = "swiglu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if variant == "swiglu":
        p["w_gate"] = (jax.random.normal(k1, (d_model, d_ff)) * s_in
                       ).astype(dtype)
    return p


def mlp(params: dict, x: jax.Array) -> jax.Array:
    """SwiGLU (3-matrix) if w_gate present, else 2-matrix GELU MLP."""
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if "w_gate" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])
