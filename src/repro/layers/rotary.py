"""Rotary position embeddings, including Qwen2-VL's 3-axis M-RoPE.

RoPE is applied to the first ``rot_dim`` dims of each head (full head_dim by
default).  M-RoPE splits the rotary *frequency* dimension into three sections
(temporal, height, width) driven by a (3, B, S) position tensor — the stub
VLM frontend supplies these; for pure text all three axes carry the same
positions, which reduces M-RoPE to standard RoPE exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_angles(positions: jax.Array, rot_half: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (..., S) -> cos/sin of shape (..., S, rot_half), fp32."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, rot_half, dtype=jnp.float32) / rot_half)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _apply(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D) rotate pairs (x1, x2) = (x[:D/2], x[D/2:])."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # cos/sin (B, S, half) -> broadcast over heads
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """Standard RoPE. x (B, S, H, D); positions (B, S)."""
    cos, sin = rope_angles(positions, x.shape[-1] // 2, theta)
    return _apply(x, cos, sin)


def mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Qwen2-VL's split of the rotary half (e.g. 64 -> 16/24/24)."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return t, h, w


def apply_mrope(x: jax.Array, positions3: jax.Array,
                theta: float = 10_000.0) -> jax.Array:
    """M-RoPE. x (B, S, H, D); positions3 (3, B, S) = (t, h, w) axes."""
    half = x.shape[-1] // 2
    sec = mrope_sections(x.shape[-1])
    cos_parts, sin_parts = [], []
    offset = 0
    for axis in range(3):
        n = sec[axis]
        freqs = 1.0 / (
            theta ** (jnp.arange(offset, offset + n, dtype=jnp.float32) / half)
        )
        ang = positions3[axis].astype(jnp.float32)[..., None] * freqs
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        offset += n
    cos = jnp.concatenate(cos_parts, axis=-1)
    sin = jnp.concatenate(sin_parts, axis=-1)
    return _apply(x, cos, sin)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position embeddings (S, D), fp32."""
    half = d_model // 2
    freqs = jnp.exp(
        -jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)
    )
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)
