"""Attention: GQA projections + three sequence-mixing implementations.

``chunked``  — flash-style two-level scan with online softmax; never
               materializes the S×S score matrix, so 32k prefill compiles
               with bounded memory on every backend.  This is the production
               jnp path used by the dry-run.
``naive``    — full score matrix; oracle for tests at small shapes.
``pallas``   — TPU kernel (repro.kernels.flash_attention), validated in
               interpret mode; selected via ``cfg.attn_impl``.

Decode (q_len == 1) uses a single-pass masked softmax over the KV cache.

``causal_block_skip`` (perf knob, §Perf): with causal masking, KV blocks
strictly in the future of a whole Q block contribute nothing — iterate only
j ≤ (q_offset + (i+1)·cq − 1)//ck blocks via a bounded ``fori_loop``,
halving prefill attention FLOPs at large S.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   qk_norm: bool = False, dtype=jnp.bfloat16) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(kq, (d_model, num_heads, head_dim)) * scale
               ).astype(dtype),
        "wk": (jax.random.normal(kk, (d_model, num_kv_heads, head_dim)) * scale
               ).astype(dtype),
        "wv": (jax.random.normal(kv, (d_model, num_kv_heads, head_dim)) * scale
               ).astype(dtype),
        "wo": (jax.random.normal(ko, (num_heads, head_dim, d_model))
               * (1.0 / math.sqrt(num_heads * head_dim))).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((num_kv_heads, head_dim), dtype)
        p["bv"] = jnp.zeros((num_kv_heads, head_dim), dtype)
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((head_dim,), jnp.float32)
    return p


def _head_rms(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def qkv_project(params: dict, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if "q_norm" in params:
        q = _head_rms(q, params["q_norm"])
        k = _head_rms(k, params["k_norm"])
    return q, k, v


def out_project(params: dict, y: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", y, params["wo"])


# ---------------------------------------------------------------------------
# Sequence mixing
# ---------------------------------------------------------------------------


def naive_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Oracle: full (Sq, Sk) scores. q (B,Sq,H,D); k/v (B,Sk,KH,D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    g = H // KH
    qg = q.reshape(B, Sq, KH, g, D)
    scores = jnp.einsum("bqngd,bsnd->bqngs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    scores = jnp.where(mask[None, :, None, None, :], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bqngs,bsnd->bqngd", p, v.astype(jnp.float32))
    return y.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                      k_chunk: int = 1024, q_offset: int = 0,
                      kv_len: Optional[jax.Array] = None,
                      block_skip: bool = False) -> jax.Array:
    """Flash-style online-softmax attention via scan over (Q, KV) blocks."""
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    g = H // KH
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    # pad to multiples
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // k_chunk)
    q_pad, k_pad = nq * q_chunk - Sq, nk * k_chunk - Sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    kv_valid = jnp.asarray(Sk if kv_len is None else kv_len, jnp.int32)
    scale = 1.0 / math.sqrt(D)

    qs = q.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)

    def one_q_block(i, qb):
        """qb (B, cq, H, D) -> attended output block."""
        qg = qb.reshape(B, q_chunk, KH, g, D)
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, j):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, j * k_chunk, k_chunk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * k_chunk, k_chunk, axis=1)
            kpos = j * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqngd,bsnd->bqngs", qg, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] < kv_valid
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqngs,bsnd->bqngd", p.astype(v.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, KH, g), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, KH, g), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, KH, g, D), jnp.float32)
        if block_skip and causal:
            # Only blocks j with j*ck <= last q position can contribute.
            last_q = q_offset + (i + 1) * q_chunk - 1
            n_blocks = jnp.minimum(last_q // k_chunk + 1, nk).astype(jnp.int32)

            def body(j, carry):
                carry, _ = kv_step(carry, j)
                return carry

            m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), jnp.arange(nk)
            )
        y = acc / jnp.maximum(l, 1e-30)[..., None]
        return y.reshape(B, q_chunk, H, D).astype(q.dtype)

    if nq == 1:
        out = one_q_block(0, qs[0])[None]
    else:
        out = jax.lax.map(lambda args: one_q_block(*args),
                          (jnp.arange(nq), qs))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, kv_len, *, chunk: int = 4096,
                     self_kv=None) -> jax.Array:
    """Single-token decode: q (B,1,H,D) vs cache (B,S,KH,D); kv_len scalar
    or (B,).  Flash-decode style: online softmax over KV chunks so no
    S-sized fp32 intermediate (or backend upcast of the whole cache) ever
    materializes.

    ``self_kv=(k_new, v_new)`` each (B,1,KH,D): the new token's own K/V,
    merged analytically into the online softmax.  This lets decode read the
    cache *immutably* (the write happens once, outside the layer scan) —
    keeping the multi-GiB cache out of every while-body op so backend float
    normalization / double buffering can't touch it."""
    B, _, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    g = H // KH
    qg = q.reshape(B, KH, g, D)
    kv_len = jnp.reshape(jnp.asarray(kv_len), (-1, 1))  # (B|1, 1)
    ck = min(chunk, S)
    if S % ck:
        ck = S  # irregular sizes: single pass
    nk = S // ck
    scale = 1.0 / math.sqrt(D)

    def kv_step(carry, j):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k_cache, j * ck, ck, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v_cache, j * ck, ck, axis=1)
        kpos = j * ck + jnp.arange(ck)
        s = jnp.einsum("bngd,bsnd->bngs", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        valid = kpos[None, :] < kv_len
        s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bngs,bsnd->bngd", p.astype(v_cache.dtype), vb,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv), None

    m0 = jnp.full((B, KH, g), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, g), jnp.float32)
    a0 = jnp.zeros((B, KH, g, D), jnp.float32)
    if nk == 1:
        (m, l, acc), _ = kv_step((m0, l0, a0), 0)
    else:
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
    if self_kv is not None:
        k_new, v_new = self_kv  # (B, 1, KH, D)
        s_self = jnp.einsum("bngd,bnd->bng", qg, k_new[:, 0],
                            preferred_element_type=jnp.float32) * scale
        m_new = jnp.maximum(m, s_self)
        p_self = jnp.exp(s_self - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p_self
        acc = acc * corr[..., None] + p_self[..., None] * v_new[:, 0][
            :, :, None, :].astype(jnp.float32)
        m = m_new
    y = acc / jnp.maximum(l, 1e-30)[..., None]
    return y.reshape(B, 1, H, D).astype(q.dtype)


def mix_sequence(cfg, q, k, v, *, causal: bool, q_offset: int = 0,
                 kv_len=None) -> jax.Array:
    """Dispatch on cfg.attn_impl."""
    if cfg.attn_impl == "naive":
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_len=kv_len)
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, causal=causal)
    if kv_len is None:
        # train/prefill: custom-VJP flash path (O(block) backward memory)
        from repro.layers.flash_vjp import chunked_attention_trainable

        return chunked_attention_trainable(
            q, k, v, causal=causal, q_chunk=cfg.attn_chunk_q,
            k_chunk=cfg.attn_chunk_k, q_offset=q_offset)
    return chunked_attention(
        q, k, v, causal=causal, q_chunk=cfg.attn_chunk_q,
        k_chunk=cfg.attn_chunk_k, q_offset=q_offset, kv_len=kv_len,
        block_skip=cfg.causal_block_skip,
    )
