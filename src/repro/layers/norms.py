"""Normalization layers (pure functions + init)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32, cast back to input dtype."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def gated_rms_norm(params: dict, x: jax.Array, z: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    """Mamba2's output norm: RMSNorm(x * silu(z))."""
    return rms_norm(params, x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    eps=eps)
