"""Memory-correct chunked attention: custom VJP (FlashAttention-2 style).

Differentiating the naive scan-of-scans online-softmax attention makes JAX
stack per-(Q-block × KV-block) score residuals — O(Sq·Sk) memory, exactly
what flash attention exists to avoid (measured: 92 GiB/device on the
whisper train cell).  This custom VJP saves only (q, k, v, o, lse) and
recomputes p-blocks in the backward:

  forward:   o, lse                      (lse = m + log l, per row)
  backward:  delta = Σ(do ⊙ o)
             p  = exp(s − lse);  ds = p ⊙ (do·vᵀ − delta)·scale
             dq = Σ_j ds·k;  dk = Σ_i dsᵀ·q;  dv = Σ_i pᵀ·do

Both passes are block-tiled scans with fp32 accumulators; no tensor larger
than one (cq × ck) block ever exists per device.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def _mask_for(qpos, kpos, causal: bool, sk_valid: int):
    mask = kpos[None, :] < sk_valid
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    return mask


def _forward_blocks(q5, k, v, *, causal, cq, ck, q_offset, sk_valid):
    """q5 (B, nq, cq, KH, g, D); k/v (B, Sk, KH, D) -> (o5, lse5)."""
    B, nq, cqs, KH, g, D = q5.shape
    Sk = k.shape[1]
    nk = Sk // ck
    scale = 1.0 / math.sqrt(D)

    def one_q(args):
        i, qb = args  # qb (B, cq, KH, g, D)
        qpos = q_offset + i * cq + jnp.arange(cq)

        def kv_step(carry, j):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
            kpos = j * ck + jnp.arange(ck)
            s = jnp.einsum("bqngd,bsnd->bqngs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask_for(qpos, kpos, causal, sk_valid)
            s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqngs,bsnd->bqngd", p.astype(v.dtype), vb,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * corr[..., None] + pv), None

        m0 = jnp.full((B, cq, KH, g), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, cq, KH, g), jnp.float32)
        a0 = jnp.zeros((B, cq, KH, g, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        l = jnp.maximum(l, 1e-30)
        o = (acc / l[..., None]).astype(q5.dtype)
        lse = m + jnp.log(l)
        return o, lse

    qs = q5.transpose(1, 0, 2, 3, 4, 5)  # (nq, B, cq, KH, g, D)
    o, lse = jax.lax.map(one_q, (jnp.arange(nq), qs))
    return o.transpose(1, 0, 2, 3, 4, 5), lse.transpose(1, 0, 2, 3, 4)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_vjp(q, k, v, causal: bool, q_chunk: int, k_chunk: int,
                        q_offset: int, sk_valid: int):
    """q (B,Sq,H,D); k/v (B,Sk,KH,D) -> (B,Sq,H,D).  Shapes must tile."""
    o, _ = _fwd_impl(q, k, v, causal, q_chunk, k_chunk, q_offset, sk_valid)
    return o


def _fwd_impl(q, k, v, causal, cq, ck, q_offset, sk_valid):
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    g = H // KH
    nq = Sq // cq
    q5 = q.reshape(B, nq, cq, KH, g, D)
    o5, lse5 = _forward_blocks(q5, k, v, causal=causal, cq=cq, ck=ck,
                               q_offset=q_offset, sk_valid=sk_valid)
    o = o5.reshape(B, Sq, H, D)
    lse = lse5.reshape(B, Sq, KH, g)
    return o, lse


def _fwd(q, k, v, causal, cq, ck, q_offset, sk_valid):
    o, lse = _fwd_impl(q, k, v, causal, cq, ck, q_offset, sk_valid)
    return o, (q, k, v, o, lse)


def _bwd(causal, cq, ck, q_offset, sk_valid, res, do):
    q, k, v, o, lse = res
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    g = H // KH
    nq, nk = Sq // cq, Sk // ck
    scale = 1.0 / math.sqrt(D)

    q5 = q.reshape(B, nq, cq, KH, g, D)
    do5 = do.reshape(B, nq, cq, KH, g, D).astype(jnp.float32)
    o5 = o.reshape(B, nq, cq, KH, g, D).astype(jnp.float32)
    lse5 = lse.reshape(B, nq, cq, KH, g)
    delta5 = jnp.sum(do5 * o5, axis=-1)  # (B, nq, cq, KH, g)

    def kv_step(dq_acc, j):
        kb = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        kpos = j * ck + jnp.arange(ck)

        def one_q(args):
            i, qb, dob, lseb, deltab = args
            qpos = q_offset + i * cq + jnp.arange(cq)
            s = jnp.einsum("bqngd,bsnd->bqngs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask_for(qpos, kpos, causal, sk_valid)
            s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
            p = jnp.exp(s - lseb[..., None])  # (B,cq,KH,g,ck)
            dp = jnp.einsum("bqngd,bsnd->bqngs", dob.astype(v.dtype), vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - deltab[..., None]) * scale
            dsb = ds.astype(q.dtype)
            dq_i = jnp.einsum("bqngs,bsnd->bqngd", dsb, kb,
                              preferred_element_type=jnp.float32)
            dk_j = jnp.einsum("bqngs,bqngd->bsnd", dsb, qb,
                              preferred_element_type=jnp.float32)
            dv_j = jnp.einsum("bqngs,bqngd->bsnd", p.astype(q.dtype), dob,
                              preferred_element_type=jnp.float32)
            return dq_i, dk_j, dv_j

        qs = q5.transpose(1, 0, 2, 3, 4, 5)
        dos = do5.transpose(1, 0, 2, 3, 4, 5)
        lses = lse5.transpose(1, 0, 2, 3, 4)
        deltas = delta5.transpose(1, 0, 2, 3, 4)
        dq_i, dk_j, dv_j = jax.lax.map(
            one_q, (jnp.arange(nq), qs, dos, lses, deltas))
        # dq_i (nq, B, cq, KH, g, D) — this KV block's contribution
        dq_acc = dq_acc + dq_i.transpose(1, 0, 2, 3, 4, 5)
        return dq_acc, (jnp.sum(dk_j, axis=0), jnp.sum(dv_j, axis=0))

    dq0 = jnp.zeros((B, nq, cq, KH, g, D), jnp.float32)
    dq_acc, (dk_blocks, dv_blocks) = jax.lax.scan(kv_step, dq0,
                                                  jnp.arange(nk))
    dq = dq_acc.reshape(B, Sq, H, D).astype(q.dtype)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KH, D).astype(
        k.dtype)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KH, D).astype(
        v.dtype)
    return dq, dk, dv


flash_attention_vjp.defvjp(_fwd, _bwd)


def chunked_attention_trainable(q, k, v, *, causal: bool, q_chunk: int = 512,
                                k_chunk: int = 1024,
                                q_offset: int = 0) -> jax.Array:
    """Public entry: pads to tile multiples, calls the custom-VJP kernel."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    cq = min(q_chunk, Sq)
    ck = min(k_chunk, Sk)
    nq, nk = -(-Sq // cq), -(-Sk // ck)
    q_pad, k_pad = nq * cq - Sq, nk * ck - Sk
    sk_valid = Sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    o = flash_attention_vjp(q, k, v, causal, cq, ck, q_offset, sk_valid)
    return o[:, :Sq]
