"""Mixture-of-Experts with expert parallelism over the ``model`` mesh axis.

Dispatch is **sort-free scatter/gather** (not the classic GShard one-hot
einsum, whose (T, E, C) dispatch tensor is infeasible at 10⁶-token batches):

  1. router top-k → (expert id, gate) per token-slot,
  2. position-in-expert via a cumsum over the one-hot assignment,
  3. scatter tokens into a capacity buffer (E, C, D) — drops overflow,
  4. *expert parallelism*: ``all_to_all`` over the ``model`` axis inside a
     ``shard_map`` region (explicit collective → visible in the roofline),
  5. batched per-expert SwiGLU matmuls (MXU-shaped),
  6. reverse all_to_all, gather + gate-combine.

Two entry points:
  * :func:`moe_apply_local`   — single-device path (smoke tests, oracle).
  * :func:`moe_apply_sharded` — shard_map path used under the production mesh.

Experts are padded to a multiple of the model-axis size (e.g. qwen2-moe's 60
routed experts → 64, the 4 pads masked to −inf in routing) so the expert
dimension shards evenly — standard practice, recorded in DESIGN.md.

The router aux (load-balance) loss is the Switch/GShard form
``E · Σ_e f_e p_e``, psum-averaged over the data axes.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

# jax.shard_map graduated from jax.experimental.shard_map (and renamed its
# replication-check kwarg check_rep -> check_vma) in jax 0.6; support both.
if hasattr(jax, "shard_map"):
    def _shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)


def moe_init(key, d_model: int, moe_d_ff: int, num_experts: int,
             num_padded: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(moe_d_ff)
    E = num_padded
    return {
        "router": (jax.random.normal(k1, (d_model, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (E, d_model, moe_d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k3, (E, d_model, moe_d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k4, (E, moe_d_ff, d_model)) * s_out).astype(dtype),
    }


def padded_experts(num_experts: int, model_axis: int) -> int:
    return -(-num_experts // model_axis) * model_axis


def _route(params, x2d, num_real: int, top_k: int):
    """x2d (T, D) -> gates (T,k) f32, ids (T,k) i32, router probs (T,E) f32."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), params["router"])
    E = logits.shape[-1]
    if num_real < E:  # mask padded experts out of routing
        pad_mask = jnp.arange(E) >= num_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    top_logits, ids = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_logits, axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    return gates, ids.astype(jnp.int32), probs


def _dispatch_compute_combine(params, x2d, gates, ids, capacity: int):
    """Scatter → batched expert SwiGLU → gather.  Local (per-shard) shapes."""
    T, D = x2d.shape
    k = ids.shape[-1]
    E = params["w_gate"].shape[0]
    flat_ids = ids.reshape(-1)  # (T*k,)
    # position of each (token, slot) within its expert
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (T*k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos_all, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < capacity
    pos_c = jnp.minimum(pos, capacity - 1)

    x_rep = jnp.repeat(x2d, k, axis=0)  # (T*k, D)
    updates = jnp.where(keep[:, None], x_rep, 0)
    buf = jnp.zeros((E, capacity, D), x2d.dtype)
    buf = buf.at[flat_ids, pos_c].add(updates, mode="drop")

    buf = _expert_ffn(params, buf)

    gathered = buf[flat_ids, pos_c]  # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.einsum("tkd,tk->td", gathered.reshape(T, k, D),
                   gates.astype(x2d.dtype))
    return y


def _expert_ffn(params, buf):
    """buf (E, C, D) -> (E, C, D) batched SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def _aux_loss(probs, ids, num_real: int, top_k: int):
    """Switch-style load-balance loss on the real experts."""
    E = probs.shape[-1]
    assigned = jax.nn.one_hot(ids.reshape(-1), E, dtype=jnp.float32)
    f = assigned.mean(axis=0) * top_k  # fraction dispatched per expert
    p = probs.mean(axis=0)
    return num_real * jnp.sum(f * p) / top_k


def moe_apply_local(params, x, cfg) -> tuple[jax.Array, jax.Array]:
    """Single-device MoE (oracle / smoke tests).  x (B,S,D)."""
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)
    gates, ids, probs = _route(params, x2d, cfg.num_experts,
                               cfg.num_experts_per_tok)
    T = B * S
    E = params["w_gate"].shape[0]
    capacity = max(
        8, int(math.ceil(T * cfg.num_experts_per_tok * cfg.capacity_factor / E))
    )
    y = _dispatch_compute_combine(params, x2d, gates, ids, capacity)
    aux = _aux_loss(probs, ids, cfg.num_experts, cfg.num_experts_per_tok)
    return y.reshape(B, S, D), aux


def moe_apply_sharded(params, x, cfg, mesh, batch_axes: tuple,
                      model_axis: str = "model") -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE under shard_map.  x (B,S,D) sharded over batch."""
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    n_model = mesh.shape[model_axis]
    E = params["w_gate"].shape[0]
    T_loc = (B * S) // n_batch_shards
    cap_loc = max(
        8,
        int(math.ceil(T_loc * cfg.num_experts_per_tok * cfg.capacity_factor / E)),
    )

    def local_fn(p_local, x_loc):
        """Per-shard: x_loc (T_loc, D); p_local has experts sharded E_loc."""
        gates, ids, probs = _route(
            {**p_local, "router": p_local["router"]}, x_loc,
            cfg.num_experts, cfg.num_experts_per_tok,
        )
        k = cfg.num_experts_per_tok
        flat_ids = ids.reshape(-1)
        onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
        pos_all = jnp.cumsum(onehot, axis=0) - 1
        pos = jnp.take_along_axis(pos_all, flat_ids[:, None], axis=1)[:, 0]
        keep = pos < cap_loc
        pos_c = jnp.minimum(pos, cap_loc - 1)
        x_rep = jnp.repeat(x_loc, k, axis=0)
        updates = jnp.where(keep[:, None], x_rep, 0)
        buf = jnp.zeros((E, cap_loc, D), x_loc.dtype)
        buf = buf.at[flat_ids, pos_c].add(updates, mode="drop")

        # expert parallelism: exchange capacity shards for expert shards
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=1,
                                 tiled=True)  # (E_loc, cap_loc*n_model, D)
        buf = _expert_ffn(
            {"w_gate": p_local["w_gate"], "w_up": p_local["w_up"],
             "w_down": p_local["w_down"]}, buf)
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=1, concat_axis=0,
                                 tiled=True)  # (E, cap_loc, D)

        gathered = buf[flat_ids, pos_c]
        gathered = jnp.where(keep[:, None], gathered, 0)
        y = jnp.einsum("tkd,tk->td", gathered.reshape(T_loc, k, D),
                       gates.astype(x_loc.dtype))
        aux = _aux_loss(probs, ids, cfg.num_experts, cfg.num_experts_per_tok)
        aux = jax.lax.pmean(aux, batch_axes)
        return y, aux

    param_specs = {
        "router": P(None, None),
        "w_gate": P(model_axis, None, None),
        "w_up": P(model_axis, None, None),
        "w_down": P(model_axis, None, None),
    }
    x2d = x.reshape(B * S, D)
    y2d, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(param_specs, P(batch_axes, None)),
        out_specs=(P(batch_axes, None), P()),
    )(params, x2d)
    return y2d.reshape(B, S, D), aux
