"""Mamba2 (state-space duality) block — TPU-idiomatic chunked formulation.

The CUDA selective-scan of Mamba1 has no TPU analogue (warp shuffles); the
Mamba2 paper's own SSD form is the TPU-native algorithm: big dense intra-chunk
matmuls (MXU) plus a cheap inter-chunk state recurrence (lax.scan over
chunks).  This module is the pure-jnp production path and the oracle for the
Pallas kernel in ``repro.kernels.ssd``.

Per-layer parameters (ngroups = 1):
  in_proj  (D, 2·d_inner + 2·N + H)  → [z, x, B, C, dt]
  conv     depthwise width-4 causal conv over [x, B, C] channels (+ silu)
  A_log(H), D(H), dt_bias(H); gated RMSNorm; out_proj (d_inner, D)

Recurrence: h_t = exp(dt_t·A)·h_{t−1} + dt_t·B_t ⊗ x_t ;  y_t = C_t·h_t + D·x_t
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.layers.norms import gated_rms_norm, rms_norm_init


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int
    n_state: int
    n_heads: int
    head_dim: int
    conv_width: int
    chunk: int

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.n_state

    @property
    def proj_dim(self) -> int:
        return 2 * self.d_inner + 2 * self.n_state + self.n_heads


def dims_from_cfg(cfg) -> SSMDims:
    return SSMDims(
        d_model=cfg.d_model,
        d_inner=cfg.d_inner,
        n_state=cfg.ssm_state,
        n_heads=cfg.ssm_heads,
        head_dim=cfg.ssm_headdim,
        conv_width=cfg.ssm_conv_width,
        chunk=cfg.ssm_chunk,
    )


def ssm_init(key, dims: SSMDims, dtype=jnp.bfloat16) -> dict:
    """Projections are SPLIT (z/x/B/C/dt as separate matrices) rather than
    packed into one in_proj: the packed layout cannot be sharded over the
    ``model`` axis without splitting its segments across shards.  With the
    split layout z/x shard over d_inner (heads), dt over heads, B/C stay
    replicated (shared across heads, ngroups=1) — clean tensor parallelism.
    """
    kz, kx, kb, kc, kdt, kcv, k3, k4 = jax.random.split(key, 8)
    s_in = 1.0 / math.sqrt(dims.d_model)
    s_out = 1.0 / math.sqrt(dims.d_inner)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba default)
    dt = jnp.exp(
        jax.random.uniform(k3, (dims.n_heads,)) * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    cw = 1.0 / math.sqrt(dims.conv_width)
    return {
        "z_proj": (jax.random.normal(kz, (dims.d_model, dims.d_inner)) * s_in
                   ).astype(dtype),
        "x_proj": (jax.random.normal(kx, (dims.d_model, dims.d_inner)) * s_in
                   ).astype(dtype),
        "b_proj": (jax.random.normal(kb, (dims.d_model, dims.n_state)) * s_in
                   ).astype(dtype),
        "c_proj": (jax.random.normal(kc, (dims.d_model, dims.n_state)) * s_in
                   ).astype(dtype),
        "dt_proj": (jax.random.normal(kdt, (dims.d_model, dims.n_heads)) * s_in
                    ).astype(dtype),
        "conv_x_w": (jax.random.normal(kcv, (dims.conv_width, dims.d_inner))
                     * cw).astype(dtype),
        "conv_x_b": jnp.zeros((dims.d_inner,), dtype),
        "conv_bc_w": (jax.random.normal(kcv, (dims.conv_width,
                                              2 * dims.n_state)) * cw
                      ).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * dims.n_state,), dtype),
        "A_log": jnp.log(jnp.arange(1, dims.n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((dims.n_heads,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": rms_norm_init(dims.d_inner),
        "out_proj": (jax.random.normal(k4, (dims.d_inner, dims.d_model)) * s_out
                     ).astype(dtype),
    }


def causal_conv(w: jax.Array, b: jax.Array, u: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds.  u (B, L, C); w (W, C)."""
    W = w.shape[0]
    out = jnp.zeros_like(u)
    for i in range(W):
        shift = W - 1 - i
        shifted = jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * w[i]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(u.dtype)


def ssd_chunked(x, dt, a_log, d_skip, b_in, c_in, *, chunk: int,
                return_final: bool = False):
    """Chunked SSD scan.

    x (B, L, H, P); dt (B, L, H) fp32 post-softplus; b_in/c_in (B, L, N);
    returns y (B, L, H, P) in x.dtype (+ final state (B,H,P,N) fp32 if
    ``return_final``; zero-padded tail steps carry dt=0 ⇒ no spurious decay).
    """
    Bsz, L, H, P = x.shape
    N = b_in.shape[-1]
    Q = min(chunk, L)
    nc = -(-L // Q)
    pad = nc * Q - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    A = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative
    da = dt * A  # (B, L', H)

    def chunkify(t, extra_dims):
        return t.reshape((Bsz, nc, Q) + extra_dims)

    xc = chunkify(x, (H, P))
    dtc = chunkify(dt, (H,))
    dac = chunkify(da, (H,))
    bc = chunkify(b_in, (N,))
    cc = chunkify(c_in, (N,))

    cum = jnp.cumsum(dac, axis=2)  # (B, nc, Q, H) inclusive
    # intra-chunk: contribution of s to q (q >= s): exp(cum_q - cum_s)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqn,bcsn->bcqs", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bcqs,bcqsh,bcshp->bcqhp", scores, decay, xdt)

    # chunk-final states: S_c = Σ_s exp(cum_last - cum_s) B_s ⊗ xdt_s
    decay_rest = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcsn,bcsh,bcshp->bchpn", bc.astype(jnp.float32),
                         decay_rest, xdt)
    total = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) whole-chunk decay

    def inter(h, inputs):
        s_c, tot = inputs  # (B,H,P,N), (B,H)
        h_next = h * tot[..., None, None] + s_c
        return h_next, h  # emit state *before* this chunk

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_final, h_before = jax.lax.scan(
        inter, h0,
        (s_chunk.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    decay_in = jnp.exp(cum)  # (B,nc,Q,H): decay from chunk start to q
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc.astype(jnp.float32),
                       h_before, decay_in)

    y = y_diag + y_off + d_skip[None, None, :, None] * xc.astype(jnp.float32)
    y = y.reshape(Bsz, nc * Q, H, P)[:, :L]
    if return_final:
        return y.astype(x.dtype), h_final
    return y.astype(x.dtype)


def ssd_reference(x, dt, a_log, d_skip, b_in, c_in) -> jax.Array:
    """O(L) sequential recurrence — the ground-truth oracle for tests."""
    Bsz, L, H, P = x.shape
    N = b_in.shape[-1]
    A = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, inputs):
        xt, dtt, bt, ct = inputs  # (B,H,P),(B,H),(B,N),(B,N)
        da = jnp.exp(dtt * A)  # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        h = h * da[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          b_in.astype(jnp.float32).transpose(1, 0, 2),
          c_in.astype(jnp.float32).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3) + d_skip[None, None, :, None] * x.astype(
        jnp.float32)
    return y.astype(x.dtype)


class SSMCache(NamedTuple):
    conv: jax.Array  # (B, conv_width-1, conv_channels)
    state: jax.Array  # (B, H, P, N) fp32


def ssm_init_cache(dims: SSMDims, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, dims.conv_width - 1, dims.conv_channels), dtype),
        state=jnp.zeros((batch, dims.n_heads, dims.head_dim, dims.n_state),
                        jnp.float32),
    )


def mamba_block(params: dict, dims: SSMDims, u: jax.Array, *,
                norm_eps: float = 1e-6, impl: str = "chunked",
                return_cache: bool = False):
    """Full Mamba2 block on a sequence.  u (B, L, D) -> (B, L, D).

    With ``return_cache`` also returns the decode :class:`SSMCache` (terminal
    recurrent state + last conv window) so prefill hands off to decode.
    """
    z = jnp.einsum("bld,di->bli", u, params["z_proj"])
    x_raw = jnp.einsum("bld,di->bli", u, params["x_proj"])
    bc_raw = jnp.concatenate(
        [jnp.einsum("bld,dn->bln", u, params["b_proj"]),
         jnp.einsum("bld,dn->bln", u, params["c_proj"])], axis=-1)
    dt_raw = jnp.einsum("bld,dh->blh", u, params["dt_proj"])
    x = causal_conv(params["conv_x_w"], params["conv_x_b"], x_raw)
    bc = causal_conv(params["conv_bc_w"], params["conv_bc_b"], bc_raw)
    b_in = bc[..., : dims.n_state]
    c_in = bc[..., dims.n_state:]
    conv_in = jnp.concatenate([x_raw, bc_raw], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    xh = x.reshape(x.shape[0], x.shape[1], dims.n_heads, dims.head_dim)
    h_final = None
    if return_cache or impl == "chunked" or impl == "pallas":
        if impl == "pallas" and not return_cache:
            from repro.kernels.ssd import ops as ssd_ops

            y = ssd_ops.ssd(xh, dt, params["A_log"], params["D"], b_in, c_in,
                            chunk=dims.chunk)
        else:
            y, h_final = ssd_chunked(xh, dt, params["A_log"], params["D"],
                                     b_in, c_in, chunk=dims.chunk,
                                     return_final=True)
    else:
        y = ssd_reference(xh, dt, params["A_log"], params["D"], b_in, c_in)
    y = y.reshape(x.shape)
    y = gated_rms_norm(params["norm"], y, z, eps=norm_eps)
    out = jnp.einsum("bli,id->bld", y, params["out_proj"])
    if return_cache:
        W = dims.conv_width
        cache = SSMCache(conv=conv_in[:, -(W - 1):, :], state=h_final)
        return out, cache
    return out


def mamba_block_decode(params: dict, dims: SSMDims, u: jax.Array,
                       cache: SSMCache, *, norm_eps: float = 1e-6
                       ) -> tuple[jax.Array, SSMCache]:
    """Single-token recurrent step.  u (B, 1, D) -> (B, 1, D)."""
    B = u.shape[0]
    ut = u[:, 0]
    z = jnp.einsum("bd,di->bi", ut, params["z_proj"])
    x_raw = jnp.einsum("bd,di->bi", ut, params["x_proj"])
    bc_raw = jnp.concatenate(
        [jnp.einsum("bd,dn->bn", ut, params["b_proj"]),
         jnp.einsum("bd,dn->bn", ut, params["c_proj"])], axis=-1)
    dt_raw = jnp.einsum("bd,dh->bh", ut, params["dt_proj"])
    conv_in = jnp.concatenate([x_raw, bc_raw], axis=-1)  # (B, C)
    window = jnp.concatenate([cache.conv, conv_in[:, None, :]], axis=1)
    conv_w = jnp.concatenate([params["conv_x_w"], params["conv_bc_w"]],
                             axis=-1)
    conv_b = jnp.concatenate([params["conv_x_b"], params["conv_bc_b"]])
    conv_out = jnp.einsum("bwc,wc->bc", window, conv_w)
    conv_out = jax.nn.silu(
        (conv_out + conv_b).astype(jnp.float32)
    ).astype(u.dtype)
    x = conv_out[..., : dims.d_inner]
    b_in = conv_out[..., dims.d_inner: dims.d_inner + dims.n_state]
    c_in = conv_out[..., dims.d_inner + dims.n_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)  # (B,H)
    xh = x.reshape(B, dims.n_heads, dims.head_dim).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None],
                     b_in.astype(jnp.float32))
    state = cache.state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c_in.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, dims.d_inner).astype(u.dtype)
    y = gated_rms_norm(params["norm"], y, z, eps=norm_eps)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"])
    new_cache = SSMCache(conv=window[:, 1:], state=state)
    return out[:, None, :], new_cache
