"""Train/serve step factories — the functions the dry-run lowers.

``make_train_step``: value_and_grad over the model loss, global-norm clip,
optional EF-int8 gradient compression, optimizer update, donated buffers.
Optional microbatch gradient accumulation runs as a ``lax.scan`` whose
per-microbatch backward overlaps the accumulated psum under GSPMD.

``make_prefill_step`` / ``make_decode_step``: serving entry points
(decode_step is what the ``decode_*``/``long_*`` dry-run cells lower).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.compression import EFState, compress_grads, ef_init
from repro.optim import Optimizer, build_optimizer, clip_by_global_norm, \
    cosine_schedule


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    ef_state: Optional[EFState]
    step: jax.Array


def init_train_state(model, key, *, compress: bool = False) -> TrainState:
    params = model.init(key)
    optimizer = build_optimizer(model.cfg)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        ef_state=ef_init(params) if compress else None,
        step=jnp.zeros((), jnp.int32),
    )


def abstract_train_state(model, *, compress: bool = False):
    return jax.eval_shape(
        lambda: init_train_state(model, jax.random.key(0), compress=compress))


def make_train_step(model, *, base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, max_grad_norm: float = 1.0,
                    compress: bool = False, accum_steps: int = 1,
                    accum_dtype=jnp.float32):
    optimizer = build_optimizer(model.cfg)
    lr_fn = cosine_schedule(base_lr, warmup, total_steps)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def micro(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
            return (acc, loss_acc + loss), None

        def split(path, x):
            # batch axis is dim 0 except M-RoPE "positions" (3, B, S)
            name = str(getattr(path[-1], "key", ""))
            if name == "positions":
                r = x.reshape(x.shape[:1] + (accum_steps,
                                             x.shape[1] // accum_steps)
                              + x.shape[2:])
                return jnp.moveaxis(r, 1, 0)  # (accum, 3, B/accum, S)
            return x.reshape((accum_steps, x.shape[0] // accum_steps)
                             + x.shape[1:])

        micro_batches = jax.tree_util.tree_map_with_path(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (grads, loss_sum), _ = jax.lax.scan(
            micro, (zeros, jnp.zeros(())), micro_batches)
        scale = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
        return loss_sum * scale, {"ce": loss_sum * scale,
                                  "aux": jnp.zeros(())}, grads

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, metrics, grads = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        ef_state = state.ef_state
        if compress and ef_state is not None:
            grads, ef_state, _ = compress_grads(grads, ef_state)
        lr = lr_fn(state.step)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params, lr)
        new_state = TrainState(params=params, opt_state=opt_state,
                               ef_state=ef_state, step=state.step + 1)
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **metrics}
        return new_state, out_metrics

    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model):
    def decode_step(params, batch, cache):
        return model.decode_step(params, batch, cache)

    return decode_step
