"""Collective-byte accounting from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but NOT collective
traffic, so we parse the HLO.  Two parts:

1. Per-op ring wire bytes (per participating device):
     all-gather         out_bytes · (S−1)/S
     reduce-scatter     out_bytes · (S−1)        (result is the shard)
     all-reduce         2 · bytes · (S−1)/S      (reduce-scatter + all-gather)
     all-to-all         bytes · (S−1)/S
     collective-permute bytes                    (one hop)
   S = replica-group size parsed per op (model=16 / data=16 / pod=2 differ).

2. **Loop awareness**: scanned models put their per-layer collectives inside
   ``while`` bodies that execute L (× microbatch) times.  We build the
   computation graph, read ``known_trip_count`` from each while's
   backend_config, and multiply body collectives through (recursively —
   grad-accumulation scans nest the layer scan).  Without this the
   collective term is undercounted by ~two orders of magnitude.

Async ``-start``/``-done`` pairs are counted once at start.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<result>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(",
)
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\'"]?:\s*\{\s*[\'"]n[\'"]:\s*[\'"]?(\d+)')


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [G, S] = G groups of size S
    return 1


def _wire_bytes(op: str, size: int, s: int) -> float:
    if op == "all-gather":
        return size * (s - 1) / s
    if op == "reduce-scatter":
        return size * (s - 1)
    if op == "all-reduce":
        return 2.0 * size * (s - 1) / s
    if op == "all-to-all":
        return size * (s - 1) / s
    return float(size)  # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes_per_device: float
    by_op: dict
    op_counts: dict


_DOT_RE = re.compile(
    r"=\s*(?P<result>[a-z0-9]+\[[0-9,]*\])\S*\s+dot\("
    r"\s*%?(?P<lhs>[\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                     r"(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\])")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])")

# ops that are pure control/aliasing — no real HBM traffic of their own
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "while", "conditional", "call", "after-all",
    "opt-barrier", "partition-id", "replica-id", "iota",
}
_OPNAME_RE = re.compile(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\])\S*\s+"
                        r"([\w\-]+)\(")


def _dims(shape_text: str) -> list[int]:
    inner = shape_text.split("[")[1].rstrip("]")
    return [int(d) for d in inner.split(",") if d]


def _comp_flops(lines: list[str], header: str) -> float:
    """2 × |output| × |contracted| summed over dot ops in one computation.

    Post-optimization HLO prints operands as bare %names, so lhs shapes are
    resolved through a per-computation symbol table (defs + header params).
    """
    table: dict[str, str] = {}
    for name, shape in _PARAM_RE.findall(header):
        table[name] = shape
    for line in lines:
        d = _DEF_RE.match(line)
        if d and d.group(2).startswith(("(",)) is False:
            table[d.group(1)] = d.group(2)
        for name, shape in _PARAM_RE.findall(line):
            table.setdefault(name, shape)
    flops = 0.0
    for line in lines:
        m = _DOT_RE.search(line)
        if not m:
            continue
        out_elems = 1
        for d in _dims(m.group("result")):
            out_elems *= d
        lhs_shape = table.get(m.group("lhs"))
        contracted = 1
        cm = _CONTRACT_RE.search(line)
        if lhs_shape and cm:
            lhs = _dims(lhs_shape)
            for i in cm.group(1).split(","):
                if i and int(i) < len(lhs):
                    contracted *= lhs[int(i)]
        flops += 2.0 * out_elems * contracted
    return flops


def _line_bytes(line: str) -> float:
    """Approximate HBM traffic: result + operand bytes of compute ops."""
    m = _OPNAME_RE.search(line)
    if not m or m.group(1) in _NO_TRAFFIC:
        return 0.0
    return float(_shape_bytes(line))


@dataclasses.dataclass
class ProgramStats:
    flops_per_device: float
    bytes_per_device: float


def program_stats(hlo_text: str) -> ProgramStats:
    """Loop-aware per-device dot-FLOPs and HBM-byte estimates.

    ``compiled.cost_analysis()`` does not multiply trip counts through
    *nested* while loops (grad-accumulation scan × layer scan), undercounting
    scanned models by orders of magnitude; this walks the computation graph
    exactly like :func:`collective_stats`.
    """
    comps, entry, edges, headers = _computations(hlo_text,
                                                 return_headers=True)
    own_f = {n: _comp_flops(ls, headers.get(n, ""))
             for n, ls in comps.items()}
    # bytes: only instructions of loop/entry computations — fusion bodies
    # never touch HBM themselves (their traffic is the call-site result,
    # already counted in the caller).  ×2 ≈ read + write.
    called = {child for name in edges for child, trip in edges[name]
              if trip == 1}
    own_b = {
        n: (0.0 if n in called else
            2.0 * sum(_line_bytes(l) for l in ls))
        for n, ls in comps.items()
    }
    memo_f: dict[str, float] = {}
    memo_b: dict[str, float] = {}

    def total_f(name, stack=()):
        if name in memo_f:
            return memo_f[name]
        if name in stack or name not in comps:
            return 0.0
        f = own_f[name]
        for child, trip in edges[name]:
            f += trip * total_f(child, stack + (name,))
        memo_f[name] = f
        return f

    def total_b(name, stack=()):
        if name in memo_b:
            return memo_b[name]
        if name in stack or name not in comps:
            return 0.0
        b = own_b[name]
        for child, trip in edges[name]:
            if trip > 1 or child not in called:  # while bodies only
                b += trip * total_b(child, stack + (name,))
        memo_b[name] = b
        return b

    if entry is None:
        return ProgramStats(0.0, 0.0)
    return ProgramStats(flops_per_device=total_f(entry),
                        bytes_per_device=total_b(entry))


_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|true_computation=|false_computation=|"
    r"branch_computations=\{)%?([\w.\-]+)")


def _computations(hlo_text: str, return_headers: bool = False):
    """comps, entry, edges where edges follow while bodies (× trip count)
    AND fusion/call/conditional targets (× 1) — dots live in fused
    computations, which are only reachable through ``calls=``."""
    comps: dict[str, list[str]] = {}
    headers: dict[str, str] = {}
    entry = None
    current = None
    edges = defaultdict(list)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_HEADER_RE.match(stripped)
        if m and ("->" in stripped):
            current = m.group(1)
            comps[current] = []
            headers[current] = stripped
            if stripped.startswith("ENTRY"):
                entry = current
            continue
        if line.rstrip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
            w = _WHILE_RE.search(stripped)
            if w:
                trip_m = _TRIP_RE.search(stripped)
                trip = int(trip_m.group(1)) if trip_m else 1
                edges[current].append((w.group(2), trip))
                continue
            for target in _CALL_RE.findall(stripped):
                edges[current].append((target, 1))
    if return_headers:
        return comps, entry, edges, headers
    return comps, entry, edges


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Loop-aware per-device ring wire bytes over every collective."""
    comps, entry, while_edges = _computations(hlo_text)

    own_bytes: dict[str, float] = defaultdict(float)
    own_by_op: dict[str, dict] = defaultdict(lambda: defaultdict(float))
    own_counts: dict[str, dict] = defaultdict(lambda: defaultdict(int))
    for name, lines in comps.items():
        for line in lines:
            m = _OP_RE.search(line)
            if m and m.group("suffix") != "-done":
                op = m.group("op")
                b = _wire_bytes(op, _shape_bytes(m.group("result")),
                                max(_group_size(line), 1))
                own_bytes[name] += b
                own_by_op[name][op] += b
                own_counts[name][op] += 1

    # ---- recursive totals ---------------------------------------------
    memo_b: dict[str, float] = {}
    memo_ops: dict[str, dict] = {}
    memo_cnt: dict[str, dict] = {}

    def total(name: str, stack=()):  # cycles impossible in HLO, but guard
        if name in memo_b:
            return memo_b[name], memo_ops[name], memo_cnt[name]
        if name in stack or name not in comps:
            return 0.0, {}, {}
        b = own_bytes[name]
        ops = dict(own_by_op[name])
        cnt = dict(own_counts[name])
        for child, trip in while_edges[name]:
            cb, cops, ccnt = total(child, stack + (name,))
            b += trip * cb
            for k, v in cops.items():
                ops[k] = ops.get(k, 0.0) + trip * v
            for k, v in ccnt.items():
                cnt[k] = cnt.get(k, 0) + trip * v
        memo_b[name], memo_ops[name], memo_cnt[name] = b, ops, cnt
        return b, ops, cnt

    if entry is None:
        entry = max(comps, key=lambda n: own_bytes[n], default=None)
    if entry is None:
        return CollectiveStats(0.0, {}, {})
    b, ops, cnt = total(entry)
    return CollectiveStats(wire_bytes_per_device=b, by_op=ops, op_counts=cnt)
