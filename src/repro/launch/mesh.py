"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no JAX device state.  The dry-run entrypoint
(`dryrun.py`) sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import; everything else in the repo sees the 1 real device.

Single pod: (16, 16) = ("data", "model") — 256 v5e chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips, the "pod"
axis is pure data parallelism across the DCN/ICI pod boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "run under dryrun.py (sets xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_elastic_mesh(n_data: int, n_model: int = 16):
    """Smaller DP width after losing spot capacity (elastic resize)."""
    n = n_data * n_model
    devices = jax.devices()[:n]
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         devices=devices)


def batch_axes_of(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
