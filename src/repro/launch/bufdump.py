import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Debug tool: lower one cell and print the largest HLO buffers + where
they come from (op kind + metadata), to localize memory blow-ups."""
import argparse
import collections
import re

_DT = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "s8": 1,
       "f16": 2, "s64": 8, "u8": 1}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--min-gib", type=float, default=0.25)
    args = ap.parse_args()

    from repro.launch import dryrun as dr

    cfg_mp = args.mesh == "multi"
    # reuse run_cell's lowering path but keep the compiled object
    import jax
    from jax.sharding import PartitionSpec as P

    # monkeypatch: capture hlo text via run_cell? simpler: inline lower
    import dataclasses as _dc

    from repro.configs import get_config
    from repro.models.config import SHAPES

    art_holder = {}
    orig_stats = dr.collective_stats

    def capture(hlo):
        art_holder["hlo"] = hlo
        return orig_stats(hlo)

    dr.collective_stats = capture
    art = dr.run_cell(args.arch, args.shape, cfg_mp)
    hlo = art_holder["hlo"]

    counts = collections.Counter()
    examples = {}
    for line in hlo.splitlines():
        m = re.search(r"=\s*([a-z0-9]+)\[([0-9,]+)\]\S*\s+([\w\-]+)\(", line)
        if not m:
            continue
        dt, dims, op = m.groups()
        if dt not in _DT:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * _DT[dt]
        if b < args.min_gib * 2**30:
            continue
        key = f"{dt}[{dims}] {b/2**30:6.2f}GiB op={op}"
        counts[key] += 1
        if key not in examples:
            meta = re.search(r'op_name="([^"]*)"', line)
            examples[key] = meta.group(1)[:120] if meta else ""
    print(f"peak estimate: {art['memory']['peak_bytes_estimate']/2**30:.2f} "
          f"GiB (args {art['memory']['argument_bytes']/2**30:.2f}, temp "
          f"{art['memory']['temp_bytes']/2**30:.2f}, alias "
          f"{art['memory']['alias_bytes']/2**30:.2f})")
    for key, c in counts.most_common(args.top):
        print(f"{c:4d} x {key}\n        {examples[key]}")


if __name__ == "__main__":
    main()
