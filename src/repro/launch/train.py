"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On this CPU container only ``--smoke`` configs are runnable end-to-end; the
full configs are exercised through ``dryrun.py``.  On a real pod the same
driver runs the production mesh (``--mesh single|multi``) with the sharding
rules from repro.distributed.
"""
import argparse
import dataclasses
import sys
import tempfile
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    import jax

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.data.pipeline import DataPipeline
    from repro.models.registry import build_model
    from repro.train.steps import init_train_state, make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0),
                             compress=args.compress_grads)
    data = DataPipeline(vocab_size=cfg.vocab_size, global_batch=args.batch,
                        seq_len=args.seq, seed=0)
    if cfg.input_mode != "tokens" or cfg.family == "encdec":
        print(f"{args.arch}: tokens-only launcher; use tests/examples for "
              "frontend-stub archs", file=sys.stderr)
        return
    step_fn = jax.jit(make_train_step(model, compress=args.compress_grads),
                      donate_argnums=(0,))
    ckpt = CheckpointManager(args.ckpt_dir or tempfile.mkdtemp("repro_train"))
    t0 = time.time()
    for i in range(args.steps):
        state, m = step_fn(state, data.next())
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    ckpt.save(args.steps, state, extra={"data": data.state()}, blocking=True)
    print(f"checkpointed step {args.steps} -> {ckpt.directory}")


if __name__ == "__main__":
    main()
