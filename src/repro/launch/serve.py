"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Runs the batched server with the spot-aware frontend (the paper's admission
controller dispatching requests between spot slots and on-demand capacity).
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--delta", type=float, default=5.0)
    ap.add_argument("--k", type=float, default=10.0)
    args = ap.parse_args()

    import jax

    from repro.cluster.orchestrator import OnlineAdmissionController
    from repro.configs import get_config
    from repro.core import Exponential
    from repro.models.registry import build_model
    from repro.serving.engine import BatchedServer, SpotServingFrontend

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    server = BatchedServer(model, params, max_batch=4,
                           max_len=args.prompt_len + args.max_new + 8)
    ctl = OnlineAdmissionController(delta=args.delta, eta=0.1, r0=2.0,
                                    window_jobs=16)
    frontend = SpotServingFrontend(server, spot_process=Exponential(1 / 3.0),
                                   controller=ctl, k_cost=args.k)
    out = frontend.run_stream(Exponential(1 / 2.0),
                              n_requests=args.requests,
                              prompt_len=args.prompt_len,
                              max_new=args.max_new, vocab=cfg.vocab_size)
    for k, v in out.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
