import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective artifacts.

MUST be the process entrypoint (the XLA_FLAGS line above runs before any
other import — jax locks the device count at first init).

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both  # everything

Artifacts land in experiments/artifacts/<arch>__<shape>__<mesh>.json and are
the single source of truth for EXPERIMENTS.md §Dry-run/§Roofline.  Completed
cells are skipped on re-run (--force overrides).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config, supported_shapes
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
    zero1_state_specs,
)
from repro.launch.hlo_analysis import collective_stats, program_stats
from repro.launch.mesh import batch_axes_of, make_production_mesh, mesh_sizes
from repro.models.base import ParallelContext
from repro.models.config import SHAPES
from repro.models.registry import build_model, input_specs
from repro.optim.adafactor import AdafactorState
from repro.optim.adamw import AdamWState
from repro.train.steps import abstract_train_state, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "artifacts")


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def opt_state_specs(opt_state_abs, pspecs, params_abs, *, data_axes,
                    data_size, zero1: bool):
    """Specs for optimizer state mirroring the param spec tree."""
    base = zero1_state_specs(pspecs, params_abs, data_axes=data_axes,
                             data_size=data_size) if zero1 else pspecs
    if isinstance(opt_state_abs, AdamWState):
        return AdamWState(m=base, v=base, count=P())
    if isinstance(opt_state_abs, AdafactorState):
        def drop_last(spec, leaf_p, leaf_s):
            dims = list(spec) + [None] * (len(leaf_p.shape) - len(spec))
            return P(*dims[:-1]) if len(leaf_p.shape) >= 2 else P(*dims)

        def drop_second_last(spec, leaf_p, leaf_s):
            dims = list(spec) + [None] * (len(leaf_p.shape) - len(spec))
            if len(leaf_p.shape) >= 2:
                return P(*(dims[:-2] + dims[-1:]))
            return P(None)

        vr = jax.tree.map(drop_last, pspecs, params_abs, params_abs,
                          is_leaf=lambda x: isinstance(x, P))
        vc = jax.tree.map(drop_second_last, pspecs, params_abs, params_abs,
                          is_leaf=lambda x: isinstance(x, P))
        return AdafactorState(m=base, vr=vr, vc=vc, count=P())
    raise TypeError(type(opt_state_abs))


def batch_divisible_specs(batch_abs, batch_axes, mesh):
    """Replicate the batch dim when it does not divide the DP shards."""
    n = 1
    for a in batch_axes:
        n *= mesh.shape[a]
    specs = batch_specs(batch_abs, batch_axes)

    def fix(spec, leaf):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, d in enumerate(dims):
            if d == batch_axes or d == batch_axes[0]:
                if leaf.shape[i] % n:
                    dims[i] = None
        return P(*dims)

    return jax.tree.map(fix, specs, batch_abs,
                        is_leaf=lambda x: isinstance(x, P))


def tpu_native_activation_bytes(cfg, cell, *, dp_size: int,
                                model_size: int) -> int:
    """Analytic bf16-native workspace model (per device).

    The CPU host backend's float-normalization pass shadows bf16 buffers
    touched by float ops with fp32 copies, inflating ``memory_analysis()``
    for train cells by ~2-3×; TPU compiles bf16 natively.  This model counts
    the real resident set: the per-layer residual carry stack (scan AD saves
    the bf16 layer inputs), a few in-flight activation tensors, the CE
    logits chunk, fp32 gradient accumulators, and MoE dispatch buffers.
    Reported as ``tpu_peak_model`` next to the raw number (§Dry-run).
    """
    D = cfg.d_model
    if cell.kind == "train":
        micro_rows = max(cell.global_batch // max(cfg.train_accum, 1), 1)
        rows = max(micro_rows // dp_size, 1)
        toks = rows * cell.seq_len
        layers = cfg.num_layers + (cfg.num_encoder_layers or 0)
        stack = layers * toks * D * 2  # bf16 residual carries
        work = 6 * toks * D * 2  # a few live activation tensors
        ce_chunk = (toks // 16) * max(cfg.vocab_size // model_size, 1) * 4
        moe = 0
        if cfg.family == "moe":
            cap = int(toks * cfg.num_experts_per_tok * cfg.capacity_factor)
            moe = 3 * cap * max(D, cfg.moe_d_ff) * 2
        shards = dp_size * model_size if cfg.fsdp else model_size
        grad_acc = (cfg.param_count() * 4 // shards
                    if cfg.train_accum > 1 else 0)
        return int(stack + work + ce_chunk + moe + grad_acc)
    kh = max(cfg.num_kv_heads, 1)
    hd = cfg.resolved_head_dim
    shard = model_size if (kh % model_size == 0 or hd % model_size == 0) \
        else 1
    if cell.kind == "prefill":
        rows = max(cell.global_batch // dp_size, 1)
        toks = rows * cell.seq_len
        work = 8 * toks * D * 2
        cache = 2 * cfg.num_layers * toks * kh * hd * 2 // shard
        return int(work + cache)
    # decode: per-layer slice workspace only (cache is in argument bytes)
    rows = max(cell.global_batch // dp_size, 1)
    return int(4 * rows * cell.seq_len * kh * hd * 4 // shard)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        import dataclasses as _dc

        cfg = _dc.replace(cfg, **overrides)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = batch_axes_of(mesh)
    sizes = mesh_sizes(mesh)
    dp_size = 1
    for a in baxes:
        dp_size *= sizes[a]
    ctx = ParallelContext(mesh=mesh, batch_axes=baxes)
    model = build_model(cfg, ctx)

    params_abs = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = param_specs(params_abs, model_size=sizes["model"],
                         num_heads=cfg.num_heads,
                         num_kv_heads=cfg.num_kv_heads)
    if cfg.fsdp:  # ZeRO-3: params additionally sharded over the data axis
        pspecs = zero1_state_specs(pspecs, params_abs, data_axes=baxes,
                                   data_size=dp_size)
    specs = input_specs(cfg, cell)
    bspecs = batch_divisible_specs(specs["batch"], baxes, mesh)
    t0 = time.time()

    with mesh:
        if cell.kind == "train":
            state_abs = abstract_train_state(model)
            ospecs = opt_state_specs(
                state_abs.opt_state, pspecs, params_abs,
                data_axes=baxes, data_size=dp_size,
                zero1=cfg.zero1_optimizer_sharding)
            from repro.train.steps import TrainState

            sspecs = TrainState(params=pspecs, opt_state=ospecs,
                                ef_state=None, step=P())
            import jax.numpy as _jnp

            train_step = make_train_step(
                model, accum_steps=cfg.train_accum,
                accum_dtype=_jnp.bfloat16
                if cfg.grad_accum_dtype == "bfloat16" else _jnp.float32)
            metric_specs = {"loss": P(), "grad_norm": P(), "lr": P(),
                            "ce": P(), "aux": P()}
            jitted = jax.jit(
                train_step,
                in_shardings=(_ns(mesh, sspecs), _ns(mesh, bspecs)),
                out_shardings=(_ns(mesh, sspecs), _ns(mesh, metric_specs)),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_abs, specs["batch"])
        elif cell.kind == "prefill":
            jitted = jax.jit(
                model.prefill,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
            )
            lowered = jitted.lower(params_abs, specs["batch"])
        else:  # decode
            cspecs = cache_specs(specs["cache"], batch_axes=baxes,
                                 model_size=sizes["model"],
                                 shard_kv_seq=cfg.shard_kv_seq)
            # batch dim of the cache must also respect divisibility
            def fix_cache(spec, leaf):
                dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
                n = 1
                for a in baxes:
                    n *= mesh.shape[a]
                for i, d in enumerate(dims):
                    if (d == baxes or d == baxes[0]) and leaf.shape[i] % n:
                        dims[i] = None
                return P(*dims)

            cspecs = jax.tree.map(fix_cache, cspecs, specs["cache"],
                                  is_leaf=lambda x: isinstance(x, P))
            jitted = jax.jit(
                model.decode_step,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs),
                              _ns(mesh, cspecs)),
                out_shardings=(None, _ns(mesh, cspecs)),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, specs["batch"], specs["cache"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    prog = program_stats(hlo)  # loop-aware (cost_analysis misses nesting)

    artifact = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multipod_2x16x16" if multi_pod else "pod_16x16",
        "chips": 512 if multi_pod else 256,
        "cell": {"seq_len": cell.seq_len, "global_batch": cell.global_batch,
                 "kind": cell.kind},
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": prog.flops_per_device,
        "bytes_accessed_per_device": prog.bytes_per_device,
        "xla_cost_flops_per_device": cost.get("flops", 0.0),
        "xla_cost_bytes_per_device": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
            # donated buffers alias their outputs — count once
            "peak_bytes_estimate": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
            # bf16-native (TPU) model: args + analytic workspace — the raw
            # CPU number includes fp32 float-normalization shadows
            "tpu_peak_model": mem.argument_size_in_bytes
            + tpu_native_activation_bytes(cfg, cell, dp_size=dp_size,
                                          model_size=sizes["model"]),
        },
        "collectives": {
            "wire_bytes_per_device": coll.wire_bytes_per_device,
            "by_op": coll.by_op,
            "op_counts": coll.op_counts,
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return artifact


def artifact_path(arch, shape_name, multi_pod):
    mesh = "multipod_2x16x16" if multi_pod else "pod_16x16"
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    return os.path.join(ARTIFACT_DIR, f"{arch}__{shape_name}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--overrides", type=str, default=None,
                    help="JSON dict of ModelConfig overrides (perf tuning)")
    ap.add_argument("--tag", type=str, default=None,
                    help="artifact filename suffix for override runs")
    args = ap.parse_args()

    cells = []
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = supported_shapes(cfg) if (args.all or not args.shape) \
            else [args.shape]
        for shape in shapes:
            if shape not in supported_shapes(cfg):
                print(f"SKIP {arch} × {shape} (unsupported: sub-quadratic "
                      "shape on full-attention arch)")
                continue
            meshes = {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]
            for mp in meshes:
                cells.append((arch, shape, mp))

    overrides = json.loads(args.overrides) if args.overrides else None
    ok = fail = skip = 0
    for arch, shape, mp in cells:
        path = artifact_path(arch, shape, mp)
        if args.tag:
            path = path.replace(".json", f"__{args.tag}.json")
        if os.path.exists(path) and not args.force:
            print(f"CACHED {os.path.basename(path)}")
            skip += 1
            continue
        label = f"{arch} × {shape} × {'multi' if mp else 'single'}"
        print(f"RUN    {label} ...", flush=True)
        try:
            art = run_cell(arch, shape, mp, overrides)
            if args.tag:
                art["tag"] = args.tag
                art["overrides"] = overrides
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
            peak = art["memory"]["peak_bytes_estimate"] / 2**30
            print(f"OK     {label}: compile={art['compile_s']}s "
                  f"flops/dev={art['flops_per_device']:.3e} "
                  f"peak/dev={peak:.2f}GiB "
                  f"coll/dev={art['collectives']['wire_bytes_per_device']:.3e}B",
                  flush=True)
            ok += 1
        except Exception:
            print(f"FAIL   {label}\n{traceback.format_exc()}", flush=True)
            fail += 1
    print(f"\ndry-run summary: ok={ok} cached={skip} fail={fail}")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
