"""Multi-region routing subsystem tests.

The acceptance contract of the region engine (repro.core.regions +
repro.core.engine's region loop):

  * a **degenerate** topology (1 region, zero hazard, unit price) with a
    non-routing kernel reproduces the PR-3 engine **bit-for-bit** per seed
    under ALL THREE executors — run_region_sim / run_region_sweep are
    indistinguishable from run_sim / run_sweep (and, with market kernels
    and region economics, from the 1-pool run_market_sim) — property-tested
    across random configs and tile sizes;
  * all scalar statistics are exactly invariant under region *relabeling*
    (permuting regions with their tags) — per-region PRNG streams are keyed
    by region tag, not position;
  * routing rules behave as named: ``cheapest`` concentrates admissions on
    the cheapest region, ``home`` never crosses regions, ``weighted``
    follows traced logits, and capacity partitions are respected (a full
    region rejects even when another partition has room under ``home``);
  * the pooled region knapsack LP lower-bounds the engine and the routed
    bound is never worse than the home-only bound (the value of routing);
  * the Theorem-1 region identity holds exactly on preemption-free runs;
  * the host MultiRegionCluster mirrors the engine's routing semantics and
    its ``what_if_sweep`` runs on-device grids against the same topology.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: deterministic fallback (see
    from _propcheck import given, settings, st  # requirements-dev.txt)

import jax
import jax.numpy as jnp

from repro.core import (
    Exponential,
    Gamma,
    NoticeAwareKernel,
    Region,
    RegionTopology,
    RoutingKernel,
    SingleSlotKernel,
    SpotMarket,
    ThreePhaseKernel,
    Uniform,
    region_cost_lower_bound,
    region_knapsack_lp,
    run_market_sim,
    run_region_sim,
    run_region_sweep,
    run_sim,
    run_sweep,
    theorem1_region_cost,
)
from repro.core.engine import INT_STATS
from repro.core.waittime import DeterministicWait

LAM, MU, K = 1 / 12, 1 / 24, 10.0


def _hetero_topology(hazard_scale: float = 1.0) -> RegionTopology:
    return RegionTopology(regions=(
        Region(Exponential(LAM / 4), Exponential(1 / 30.0), price=0.5,
               hazard=0.02 * hazard_scale, notice=0.5, rmax=16),
        Region(Exponential(LAM / 2), Exponential(1 / 40.0), price=0.3,
               hazard=0.05 * hazard_scale, notice=0.01, rmax=8),
        Region(Exponential(LAM / 8), Exponential(1 / 60.0), price=0.2,
               rmax=4),
        Region(Exponential(LAM / 8), Exponential(1 / 90.0), price=0.1,
               hazard=0.10 * hazard_scale, notice=2.0, rmax=16),
    ))


def assert_stats_equal(a: dict, b: dict, context=""):
    for name, v in a.items():
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(b[name]),
            err_msg=f"{name} diverged ({context})")


def assert_stats_close(xla: dict, pal: dict, context=""):
    """The cross-layout contract vs the production XLA executor: integer
    event accounting bitwise, float sums to ~ulp rtol."""
    for name, v in xla.items():
        if name in INT_STATS:
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(pal[name]),
                err_msg=f"{name} diverged ({context})")
        else:
            np.testing.assert_allclose(
                np.asarray(v), np.asarray(pal[name]), rtol=1e-5,
                err_msg=f"{name} diverged ({context})")


# ---------------------------------------------------------------------------
# Degenerate topology == PR-3 engine, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "job,spot,r",
    [
        (Exponential(LAM), Exponential(MU), 1.5),
        (Gamma(12.0, 1.0), Exponential(MU), 3.0),
        (Exponential(LAM), Uniform(0.0, 48.0), 2.5),
        (Exponential(LAM), Exponential(MU), 0.0),
    ],
    ids=["mm", "gm", "mu", "r0"],
)
def test_degenerate_region_bit_for_bit(job, spot, r):
    key = jax.random.key(7)
    kernel = ThreePhaseKernel()
    ref = run_sim(job, spot, kernel, {"r": jnp.float32(r)}, k=K,
                  n_events=30_000, key=key)
    new = run_region_sim(RegionTopology.single(job, spot), kernel,
                         {"r": jnp.float32(r)}, k=K, n_events=30_000,
                         key=key)
    for name, v in ref.items():
        assert new[name] == v, name  # identical to the last bit
    assert new["preemptions"] == 0.0 and new["resumed"] == 0.0
    assert new["spot_cost"] == new["spot_served"]  # unit price
    # every admission stays home, every serve lands in region 0
    assert new["cross_region_frac"] == 0.0
    assert new["region_served"][0] == new["spot_served"]
    assert new["region_routed"][0] == new["routed_home"]


def test_degenerate_region_bit_for_bit_single_slot_and_chunked():
    job, spot = Exponential(LAM), Exponential(MU)
    kernel = SingleSlotKernel(wait=DeterministicWait(5.0))
    key = jax.random.key(3)
    ref = run_sim(job, spot, kernel, {}, k=K, n_events=30_000, key=key,
                  rmax=1, chunk_events=4096)
    new = run_region_sim(RegionTopology.single(job, spot, rmax=1), kernel,
                         {}, k=K, n_events=30_000, key=key,
                         chunk_events=4096)
    for name, v in ref.items():
        assert new[name] == v, name


def test_degenerate_region_vs_market_with_economics():
    """A 1-region topology with price/hazard/notice is the 1-pool market,
    bit for bit — including the preemption path and a market-protocol
    kernel (admit_market + on_preempt)."""
    job, spot = Exponential(LAM), Exponential(1 / 40.0)
    kernel = NoticeAwareKernel(checkpoint_time=0.05)
    key = jax.random.key(11)
    mkt = run_market_sim(job, SpotMarket.single(spot, price=0.4, hazard=0.05,
                                                notice=1.0),
                         kernel, kernel.init_params(2.0), k=K,
                         n_events=30_000, key=key, chunk_events=4096)
    reg = run_region_sim(RegionTopology.single(job, spot, price=0.4,
                                               hazard=0.05, notice=1.0),
                         kernel, kernel.init_params(2.0), k=K,
                         n_events=30_000, key=key, chunk_events=4096)
    assert mkt["preemptions"] > 0 and mkt["resumed"] > 0  # the path is live
    for name, v in mkt.items():
        if name.startswith("pool_"):
            np.testing.assert_array_equal(
                np.asarray(reg[name.replace("pool_", "region_")]),
                np.asarray(v), err_msg=name)
        else:
            np.testing.assert_array_equal(np.asarray(reg[name]),
                                          np.asarray(v), err_msg=name)


@settings(max_examples=8, deadline=None)
@given(
    r_lo=st.floats(min_value=0.0, max_value=3.0),
    rmax=st.integers(min_value=1, max_value=12),
    chunk=st.sampled_from([256, 1000, 4096]),
    tile=st.sampled_from([1, 3, 256]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_degenerate_region_sweep_bitwise_property(r_lo, rmax, chunk, tile,
                                                  seed):
    """The ISSUE-4 frozen contract: a 1-region ``run_region_sweep`` is
    bitwise-identical to ``run_sweep`` across random configs and tile
    sizes, under all three executors."""
    job, spot = Exponential(LAM), Exponential(MU)
    params = {"r": jnp.linspace(r_lo, r_lo + 2.0, 3)}
    topo = RegionTopology.single(job, spot, rmax=rmax)
    kw = dict(k=K, n_events=2_000, key=jax.random.key(seed), n_seeds=2,
              chunk_events=chunk, burn_in=128)
    for impl in ("xla", "ref"):
        ref = run_sweep(job, spot, ThreePhaseKernel(), params, rmax=rmax,
                        impl=impl, **kw)
        new = run_region_sweep(topo, ThreePhaseKernel(), params, impl=impl,
                               **kw)
        assert_stats_equal(ref, new, f"impl={impl} seed={seed}")
    ref = run_sweep(job, spot, ThreePhaseKernel(), params, rmax=rmax,
                    impl="pallas", interpret=True, tile=tile, **kw)
    new = run_region_sweep(topo, ThreePhaseKernel(), params, impl="pallas",
                           interpret=True, tile=tile, **kw)
    assert_stats_equal(ref, new, f"impl=pallas tile={tile} seed={seed}")


# ---------------------------------------------------------------------------
# Executor equivalence on heterogeneous topologies (the PR-3 ledger, grown
# a region axis)
# ---------------------------------------------------------------------------
def test_region_sweep_pallas_bit_for_bit():
    topo = _hetero_topology()
    kern = RoutingKernel(NoticeAwareKernel(checkpoint_time=0.05),
                         choice="least_loaded")
    params = {"r": jnp.linspace(0.5, 4.0, 4)}
    kw = dict(k=K, n_events=5_000, key=jax.random.key(0), n_seeds=2,
              chunk_events=2_048)
    ref = run_region_sweep(topo, kern, params, impl="ref", **kw)
    pal = run_region_sweep(topo, kern, params, impl="pallas",
                           interpret=True, **kw)
    assert_stats_equal(ref, pal, "hetero-routing")
    assert_stats_close(run_region_sweep(topo, kern, params, **kw), pal,
                       "hetero-routing")


# ---------------------------------------------------------------------------
# Property: statistics exactly invariant under region relabeling
# ---------------------------------------------------------------------------
_SCALAR_INVARIANTS = ("avg_cost", "avg_delay", "pi0_time", "pi0_spot",
                      "spot_utilization", "jobs_arrived", "spot_served",
                      "ondemand", "preemptions", "resumed", "spot_cost",
                      "routed_home", "cross_region_frac", "time")


@settings(max_examples=6, deadline=None)
@given(perm=st.sampled_from([(1, 0, 2, 3), (3, 2, 1, 0), (2, 3, 0, 1),
                             (1, 2, 3, 0)]),
       r=st.floats(min_value=0.5, max_value=4.0))
def test_region_relabeling_invariance(perm, r):
    topo = _hetero_topology()
    kernel = RoutingKernel(NoticeAwareKernel(checkpoint_time=0.05),
                           choice="cheapest")
    kw = dict(k=K, n_events=15_000, key=jax.random.key(11),
              chunk_events=4096)
    res = run_region_sim(topo, kernel, {"r": jnp.float32(r)}, **kw)
    res_p = run_region_sim(topo.relabel(list(perm)), kernel,
                           {"r": jnp.float32(r)}, **kw)
    for name in _SCALAR_INVARIANTS:
        assert res[name] == res_p[name], name  # exact, not approximate
    inv = [list(perm).index(i) for i in range(4)]
    for name in ("region_served", "region_spot_arrivals", "region_preempted",
                 "region_jobs", "region_routed"):
        np.testing.assert_array_equal(np.asarray(res[name]),
                                      np.asarray(res_p[name])[inv],
                                      err_msg=name)


# ---------------------------------------------------------------------------
# Routing semantics
# ---------------------------------------------------------------------------
def test_routing_rules():
    topo = _hetero_topology(hazard_scale=0.0)
    kw = dict(k=K, n_events=20_000, key=jax.random.key(8))
    cheapest = run_region_sim(topo, RoutingKernel(ThreePhaseKernel(),
                                                  choice="cheapest"),
                              {"r": jnp.float32(3.0)}, **kw)
    # all admissions target region 3 (price 0.1)
    assert np.asarray(cheapest["region_routed"])[:3].sum() == 0
    assert cheapest["cross_region_frac"] > 0.0
    home = run_region_sim(topo, ThreePhaseKernel(),  # no route hook
                          {"r": jnp.float32(3.0)}, **kw)
    assert home["cross_region_frac"] == 0.0
    assert home["routed_home"] == np.asarray(home["region_routed"]).sum()
    # demand follows the per-region job rates under home routing
    jobs = np.asarray(home["region_jobs"])
    assert jobs[1] > jobs[2] and jobs[1] > jobs[3]
    weighted = run_region_sim(
        topo, RoutingKernel(ThreePhaseKernel(), choice="weighted"),
        {"r": jnp.float32(3.0),
         "region_logits": jnp.array([-9.0, -9.0, 9.0, -9.0])}, **kw)
    routed = np.asarray(weighted["region_routed"])
    assert routed[2] > 0 and routed[[0, 1, 3]].sum() == 0
    spread = run_region_sim(topo, RoutingKernel(ThreePhaseKernel(),
                                                choice="least_loaded"),
                            {"r": jnp.float32(3.0)}, **kw)
    assert (np.asarray(spread["region_routed"]) > 0).all()


def test_capacity_partitions_are_respected():
    """rmax_r gates each region separately: under home routing a full
    region rejects to on-demand even while another partition is empty."""
    topo = RegionTopology(regions=(
        Region(Exponential(1.0), Exponential(1e-6), rmax=1),  # swamped
        Region(Exponential(1e-6), Exponential(1.0), rmax=64),  # idle
    ))
    res = run_region_sim(topo, ThreePhaseKernel(), {"r": jnp.float32(8.0)},
                         k=K, n_events=4_000, key=jax.random.key(2))
    region_routed = np.asarray(res["region_routed"])
    assert region_routed[0] >= 1 and region_routed[1] == 0
    assert res["ondemand"] > 0  # overflow went on-demand, not cross-region
    assert np.asarray(res["region_served"])[1] == 0


def test_routing_beats_home_only_on_skewed_topology():
    """Hot demand in a pricey region + idle cheap capacity elsewhere: the
    least-loaded router must beat home-only cost (CRN seeds, wide margin)."""
    mk = lambda: RegionTopology(regions=(
        Region(Exponential(LAM), Exponential(MU / 8), price=0.9, rmax=16),
        Region(Exponential(LAM / 50), Exponential(MU), price=0.1, rmax=16),
    ))
    kw = dict(k=K, n_events=40_000, key=jax.random.key(5), n_seeds=2)
    home = run_region_sweep(mk(), ThreePhaseKernel(),
                            {"r": jnp.float32(4.0)}, **kw)
    routed = run_region_sweep(mk(), RoutingKernel(ThreePhaseKernel(),
                                                  choice="least_loaded"),
                              {"r": jnp.float32(4.0)}, **kw)
    assert routed["avg_cost_job"].mean() < home["avg_cost_job"].mean() - 0.5
    assert routed["cross_region_frac"].mean() > 0.1


# ---------------------------------------------------------------------------
# Batched region sweeps: one jit over (params × k × regions-config × seeds)
# ---------------------------------------------------------------------------
def test_region_sweep_matches_per_point_calls():
    topo = _hetero_topology()
    kernel = RoutingKernel(NoticeAwareKernel(checkpoint_time=0.05),
                           choice="cheapest")
    rs = jnp.linspace(0.5, 4.0, 6)
    key = jax.random.key(0)
    out = run_region_sweep(topo, kernel, {"r": rs}, k=K, n_events=10_000,
                           key=key, n_seeds=2)
    assert out["avg_cost"].shape == (6, 2)
    assert out["region_served"].shape == (6, 2, 4)
    seed_keys = jax.random.split(key, 2)
    for i in (0, 5):
        for s in range(2):
            pt = run_region_sim(topo, kernel, {"r": rs[i]}, k=K,
                                n_events=10_000, key=seed_keys[s])
            assert pt["jobs_arrived"] == out["jobs_arrived"][i, s]
            np.testing.assert_allclose(out["avg_cost"][i, s],
                                       pt["avg_cost"], rtol=1e-6)
            np.testing.assert_array_equal(
                np.asarray(pt["region_routed"]),
                np.asarray(out["region_routed"])[i, s])


def test_region_sweep_regions_config_axis():
    """The region configuration itself is a grid axis of one compiled call
    — including the demand axis (job_scales) the market engine lacks."""
    topo = _hetero_topology()
    kernel = RoutingKernel(NoticeAwareKernel(checkpoint_time=0.05),
                           choice="cheapest")
    scale = np.linspace(0.5, 2.0, 5)
    price_grid = topo.prices()[None, :] * scale[:, None]  # (5, R)
    out = run_region_sweep(topo, kernel, {"r": jnp.float32(3.0)}, k=K,
                           prices=price_grid, n_events=10_000,
                           key=jax.random.key(4), n_seeds=2)
    assert out["avg_cost"].shape == (5, 2)
    cost = out["avg_cost"].mean(-1)
    assert cost[0] < cost[-1]  # pricier regions -> pricier jobs
    # slowing demand everywhere cuts arrivals per (fixed-event) horizon
    out2 = run_region_sweep(topo, kernel, {"r": jnp.float32(3.0)}, k=K,
                            job_scales=np.array([1.0, 4.0])[:, None]
                            * np.ones((1, 4)),
                            n_events=10_000, key=jax.random.key(4),
                            n_seeds=1)
    assert (out2["jobs_arrived"][0] > out2["jobs_arrived"][1]).all()
    # hazard override on a statically hazard-free topology arms preemption
    out3 = run_region_sweep(topo.relabel([0, 1, 2, 3]), kernel,
                            {"r": jnp.float32(3.0)}, k=K, hazards=0.05,
                            n_events=10_000, key=jax.random.key(4),
                            n_seeds=1)
    assert (out3["preemptions"] > 0).all()


# ---------------------------------------------------------------------------
# Region LP + Theorem-1 generalization
# ---------------------------------------------------------------------------
def test_region_lp_degenerate_and_routing_value():
    # 1 region, unit price: the paper's min(1, λδ) bound
    topo1 = RegionTopology.single(Exponential(LAM), Exponential(MU))
    from repro.core import cost_lower_bound
    for delta in (3.0, 27.0):
        out = region_knapsack_lp(K, delta, topo1)
        np.testing.assert_allclose(out["objective"],
                                   cost_lower_bound(K, LAM, MU, delta),
                                   rtol=1e-12)
    # pooling demand against all supply can only improve the floor
    topo = _hetero_topology()
    for delta in (3.0, 27.0):
        routed = region_cost_lower_bound(K, delta, topo, routed=True)
        home = region_cost_lower_bound(K, delta, topo, routed=False)
        assert routed <= home + 1e-12
    # preemption-priced effective costs weaken (raise) the floor
    assert (region_cost_lower_bound(K, 27.0, topo, include_preemption=True)
            >= region_cost_lower_bound(K, 27.0, topo) - 1e-12)


def test_theorem1_region_cost_identity_on_engine_run():
    topo = _hetero_topology(hazard_scale=0.0)  # preemption-free identity
    kernel = RoutingKernel(ThreePhaseKernel(), choice="uniform")
    res = run_region_sim(topo, kernel, {"r": jnp.float32(4.0)}, k=K,
                         n_events=60_000, key=jax.random.key(9),
                         chunk_events=4096)
    # exact empirical identity: (k - avg_cost) * completed
    #   == sum_r (k - c_r) * served_r
    lhs = (K - res["avg_cost"]) * res["jobs_completed"]
    rhs = ((K - topo.prices()) * np.asarray(res["region_served"])).sum()
    np.testing.assert_allclose(lhs, rhs, rtol=2e-5)
    # population form: empirical rates + utilizations plug into the law
    lam_emp = res["arrival_rate"]
    rates_emp = np.asarray(res["region_spot_arrivals"]) / res["time"]
    pred = theorem1_region_cost(K, lam_emp, rates_emp, topo.prices(),
                                np.asarray(res["region_utilization"]))
    np.testing.assert_allclose(pred, res["avg_cost"], rtol=1e-3)
    # the engine respects the pooled LP floor at the realized delay
    lp = region_knapsack_lp(K, res["avg_delay_job"], topo)
    assert res["avg_cost_job"] > lp["objective"] - 0.3


# ---------------------------------------------------------------------------
# Host-side routing: MultiRegionCluster
# ---------------------------------------------------------------------------
def test_multi_region_cluster_mirrors_engine_semantics():
    from repro.cluster.orchestrator import (MultiRegionCluster,
                                            OnlineAdmissionController)

    topo = _hetero_topology()
    ctl = OnlineAdmissionController(delta=27.0, r0=3.0, eta=0.0)
    cluster = MultiRegionCluster(topology=topo, controller=ctl,
                                 route="cheapest", checkpoint_hours=0.05,
                                 seed=3)
    stats = cluster.run(8_000)
    assert stats.jobs_completed > 0 and stats.preemptions > 0
    # cheapest routing: only the cheapest region's queue is ever fed
    assert sum(stats.region_routed[:3]) == 0
    assert sum(stats.region_served[:3]) == 0
    # leg accounting conserves cost exactly like the engine
    spot_spend = stats.spot_cost
    np.testing.assert_allclose(
        stats.total_cost, spot_spend + K * stats.ondemand_served, rtol=1e-9)
    # the on-device what-if grid runs against the same topology
    out = cluster.what_if_sweep([1.0, 3.0], n_events=3_000, n_seeds=2)
    assert out["avg_cost_job"].shape == (2, 2)
    assert out["region_routed"].shape == (2, 2, 4)
    assert np.asarray(out["region_routed"])[:, :, :3].sum() == 0


def test_topology_validation_and_views():
    with pytest.raises(ValueError, match="at least one region"):
        RegionTopology(regions=())
    with pytest.raises(ValueError, match="unique"):
        RegionTopology(regions=(
            Region(Exponential(1.0), Exponential(1.0), tag=0),
            Region(Exponential(1.0), Exponential(1.0), tag=0)))
    topo = _hetero_topology()
    assert topo.total_slots == 16 + 8 + 4 + 16
    np.testing.assert_array_equal(topo.slot_offsets(), [0, 16, 24, 28])
    assert topo.preemptible and not topo.is_degenerate
    assert RegionTopology.single(Exponential(LAM),
                                 Exponential(MU)).is_degenerate
    np.testing.assert_allclose(topo.total_job_rate(), LAM, rtol=1e-12)
