"""The supply-shock contract (repro.core.env + the engine ``env=`` axis).

Frozen guarantees:

  * **Zero-cost off** — ``env=None`` lowers to byte-identical StableHLO
    (sha256 of the whole 24-cell loop × executor × rng matrix, frozen in
    tests/data/hlo_pr6.json) and a single-segment constant timeline
    reproduces the pre-env engine **bit-for-bit** on every loop ×
    executor × rng.
  * **Shock accounting is exact** — storms/blackouts/spikes observed
    equal the timeline's injected counts; shock dwell times are exact
    (the boundary-as-event design means no event interval straddles a
    segment); degradation is bounded by exposure.
  * **Graceful degradation** — :class:`repro.core.market.PanicKernel`
    is the identity without blackouts (bitwise) and routes admissions
    around dead pools/regions under one; the Algorithm-1 learner stays
    finite and bounded across regime flips with the guardrails on.
  * **Loud failure** — malformed timelines, override grids, and run
    plans raise actionable ``ValueError``s at the host boundary, and
    poisoned (NaN/inf) windows raise :class:`NonFiniteStatsError`
    instead of leaking silent NaN averages.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EnvTimeline,
    Exponential,
    NonFiniteStatsError,
    PanicKernel,
    inject_blackout,
    inject_price_spike,
    inject_storm,
    markov_timeline,
    run_market_sim,
    run_market_sweep,
    run_region_sim,
    run_region_sweep,
    run_sim,
    run_sweep,
)
from repro.core.adaptive import adaptive_admission_control
from repro.core.engine import _check_finite_stats
from repro.core.env import Regime, SEG_STORM
from repro.core.market import NoticeAwareKernel, SpotMarket, SpotPool
from repro.core.policies import ThreePhaseKernel
from repro.core.regions import Region, RegionTopology, RoutingKernel

_BASELINE = Path(__file__).parent / "data" / "hlo_pr6.json"

N_EVENTS, CHUNK = 2500, 1024
KEY = jax.random.key(7)


def _market() -> SpotMarket:
    return SpotMarket(pools=(
        SpotPool(arrival=Exponential(0.9), price=1.0, hazard=0.3,
                 notice=0.1),
        SpotPool(arrival=Exponential(0.5), price=0.6, hazard=0.8,
                 notice=0.3),
    ))


def _topo() -> RegionTopology:
    return RegionTopology(regions=(
        Region(job=Exponential(1.2), spot=Exponential(0.9), price=1.0,
               hazard=0.3, notice=0.1, rmax=4),
        Region(job=Exponential(0.7), spot=Exponential(0.5), price=0.6,
               hazard=0.8, notice=0.3, rmax=4),
    ))


def _shock_timeline() -> EnvTimeline:
    tl = EnvTimeline.constant()
    tl = inject_storm(tl, 100.0, 400.0, hazard_mult=6.0)
    tl = inject_blackout(tl, 600.0, 800.0, loc=1, n_locs=2)
    return tl


def _run(loop: str, impl: str, rng: str, env, kernel=None) -> dict:
    kw = dict(k=10.0, n_events=N_EVENTS, key=KEY, burn_in=256,
              chunk_events=CHUNK, impl=impl, rng=rng, interpret=True,
              tile=2, env=env)
    if loop == "single":
        return run_sim(Exponential(1.2), Exponential(0.9),
                       ThreePhaseKernel(), {"r": jnp.float32(2.0)}, **kw)
    if loop == "market":
        kern = kernel or NoticeAwareKernel(checkpoint_time=0.05)
        return run_market_sim(Exponential(1.2), _market(), kern,
                              {"r": jnp.float32(2.0)}, **kw)
    kern = kernel or RoutingKernel(base=NoticeAwareKernel(
        checkpoint_time=0.05), choice="cheapest")
    return run_region_sim(_topo(), kern, {"r": jnp.float32(2.0)}, **kw)


def _assert_bitwise(a: dict, b: dict, extra_keys_ok: bool = False) -> None:
    keys = a.keys() if not extra_keys_ok else (a.keys() & b.keys())
    for name in keys:
        av, bv = np.asarray(a[name]), np.asarray(b[name])
        assert av.shape == bv.shape and (av == bv).all(), (
            f"{name}: {av} != {bv}")


# ---------------------------------------------------------------------------
# Zero-cost off
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "pallas", "ref"])
@pytest.mark.parametrize("rng", ["split", "slab"])
@pytest.mark.parametrize("loop", ["single", "market", "region"])
def test_constant_timeline_is_bitwise_off(loop, impl, rng):
    """A single-segment constant timeline == env=None, bit for bit, on
    every loop × executor × rng (the base keys; env adds its counters)."""
    off = _run(loop, impl, rng, env=None)
    on = _run(loop, impl, rng, env=EnvTimeline.constant())
    for name in off:
        av, bv = np.asarray(off[name]), np.asarray(on[name])
        assert av.shape == bv.shape and (av == bv).all(), (loop, impl, rng,
                                                           name)
    assert on["env_boundaries"] == 0
    assert on["storm_time"] == 0.0 and on["blackout_time"] == 0.0


@pytest.mark.parametrize("rng", ["split", "slab"])
def test_constant_timeline_sweep_bitwise_off(rng):
    """Sweep entries (grid × seeds lanes) obey the same off-contract."""
    kw = dict(k=10.0, n_events=2000, key=KEY, n_seeds=2, burn_in=128,
              chunk_events=1024, rng=rng)
    a = run_market_sweep(Exponential(1.2), _market(),
                         NoticeAwareKernel(checkpoint_time=0.05),
                         {"r": jnp.float32([1.5, 2.5])}, **kw)
    b = run_market_sweep(Exponential(1.2), _market(),
                         NoticeAwareKernel(checkpoint_time=0.05),
                         {"r": jnp.float32([1.5, 2.5])},
                         env=EnvTimeline.constant(), **kw)
    for name in a:
        assert (np.asarray(a[name]) == np.asarray(b[name])).all(), name
    c = run_sweep(Exponential(1.2), Exponential(0.9), ThreePhaseKernel(),
                  {"r": jnp.float32([1.5, 2.5])}, **kw)
    d = run_sweep(Exponential(1.2), Exponential(0.9), ThreePhaseKernel(),
                  {"r": jnp.float32([1.5, 2.5])},
                  env=EnvTimeline.constant(), **kw)
    for name in c:
        assert (np.asarray(c[name]) == np.asarray(d[name])).all(), name
    e = run_region_sweep(_topo(), RoutingKernel(
        base=NoticeAwareKernel(checkpoint_time=0.05), choice="cheapest"),
        {"r": jnp.float32([1.5, 2.5])}, **kw)
    f = run_region_sweep(_topo(), RoutingKernel(
        base=NoticeAwareKernel(checkpoint_time=0.05), choice="cheapest"),
        {"r": jnp.float32([1.5, 2.5])}, env=EnvTimeline.constant(), **kw)
    for name in e:
        assert (np.asarray(e[name]) == np.asarray(f[name])).all(), name


def test_env_off_lowering_frozen():
    """env=None compiles the byte-identical program it did before the env
    axis existed: sha256 of the lowered StableHLO for all 24 matrix cells
    matches the frozen pre-env baseline.  Lowered in a fresh subprocess
    with XLA_FLAGS scrubbed — other test modules override the host
    device count in-process, which perturbs lowered text."""
    baseline = json.loads(_BASELINE.read_text())
    here = Path(__file__).parent
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = os.pathsep.join(
        [str(here.parent / "src"), env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, str(here / "_hlo_matrix.py")],
        capture_output=True, text=True, env=env, check=True)
    fresh = json.loads(proc.stdout)
    for k, v in fresh["tag"].items():
        if baseline[k] != v:
            pytest.skip(f"baseline frozen under {k}={baseline[k]}, "
                        f"running {v}")
    digests = fresh["digests"]
    assert digests.keys() == baseline["digests"].keys()
    moved = [k for k, v in digests.items() if baseline["digests"][k] != v]
    assert not moved, f"env=None lowering changed for cells: {moved}"


# ---------------------------------------------------------------------------
# Exact shock accounting
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl,rng", [("xla", "split"), ("xla", "slab"),
                                      ("pallas", "slab")])
@pytest.mark.parametrize("loop", ["market", "region"])
def test_shock_counter_identities(loop, impl, rng):
    tl = _shock_timeline()
    kw = dict(k=10.0, n_events=6000, key=KEY, burn_in=0, chunk_events=2048,
              impl=impl, rng=rng, interpret=True, tile=2, env=tl)
    if loop == "market":
        out = run_market_sim(Exponential(1.2), _market(),
                             NoticeAwareKernel(checkpoint_time=0.05),
                             {"r": jnp.float32(2.0)}, **kw)
    else:
        out = run_region_sim(_topo(), RoutingKernel(
            base=NoticeAwareKernel(checkpoint_time=0.05),
            choice="cheapest"), {"r": jnp.float32(2.0)}, **kw)
    assert out["storms_observed"] == tl.count_storms() == 1
    assert out["blackouts_observed"] == tl.count_blackouts() == 1
    assert out["env_boundaries"] == 4  # enter/leave storm, enter/leave blk
    # dwell times are exact: dt never spans a segment boundary
    np.testing.assert_allclose(out["storm_time"], 300.0, rtol=1e-5)
    np.testing.assert_allclose(out["blackout_time"], 200.0, rtol=1e-5)
    assert out["degraded_admits"] <= out["shock_arrivals"]


def test_single_loop_blackout_starves_spot():
    """Single-loop blackout: spot supply vanishes over the window, so no
    spot serves can land inside it (clocks are inflated, not dropped)."""
    tl = inject_blackout(EnvTimeline.constant(), 200.0, 500.0)
    out = run_sim(Exponential(1.2), Exponential(0.9), ThreePhaseKernel(),
                  {"r": jnp.float32(2.0)}, k=10.0, n_events=4000, key=KEY,
                  burn_in=0, env=tl)
    assert out["blackouts_observed"] == 1
    assert out["shock_served"] == 0
    np.testing.assert_allclose(out["blackout_time"], 300.0, rtol=1e-5)


def test_markov_timeline_is_valid_and_counted():
    regimes = (Regime(mean_hold=50.0),
               Regime(mean_hold=10.0, hazard_mult=5.0, kind=SEG_STORM))
    tl = markov_timeline(regimes, horizon=500.0, seed=3)
    assert tl.n_segments >= 2 and 0.0 < tl.span() <= 500.0
    out = run_market_sim(Exponential(1.2), _market(),
                         NoticeAwareKernel(checkpoint_time=0.05),
                         {"r": jnp.float32(2.0)}, k=10.0, n_events=4000,
                         key=KEY, burn_in=0, env=tl)
    assert out["storms_observed"] <= tl.count_storms()
    assert out["env_boundaries"] >= out["storms_observed"]


# ---------------------------------------------------------------------------
# Graceful degradation: PanicKernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl,rng", [("xla", "split"), ("xla", "slab"),
                                      ("pallas", "slab"), ("ref", "split")])
@pytest.mark.parametrize("loop", ["market", "region"])
def test_panic_kernel_identity_without_blackout(loop, impl, rng):
    """No blackout in the timeline → PanicKernel == its base, bitwise."""
    base = (NoticeAwareKernel(checkpoint_time=0.05) if loop == "market"
            else RoutingKernel(base=NoticeAwareKernel(checkpoint_time=0.05),
                               choice="cheapest"))
    a = _run(loop, impl, rng, env=None, kernel=base)
    b = _run(loop, impl, rng, env=None, kernel=PanicKernel(base=base))
    _assert_bitwise(a, b)


def test_panic_kernel_routes_around_dead_pool():
    """Blackout on the cheap pool: the base kernel strands admissions on
    it; PanicKernel re-targets the live pool, which then serves."""
    job = Exponential(1.2)
    market = SpotMarket(pools=(
        SpotPool(arrival=Exponential(1.1), price=1.0, hazard=0.3,
                 notice=0.1),
        SpotPool(arrival=Exponential(1.5), price=0.6, hazard=0.8,
                 notice=0.3),
    ))
    base = NoticeAwareKernel(checkpoint_time=0.05)
    tl = inject_blackout(EnvTimeline.constant(), 300.0, 700.0, loc=1,
                         n_locs=2)
    kw = dict(k=10.0, n_events=8000, key=KEY, burn_in=0, chunk_events=2048,
              impl="xla", rng="slab", env=tl)
    plain = run_market_sim(job, market, base, {"r": jnp.float32(3.0)}, **kw)
    panic = run_market_sim(job, market, PanicKernel(base=base),
                           {"r": jnp.float32(3.0)}, **kw)
    assert plain["pool_served"][0] == 0  # cheapest-rule never leaves pool 1
    assert panic["pool_served"][0] > 0  # failover lands work on the live one
    assert panic["degraded_admits"] < plain["degraded_admits"]
    assert panic["avg_cost"] < plain["avg_cost"]


def test_panic_kernel_reroutes_dead_region():
    """Region blackout: a panic-wrapped routing kernel sends cross-region
    traffic around the dead region."""
    tl = inject_blackout(EnvTimeline.constant(), 300.0, 700.0, loc=1,
                         n_locs=2)
    rkern = RoutingKernel(base=NoticeAwareKernel(checkpoint_time=0.05),
                          choice="cheapest")
    kw = dict(k=10.0, n_events=6000, key=KEY, burn_in=0, chunk_events=2048,
              impl="xla", rng="slab", env=tl)
    plain = run_region_sim(_topo(), rkern, {"r": jnp.float32(2.0)}, **kw)
    panic = run_region_sim(_topo(), PanicKernel(base=rkern),
                           {"r": jnp.float32(2.0)}, **kw)
    assert panic["degraded_admits"] < plain["degraded_admits"]


# ---------------------------------------------------------------------------
# Graceful degradation: learner guardrails
# ---------------------------------------------------------------------------
def test_learner_survives_regime_flips():
    tl = inject_storm(EnvTimeline.constant(), 20.0, 200.0, hazard_mult=8.0)
    tl = inject_price_spike(tl, 300.0, 500.0, price_mult=3.0)
    job = Exponential(1.0)
    market = SpotMarket(pools=(SpotPool(arrival=Exponential(1.3), price=1.0,
                                        hazard=0.2, notice=0.1),))
    out = adaptive_admission_control(
        job, market, k=10.0, delta=2.0, eta=0.1, r0=1.0, window_events=512,
        n_windows=40, key=jax.random.key(0), env=tl, max_step=0.5,
        shock_reset=True)
    r = np.asarray(out["r"])
    assert np.isfinite(r).all()
    assert (r >= 0.0).all() and (r <= 16.0).all()
    # the clamp bounds every excursion except the shock_reset jumps back
    # toward r0=1.0 (which only ever shrink r here)
    dr = np.diff(r)
    assert ((dr <= 0.5 + 1e-6) | (r[1:] == 1.0)).all()
    # guardrails off at defaults: identical signature still works
    base = adaptive_admission_control(
        job, market, k=10.0, delta=2.0, eta=0.1, r0=1.0, window_events=512,
        n_windows=5, key=jax.random.key(0))
    assert np.isfinite(np.asarray(base["r"])).all()


# ---------------------------------------------------------------------------
# Loud failure: input validation at every entry point
# ---------------------------------------------------------------------------
def test_env_rejects_wrong_type():
    with pytest.raises(TypeError, match="EnvTimeline"):
        run_sim(Exponential(1.2), Exponential(0.9), ThreePhaseKernel(),
                {"r": jnp.float32(2.0)}, k=10.0, n_events=100, key=KEY,
                env={"not": "a timeline"})


def test_run_shape_validation():
    with pytest.raises(ValueError, match="n_events"):
        run_sim(Exponential(1.2), Exponential(0.9), ThreePhaseKernel(),
                {"r": jnp.float32(2.0)}, k=10.0, n_events=0, key=KEY)
    with pytest.raises(ValueError, match="burn_in"):
        run_market_sim(Exponential(1.2), _market(),
                       NoticeAwareKernel(), {"r": jnp.float32(2.0)},
                       k=10.0, n_events=100, burn_in=-1, key=KEY)


def test_loc_override_validation():
    with pytest.raises(ValueError, match="last-axis length 2"):
        run_market_sweep(Exponential(1.2), _market(), NoticeAwareKernel(),
                         {"r": jnp.float32(2.0)}, k=10.0, n_events=100,
                         key=KEY, prices=jnp.ones((3,)))
    with pytest.raises(ValueError, match="non-negative"):
        run_market_sweep(Exponential(1.2), _market(), NoticeAwareKernel(),
                         {"r": jnp.float32(2.0)}, k=10.0, n_events=100,
                         key=KEY, hazards=jnp.float32([-1.0, 0.5]))
    with pytest.raises(ValueError, match="non-finite"):
        run_region_sweep(_topo(), RoutingKernel(
            base=NoticeAwareKernel(), choice="cheapest"),
            {"r": jnp.float32(2.0)}, k=10.0, n_events=100, key=KEY,
            prices=jnp.float32([np.inf, 1.0]))
    # scalar broadcast stays legal (fills every pool)
    out = run_market_sweep(Exponential(1.2), _market(), NoticeAwareKernel(),
                           {"r": jnp.float32(2.0)}, k=10.0, n_events=500,
                           key=KEY, hazards=0.05)
    assert np.isfinite(out["avg_cost"]).all()


def test_timeline_validation():
    with pytest.raises(ValueError, match="increasing"):
        EnvTimeline(t_end=(5.0, 2.0, float("inf")))
    with pytest.raises(ValueError, match="open-ended"):
        EnvTimeline(t_end=(5.0, 10.0))
    with pytest.raises(ValueError, match="hazard_mult"):
        inject_storm(EnvTimeline.constant(), 1.0, 2.0, hazard_mult=0.0)
    with pytest.raises(ValueError, match="price_mult"):
        inject_price_spike(EnvTimeline.constant(), 1.0, 2.0, price_mult=-1.0)


def test_non_finite_stats_raise():
    good = SimpleNamespace(cost_sum=np.float64(1.0),
                           delay_sum=np.float64(2.0),
                           time_elapsed=np.float64(3.0))
    _check_finite_stats(good)
    bad = SimpleNamespace(cost_sum=np.float64(np.nan),
                          delay_sum=np.float64(2.0),
                          time_elapsed=np.float64(3.0))
    with pytest.raises(NonFiniteStatsError, match="cost_sum"):
        _check_finite_stats(bad)
