"""Pallas batched-event kernel ↔ scan-engine equivalence.

Three layers of contract, strictest first:

  * kernel == ref (the generic layout machinery): the batched-event kernel
    against its pure-JAX reference on the same lane layout — bit-for-bit
    at every tile size, including lane padding.
  * engine ``impl="pallas"`` == engine ``impl="ref"`` (the same scan
    executor the kernel fuses, on the kernel's own lane layout):
    bit-for-bit identical WindowStats / MarketWindowStats across policies
    and random market configs, including the degenerate 1-pool zero-hazard
    market.
  * engine ``impl="pallas"`` vs engine ``impl="xla"`` (the production
    broadcast-nested scan executor): integer event accounting is bitwise
    identical — every admit/serve/defect/preempt decision agrees — while
    float32 window sums are asserted to a ~ulp rtol: on CPU, LLVM's
    transcendental codegen (log1p in the exponential sampler) can round an
    ulp apart between batch layouts, which is also why sub-lane tiling
    (``tile`` < lanes) gets the same soft treatment (see EXPERIMENTS.md,
    "Engine kernel: Pallas batched-event executor").

Everything runs in interpret mode (`JAX_PLATFORMS=cpu` in the CI job).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: deterministic fallback
    from _propcheck import given, settings, st

from repro.core import (
    Exponential,
    Gamma,
    NoticeAwareKernel,
    PoolChoiceKernel,
    SingleSlotKernel,
    SpotMarket,
    SpotPool,
    ThreePhaseKernel,
    Uniform,
    run_market_sweep,
    run_sim,
    run_sweep,
)
from repro.core.engine import INT_STATS as _INT_STATS
from repro.core.waittime import DeterministicWait, ExponentialWait
from repro.kernels.sweep import batched_events, batched_event_windows_ref

LAM, MU, K = 1 / 12, 1 / 24, 10.0


def assert_stats_equal(a: dict, b: dict, context=""):
    for name, v in a.items():
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(b[name]),
            err_msg=f"{name} diverged ({context})")


# ---------------------------------------------------------------------------
# Layer 1: generic kernel == reference, every tile size, bit for bit
# ---------------------------------------------------------------------------
def _toy_step(state, stats, params):
    """Minimal event body exercising PRNG, slot selects, and mixed dtypes."""
    key, k1, k2 = jax.random.split(state["key"], 3)
    u = jax.random.uniform(k1)
    dt = jax.random.exponential(k2, dtype=jnp.float32) * params["scale"]
    iota = jax.lax.iota(jnp.int32, 8)
    slot = jnp.argmin(state["slots"])
    slots = jnp.where(iota == slot, state["slots"] + dt, state["slots"])
    return (
        {"key": key, "slots": slots},
        {"total": stats["total"] + dt,
         "hits": stats["hits"] + (u < 0.5).astype(jnp.int32)},
    )


@pytest.mark.parametrize("tile", [1, 3, 4, 64])
def test_kernel_matches_ref_all_tiles(tile):
    b = 10  # deliberately not a multiple of most tiles: exercises padding
    keys = jax.random.key_data(jax.random.split(jax.random.key(0), b))
    state = {"key": keys, "slots": jnp.zeros((b, 8), jnp.float32)}
    params = {"scale": jnp.linspace(0.5, 2.0, b)}
    zeros = {"total": jnp.zeros((), jnp.float32),
             "hits": jnp.zeros((), jnp.int32)}
    ev = (5, 12, 1)
    fs_k, st_k = batched_events(_toy_step, state, params, zeros, ev,
                                tile=tile, interpret=True)
    fs_r, st_r = batched_event_windows_ref(_toy_step, state, params, zeros,
                                           ev)
    for name in zeros:
        assert st_k[name].shape == (b, len(ev))
        np.testing.assert_array_equal(np.asarray(st_k[name]),
                                      np.asarray(st_r[name]))
    for lk, lr in zip(jax.tree.leaves(fs_k), jax.tree.leaves(fs_r)):
        np.testing.assert_array_equal(np.asarray(lk), np.asarray(lr))


# ---------------------------------------------------------------------------
# Layer 2: engine executors, bit for bit at matched lane width
# ---------------------------------------------------------------------------
ENGINE_CASES = [
    ("three_phase", Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
     {"r": jnp.linspace(0.25, 4.0, 5)}),
    ("three_phase_gamma", Gamma(12.0, 1.0), Exponential(MU),
     ThreePhaseKernel(), {"r": jnp.linspace(0.0, 3.0, 4)}),
    ("single_slot", Exponential(LAM), Uniform(0.0, 48.0),
     SingleSlotKernel(wait=DeterministicWait(3.0)), {}),
    ("single_slot_exp_wait", Exponential(LAM), Exponential(MU),
     SingleSlotKernel(wait=ExponentialWait(0.5)), {}),
]


def assert_stats_close(xla: dict, pal: dict, context=""):
    """The cross-layout contract vs the production XLA executor: integer
    event accounting bitwise, float sums to ~ulp rtol."""
    for name, v in xla.items():
        if name in _INT_STATS:
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(pal[name]),
                err_msg=f"{name} diverged ({context})")
        else:
            np.testing.assert_allclose(
                np.asarray(v), np.asarray(pal[name]), rtol=1e-5,
                err_msg=f"{name} diverged ({context})")


@pytest.mark.parametrize("name,job,spot,kernel,params",
                         ENGINE_CASES, ids=[c[0] for c in ENGINE_CASES])
def test_sweep_pallas_bit_for_bit(name, job, spot, kernel, params):
    kw = dict(k=K, n_events=6_000, key=jax.random.key(7), n_seeds=3,
              rmax=8 if params else 1, chunk_events=2_048, burn_in=512)
    ref = run_sweep(job, spot, kernel, params, impl="ref", **kw)
    pal = run_sweep(job, spot, kernel, params, impl="pallas",
                    interpret=True, **kw)
    assert_stats_equal(ref, pal, name)
    assert_stats_close(run_sweep(job, spot, kernel, params, **kw), pal,
                       name)


def test_run_sim_pallas_bit_for_bit():
    kw = dict(k=K, n_events=8_000, key=jax.random.key(3), rmax=16,
              chunk_events=1_024)
    a = run_sim(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                {"r": jnp.float32(2.5)}, **kw)
    b = run_sim(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                {"r": jnp.float32(2.5)}, impl="pallas", interpret=True, **kw)
    assert a == b


def _market(prices, hazards, notices):
    pools = tuple(
        SpotPool(Exponential(MU / len(prices)), price=p, hazard=h, notice=n)
        for p, h, n in zip(prices, hazards, notices))
    return SpotMarket(pools=pools)


MARKET_CASES = [
    ("degenerate_1pool", SpotMarket.single(Exponential(MU)),
     ThreePhaseKernel(), {"r": jnp.linspace(0.25, 4.0, 5)}),
    ("heterogeneous_notice",
     _market((0.5, 0.3, 0.2, 0.1), (0.02, 0.05, 0.0, 0.10),
             (0.5, 0.01, 0.0, 2.0)),
     NoticeAwareKernel(checkpoint_time=0.05),
     {"r": jnp.linspace(0.25, 4.0, 4)}),
    ("pool_choice_fastest",
     _market((1.0, 0.4), (0.0, 0.08), (0.0, 0.3)),
     PoolChoiceKernel(base=ThreePhaseKernel(), choice="fastest"),
     {"r": jnp.linspace(0.5, 3.0, 3)}),
]


@pytest.mark.parametrize("name,market,kernel,params",
                         MARKET_CASES, ids=[c[0] for c in MARKET_CASES])
def test_market_sweep_pallas_bit_for_bit(name, market, kernel, params):
    kw = dict(k=K, n_events=5_000, key=jax.random.key(0), n_seeds=2,
              rmax=16, chunk_events=2_048)
    ref = run_market_sweep(Exponential(LAM), market, kernel, params,
                           impl="ref", **kw)
    pal = run_market_sweep(Exponential(LAM), market, kernel, params,
                           impl="pallas", interpret=True, **kw)
    assert_stats_equal(ref, pal, name)
    assert_stats_close(
        run_market_sweep(Exponential(LAM), market, kernel, params, **kw),
        pal, name)


@settings(max_examples=10, deadline=None)
@given(
    r_lo=st.floats(min_value=0.0, max_value=2.0),
    price=st.floats(min_value=0.05, max_value=1.0),
    hazard=st.floats(min_value=0.0, max_value=0.2),
    notice=st.floats(min_value=0.0, max_value=2.0),
    n_pools=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_market_sweep_pallas_property(r_lo, price, hazard, notice, n_pools,
                                      seed):
    """Random market configs: pallas == ref to the last bit, xla to ints
    exactly + floats at rtol."""
    market = _market((price,) * n_pools,
                     tuple(hazard * (i % 2) for i in range(n_pools)),
                     (notice,) * n_pools)
    params = {"r": jnp.linspace(r_lo, r_lo + 2.0, 3)}
    kw = dict(k=K, n_events=2_000, key=jax.random.key(seed), n_seeds=2,
              rmax=8, chunk_events=512)
    kernel = NoticeAwareKernel(checkpoint_time=0.05)
    ref = run_market_sweep(Exponential(LAM), market, kernel, params,
                           impl="ref", **kw)
    pal = run_market_sweep(Exponential(LAM), market, kernel, params,
                           impl="pallas", interpret=True, **kw)
    assert_stats_equal(ref, pal, f"pools={n_pools} seed={seed}")
    assert_stats_close(
        run_market_sweep(Exponential(LAM), market, kernel, params, **kw),
        pal, f"pools={n_pools} seed={seed}")


# ---------------------------------------------------------------------------
# Layer 3: sub-lane tiling — ints exact, floats to ulp-level rtol
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tile", [3, 7])
def test_sweep_pallas_small_tiles(tile):
    """Splitting lanes across kernel instances keeps every event decision
    identical (integer counters bitwise); float32 sums may pick up a few
    ulps from width-dependent CPU transcendental codegen."""
    kw = dict(k=K, n_events=4_000, key=jax.random.key(1), n_seeds=2,
              rmax=8, chunk_events=1_024)
    params = {"r": jnp.linspace(0.25, 4.0, 5)}
    xla = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                    params, **kw)
    pal = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                    params, impl="pallas", interpret=True, tile=tile, **kw)
    assert_stats_close(xla, pal, f"tile={tile}")


def test_unknown_impl_raises():
    with pytest.raises(ValueError, match="unknown impl"):
        run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                  {"r": jnp.float32(1.0)}, k=K, n_events=64,
                  key=jax.random.key(0), impl="cuda")


# ---------------------------------------------------------------------------
# Satellite: int32 order/next_seq wrap protection at window boundaries
# ---------------------------------------------------------------------------
def test_order_rebase_prevents_int32_wrap():
    """Start the engine a hair below INT32_MAX in sequence space: without
    the per-window rebase the counter wraps negative within a few chunks
    and the FIFO argmin serves newest-first; with it the shifted run is
    bitwise the run that started at zero."""
    from repro.core.engine import (WindowStats, _rebase_order,
                                   init_engine_state, run_chunked)

    job, spot, kernel = Exponential(1.0), Exponential(1.0), ThreePhaseKernel()
    rmax, chunk, n_events = 8, 128, 4_000
    params = {"r": jnp.float32(6.0)}

    @jax.jit
    def run_from(offset):
        state = init_engine_state(jax.random.key(2), job, spot, rmax)
        state = state._replace(
            order=state.order + offset * state.occ.astype(jnp.int32),
            next_seq=state.next_seq + offset)
        return run_chunked(job, spot, kernel, rmax, state, params,
                           jnp.float32(10.0), n_events, chunk)

    offset = jnp.int32(2**31 - 10_000)  # wraps within ~chunks without rebase
    s_hi, stats_hi = run_from(offset)
    s_lo, stats_lo = run_from(jnp.int32(0))
    for name in WindowStats._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(stats_hi, name)),
            np.asarray(getattr(stats_lo, name)), err_msg=name)
    # the rebase keeps the live counter bounded by window size + queue depth
    assert int(s_hi.next_seq) <= chunk + rmax
    assert int(s_lo.next_seq) <= chunk + rmax
    # and it is shift-invariant as a law, not just on this trajectory
    reb = _rebase_order(s_hi)
    assert int(jnp.min(jnp.where(reb.occ, reb.order, reb.next_seq))) == 0
