"""The slab PRNG stream (``rng="slab"``) + superposed preemption clocks.

Contract layers (ISSUE 5 / PR 5):

  * **Its own bitwise ledger** — on the slab stream, ``impl="pallas"`` ==
    ``impl="ref"`` to the last bit at every tile size, and vs ``impl="xla"``
    integer event accounting is bitwise (floats ~ulp) — the same executor
    contract the split stream holds, re-proven for the new stream on all
    three loops (single / market / region).
  * **Degenerate cross-loop identity** — a 1-pool zero-hazard market and a
    1-region topology on the slab stream reproduce the single-queue slab
    engine bit-for-bit (the slab analogue of the PR-2/PR-4 ledger: the
    column layout reduces exactly to the simpler loop's).
  * **Slab == split in distribution** — the two streams simulate the same
    continuous-time model (the superposed scalar preemption clock is
    *exactly* the per-pool vector clock law, by the Poisson superposition
    theorem), so per-seed sweep marginals pass two-sample KS tests at any
    power (tests/_stats.py; property-tested across random market and
    region configurations).
  * **Seed-compat wrappers untouched** — the wrappers never pass ``rng``
    and therefore stay on the frozen split stream (their bit-for-bit
    contract is frozen in tests/test_core_engine.py).

Everything runs in interpret mode (`JAX_PLATFORMS=cpu` in the CI job).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: deterministic fallback
    from _propcheck import given, settings, st

from _stats import (assert_same_distribution, assert_stats_close,
                    assert_stats_equal, ks_2samp)

from repro.core import (
    Exponential,
    Gamma,
    NoticeAwareKernel,
    PoolChoiceKernel,
    Region,
    RegionTopology,
    RoutingKernel,
    SingleSlotKernel,
    SpotMarket,
    SpotPool,
    ThreePhaseKernel,
    Uniform,
    run_market_sim,
    run_market_sweep,
    run_region_sweep,
    run_sim,
    run_sweep,
)
from repro.core.waittime import DeterministicWait, ExponentialWait

LAM, MU, K = 1 / 12, 1 / 24, 10.0


def _market(prices, hazards, notices):
    pools = tuple(
        SpotPool(Exponential(MU / len(prices)), price=p, hazard=h, notice=n)
        for p, h, n in zip(prices, hazards, notices))
    return SpotMarket(pools=pools)


def _topology(rmax=8):
    return RegionTopology(regions=(
        Region(Exponential(LAM / 4), Exponential(MU / 4), price=0.5,
               hazard=0.02, notice=0.5, rmax=rmax),
        Region(Exponential(LAM / 2), Exponential(MU / 4), price=0.3,
               hazard=0.05, notice=0.01, rmax=rmax),
        Region(Exponential(LAM / 4), Exponential(MU / 2), price=0.1,
               hazard=0.10, notice=2.0, rmax=rmax),
    ))


# ---------------------------------------------------------------------------
# Layer 1: the slab stream's own executor ledger, every tile size
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tile", [1, 3, 4, 64])
def test_slab_single_ledger_all_tiles(tile):
    kw = dict(k=K, n_events=5_000, key=jax.random.key(7), n_seeds=3,
              rmax=8, chunk_events=2_048, burn_in=512, rng="slab")
    params = {"r": jnp.linspace(0.25, 4.0, 5)}
    ref = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                    params, impl="ref", **kw)
    pal = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                    params, impl="pallas", interpret=True, tile=tile, **kw)
    assert_stats_equal(ref, pal, f"slab tile={tile}")
    assert_stats_close(
        run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                  params, **kw), pal, f"slab tile={tile}")


@pytest.mark.parametrize("tile", [1, 3, 64])
def test_slab_market_ledger_all_tiles(tile):
    kw = dict(k=K, n_events=4_000, key=jax.random.key(0), n_seeds=2,
              rmax=16, chunk_events=1_024, rng="slab")
    market = _market((0.5, 0.3, 0.2, 0.1), (0.02, 0.05, 0.0, 0.10),
                     (0.5, 0.01, 0.0, 2.0))
    kernel = NoticeAwareKernel(checkpoint_time=0.05)
    params = {"r": jnp.linspace(0.25, 4.0, 4)}
    ref = run_market_sweep(Exponential(LAM), market, kernel, params,
                           impl="ref", **kw)
    pal = run_market_sweep(Exponential(LAM), market, kernel, params,
                           impl="pallas", interpret=True, tile=tile, **kw)
    assert_stats_equal(ref, pal, f"slab market tile={tile}")
    assert_stats_close(
        run_market_sweep(Exponential(LAM), market, kernel, params, **kw),
        pal, f"slab market tile={tile}")


@pytest.mark.parametrize("tile", [1, 3, 64])
def test_slab_region_ledger_all_tiles(tile):
    kw = dict(k=K, n_events=4_000, key=jax.random.key(1), n_seeds=2,
              chunk_events=1_024, rng="slab")
    topo = _topology()
    kernel = RoutingKernel(NoticeAwareKernel(checkpoint_time=0.05),
                           choice="least_loaded")
    params = {"r": jnp.linspace(0.5, 3.0, 4)}
    ref = run_region_sweep(topo, kernel, params, impl="ref", **kw)
    pal = run_region_sweep(topo, kernel, params, impl="pallas",
                           interpret=True, tile=tile, **kw)
    assert_stats_equal(ref, pal, f"slab region tile={tile}")
    assert_stats_close(run_region_sweep(topo, kernel, params, **kw), pal,
                       f"slab region tile={tile}")


# ---------------------------------------------------------------------------
# Layer 2: degenerate cross-loop identity on the slab stream
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "ref"])
def test_slab_degenerate_market_is_single_engine(impl):
    kw = dict(k=K, n_events=5_000, key=jax.random.key(3), n_seeds=2,
              rmax=8, chunk_events=1_024, rng="slab", impl=impl)
    params = {"r": jnp.linspace(0.25, 4.0, 4)}
    single = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                       params, **kw)
    market = run_market_sweep(Exponential(LAM),
                              SpotMarket.single(Exponential(MU)),
                              ThreePhaseKernel(), params, **kw)
    for name, v in single.items():
        got = np.asarray(market[name])
        got = got[..., 0] if got.ndim > np.ndim(v) else got
        np.testing.assert_array_equal(np.asarray(v), got,
                                      err_msg=f"{name} ({impl})")


@pytest.mark.parametrize("impl", ["xla", "ref"])
def test_slab_degenerate_region_is_single_engine(impl):
    kw = dict(k=K, n_events=5_000, key=jax.random.key(4), n_seeds=2,
              chunk_events=1_024, rng="slab", impl=impl)
    params = {"r": jnp.linspace(0.25, 4.0, 4)}
    single = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                       params, rmax=8, **kw)
    topo = RegionTopology.single(Exponential(LAM), Exponential(MU), rmax=8)
    region = run_region_sweep(topo, ThreePhaseKernel(), params, **kw)
    for name, v in single.items():
        got = np.asarray(region[name])
        got = got[..., 0] if got.ndim > np.ndim(v) else got
        np.testing.assert_array_equal(np.asarray(v), got,
                                      err_msg=f"{name} ({impl})")


# ---------------------------------------------------------------------------
# Layer 3: slab == split in distribution (KS on per-seed sweep marginals)
# ---------------------------------------------------------------------------
_KS_STATS = ("avg_cost", "avg_delay", "spot_served", "pi0_spot")

# Pinned RNG seeds for the property-driven KS checks below.  H0 ("slab and
# split draw from the same law") is *exactly* true, so with a fresh random
# seed every run is an independent alpha-level coin flip — per-assertion
# flake probability 1e-4, but across many CI runs of many assertions that
# compounds into rare red builds.  Drawing the seed from this pre-verified
# pinned set instead makes the KS draw deterministic per example (the
# continuous config knobs hypothesis still varies don't re-randomize the
# sample — the key does), killing the flake channel without losing config
# coverage.  The _propcheck fallback walks the same set, so the bare-
# interpreter smoke run is fully deterministic.
_KS_SEEDS = (7, 1234, 9090, 23205, 40321, 65535)


def _marginals(run, rng, key, stats=_KS_STATS):
    out = run(rng=rng, key=key)
    return {name: np.asarray(out[name], np.float64).ravel()
            for name in stats if name in out}


def test_ks_helper_meta_power():
    """The KS helper itself: same config passes, different r fails."""
    def run(r, key):
        return run_sweep(Exponential(LAM), Exponential(MU),
                         ThreePhaseKernel(), {"r": jnp.float32(r)}, k=K,
                         n_events=2_000, key=key, n_seeds=64, rmax=8)

    same_a = run(1.5, jax.random.key(11))["avg_cost"].ravel()
    same_b = run(1.5, jax.random.key(12))["avg_cost"].ravel()
    assert_same_distribution(same_a, same_b, name="same-config avg_cost")
    diff = run(4.0, jax.random.key(13))["avg_cost"].ravel()
    _, p = ks_2samp(same_a, diff)
    assert p < 1e-6, f"KS failed to separate r=1.5 from r=4.0 (p={p:.2e})"


def test_ks_helper_null_calibration():
    """Under H0 the helper's p-values must be (sub-)uniform — the property
    that makes ``alpha=1e-4`` a real flake bound for every KS call site in
    the suite.  300 pinned-seed same-distribution pairs: the empirical
    p-value CDF sits at or below uniform + small-sample slack at every
    level, and nothing lands anywhere near the assertion threshold.
    Deterministic (one fixed numpy seed), so this meta-test cannot itself
    flake."""
    r = np.random.default_rng(2026_08)
    ps = np.array([ks_2samp(r.normal(size=100), r.normal(size=100))[1]
                   for _ in range(300)])
    assert ps.min() > 1e-3, ps.min()  # far above the 1e-4 call-site alpha
    for level in (0.05, 0.1, 0.25, 0.5):
        frac = float((ps <= level).mean())
        # asymptotic p-values are conservative at n=100 (frac <= level);
        # the +0.06 absorbs binomial noise at 300 draws
        assert frac <= level + 0.06, (level, frac)


def test_slab_vs_split_single_queue_marginals():
    def run(rng, key):
        return run_sweep(Exponential(LAM), Exponential(MU),
                         ThreePhaseKernel(), {"r": jnp.float32(1.5)}, k=K,
                         n_events=2_000, key=key, n_seeds=96, rmax=8,
                         rng=rng)

    split = _marginals(run, "split", jax.random.key(21))
    slab = _marginals(run, "slab", jax.random.key(22))
    for name in split:
        assert_same_distribution(split[name], slab[name], name=name)


def test_slab_vs_split_single_slot_wait_family():
    """The wait-time slab samplers (SingleSlotKernel's admit_u)."""
    for wait in (DeterministicWait(3.0), ExponentialWait(0.5)):
        def run(rng, key):
            return run_sweep(Exponential(LAM), Exponential(MU),
                             SingleSlotKernel(wait=wait), {}, k=K,
                             n_events=2_000, key=key, n_seeds=96, rmax=1,
                             rng=rng)

        split = _marginals(run, "split", jax.random.key(31))
        slab = _marginals(run, "slab", jax.random.key(32))
        for name in split:
            assert_same_distribution(split[name], slab[name],
                                     name=f"{type(wait).__name__}:{name}")


@settings(max_examples=6, deadline=None)
@given(
    r=st.floats(min_value=0.5, max_value=3.0),
    price=st.floats(min_value=0.05, max_value=1.0),
    hazard=st.floats(min_value=0.0, max_value=0.2),
    notice=st.floats(min_value=0.0, max_value=2.0),
    n_pools=st.integers(min_value=1, max_value=4),
    seed=st.sampled_from(_KS_SEEDS),
)
def test_slab_vs_split_market_marginals(r, price, hazard, notice, n_pools,
                                        seed):
    """Random market configs: slab-vs-split KS green on cost/delay/
    preemption marginals (plus the slab executor ledger on the way)."""
    market = _market((price,) * n_pools,
                     tuple(hazard * ((i % 2) + 1) / 2
                           for i in range(n_pools)),
                     (notice,) * n_pools)
    kernel = NoticeAwareKernel(checkpoint_time=0.05)

    def run(rng, key):
        return run_market_sweep(Exponential(LAM), market, kernel,
                                {"r": jnp.float32(r)}, k=K, n_events=2_000,
                                key=key, n_seeds=64, rmax=8, rng=rng)

    stats = _KS_STATS + ("preemptions", "resumed", "spot_cost")
    split = _marginals(run, "split", jax.random.key(seed), stats)
    slab = _marginals(run, "slab", jax.random.key(seed + 77_777), stats)
    for name in split:
        assert_same_distribution(split[name], slab[name],
                                 name=f"market:{name} seed={seed}")


@settings(max_examples=4, deadline=None)
@given(
    r=st.floats(min_value=0.5, max_value=3.0),
    hazard=st.floats(min_value=0.0, max_value=0.15),
    seed=st.sampled_from(_KS_SEEDS),
)
def test_slab_vs_split_region_marginals(r, hazard, seed):
    """Random region configs (hazard override sweeps the superposed clock's
    total) under a routing kernel."""
    topo = _topology()
    kernel = RoutingKernel(NoticeAwareKernel(checkpoint_time=0.05),
                           choice="least_loaded")

    def run(rng, key):
        return run_region_sweep(topo, kernel, {"r": jnp.float32(r)}, k=K,
                                hazards=jnp.float32(hazard),
                                n_events=2_000, key=key, n_seeds=64,
                                rng=rng)

    stats = _KS_STATS + ("preemptions", "cross_region_frac")
    split = _marginals(run, "split", jax.random.key(seed), stats)
    slab = _marginals(run, "slab", jax.random.key(seed + 77_777), stats)
    for name in split:
        assert_same_distribution(split[name], slab[name],
                                 name=f"region:{name} seed={seed}")


# ---------------------------------------------------------------------------
# Protocol edges: key-synthesis fallback, Gamma shapes, choice rules
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _LegacyKernel:
    """A kernel with NO slab hooks: exercises the synthesized-key fallback
    (two raw slab columns -> a legacy threefry key, hook unchanged)."""

    def admit(self, params, qlen, key):
        return jax.random.uniform(key) < jnp.float32(0.7), jnp.float32(3e38)


def test_legacy_kernel_key_synthesis_fallback():
    kw = dict(k=K, n_events=3_000, key=jax.random.key(5), n_seeds=2,
              rmax=4, chunk_events=512, rng="slab")
    ref = run_sweep(Exponential(LAM), Exponential(MU), _LegacyKernel(), {},
                    impl="ref", **kw)
    pal = run_sweep(Exponential(LAM), Exponential(MU), _LegacyKernel(), {},
                    impl="pallas", interpret=True, **kw)
    assert_stats_equal(ref, pal, "legacy fallback")
    xla = run_sweep(Exponential(LAM), Exponential(MU), _LegacyKernel(), {},
                    **kw)
    assert_stats_close(xla, pal, "legacy fallback")
    # and the kernel admits ~70% of arrivals, i.e. the synthesized key
    # actually drives the in-body draw
    admitted = xla["spot_served"].sum() + 0.0
    assert admitted > 0


def test_pool_choice_kernel_slab_delegation():
    """PoolChoiceKernel is slab-aware iff its base is: slab-aware base
    composes columns; the uniform rule consumes its own column."""
    market = _market((1.0, 0.4), (0.0, 0.08), (0.0, 0.3))
    kernel = PoolChoiceKernel(base=ThreePhaseKernel(), choice="uniform")

    def run(rng, key):
        return run_market_sweep(Exponential(LAM), market, kernel,
                                {"r": jnp.float32(2.0)}, k=K,
                                n_events=2_000, key=key, n_seeds=64,
                                rmax=8, rng=rng)

    split = _marginals(run, "split", jax.random.key(41),
                       _KS_STATS + ("preemptions",))
    slab = _marginals(run, "slab", jax.random.key(42),
                      _KS_STATS + ("preemptions",))
    for name in split:
        assert_same_distribution(split[name], slab[name],
                                 name=f"pool_choice:{name}")
    # legacy base -> the whole admit_market hook falls back to key synthesis
    legacy = PoolChoiceKernel(base=_LegacyKernel(), choice="cheapest")
    assert legacy.slab_cols("admit_market", 2) is None
    out = run_market_sim(Exponential(LAM), market, legacy, {}, k=K,
                         n_events=1_000, key=jax.random.key(6), rmax=8,
                         rng="slab")
    assert out["jobs_completed"] > 0


def test_gamma_shapes_in_slab_mode():
    # integer shape: sum-of-exponentials slab sampler, KS vs split
    def run(rng, key):
        return run_sweep(Gamma(12.0, 1.0), Exponential(MU),
                         ThreePhaseKernel(), {"r": jnp.float32(1.5)}, k=K,
                         n_events=2_000, key=key, n_seeds=64, rmax=8,
                         rng=rng)

    split = _marginals(run, "split", jax.random.key(51))
    slab = _marginals(run, "slab", jax.random.key(52))
    for name in split:
        assert_same_distribution(split[name], slab[name],
                                 name=f"gamma12:{name}")
    # non-integer shape: a clear error pointing at rng="split"
    with pytest.raises(NotImplementedError, match="rng='split'"):
        run_sweep(Gamma(1.7, 1.0), Exponential(MU), ThreePhaseKernel(),
                  {"r": jnp.float32(1.5)}, k=K, n_events=64,
                  key=jax.random.key(0), rng="slab")
    # ... and still runs fine on the split stream
    out = run_sim(Gamma(1.7, 1.0), Exponential(MU), ThreePhaseKernel(),
                  {"r": jnp.float32(1.5)}, k=K, n_events=256,
                  key=jax.random.key(0))
    assert out["jobs_arrived"] > 0


def test_uniform_spot_family_slab():
    """Non-exponential spot supply exercises sample_u beyond icdf-exp."""
    def run(rng, key):
        return run_sweep(Exponential(LAM), Uniform(0.0, 48.0),
                         ThreePhaseKernel(), {"r": jnp.float32(1.5)}, k=K,
                         n_events=2_000, key=key, n_seeds=64, rmax=8,
                         rng=rng)

    split = _marginals(run, "split", jax.random.key(61))
    slab = _marginals(run, "slab", jax.random.key(62))
    for name in split:
        assert_same_distribution(split[name], slab[name],
                                 name=f"uniform_spot:{name}")


def test_unknown_rng_raises():
    with pytest.raises(ValueError, match="unknown rng"):
        run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                  {"r": jnp.float32(1.0)}, k=K, n_events=64,
                  key=jax.random.key(0), rng="counter")


# ---------------------------------------------------------------------------
# The superposition law itself (unit level)
# ---------------------------------------------------------------------------
def test_superposed_clock_law():
    """hazard_clock/thinning_pick reproduce the vector-clock (min, argmin)
    joint law: matching first/second moments of the min and matching pick
    frequencies, on host numpy draws."""
    from repro.core.clocks import hazard_clock, thinning_pick

    hazards = np.array([0.4, 0.0, 1.1, 0.5])
    rng = np.random.default_rng(0)
    n = 20_000
    # vector model: min of per-pool Exp(h_p) + its argmin
    draws = rng.exponential(1.0, size=(n, 4)) / np.where(hazards > 0,
                                                         hazards, 1e-30)
    vec_min = draws.min(axis=1)
    vec_arg = draws.argmin(axis=1)
    # superposed model (the shared law, host backend)
    sup_min = np.array([hazard_clock(hazards, rng.random())
                        for _ in range(n)])
    sup_arg = np.array([thinning_pick(hazards, rng.random())
                        for _ in range(n)])
    assert_same_distribution(vec_min, sup_min, name="superposed min")
    total = hazards.sum()
    for p, h in enumerate(hazards):
        want = h / total
        np.testing.assert_allclose((sup_arg == p).mean(), want, atol=0.02)
        np.testing.assert_allclose((vec_arg == p).mean(), want, atol=0.02)
    # zero total hazard never fires
    assert np.isinf(hazard_clock(np.zeros(3), 0.5))
