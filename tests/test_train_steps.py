"""Training-step integration: loss goes down, accumulation is consistent,
state donation round-trips, serving engine generates coherently."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.models.registry import build_model
from repro.train.steps import (
    abstract_train_state,
    init_train_state,
    make_train_step,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen1.5-4b", smoke=True)
    cfg = dataclasses.replace(cfg, num_layers=2, remat=False)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    data = DataPipeline(vocab_size=cfg.vocab_size, global_batch=8,
                        seq_len=32, seed=0)
    return cfg, model, state, data


def test_loss_decreases_over_steps(tiny):
    cfg, model, state, data = tiny
    state = jax.tree.map(lambda x: x.copy(), state)  # fixture stays alive
    step = jax.jit(make_train_step(model, base_lr=1e-3, warmup=5,
                                   total_steps=100), donate_argnums=(0,))
    losses = []
    for _ in range(30):
        state, metrics = step(state, data.next())
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_metrics_contents(tiny):
    cfg, model, state, data = tiny
    step = jax.jit(make_train_step(model))
    _, metrics = step(state, data.next())
    for key in ("loss", "grad_norm", "lr", "ce", "aux"):
        assert key in metrics
        assert np.isfinite(float(metrics[key]))


def test_grad_accumulation_matches_full_batch(tiny):
    """accum=4 must produce (nearly) the same update as accum=1."""
    cfg, model, state, data = tiny
    batch = data.next()
    s1, m1 = jax.jit(make_train_step(model, accum_steps=1))(state, batch)
    s4, m4 = jax.jit(make_train_step(model, accum_steps=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-2)
    l1 = jax.tree.leaves(s1.params)
    l4 = jax.tree.leaves(s4.params)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-4)


def test_compressed_training_runs(tiny):
    cfg, model, _, data = tiny
    state = init_train_state(model, jax.random.key(1), compress=True)
    step = jax.jit(make_train_step(model, compress=True, base_lr=1e-3))
    losses = []
    for _ in range(20):
        state, metrics = step(state, data.next())
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_abstract_state_matches_concrete(tiny):
    cfg, model, state, _ = tiny
    abs_state = abstract_train_state(model)
    concrete = jax.tree.leaves(state)
    abstract = jax.tree.leaves(abs_state)
    assert len(concrete) == len(abstract)
    for c, a in zip(concrete, abstract):
        assert c.shape == a.shape
        assert c.dtype == a.dtype


def test_serving_engine_generates():
    from repro.serving.engine import BatchedServer

    cfg = get_config("qwen1.5-4b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    server = BatchedServer(model, params, max_batch=2, max_len=48)
    prompts = [np.arange(2, 18, dtype=np.int32) for _ in range(2)]
    outs = server.generate(prompts, max_new=8)
    assert len(outs) == 2 and len(outs[0]) == 8
    assert all(0 <= t < cfg.vocab_size for t in outs[0])


def test_serving_greedy_decode_matches_teacher_forcing():
    """Generated token i must equal argmax of teacher-forced logits."""
    from repro.serving.engine import BatchedServer

    cfg = get_config("mamba2-780m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    server = BatchedServer(model, params, max_batch=1, max_len=48)
    prompt = np.arange(2, 18, dtype=np.int32)
    outs = server.generate([prompt], max_new=4)[0]
    # teacher-forced re-run
    seq = list(prompt)
    for i in range(4):
        toks = jnp.asarray(np.asarray(seq, np.int32))[None]
        logits, _ = jax.jit(model.prefill)(params, {"tokens": toks})
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == outs[i], (i, nxt, outs[i])
        seq.append(nxt)
