"""Per-architecture smoke tests (reduced configs, single CPU device).

For every assigned arch: instantiate the reduced same-family config, run one
forward/train step, assert output shapes + finiteness, check grads are
finite, and verify prefill→decode_step consistency against teacher-forced
full-sequence logits (the serving path must agree with the training path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, supported_shapes
from repro.models.registry import build_model, input_specs
from repro.models.config import SHAPES

B, S = 2, 32


def make_batch(cfg, key, *, batch=B, seq=S, with_targets=True):
    ks = jax.random.split(key, 4)
    out = {}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model),
                                          jnp.bfloat16)
        out["tokens"] = jax.random.randint(ks[1], (batch, seq), 0,
                                           cfg.vocab_size)
    elif cfg.input_mode == "embeddings":
        out["embeds"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model),
                                          jnp.bfloat16)
        if cfg.mrope:
            out["positions"] = jnp.broadcast_to(
                jnp.arange(seq)[None, None, :], (3, batch, seq))
    else:
        out["tokens"] = jax.random.randint(ks[1], (batch, seq), 0,
                                           cfg.vocab_size)
    if with_targets:
        out["targets"] = jax.random.randint(ks[2], (batch, seq), 0,
                                            cfg.vocab_size)
    return out


@pytest.fixture(scope="module")
def built():
    """Init each smoke model once per test session."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name, smoke=True)
            model = build_model(cfg)
            params = model.init(jax.random.key(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_loss_finite(name, built):
    cfg, model, params = built(name)
    batch = make_batch(cfg, jax.random.key(1))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_grads_finite(name, built):
    cfg, model, params = built(name)
    batch = make_batch(cfg, jax.random.key(2))
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    flat, _ = jax.tree.flatten(grads)
    for g in flat:
        assert np.isfinite(np.asarray(g, np.float32)).all(), name
    # at least some gradient signal
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in flat]
    assert max(norms) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name, built):
    """decode_step after prefill(T) must reproduce teacher-forced logits."""
    cfg, model, params = built(name)
    T = 16
    full = make_batch(cfg, jax.random.key(3), with_targets=False)

    def slice_batch(b, lo, hi):
        out = {}
        for k, v in b.items():
            if k == "positions":
                out[k] = v[:, :, lo:hi]
            elif k == "frames":
                out[k] = v  # encoder input is not sliced
            else:
                out[k] = v[:, lo:hi]
        return out

    prefix = slice_batch(full, 0, T)
    logits_p, cache = jax.jit(model.prefill, static_argnames=("max_len",))(
        params, prefix, max_len=S)
    step = slice_batch(full, T, T + 1)
    logits_d, cache = jax.jit(model.decode_step)(params, step, cache)

    # teacher-forced oracle: prefill over T+1 tokens, take last logits
    longer = slice_batch(full, 0, T + 1)
    logits_full, _ = jax.jit(model.prefill, static_argnames=("max_len",))(
        params, longer, max_len=S)
    # bf16 params/activations: allow bf16-scale accumulation noise
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(logits_full[:, 0], np.float32),
        rtol=1e-1, atol=6e-2,
    )


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_advances_cache(name, built):
    cfg, model, params = built(name)
    cache = model.init_cache(B, S)
    step = make_batch(cfg, jax.random.key(4), seq=1, with_targets=False)
    if cfg.family == "encdec":
        # decode against an empty cross cache is legal (masked)
        step.pop("frames")
        step["tokens"] = step["tokens"][:, :1]
    logits, cache2 = jax.jit(model.decode_step)(params, step, cache)
    assert int(cache2.index) == int(cache.index) + 1
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_input_specs_cover_supported_shapes(name):
    cfg = get_config(name)
    for shape_name in supported_shapes(cfg):
        cell = SHAPES[shape_name]
        specs = input_specs(cfg, cell)
        assert "batch" in specs
        if cell.kind == "decode":
            assert "cache" in specs
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_full_configs_match_assignment():
    """Spot-check the exact published numbers."""
    c = get_config("granite-20b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (52, 6144, 48, 1, 24576, 49152)
    c = get_config("qwen3-32b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.vocab_size, c.qk_norm) == (64, 5120, 64, 8, 151936, True)
    c = get_config("arctic-480b")
    assert (c.num_experts, c.num_experts_per_tok, c.dense_residual,
            c.d_model) == (128, 2, True, 7168)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.num_experts, c.num_experts_per_tok,
            c.num_shared_experts) == (60, 4, 4)
    c = get_config("mamba2-780m")
    assert (c.num_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = get_config("zamba2-1.2b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (38, 2048, 64)
    c = get_config("qwen2-vl-72b")
    assert (c.num_layers, c.d_model, c.mrope) == (80, 8192, True)
    c = get_config("whisper-small")
    assert (c.num_layers, c.num_encoder_layers, c.d_model) == (12, 12, 768)
    # parameter counts are in the advertised ballpark
    assert 15e9 < get_config("granite-20b").param_count() < 25e9
    assert 25e9 < get_config("qwen3-32b").param_count() < 40e9
    assert 420e9 < get_config("arctic-480b").param_count() < 540e9
    assert 0.6e9 < get_config("mamba2-780m").param_count() < 1.0e9
    assert 60e9 < get_config("qwen2-vl-72b").param_count() < 85e9
