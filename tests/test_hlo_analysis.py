"""Loop-aware collective parsing: unit tests on synthetic + real HLO.

The real-HLO test runs in a subprocess with forced host devices so the main
pytest process keeps its single real device.
"""
import subprocess
import sys
import textwrap

from repro.launch.hlo_analysis import collective_stats


def test_synthetic_hlo_while_multiplier():
    hlo = textwrap.dedent("""
    HloModule test

    %add (a: f32[], b: f32[]) -> f32[] {
      ROOT %r = f32[] add(%a, %b)
    }

    %body (p: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
      %ar = f32[8,64]{1,0} all-reduce(%x), replica_groups=[4,4]<=[16], to_apply=%add
      ROOT %t = (s32[], f32[8,64]) tuple(%i, %ar)
    }

    %cond (p: (s32[], f32[8,64])) -> pred[] {
      ROOT %c = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (x: f32[8,64]) -> f32[] {
      %w = (s32[], f32[8,64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
      ROOT %out = f32[] all-reduce(%s), replica_groups={{0,1,2,3}}, to_apply=%add
    }
    """)
    st = collective_stats(hlo)
    # body all-reduce: 2 * (8*64*4) * 3/4 = 3072 B/device, 7 trips
    # entry all-reduce: scalar f32, group 4: 2*4*3/4 = 6
    assert abs(st.wire_bytes_per_device - (7 * 3072 + 6)) < 1e-6
    assert st.op_counts["all-reduce"] == 8


def test_real_hlo_scan_collectives():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import collective_stats
        mesh = jax.make_mesh((4, 4), ("data", "model"),
                             devices=jax.devices())
        def step(w, x):
            def body(c, wl):
                h = jnp.einsum("bd,df->bf", c, wl)
                return jnp.einsum("bf,df->bd", h, wl), None
            c, _ = jax.lax.scan(body, x, w)
            return jnp.sum(c)
        w = jax.ShapeDtypeStruct((7, 64, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        txt = jax.jit(step, in_shardings=(
            NamedSharding(mesh, P(None, None, "model")),
            NamedSharding(mesh, P("data", None)))).lower(w, x)\
            .compile().as_text()
        st = collective_stats(txt)
        assert abs(st.wire_bytes_per_device - (7 * 3072 + 6)) < 1.0, \
            st.wire_bytes_per_device
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=600)
    assert "OK" in out.stdout, out.stderr[-2000:]
