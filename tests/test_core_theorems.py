"""Validation of the paper's theorems against the event simulator.

These are the reproduction's correctness spine: every closed-form claim in
the paper is checked against the jit-compiled G/G/1+spot simulator.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: deterministic fallback (see
    from _propcheck import given, settings, st  # requirements-dev.txt)

from repro.core import (
    Exponential,
    Gamma,
    Uniform,
    optimal_deterministic,
    optimal_exp_rate,
    optimal_two_point,
    laplace_target,
    run_queue_sim,
    run_single_slot_sim,
    theorem1_cost,
    theorem2_cost,
    theorem2_delta_max,
    theorem5_cost,
    theorem5_delta,
)
from repro.core.analytic import mm1n_cost_from_pi, mm1n_pi
from repro.core.cost import cost_lower_bound, pi0_from_cost

LAM, MU, K = 1 / 12, 1 / 24, 10.0
N_EVENTS = 300_000


# ---------------------------------------------------------------------------
# Theorem 1: E[C] = k − (k−1)(μ/λ)(1−π₀) for ANY policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "job,spot,r",
    [
        (Exponential(LAM), Exponential(MU), 1.0),
        (Exponential(LAM), Exponential(MU), 2.5),
        (Gamma(12.0, 1.0), Exponential(MU), 3.0),
        (Exponential(LAM), Uniform(0.0, 48.0), 1.5),
        (Gamma(12.0, 1.0), Uniform(0.0, 48.0), 0.7),
    ],
    ids=["mm-r1", "mm-r2.5", "gm-r3", "mu-r1.5", "gu-r0.7"],
)
def test_theorem1_cost_law(job, spot, r):
    res = run_queue_sim(job, spot, k=K, r=r, n_events=N_EVENTS,
                        key=jax.random.key(42))
    lam, mu = job.rate(), spot.rate()
    predicted = theorem1_cost(K, lam, mu, res["pi0_spot"])
    assert abs(predicted - res["avg_cost"]) < 0.06, (predicted, res["avg_cost"])


def test_pi0_from_cost_inverts():
    pi0 = 0.37
    c = theorem1_cost(K, LAM, MU, pi0)
    np.testing.assert_allclose(pi0_from_cost(K, LAM, MU, c), pi0, rtol=1e-12)


# ---------------------------------------------------------------------------
# Theorem 2 + Corollaries: strong-delay regime
# ---------------------------------------------------------------------------
def test_theorem2_regime_boundary():
    # Exponentials: P(A<=S)/λ = (λ/(λ+μ))/λ = 1/(λ+μ) = 8 h
    np.testing.assert_allclose(
        theorem2_delta_max(Exponential(LAM), Exponential(MU)), 8.0, rtol=1e-3
    )


@pytest.mark.parametrize("delta", [1.5, 3.0, 5.0])
def test_corollary4_deterministic_wait_achieves_optimum(delta):
    wait = optimal_deterministic(LAM, MU, delta)
    res = run_single_slot_sim(
        Exponential(LAM), Exponential(MU), wait, k=K, n_events=N_EVENTS,
        key=jax.random.key(0),
    )
    target = theorem2_cost(K, MU, delta)
    assert abs(res["avg_cost"] - target) < 0.08, (res["avg_cost"], target)
    assert abs(res["avg_delay"] - delta) < 0.15


@pytest.mark.parametrize("delta", [1.5, 3.0])
def test_remark2_exponential_wait_achieves_optimum(delta):
    wait = optimal_exp_rate(LAM, MU, delta)
    np.testing.assert_allclose(
        wait.laplace(MU), laplace_target(LAM, MU, delta), rtol=1e-12
    )
    res = run_single_slot_sim(
        Exponential(LAM), Exponential(MU), wait, k=K, n_events=N_EVENTS,
        key=jax.random.key(1),
    )
    assert abs(res["avg_cost"] - theorem2_cost(K, MU, delta)) < 0.08
    assert abs(res["avg_delay"] - delta) < 0.15


def test_corollary1_two_point_finite_support():
    """Uniform spot on [0,L]: X ∈ {0, L} with p = μδ/(1−λδ) is optimal.

    The two-point policy maximizes P(X > S) at the same E[C] bound; its
    realized delay is ≤ δ (the bound construction guards the worst case), and
    its cost must beat any other feasible single-slot policy at equal delay.
    """
    L, delta = 48.0, 3.0
    mu = 2.0 / L
    wait = optimal_two_point(LAM, mu, delta, L)
    np.testing.assert_allclose(wait.p, mu * delta / (1 - LAM * delta), rtol=1e-12)
    res = run_single_slot_sim(
        Exponential(LAM), Uniform(0.0, L), wait, k=K, n_events=N_EVENTS,
        key=jax.random.key(2),
    )
    # cost within the theorem-2 bound window and delay within budget
    assert res["avg_delay"] <= delta + 0.1
    assert res["avg_cost"] <= theorem2_cost(K, mu, delta) + 0.1


@given(delta=st.floats(0.5, 6.0))
@settings(max_examples=10, deadline=None)
def test_theorem2_cost_is_lower_bound_property(delta):
    """No single-slot policy simulated at E[T]≈δ beats k−(k−1)μδ."""
    wait = optimal_deterministic(LAM, MU, delta)
    res = run_single_slot_sim(
        Exponential(LAM), Exponential(MU), wait, k=K, n_events=80_000,
        key=jax.random.key(3),
    )
    assert res["avg_cost"] >= theorem2_cost(K, MU, delta) - 0.15


# ---------------------------------------------------------------------------
# Theorem 5: M/M/1/N closed forms
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_cap", [1, 2, 3, 4])
def test_theorem5_cost_and_delay(n_cap):
    res = run_queue_sim(
        Exponential(LAM), Exponential(MU), k=K, r=float(n_cap),
        n_events=N_EVENTS, key=jax.random.key(n_cap),
    )
    assert abs(res["avg_cost"] - theorem5_cost(K, LAM, MU, n_cap)) < 0.08
    assert abs(res["avg_delay"] - theorem5_delta(LAM, MU, n_cap)) < 0.8
    assert abs(res["pi0_spot"] - mm1n_pi(LAM, MU, n_cap)[0]) < 0.01


def test_theorem5_equals_theorem1_on_mm1n():
    for n in range(1, 8):
        np.testing.assert_allclose(
            theorem5_cost(K, LAM, MU, n), mm1n_cost_from_pi(K, LAM, MU, n),
            rtol=1e-12,
        )


def test_theorem5_monotonicity():
    costs = [theorem5_cost(K, LAM, MU, n) for n in range(1, 10)]
    deltas = [theorem5_delta(LAM, MU, n) for n in range(1, 10)]
    assert all(a > b for a, b in zip(costs, costs[1:]))  # strictly decreasing
    assert all(a < b for a, b in zip(deltas, deltas[1:]))  # strictly increasing


@given(
    lam=st.floats(0.05, 0.5),
    ratio=st.floats(0.2, 3.0).filter(lambda x: abs(x - 1.0) > 0.05),
    n=st.integers(1, 12),
)
@settings(max_examples=50, deadline=None)
def test_theorem5_cost_in_range_property(lam, ratio, n):
    mu = lam * ratio
    c = theorem5_cost(K, lam, mu, n)
    # cost is always in [max(1, k-(k-1)μ/λ), k]
    assert c <= K + 1e-9
    assert c >= max(1.0, K - (K - 1) * mu / lam) - 1e-9
    assert c >= cost_lower_bound(K, lam, mu, theorem5_delta(lam, mu, n)) - 1e-6


# ---------------------------------------------------------------------------
# Fractional admission r = N + p interpolates Theorem-5 costs
# ---------------------------------------------------------------------------
def test_fractional_r_interpolates():
    r = 1.5
    res = run_queue_sim(Exponential(LAM), Exponential(MU), k=K, r=r,
                        n_events=N_EVENTS, key=jax.random.key(9))
    c1 = theorem5_cost(K, LAM, MU, 1)
    c2 = theorem5_cost(K, LAM, MU, 2)
    assert c2 - 0.06 <= res["avg_cost"] <= c1 + 0.06
