"""The sharding-equivalence ledger: ``shard="lanes"`` == ``shard="none"``.

The sweep entry points' ``shard="lanes"`` dispatch partitions the
flattened (grid × seeds) lane axis across a 1-D device mesh with
``shard_map`` (repro.distributed.sharding).  Lane keys are independent in
both RNG streams, so per-lane trajectories are untouched by construction
— which makes the equivalence contract *checkable*, not aspirational:

* integer stats (engine ``INT_STATS``, telemetry ``TEL_INT_STATS``,
  shock ``ENV_INT_STATS``) and telemetry histograms: **bitwise** against
  the unsharded run, always;
* ``impl="ref"``/``"pallas"``: **everything** bitwise (the sharded body
  runs the identical flat-lane ops per shard);
* ``impl="xla"`` floats: ~ulp (rtol 1e-5) — the sharded path runs the
  per-lane program under one materialized flat vmap while the unsharded
  wrapper uses broadcast nested vmaps, the PR-3 layout caveat.

The ledger runs every (loop × executor × rng) cell at 1/2/4/8 shards
with ``telemetry=`` and ``env=`` on; lane counts are deliberately NOT
divisible by 4 or 8, so the pad-and-mask path (pad with copies of lane 0,
slice off after) is exercised whenever it can be.  Cells needing more
devices than the process has skip with the ``XLA_FLAGS`` hint — the CI
fleet job runs the full matrix under 8 simulated host devices; the
subprocess test below keeps a real multi-shard check in tier-1 on any
machine.

Also here: property tests (hypothesis, with the tests/_propcheck
fallback) for the cross-shard merge helpers — ``telemetry_merge`` /
``telemetry_reduce`` / ``env_merge`` / ``env_reduce`` are associative,
commutative, and partition-invariant on their int32 counters, the
algebra that makes host-side cross-shard aggregation order-independent.
"""
import functools
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: deterministic fallback
    from _propcheck import given, settings, st

from repro.core.arrivals import Exponential
from repro.core.engine import (INT_STATS, run_market_sweep,
                               run_region_sweep, run_sweep)
from repro.core.env import EnvTimeline, inject_storm
from repro.core.market import NoticeAwareKernel, SpotMarket, SpotPool
from repro.core.policies import ThreePhaseKernel
from repro.core.regions import Region, RegionTopology, RoutingKernel
from repro.distributed.sharding import lane_mesh, lane_spec, pad_lanes
from repro.obs import (ENV_INT_STATS, TEL_INT_STATS, EnvWindowStats,
                       Telemetry, TelemetryWindowStats, env_merge,
                       env_reduce, telemetry_merge, telemetry_reduce)

LAM, MU, K = 1.2, 0.9, 12.0


def _market() -> SpotMarket:
    return SpotMarket(pools=(
        SpotPool(arrival=Exponential(0.9), price=1.0, hazard=0.3, notice=0.1),
        SpotPool(arrival=Exponential(0.5), price=0.6, hazard=0.8, notice=0.3),
    ))


def _topo() -> RegionTopology:
    return RegionTopology(regions=(
        Region(job=Exponential(1.2), spot=Exponential(0.9), price=1.0,
               hazard=0.3, notice=0.1, rmax=4),
        Region(job=Exponential(0.7), spot=Exponential(0.5), price=0.6,
               hazard=0.8, notice=0.3, rmax=4),
    ))


def _env() -> EnvTimeline:
    return inject_storm(EnvTimeline.constant(), 20.0, 60.0, hazard_mult=6.0)


def _run(loop: str, impl: str, rng: str, **over) -> dict:
    # 3 grid points × 2 seeds = 6 lanes: divisible by 1 and 2, pad-and-mask
    # (2 pad lanes) at 4 and 8 shards
    kw = dict(k=K, n_events=300, key=jax.random.key(0), n_seeds=2,
              burn_in=64, chunk_events=128, impl=impl, rng=rng, tile=2,
              telemetry=Telemetry(), env=_env())
    kw.update(over)
    params = {"r": jnp.linspace(0.5, 2.5, 3)}
    if loop == "single":
        return run_sweep(Exponential(LAM), Exponential(MU),
                         ThreePhaseKernel(), params, rmax=8, **kw)
    if loop == "market":
        return run_market_sweep(Exponential(LAM), _market(),
                                NoticeAwareKernel(checkpoint_time=0.05),
                                params, rmax=8, **kw)
    return run_region_sweep(_topo(), RoutingKernel(
        NoticeAwareKernel(checkpoint_time=0.05), choice="cheapest"),
        params, **kw)


@functools.lru_cache(maxsize=None)
def _unsharded(loop: str, impl: str, rng: str) -> dict:
    return _run(loop, impl, rng)


# histograms are integer counts of per-lane binning decisions — bitwise
# across shardings just like the decision counters (repro.obs.stats
# TEL_INT_STATS note: it is cross-*executor* layouts that may flip a
# boundary bin, not cross-shard partitions of the same executor)
_EXACT_KEYS = (set(INT_STATS) | set(TEL_INT_STATS) | set(ENV_INT_STATS)
               | {"wait_hist", "cost_hist"})


def _assert_ledger(ref: dict, sharded: dict, impl: str, context: str):
    assert set(ref) == set(sharded), context
    for name, v in ref.items():
        a, b = np.asarray(v), np.asarray(sharded[name])
        assert a.shape == b.shape, f"{name} shape ({context})"
        if (impl in ("pallas", "ref") or name in _EXACT_KEYS
                or np.issubdtype(a.dtype, np.integer)):
            np.testing.assert_array_equal(
                a, b, err_msg=f"{name} diverged ({context})")
        else:
            np.testing.assert_allclose(
                a, b, rtol=1e-5, err_msg=f"{name} diverged ({context})")


_CELLS = [(loop, impl, rng)
          for loop in ("single", "market", "region")
          for impl in ("xla", "pallas", "ref")
          for rng in ("split", "slab")]


@pytest.mark.parametrize("n_shards", (1, 2, 4, 8))
@pytest.mark.parametrize("loop,impl,rng", _CELLS,
                         ids=[f"{c[0]}-{c[1]}-{c[2]}" for c in _CELLS])
def test_sharding_equivalence_ledger(loop, impl, rng, n_shards):
    if n_shards > len(jax.devices()):
        pytest.skip(
            f"needs {n_shards} devices, have {len(jax.devices())} — run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            f"(the CI fleet job) for the full ledger")
    sharded = _run(loop, impl, rng, shard="lanes", mesh=lane_mesh(n_shards))
    _assert_ledger(_unsharded(loop, impl, rng), sharded, impl,
                   f"{loop}/{impl}/{rng} @ {n_shards} shards")


def test_shard_default_mesh_single_device():
    """``shard='lanes'`` with ``mesh=None`` builds the every-local-device
    mesh; on one device that is still the sharded code path end-to-end."""
    out = _run("single", "xla", "slab", shard="lanes")
    _assert_ledger(_unsharded("single", "xla", "slab"), out, "xla",
                   "single/xla/slab @ default mesh")


# ---------------------------------------------------------------------------
# Pad-and-mask + mesh/spec helpers (device-count independent)
# ---------------------------------------------------------------------------
def test_pad_lanes_replicates_lane_zero():
    tree = {"a": jnp.arange(6.0).reshape(3, 2), "k": jnp.arange(3.0)}
    padded = pad_lanes(tree, 2)
    assert padded["a"].shape == (5, 2) and padded["k"].shape == (5,)
    np.testing.assert_array_equal(np.asarray(padded["a"][:3]),
                                  np.asarray(tree["a"]))
    # pad lanes are copies of lane 0 — real params, valid simulations
    np.testing.assert_array_equal(np.asarray(padded["a"][3:]),
                                  np.tile(np.asarray(tree["a"][:1]), (2, 1)))
    np.testing.assert_array_equal(np.asarray(padded["k"][3:]),
                                  np.zeros(2))
    assert pad_lanes(tree, 0) is tree  # n_pad=0 is the identity


def test_lane_mesh_and_spec_validation():
    mesh = lane_mesh(1)
    assert mesh.size == 1 and mesh.axis_names == ("lanes",)
    assert lane_spec(mesh) == jax.sharding.PartitionSpec("lanes")
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        lane_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError, match="1-D"):
        lane_spec(jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("a", "b")))


def test_shard_argument_validation():
    with pytest.raises(ValueError, match="unknown shard"):
        _run("single", "xla", "split", shard="pods")
    with pytest.raises(ValueError, match="requires shard='lanes'"):
        _run("single", "xla", "split", mesh=lane_mesh(1))
    with pytest.raises(ValueError, match="1-D mesh"):
        _run("single", "xla", "split", shard="lanes",
             mesh=jax.sharding.Mesh(
                 np.array(jax.devices()[:1]).reshape(1, 1), ("a", "b")))


# ---------------------------------------------------------------------------
# Real multi-shard check inside tier-1: subprocess with 2 forced host
# devices (same pattern as tests/test_distributed.py — the main pytest
# process must keep its single real device)
# ---------------------------------------------------------------------------
def test_multi_device_subprocess_uneven_lanes():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.arrivals import Exponential
        from repro.core.engine import INT_STATS, run_sweep, run_market_sweep
        from repro.core.market import (NoticeAwareKernel, SpotMarket,
                                       SpotPool)
        from repro.core.policies import ThreePhaseKernel
        from repro.distributed.sharding import lane_mesh
        from repro.obs import TEL_INT_STATS, Telemetry

        assert len(jax.devices()) == 2, jax.devices()

        def check(run, impl, **kw):
            a = run(impl=impl, **kw)
            b = run(impl=impl, shard="lanes", mesh=lane_mesh(2), **kw)
            for name in a:
                x, y = np.asarray(a[name]), np.asarray(b[name])
                if impl == "ref" or name in INT_STATS \\
                        or name in TEL_INT_STATS \\
                        or np.issubdtype(x.dtype, np.integer):
                    np.testing.assert_array_equal(x, y, err_msg=name)
                else:
                    np.testing.assert_allclose(x, y, rtol=1e-5,
                                               err_msg=name)

        # 5 grid points x 1 seed = 5 lanes on 2 shards: pad-and-mask live
        kw = dict(k=12.0, n_events=200, key=jax.random.key(0), n_seeds=1,
                  burn_in=32, chunk_events=64, telemetry=Telemetry())
        def single(**kws):
            return run_sweep(Exponential(1.2), Exponential(0.9),
                             ThreePhaseKernel(),
                             {"r": jnp.linspace(0.5, 2.5, 5)}, rmax=8,
                             **kw, **kws)
        market = SpotMarket(pools=(
            SpotPool(arrival=Exponential(0.9), price=1.0, hazard=0.3,
                     notice=0.1),
            SpotPool(arrival=Exponential(0.5), price=0.6, hazard=0.8,
                     notice=0.3)))
        def mkt(**kws):
            return run_market_sweep(Exponential(1.2), market,
                                    NoticeAwareKernel(checkpoint_time=0.05),
                                    {"r": jnp.linspace(0.5, 2.5, 5)},
                                    rmax=8, **kw, **kws)

        check(single, "xla", rng="slab")
        check(single, "ref", rng="split")
        check(mkt, "xla", rng="split")
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=900)
    assert "OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])


# ---------------------------------------------------------------------------
# Merge algebra: the host-side cross-shard aggregation helpers
# ---------------------------------------------------------------------------
def _tel_blocks(seed: int, n: int, n_bins: int = 8,
                n_locs: int = 2) -> TelemetryWindowStats:
    """``n`` stacked synthetic counter blocks (rings off), leading axis 0."""
    r = np.random.default_rng(seed)

    def i32(*shape):
        return r.integers(0, 1_000, size=shape, dtype=np.int32)

    return TelemetryWindowStats(
        wait_hist=i32(n, n_bins), cost_hist=i32(n, n_bins),
        events=i32(n, 4), spot_starts=i32(n), preempts_fired=i32(n),
        notices_honored=i32(n), deadline_defects=i32(n), rejects=i32(n),
        loc_defects=i32(n, n_locs), loc_resumed=i32(n, n_locs),
        ring_t=None, ring_type=None, ring_loc=None, ring_qlen=None,
        ring_val=None, ring_n=None)


def _env_blocks(seed: int, n: int) -> EnvWindowStats:
    r = np.random.default_rng(seed + 1)
    ints = [r.integers(0, 1_000, size=n, dtype=np.int32) for _ in range(8)]
    floats = [r.random(n).astype(np.float32) for _ in range(2)]
    return EnvWindowStats(*ints, *floats)


def _slice(ts, i):
    return type(ts)(*(None if x is None else x[i] for x in ts))


def _sub(ts, sl):
    return type(ts)(*(None if x is None else x[sl] for x in ts))


def _assert_blocks_equal(a, b, *, float_rtol=None):
    for name, x, y in zip(type(a)._fields, a, b):
        if x is None:
            assert y is None, name
        elif float_rtol is not None and np.issubdtype(
                np.asarray(x).dtype, np.floating):
            np.testing.assert_allclose(x, y, rtol=float_rtol, err_msg=name)
        else:
            np.testing.assert_array_equal(x, y, err_msg=name)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16),
       n=st.integers(min_value=3, max_value=6),
       pivot=st.integers(min_value=1, max_value=5))
def test_telemetry_merge_algebra(seed, n, pivot):
    """merge is associative + commutative; reduce is its n-way fold; any
    two-way partition of the lane axis reduces to the same block.  All
    counter fields are int32, so every identity is exact."""
    ts = _tel_blocks(seed, n)
    a, b, c = _slice(ts, 0), _slice(ts, 1), _slice(ts, 2)
    _assert_blocks_equal(telemetry_merge(a, b), telemetry_merge(b, a))
    _assert_blocks_equal(telemetry_merge(telemetry_merge(a, b), c),
                         telemetry_merge(a, telemetry_merge(b, c)))
    folded = _slice(ts, 0)
    for i in range(1, n):
        folded = telemetry_merge(folded, _slice(ts, i))
    _assert_blocks_equal(telemetry_reduce(ts), folded)
    p = min(pivot, n - 1)
    _assert_blocks_equal(
        telemetry_merge(telemetry_reduce(_sub(ts, slice(None, p))),
                        telemetry_reduce(_sub(ts, slice(p, None)))),
        telemetry_reduce(ts))


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16),
       n=st.integers(min_value=3, max_value=6),
       pivot=st.integers(min_value=1, max_value=5))
def test_env_merge_algebra(seed, n, pivot):
    """Same algebra for the shock counters: the eight int32 fields are
    exact under any association/partition; the two float dwell sums are
    commutative bitwise (IEEE adds commute) and associative/partition-
    invariant to ~ulp — the documented env_merge contract."""
    es = _env_blocks(seed, n)
    a, b, c = _slice(es, 0), _slice(es, 1), _slice(es, 2)
    _assert_blocks_equal(env_merge(a, b), env_merge(b, a))
    _assert_blocks_equal(env_merge(env_merge(a, b), c),
                         env_merge(a, env_merge(b, c)), float_rtol=1e-6)
    folded = _slice(es, 0)
    for i in range(1, n):
        folded = env_merge(folded, _slice(es, i))
    _assert_blocks_equal(env_reduce(es), folded, float_rtol=1e-6)
    p = min(pivot, n - 1)
    _assert_blocks_equal(
        env_merge(env_reduce(_sub(es, slice(None, p))),
                  env_reduce(_sub(es, slice(p, None)))),
        env_reduce(es), float_rtol=1e-6)


def test_telemetry_merge_rejects_trace_rings():
    """Trace rings are per-lane drains, not mergeable counters — the merge
    helpers refuse them loudly instead of silently dropping records."""
    ts = _tel_blocks(3, 2)
    with_rings = ts._replace(ring_n=np.zeros(2, np.int32))
    with pytest.raises(ValueError, match="ring"):
        telemetry_merge(_slice(with_rings, 0), _slice(with_rings, 1))
    with pytest.raises(ValueError, match="ring"):
        telemetry_reduce(with_rings)
