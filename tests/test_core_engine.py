"""Sweep-engine equivalence and invariants.

The kernel-parameterized engine replaced the seed's two hand-written event
loops.  To keep that refactor honest, this module carries *frozen reference
copies* of the seed's event bodies (``_ref_queue_sim`` /
``_ref_single_slot_sim``, verbatim from the pre-engine simulator.py) and
asserts the engine reproduces their statistics **bit-for-bit** per seed —
same PRNG split layout, same float32 accumulation order.

Also covered: traced three-phase admission vs the host policy descriptor,
run_sweep vs per-point calls on a ≥64-point grid, chunked-window
consistency, traced wait-time parameter sweeps, batched vs scalar
Algorithm-1 learners, and conservation invariants of the generic
finite-budget (defect-on-deadline) path no seed loop exercised.
"""
import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Exponential,
    Gamma,
    ThreePhaseKernel,
    ThreePhasePolicy,
    Uniform,
    adaptive_admission_control,
    adaptive_admission_control_batched,
    optimal_deterministic,
    optimal_exp_rate,
    optimal_two_point,
    run_queue_sim,
    run_single_slot_sim,
    run_sim,
    run_sweep,
    three_phase_admit_prob,
)
from repro.core.engine import WindowStats
from repro.core.waittime import DeterministicWait

LAM, MU, K = 1 / 12, 1 / 24, 10.0
N_EVENTS = 40_000

_INF = jnp.float32(3e38)


# ---------------------------------------------------------------------------
# Frozen seed reference: the pre-engine event loops, verbatim
# ---------------------------------------------------------------------------


class _RefQueueCarry(NamedTuple):
    key: jax.Array
    next_job: jax.Array
    next_spot: jax.Array
    ages: jax.Array
    head: jax.Array
    qlen: jax.Array


def _ref_admit_prob(qlen, r):
    n_hat = jnp.floor(r)
    frac = r - n_hat
    qf = qlen.astype(jnp.float32)
    return jnp.where(qf < n_hat, 1.0, jnp.where(qf == n_hat, frac, 0.0))


def _ref_queue_event(job, spot, k_cost, rmax, carry, stats, r):
    key, k_job, k_spot, k_adm = jax.random.split(carry.key, 4)
    is_job = carry.next_job <= carry.next_spot
    dt = jnp.minimum(carry.next_job, carry.next_spot)
    ages = carry.ages + dt
    p_admit = _ref_admit_prob(carry.qlen, r)
    admit = (jax.random.uniform(k_adm) < p_admit) & (carry.qlen < rmax)
    tail = (carry.head + carry.qlen) % rmax
    ages_job = jnp.where(admit, ages.at[tail].set(0.0), ages)
    qlen_job = carry.qlen + jnp.where(admit, 1, 0)
    od_inc = jnp.where(admit, 0, 1)
    has_job = carry.qlen > 0
    wait = ages[carry.head]
    head_spot = jnp.where(has_job, (carry.head + 1) % rmax, carry.head)
    qlen_spot = carry.qlen - jnp.where(has_job, 1, 0)
    new_carry = _RefQueueCarry(
        key=key,
        next_job=jnp.where(is_job, job.sample(k_job), carry.next_job - dt),
        next_spot=jnp.where(is_job, carry.next_spot - dt, spot.sample(k_spot)),
        ages=jnp.where(is_job, ages_job, ages),
        head=jnp.where(is_job, carry.head, head_spot),
        qlen=jnp.where(is_job, qlen_job, qlen_spot),
    )
    served = (~is_job) & has_job
    new_stats = WindowStats(
        jobs_arrived=stats.jobs_arrived + jnp.where(is_job, 1, 0),
        jobs_completed=stats.jobs_completed
        + jnp.where(is_job, od_inc, jnp.where(served, 1, 0)),
        spot_served=stats.spot_served + jnp.where(served, 1, 0),
        ondemand=stats.ondemand + jnp.where(is_job, od_inc, 0),
        cost_sum=stats.cost_sum
        + jnp.where(is_job, od_inc.astype(jnp.float32) * k_cost, 0.0)
        + jnp.where(served, 1.0, 0.0),
        delay_sum=stats.delay_sum + jnp.where(served, wait, 0.0),
        time_elapsed=stats.time_elapsed + dt,
        empty_time=stats.empty_time + jnp.where(carry.qlen == 0, dt, 0.0),
        spot_arrivals=stats.spot_arrivals + jnp.where(is_job, 0, 1),
        spot_found_empty=stats.spot_found_empty
        + jnp.where((~is_job) & (~has_job), 1, 0),
    )
    return new_carry, new_stats


def _ref_queue_sim(job, spot, *, k, r, n_events, key, rmax=64):
    def run(key):
        kj, ks, kc = jax.random.split(key, 3)
        carry = _RefQueueCarry(
            key=kc, next_job=job.sample(kj), next_spot=spot.sample(ks),
            ages=jnp.zeros((rmax,), jnp.float32),
            head=jnp.zeros((), jnp.int32), qlen=jnp.zeros((), jnp.int32))

        def body(state, _):
            c, s = state
            c, s = _ref_queue_event(job, spot, k, rmax, c, s, jnp.float32(r))
            return (c, s), None

        (carry, stats), _ = jax.lax.scan(
            body, (carry, WindowStats.zeros()), None, length=n_events)
        return stats

    return _ref_summarize(jax.jit(run)(key))


class _RefSingleSlotCarry(NamedTuple):
    key: jax.Array
    next_job: jax.Array
    next_spot: jax.Array
    occupied: jax.Array
    age: jax.Array
    x_left: jax.Array


def _ref_single_slot_event(job, spot, wait, k_cost, carry, stats):
    key, k_job, k_spot, k_x = jax.random.split(carry.key, 4)
    deadline = jnp.where(carry.occupied, carry.x_left, _INF)
    dt = jnp.minimum(jnp.minimum(carry.next_job, carry.next_spot), deadline)
    is_spot = carry.next_spot <= jnp.minimum(carry.next_job, deadline)
    is_deadline = (~is_spot) & (deadline <= carry.next_job)
    is_job = (~is_spot) & (~is_deadline)
    age = carry.age + dt
    served = is_spot & carry.occupied
    defected = is_deadline
    x_new = wait.sample(k_x)
    joins = is_job & (~carry.occupied) & (x_new > 0.0)
    od_now = is_job & (carry.occupied | (x_new <= 0.0))
    new_carry = _RefSingleSlotCarry(
        key=key,
        next_job=jnp.where(is_job, job.sample(k_job), carry.next_job - dt),
        next_spot=jnp.where(is_spot, spot.sample(k_spot),
                            carry.next_spot - dt),
        occupied=jnp.where(served | defected, False,
                           jnp.where(joins, True, carry.occupied)),
        age=jnp.where(joins, 0.0, age),
        x_left=jnp.where(joins, x_new,
                         jnp.where(carry.occupied, carry.x_left - dt, _INF)),
    )
    completed_inc = (served | defected | od_now).astype(jnp.int32)
    new_stats = WindowStats(
        jobs_arrived=stats.jobs_arrived + is_job.astype(jnp.int32),
        jobs_completed=stats.jobs_completed + completed_inc,
        spot_served=stats.spot_served + served.astype(jnp.int32),
        ondemand=stats.ondemand + (defected | od_now).astype(jnp.int32),
        cost_sum=stats.cost_sum
        + jnp.where(served, 1.0, 0.0)
        + jnp.where(defected | od_now, k_cost, 0.0),
        delay_sum=stats.delay_sum + jnp.where(served | defected, age, 0.0),
        time_elapsed=stats.time_elapsed + dt,
        empty_time=stats.empty_time + jnp.where(carry.occupied, 0.0, dt),
        spot_arrivals=stats.spot_arrivals + is_spot.astype(jnp.int32),
        spot_found_empty=stats.spot_found_empty
        + (is_spot & (~carry.occupied)).astype(jnp.int32),
    )
    return new_carry, new_stats


def _ref_single_slot_sim(job, spot, wait, *, k, n_events, key):
    def run(key):
        kj, ks, kc = jax.random.split(key, 3)
        carry = _RefSingleSlotCarry(
            key=kc, next_job=job.sample(kj), next_spot=spot.sample(ks),
            occupied=jnp.zeros((), jnp.bool_),
            age=jnp.zeros((), jnp.float32), x_left=_INF)

        def body(state, _):
            c, s = state
            c, s = _ref_single_slot_event(job, spot, wait, k, c, s)
            return (c, s), None

        (carry, stats), _ = jax.lax.scan(
            body, (carry, WindowStats.zeros()), None, length=n_events)
        return stats

    return _ref_summarize(jax.jit(run)(key))


def _ref_summarize(stats):
    s = jax.tree.map(lambda x: np.asarray(x, np.float64), stats)
    completed = max(s.jobs_completed, 1.0)
    arrived = max(s.jobs_arrived, 1.0)
    return {
        "jobs_arrived": float(s.jobs_arrived),
        "jobs_completed": float(s.jobs_completed),
        "spot_served": float(s.spot_served),
        "ondemand": float(s.ondemand),
        "avg_cost": float(s.cost_sum / completed),
        "avg_delay": float(s.delay_sum / completed),
        "time": float(s.time_elapsed),
        "pi0_time": float(s.empty_time / max(s.time_elapsed, 1e-12)),
        "pi0_spot": float(s.spot_found_empty / max(s.spot_arrivals, 1.0)),
        "spot_utilization": float(
            (s.spot_arrivals - s.spot_found_empty) / max(s.spot_arrivals, 1.0)
        ),
        "arrival_rate": float(arrived / max(s.time_elapsed, 1e-12)),
    }


# ---------------------------------------------------------------------------
# Bit-for-bit equivalence: engine == seed event loops
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "job,spot,r",
    [
        (Exponential(LAM), Exponential(MU), 1.5),
        (Gamma(12.0, 1.0), Exponential(MU), 3.0),
        (Exponential(LAM), Uniform(0.0, 48.0), 2.5),
        (Exponential(LAM), Exponential(MU), 0.0),
    ],
    ids=["mm", "gm", "mu", "r0"],
)
def test_queue_engine_bit_for_bit(job, spot, r):
    key = jax.random.key(7)
    ref = _ref_queue_sim(job, spot, k=K, r=r, n_events=N_EVENTS, key=key)
    new = run_queue_sim(job, spot, k=K, r=r, n_events=N_EVENTS, key=key)
    assert ref == new  # every statistic identical to the last bit


@pytest.mark.parametrize(
    "wait",
    [
        optimal_deterministic(LAM, MU, 3.0),
        optimal_exp_rate(LAM, MU, 2.0),
        optimal_two_point(LAM, 2 / 48.0, 3.0, 48.0),
    ],
    ids=lambda w: type(w).__name__,
)
def test_single_slot_engine_bit_for_bit(wait):
    key = jax.random.key(3)
    ref = _ref_single_slot_sim(Exponential(LAM), Exponential(MU), wait, k=K,
                               n_events=N_EVENTS, key=key)
    new = run_single_slot_sim(Exponential(LAM), Exponential(MU), wait, k=K,
                              n_events=N_EVENTS, key=key)
    assert ref == new


# ---------------------------------------------------------------------------
# One admission law: traced kernel == host policy descriptor
# ---------------------------------------------------------------------------
def test_three_phase_kernel_matches_policy_admit_prob():
    qlens = jnp.arange(0, 8)
    for r in (0.0, 0.25, 1.0, 2.5, 3.0, 3.4, 6.99):
        traced = jax.jit(three_phase_admit_prob)(qlens, jnp.float32(r))
        host = [ThreePhasePolicy(r=r).admit_prob(int(q)) for q in qlens]
        # traced path rounds r to float32; host path is exact float64
        np.testing.assert_allclose(np.asarray(traced), host, atol=1e-6)


def test_three_phase_admission_frequencies():
    """Engine-level check: empirical admit rate at qlen==N̂ equals q."""
    r = 1.3
    res = run_queue_sim(Exponential(1.0), Exponential(1.0), k=K, r=r,
                        n_events=200_000, key=jax.random.key(0), rmax=4)
    # with λ=μ and r=1.3 the queue spends much of its time at qlen==1;
    # overall admission fraction must sit strictly between phase probs
    admitted = 1.0 - res["ondemand"] / res["jobs_arrived"]
    assert 0.05 < admitted < 1.0


# ---------------------------------------------------------------------------
# run_sweep: one jitted grid == per-point calls
# ---------------------------------------------------------------------------
def test_run_sweep_matches_per_point_calls():
    job, spot = Exponential(LAM), Exponential(MU)
    rs = jnp.linspace(0.25, 4.0, 16)
    n_seeds = 4
    key = jax.random.key(0)
    out = run_sweep(job, spot, ThreePhaseKernel(), {"r": rs}, k=K,
                    n_events=10_000, key=key, n_seeds=n_seeds)
    assert out["avg_cost"].shape == (16, n_seeds)  # ≥64-point grid, one jit
    seed_keys = jax.random.split(key, n_seeds)
    for i in (0, 7, 15):
        for s in range(n_seeds):
            pt = run_queue_sim(job, spot, k=K, r=float(rs[i]),
                               n_events=10_000, key=seed_keys[s])
            assert pt["jobs_arrived"] == out["jobs_arrived"][i, s]
            assert pt["spot_served"] == out["spot_served"][i, s]
            np.testing.assert_allclose(out["avg_cost"][i, s], pt["avg_cost"],
                                       rtol=1e-6)
            np.testing.assert_allclose(out["avg_delay"][i, s],
                                       pt["avg_delay"], rtol=1e-6)


def test_run_sweep_k_axis_broadcasts():
    rg, kg = jnp.meshgrid(jnp.array([1.0, 2.0]), jnp.array([5.0, 10.0, 20.0]),
                          indexing="ij")
    out = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                    {"r": rg}, k=kg, n_events=20_000, key=jax.random.key(1))
    assert out["avg_cost"].shape == (2, 3, 1)
    # cost strictly increases in k at fixed r (more expensive on-demand)
    cost = out["avg_cost"][..., 0]
    assert np.all(np.diff(cost, axis=1) > 0)


def test_run_sweep_traced_wait_params():
    from repro.core import SingleSlotKernel

    job, spot = Exponential(LAM), Exponential(MU)
    xs = jnp.array([2.0, 8.0, 20.0])
    kernel = SingleSlotKernel(wait=DeterministicWait(1.0))
    out = run_sweep(job, spot, kernel, {"wait": {"value": xs}}, k=K,
                    n_events=20_000, key=jax.random.key(2), rmax=1)
    seed_key = jax.random.split(jax.random.key(2), 1)[0]  # run_sweep's seed 0
    for i, x in enumerate(np.asarray(xs)):
        pt = run_single_slot_sim(job, spot, DeterministicWait(float(x)), k=K,
                                 n_events=20_000, key=seed_key)
        np.testing.assert_allclose(out["avg_cost"][i, 0], pt["avg_cost"],
                                   rtol=1e-6)
    # longer allowed wait -> more spot service -> cheaper
    cost = out["avg_cost"][:, 0]
    assert cost[0] > cost[-1]


# ---------------------------------------------------------------------------
# Chunked windows: float32 sums re-zeroed per chunk, float64 assembly
# ---------------------------------------------------------------------------
def test_chunked_equals_single_window():
    job, spot = Exponential(LAM), Exponential(MU)
    kernel = ThreePhaseKernel()
    a = run_sim(job, spot, kernel, {"r": jnp.float32(2.0)}, k=K,
                n_events=50_000, key=jax.random.key(5))
    b = run_sim(job, spot, kernel, {"r": jnp.float32(2.0)}, k=K,
                n_events=50_000, key=jax.random.key(5), chunk_events=4096)
    # identical event stream; only the summation grouping differs
    assert a["jobs_arrived"] == b["jobs_arrived"]
    assert a["spot_served"] == b["spot_served"]
    np.testing.assert_allclose(a["avg_cost"], b["avg_cost"], rtol=1e-5)
    np.testing.assert_allclose(a["time"], b["time"], rtol=1e-5)


def test_chunking_fixes_float32_saturation():
    """A float32 sum saturates once increments fall below the ulp; chunked
    accumulation must keep growing."""
    big = np.float32(3e7)
    # sub-ulp increments (here 0.5 < ulp(3e7)/2 = 1) vanish against a large
    # float32 accumulator — the failure mode chunking prevents
    assert np.float32(big + np.float32(0.5)) == big
    # engine-level: each chunk's float32 sum stays tiny, and the float64
    # assembly tracks the exact expected horizon (merged rate λ+μ = 2/h)
    n_events = 400_000
    res = run_sim(Exponential(1.0), Exponential(1.0), ThreePhaseKernel(),
                  {"r": jnp.float32(1.0)}, k=K, n_events=n_events,
                  key=jax.random.key(6), rmax=4, chunk_events=1 << 14)
    np.testing.assert_allclose(res["time"], n_events / 2.0, rtol=0.02)
    np.testing.assert_allclose(res["jobs_arrived"], n_events / 2.0,
                               rtol=0.02)


# ---------------------------------------------------------------------------
# Generic finite-budget path (no seed loop exercised multi-slot defection)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _BudgetKernel:
    """Admit below a cap; every admitted job may wait at most ``x``."""

    cap: int = 3
    x: float = 5.0

    def admit(self, params, qlen, key):
        del params, key
        return qlen < self.cap, jnp.float32(self.x)


def test_multi_slot_defection_invariants():
    res = run_sim(Exponential(0.5), Exponential(0.2), _BudgetKernel(), {},
                  k=K, n_events=100_000, key=jax.random.key(8), rmax=8)
    # conservation: every completion is spot-served or on-demand
    assert res["jobs_completed"] == res["spot_served"] + res["ondemand"]
    # exact cost accounting
    np.testing.assert_allclose(
        res["avg_cost"] * res["jobs_completed"],
        res["spot_served"] + K * res["ondemand"], rtol=1e-6)
    # λ > μ with a 5h budget: defections must actually happen
    assert res["ondemand"] > 0
    # no served/defected job can have waited past its budget
    assert res["avg_delay"] <= _BudgetKernel.x + 1e-3
    # arrivals split between service modes, none lost
    in_queue = res["jobs_arrived"] - res["jobs_completed"]
    assert 0 <= in_queue <= 8


# ---------------------------------------------------------------------------
# Batched Algorithm 1 == scalar Algorithm 1
# ---------------------------------------------------------------------------
def test_batched_adaptive_matches_scalar():
    job, spot = Exponential(LAM), Exponential(MU)
    kw = dict(k=K, delta=3.0, eta=0.05, eta_decay=0.05, window_events=512,
              n_windows=40, key=jax.random.key(11))
    batched = adaptive_admission_control_batched(
        job, spot, r0=jnp.array([0.5, 4.0]), **kw)
    for i, r0 in enumerate([0.5, 4.0]):
        scalar = adaptive_admission_control(job, spot, r0=r0, **kw)
        np.testing.assert_allclose(batched["r"][i], scalar["r"], rtol=1e-6,
                                   atol=1e-7)
        np.testing.assert_allclose(batched["final_cost"][i],
                                   scalar["final_cost"], rtol=1e-6)


def test_batched_adaptive_2d_meshgrid():
    """(δ × r0) meshgrid batches must flatten through vmap and reshape back."""
    dg, rg = jnp.meshgrid(jnp.array([3.0, 27.0]), jnp.array([0.5, 4.0]),
                          indexing="ij")
    out = adaptive_admission_control_batched(
        Exponential(LAM), Exponential(MU), k=K, delta=dg, r0=rg, eta=0.05,
        window_events=256, n_windows=10, key=jax.random.key(13))
    assert out["r_star"].shape == (2, 2)
    assert out["r"].shape == (2, 2, 10)


def test_batched_adaptive_multi_delta_shapes():
    deltas = jnp.array([3.0, 10.0, 27.0])
    out = adaptive_admission_control_batched(
        Exponential(LAM), Exponential(MU), k=K, delta=deltas, eta=0.02,
        eta_decay=0.05, r0=1.0, r_max=8.0, window_events=512, n_windows=60,
        key=jax.random.key(12))
    assert out["r"].shape == (3, 60)
    assert out["r_star"].shape == (3,)
    # looser delay targets admit deeper queues
    assert out["r_star"][0] < out["r_star"][2]
