"""Spot-market subsystem tests.

The acceptance contract of the market engine (repro.core.market +
repro.core.engine's market loop):

  * a **degenerate** market (1 pool, zero hazard, unit price) with a legacy
    kernel reproduces the PR-1 engine **bit-for-bit** per seed — run_sim /
    run_sweep and run_market_sim / run_market_sweep are indistinguishable;
  * merged per-pool clocks preserve the event ordering and tie rules
    (spot > preempt > deadline > job; pools tie by position) — property
    test against a host-side float32 reference merge;
  * π₀ and the cost accounting are exactly invariant under pool
    *relabeling* (permuting pools with their tags) — per-pool PRNG streams
    are keyed by pool tag, not position;
  * preemption-with-notice: partial legs are paid, checkpoint-within-notice
    re-queues (leg accounting), defects go on-demand — cost conservation
    identities hold to float32 accumulation error;
  * the multi-pool knapsack LP reduces to the paper's min(1, λδ) bound for
    one unit-price pool and respects its caps.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: deterministic fallback (see
    from _propcheck import given, settings, st  # requirements-dev.txt)

import jax
import jax.numpy as jnp

from repro.core import (
    Deterministic,
    Exponential,
    Gamma,
    NoticeAwareKernel,
    PoolChoiceKernel,
    SingleSlotKernel,
    SpotMarket,
    SpotPool,
    ThreePhaseKernel,
    Uniform,
    adaptive_admission_control_batched,
    checkpoint_within_notice,
    cost_lower_bound,
    market_cost_lower_bound,
    market_knapsack_lp,
    run_market_sim,
    run_market_sweep,
    run_sim,
    run_sweep,
    theorem1_market_cost,
)
from repro.core.waittime import DeterministicWait

LAM, MU, K = 1 / 12, 1 / 24, 10.0


def _hetero_market(hazard_scale: float = 1.0) -> SpotMarket:
    return SpotMarket(pools=(
        SpotPool(Exponential(1 / 30.0), price=0.5, hazard=0.02 * hazard_scale,
                 notice=0.5),
        SpotPool(Exponential(1 / 40.0), price=0.3, hazard=0.05 * hazard_scale,
                 notice=0.01),
        SpotPool(Exponential(1 / 60.0), price=0.2, hazard=0.0),
        SpotPool(Exponential(1 / 90.0), price=0.1, hazard=0.10 * hazard_scale,
                 notice=2.0),
    ))


# ---------------------------------------------------------------------------
# Degenerate market == PR-1 engine, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "job,spot,r",
    [
        (Exponential(LAM), Exponential(MU), 1.5),
        (Gamma(12.0, 1.0), Exponential(MU), 3.0),
        (Exponential(LAM), Uniform(0.0, 48.0), 2.5),
        (Exponential(LAM), Exponential(MU), 0.0),
    ],
    ids=["mm", "gm", "mu", "r0"],
)
def test_degenerate_market_bit_for_bit(job, spot, r):
    key = jax.random.key(7)
    kernel = ThreePhaseKernel()
    ref = run_sim(job, spot, kernel, {"r": jnp.float32(r)}, k=K,
                  n_events=30_000, key=key)
    new = run_market_sim(job, SpotMarket.single(spot), kernel,
                         {"r": jnp.float32(r)}, k=K, n_events=30_000,
                         key=key)
    for name, v in ref.items():
        assert new[name] == v, name  # identical to the last bit
    assert new["preemptions"] == 0.0 and new["resumed"] == 0.0
    assert new["spot_cost"] == new["spot_served"]  # unit price
    # without preemption, per-leg and per-job statistics coincide
    assert new["avg_cost_job"] == new["avg_cost"]
    assert new["avg_delay_job"] == new["avg_delay"]


def test_degenerate_market_bit_for_bit_single_slot_and_chunked():
    job, spot = Exponential(LAM), Exponential(MU)
    kernel = SingleSlotKernel(wait=DeterministicWait(5.0))
    key = jax.random.key(3)
    ref = run_sim(job, spot, kernel, {}, k=K, n_events=30_000, key=key,
                  rmax=1, chunk_events=4096)
    new = run_market_sim(job, SpotMarket.single(spot), kernel, {}, k=K,
                         n_events=30_000, key=key, rmax=1,
                         chunk_events=4096)
    for name, v in ref.items():
        assert new[name] == v, name


def test_degenerate_market_sweep_bit_for_bit():
    job, spot = Exponential(LAM), Exponential(MU)
    rs = jnp.linspace(0.25, 4.0, 8)
    key = jax.random.key(0)
    ref = run_sweep(job, spot, ThreePhaseKernel(), {"r": rs}, k=K,
                    n_events=10_000, key=key, n_seeds=3)
    new = run_market_sweep(job, SpotMarket.single(spot), ThreePhaseKernel(),
                           {"r": rs}, k=K, n_events=10_000, key=key,
                           n_seeds=3)
    for name, v in ref.items():
        np.testing.assert_array_equal(np.asarray(new[name]), np.asarray(v),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# Property: merged pool clocks preserve event ordering and ties
# ---------------------------------------------------------------------------
def _host_merge_reference(job_period, pool_periods, n_events):
    """Float32 host replay of the engine's clock merge (no queue effects:
    r=0 rejects every job so deadlines never arm)."""
    nj = np.float32(job_period)
    ns = np.array(pool_periods, np.float32)
    pool_counts = np.zeros(len(pool_periods), np.int64)
    jobs = 0
    for _ in range(n_events):
        p = int(np.argmin(ns))  # pools tie by position
        m = ns[p]
        is_spot = m <= nj  # tie order: spot > job
        dt = m if is_spot else nj
        ns = (ns - dt).astype(np.float32)
        nj = np.float32(nj - dt)
        if is_spot:
            pool_counts[p] += 1
            ns[p] = np.float32(pool_periods[p])
        else:
            jobs += 1
            nj = np.float32(job_period)
    return jobs, pool_counts


@settings(max_examples=10, deadline=None)
@given(base=st.floats(min_value=0.7, max_value=3.1))
def test_merged_pool_clocks_match_host_reference(base):
    job_period = 1.9 * base
    pool_periods = [base, 1.37 * base, 0.73 * base]
    market = SpotMarket(pools=tuple(
        SpotPool(Deterministic(p)) for p in pool_periods))
    n_events = 2_000
    res = run_market_sim(Deterministic(job_period), market,
                         ThreePhaseKernel(),
                         {"r": jnp.float32(0.0)},  # reject all: pure clocks
                         k=K, n_events=n_events, key=jax.random.key(1))
    jobs, pool_counts = _host_merge_reference(job_period, pool_periods,
                                              n_events)
    assert res["jobs_arrived"] == jobs
    np.testing.assert_array_equal(np.asarray(res["pool_spot_arrivals"]),
                                  pool_counts)


def test_tie_order_spot_beats_job():
    """Exact job/spot ties: the slot fires first, so the job admitted in
    the same instant waits one full period — avg delay 1, not 0."""
    market = SpotMarket.single(Deterministic(1.0))
    res = run_market_sim(Deterministic(1.0), market, ThreePhaseKernel(),
                         {"r": jnp.float32(4.0)}, k=K, n_events=4_000,
                         key=jax.random.key(2))
    np.testing.assert_allclose(res["avg_delay"], 1.0, rtol=1e-5)
    # the very first slot (t=1) fires into an empty queue; every later slot
    # serves the job admitted in the same instant one period earlier
    slots = np.asarray(res["pool_spot_arrivals"]).sum()
    np.testing.assert_allclose(res["pi0_spot"] * slots, 1.0, rtol=1e-9)


def test_tie_between_pools_resolves_by_position():
    market = SpotMarket(pools=(SpotPool(Deterministic(1.0)),
                               SpotPool(Deterministic(1.0))))
    res = run_market_sim(Deterministic(10.0), market, ThreePhaseKernel(),
                         {"r": jnp.float32(0.0)}, k=K, n_events=1_000,
                         key=jax.random.key(3))
    counts = np.asarray(res["pool_spot_arrivals"])
    # both fire every period (the tied pool fires on a dt=0 follow-up
    # event), alternating pool 0 first
    assert abs(counts[0] - counts[1]) <= 1
    assert counts.sum() + res["jobs_arrived"] == 1_000


# ---------------------------------------------------------------------------
# Property: π₀ / cost accounting exactly invariant under pool relabeling
# ---------------------------------------------------------------------------
_SCALAR_INVARIANTS = ("avg_cost", "avg_delay", "pi0_time", "pi0_spot",
                      "spot_utilization", "jobs_arrived", "spot_served",
                      "ondemand", "preemptions", "resumed", "spot_cost",
                      "time")


@settings(max_examples=6, deadline=None)
@given(perm=st.sampled_from([(1, 0, 2, 3), (3, 2, 1, 0), (2, 3, 0, 1),
                             (1, 2, 3, 0)]),
       r=st.floats(min_value=0.5, max_value=4.0))
def test_pool_relabeling_invariance(perm, r):
    market = _hetero_market()
    kernel = NoticeAwareKernel(checkpoint_time=0.05)
    kw = dict(k=K, n_events=15_000, key=jax.random.key(11),
              chunk_events=4096)
    res = run_market_sim(Exponential(LAM), market, kernel,
                         {"r": jnp.float32(r)}, **kw)
    res_p = run_market_sim(Exponential(LAM), market.relabel(list(perm)),
                           kernel, {"r": jnp.float32(r)}, **kw)
    for name in _SCALAR_INVARIANTS:
        assert res[name] == res_p[name], name  # exact, not approximate
    inv = [list(perm).index(i) for i in range(4)]
    for name in ("pool_served", "pool_spot_arrivals", "pool_preempted"):
        np.testing.assert_array_equal(np.asarray(res[name]),
                                      np.asarray(res_p[name])[inv],
                                      err_msg=name)


# ---------------------------------------------------------------------------
# Preemption-with-notice semantics + cost conservation
# ---------------------------------------------------------------------------
def test_preemption_accounting_identities():
    market = _hetero_market()
    kernel = NoticeAwareKernel(checkpoint_time=0.05)
    res = run_market_sim(Exponential(LAM), market, kernel,
                         kernel.init_params(3.0), k=K, n_events=60_000,
                         key=jax.random.key(0), chunk_events=4096)
    assert res["preemptions"] > 0 and res["resumed"] > 0
    # every completed leg is a spot service, an on-demand dispatch, or a
    # checkpointed (resumed) preemption leg
    assert res["jobs_completed"] == (res["spot_served"] + res["ondemand"]
                                     + res["resumed"])
    # cost conservation: spot legs (complete + partial) at pool prices,
    # on-demand at k
    prices = market.prices()
    spot_spend = (prices * (np.asarray(res["pool_served"])
                            + np.asarray(res["pool_preempted"]))).sum()
    np.testing.assert_allclose(res["spot_cost"], spot_spend, rtol=2e-5)
    cost_sum = res["avg_cost"] * res["jobs_completed"]
    np.testing.assert_allclose(cost_sum,
                               spot_spend + K * res["ondemand"], rtol=2e-5)
    # per-job stats divide the same totals by FINAL completions only
    final = res["spot_served"] + res["ondemand"]
    np.testing.assert_allclose(res["avg_cost_job"] * final, cost_sum,
                               rtol=1e-9)
    assert res["avg_cost_job"] > res["avg_cost"]  # resumed legs dilute
    # the per-job cost respects the preemption-priced LP floor
    lp = market_knapsack_lp(K, LAM, res["avg_delay_job"], market,
                            include_preemption=True)
    assert res["avg_cost_job"] > lp["objective"] - 0.3


def test_notice_window_gates_checkpointing():
    # one preemptible pool; notice shorter than the checkpoint -> all
    # revocations defect; notice longer -> revocations resume (r large
    # keeps re-admission open)
    def run(notice):
        market = SpotMarket.single(Exponential(1 / 40.0), hazard=0.05,
                                   notice=notice)
        kernel = NoticeAwareKernel(checkpoint_time=0.1)
        return run_market_sim(Exponential(LAM), market, kernel,
                              kernel.init_params(8.0), k=K,
                              n_events=30_000, key=jax.random.key(5))

    tight = run(notice=0.01)
    roomy = run(notice=1.0)
    assert tight["preemptions"] > 0 and tight["resumed"] == 0
    assert roomy["resumed"] > 0
    # host/traced notice law agree
    assert not checkpoint_within_notice(0.1, 0.01)
    assert checkpoint_within_notice(0.1, 1.0)
    assert bool(checkpoint_within_notice(jnp.float32(0.1),
                                         jnp.float32(1.0)))


def test_preempt_readmission_excludes_revoked_job():
    """Re-admission after revocation sees the queue WITHOUT the revoked job
    (the host orchestrator pops it first).  At r=1 a queue holding only the
    revoked job re-admits with probability 1 — every hit must resume."""
    market = SpotMarket.single(Exponential(1 / 40.0), hazard=0.05,
                               notice=10.0)
    kernel = NoticeAwareKernel(checkpoint_time=0.1)
    res = run_market_sim(Exponential(LAM), market, kernel,
                         kernel.init_params(1.0), k=K, n_events=30_000,
                         key=jax.random.key(7), rmax=1)
    assert res["preemptions"] > 0
    # rmax=1 caps the queue at the revoked job itself, so post-pop qlen is
    # always 0: phase 1 of the three-phase law, admit with certainty
    assert res["resumed"] == res["preemptions"]


def test_legacy_kernel_defects_on_preemption():
    """Two-tuple kernels have no on_preempt hook: every revocation goes
    on-demand, none resume."""
    market = SpotMarket.single(Exponential(1 / 40.0), hazard=0.05,
                               notice=10.0)
    res = run_market_sim(Exponential(LAM), market, ThreePhaseKernel(),
                         {"r": jnp.float32(8.0)}, k=K, n_events=30_000,
                         key=jax.random.key(6))
    assert res["preemptions"] > 0
    assert res["resumed"] == 0


# ---------------------------------------------------------------------------
# Pool choice
# ---------------------------------------------------------------------------
def test_pool_choice_rules():
    market = _hetero_market(hazard_scale=0.0)
    job = Exponential(LAM)
    kw = dict(k=K, n_events=20_000, key=jax.random.key(8))
    cheapest = run_market_sim(job, market,
                              PoolChoiceKernel(ThreePhaseKernel(),
                                               choice="cheapest"),
                              {"r": jnp.float32(3.0)}, **kw)
    assert np.asarray(cheapest["pool_served"])[:3].sum() == 0  # all pool 3
    uniform = run_market_sim(job, market,
                             PoolChoiceKernel(ThreePhaseKernel(),
                                              choice="uniform"),
                             {"r": jnp.float32(3.0)}, **kw)
    assert (np.asarray(uniform["pool_served"]) > 0).all()
    weighted = run_market_sim(
        job, market, PoolChoiceKernel(ThreePhaseKernel(), choice="weighted"),
        {"r": jnp.float32(3.0),
         "pool_logits": jnp.array([-9.0, -9.0, 9.0, -9.0])}, **kw)
    served = np.asarray(weighted["pool_served"])
    assert served[2] > 0 and served[[0, 1, 3]].sum() == 0


# ---------------------------------------------------------------------------
# Batched market sweeps: one jit over (params × k × pools-config × seeds)
# ---------------------------------------------------------------------------
def test_market_sweep_matches_per_point_calls():
    market = _hetero_market()
    kernel = NoticeAwareKernel(checkpoint_time=0.05)
    rs = jnp.linspace(0.5, 4.0, 6)
    key = jax.random.key(0)
    out = run_market_sweep(Exponential(LAM), market, kernel, {"r": rs}, k=K,
                           n_events=10_000, key=key, n_seeds=2)
    assert out["avg_cost"].shape == (6, 2)
    assert out["pool_served"].shape == (6, 2, 4)
    seed_keys = jax.random.split(key, 2)
    for i in (0, 5):
        for s in range(2):
            pt = run_market_sim(Exponential(LAM), market, kernel,
                                {"r": rs[i]}, k=K, n_events=10_000,
                                key=seed_keys[s])
            assert pt["jobs_arrived"] == out["jobs_arrived"][i, s]
            np.testing.assert_allclose(out["avg_cost"][i, s],
                                       pt["avg_cost"], rtol=1e-6)
            np.testing.assert_array_equal(np.asarray(pt["pool_served"]),
                                          np.asarray(out["pool_served"])[i, s])


def test_market_sweep_pools_config_axis():
    """The pool configuration itself is a grid axis of one compiled call."""
    market = _hetero_market()
    kernel = NoticeAwareKernel(checkpoint_time=0.05)
    scale = np.linspace(0.5, 2.0, 5)
    price_grid = market.prices()[None, :] * scale[:, None]  # (5, P)
    out = run_market_sweep(Exponential(LAM), market, kernel,
                           {"r": jnp.float32(3.0)}, k=K, prices=price_grid,
                           n_events=10_000, key=jax.random.key(4),
                           n_seeds=2)
    assert out["avg_cost"].shape == (5, 2)
    cost = out["avg_cost"].mean(-1)
    assert cost[0] < cost[-1]  # pricier pools -> pricier jobs
    # hazard override on a statically hazard-free market arms preemption
    out2 = run_market_sweep(Exponential(LAM), _hetero_market(0.0), kernel,
                            {"r": jnp.float32(3.0)}, k=K, hazards=0.05,
                            n_events=10_000, key=jax.random.key(4),
                            n_seeds=1)
    assert (out2["preemptions"] > 0).all()


# ---------------------------------------------------------------------------
# Market LP + Theorem-1 generalization
# ---------------------------------------------------------------------------
def test_market_lp_degenerate_matches_paper_bound():
    market = SpotMarket.single(Exponential(MU))
    for delta in (3.0, 27.0):
        out = market_knapsack_lp(K, LAM, delta, market)
        np.testing.assert_allclose(out["objective"],
                                   cost_lower_bound(K, LAM, MU, delta),
                                   rtol=1e-12)
        np.testing.assert_allclose(
            market_cost_lower_bound(K, LAM, delta, market),
            out["objective"])


def test_market_lp_greedy_fill_and_caps():
    market = _hetero_market()
    out = market_knapsack_lp(K, LAM, 27.0, market)
    # best-savings-first: savings rate (k - c_p) * mu_p decides the order
    savings = (K - market.prices()) * market.rates() / LAM
    assert out["support"] == sorted(range(4), key=lambda p: -savings[p])[
        :len(out["support"])]
    assert (out["u"] <= 1.0 + 1e-12).all()
    assert out["u"].sum() <= LAM * 27.0 + 1e-12
    # preemption-aware effective prices weaken the bound (cost goes up)
    pre = market_knapsack_lp(K, LAM, 27.0, market, include_preemption=True)
    assert pre["objective"] >= out["objective"]
    assert (pre["effective_prices"] >= out["effective_prices"]).all()


def test_theorem1_market_cost_identity_on_engine_run():
    market = _hetero_market(hazard_scale=0.0)  # preemption-free identity
    kernel = PoolChoiceKernel(ThreePhaseKernel(), choice="uniform")
    res = run_market_sim(Exponential(LAM), market, kernel,
                         {"r": jnp.float32(4.0)}, k=K, n_events=60_000,
                         key=jax.random.key(9), chunk_events=4096)
    # exact empirical identity: (k - avg_cost) * completed
    #   == sum_p (k - c_p) * served_p
    lhs = (K - res["avg_cost"]) * res["jobs_completed"]
    rhs = ((K - market.prices()) * np.asarray(res["pool_served"])).sum()
    np.testing.assert_allclose(lhs, rhs, rtol=2e-5)
    # population form: empirical rates + utilizations plug into the law
    lam_emp = res["arrival_rate"]
    rates_emp = np.asarray(res["pool_spot_arrivals"]) / res["time"]
    pred = theorem1_market_cost(K, lam_emp, rates_emp, market.prices(),
                                np.asarray(res["pool_utilization"]))
    np.testing.assert_allclose(pred, res["avg_cost"], rtol=1e-3)


# ---------------------------------------------------------------------------
# Algorithm-1 fleets on a preemptible market
# ---------------------------------------------------------------------------
def test_batched_adaptive_on_market():
    market = _hetero_market()
    out = adaptive_admission_control_batched(
        Exponential(LAM), market, k=K, delta=jnp.array([3.0, 27.0]),
        eta=0.05, window_events=512, n_windows=30, key=jax.random.key(12))
    assert out["r"].shape == (2, 30)
    assert out["preemptions_total"].shape == (2,)
    assert (out["preemptions_total"] > 0).all()
    # looser delay target admits deeper queues
    assert out["r_star"][0] < out["r_star"][1]
