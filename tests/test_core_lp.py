"""LP-oracle tests: knapsack structure (eqs. 9-11) and the Theorem-3 LP."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: deterministic fallback (see
    from _propcheck import given, settings, st  # requirements-dev.txt)

from repro.core import Exponential, Uniform
from repro.core.lp import knapsack_lp, waittime_lp, waittime_lp_cost
from repro.core.analytic import theorem2_cost
from repro.core.waittime import optimal_deterministic

LAM, MU, K = 1 / 12, 1 / 24, 10.0


def test_knapsack_strong_regime_mass_at_one():
    """λδ ≤ 1: all mass at n=1 (Theorem 2's queue-length-one optimality)."""
    out = knapsack_lp(LAM, 3.0)
    assert out["support"] == [1]
    np.testing.assert_allclose(out["objective"], LAM * 3.0, rtol=1e-12)
    np.testing.assert_allclose(out["objective"], out["analytic_objective"])


def test_knapsack_relaxed_regime_saturates():
    """λδ > 1: the LP saturates Σπ = 1 using only n=1."""
    out = knapsack_lp(LAM, 27.0)
    np.testing.assert_allclose(out["objective"], 1.0, rtol=1e-12)


@given(delta=st.floats(0.1, 40.0), lam=st.floats(0.02, 0.5))
@settings(max_examples=50, deadline=None)
def test_knapsack_greedy_equals_analytic(delta, lam):
    out = knapsack_lp(lam, delta)
    np.testing.assert_allclose(out["objective"], min(1.0, lam * delta),
                               rtol=1e-9)


def test_knapsack_greedy_dominates_any_feasible():
    """Random feasible π allocations never beat the greedy objective."""
    rng = np.random.default_rng(0)
    out = knapsack_lp(LAM, 3.0)
    budget = LAM * 3.0
    for _ in range(200):
        raw = rng.random(16)
        raw = raw / raw.sum() * rng.random()  # Σπ ≤ 1
        w = np.arange(1, 17, dtype=np.float64)
        scale = min(1.0, budget / np.dot(w, raw))
        feasible = raw * scale
        assert feasible.sum() <= out["objective"] + 1e-9


# ---------------------------------------------------------------------------
# Theorem-3 LP
# ---------------------------------------------------------------------------
def test_waittime_lp_uniform_recovers_corollary1():
    L, delta = 48.0, 3.0
    spot = Uniform(0.0, L)
    mu = spot.rate()
    res = waittime_lp(spot, LAM, delta)
    # Corollary 1/2: support exactly {0} ∪ [L, ∞) with p = μδ/(1−λδ)
    p_expected = mu * delta / (1 - LAM * delta)
    assert res.support[0] < L / 100
    assert res.support[-1] >= L - 1e-6
    np.testing.assert_allclose(res.masses[-1], p_expected, rtol=1e-3)
    np.testing.assert_allclose(res.objective, p_expected, rtol=1e-3)
    # implied cost hits the Theorem-2 bound
    np.testing.assert_allclose(
        waittime_lp_cost(K, LAM, delta, res), theorem2_cost(K, mu, delta),
        rtol=1e-3,
    )


def test_waittime_lp_exponential_matches_corollary3():
    """Exp spot: LP optimum must equal μδ/(1−λδ) (Corollary 3's objective)."""
    delta = 3.0
    spot = Exponential(MU)
    res = waittime_lp(spot, LAM, delta, w_max=400.0, grid_points=3000)
    np.testing.assert_allclose(
        res.objective, MU * delta / (1 - LAM * delta), rtol=2e-3
    )
    # Corollary 4's deterministic wait is one optimal solution; the LP cannot
    # beat the common optimum.
    det = optimal_deterministic(LAM, MU, delta)
    det_obj = 1.0 - np.exp(-MU * det.value)
    assert res.objective >= det_obj - 2e-3


@given(delta=st.floats(0.5, 6.0))
@settings(max_examples=15, deadline=None)
def test_waittime_lp_objective_never_exceeds_bound(delta):
    """P(X>S) ≤ μδ/(1−λδ) — the Theorem-2 optimum is a hard ceiling."""
    spot = Uniform(0.0, 48.0)
    res = waittime_lp(spot, LAM, delta, grid_points=600)
    assert res.objective <= spot.rate() * delta / (1 - LAM * delta) + 1e-6


def test_waittime_lp_masses_are_distribution():
    res = waittime_lp(Uniform(0.0, 48.0), LAM, 3.0)
    assert np.all(res.masses >= -1e-12)
    np.testing.assert_allclose(res.masses.sum(), 1.0, rtol=1e-9)
