"""Pallas kernel tests: shape/dtype sweeps + allclose vs ref.py oracles,
executed in interpret mode (kernel bodies run in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: deterministic fallback (see
    from _propcheck import given, settings, st  # requirements-dev.txt)

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FA_CASES = [
    # (B, Sq, Sk, H, KH, D, causal, bq, bk)
    (2, 128, 128, 8, 2, 64, True, 64, 64),
    (1, 256, 256, 4, 4, 32, True, 128, 128),
    (2, 64, 256, 8, 1, 64, False, 32, 64),
    (1, 128, 384, 6, 2, 128, True, 64, 128),
    (1, 64, 64, 2, 2, 16, True, 64, 64),  # single-tile path
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, H, KH, D, causal, bq, bk = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KH, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KH, D), dtype)
    off = Sk - Sq if causal else 0
    o = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                        q_offset=off)
    r = attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


@given(
    b=st.integers(1, 3),
    nq=st.integers(1, 4),
    nk_extra=st.integers(0, 3),
    kh=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(b, nq, nk_extra, kh, g, d, causal):
    """Property: kernel == oracle across random tile configurations."""
    bq = 32
    sq = nq * bq
    sk = sq + nk_extra * bq
    ks = jax.random.split(jax.random.key(b * 7 + nq), 3)
    q = jax.random.normal(ks[0], (b, sq, kh * g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, kh, d), jnp.float32)
    off = sk - sq if causal else 0
    o = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bq,
                        q_offset=off)
    r = attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(o, r, rtol=3e-5, atol=3e-5)


def test_flash_attention_rejects_bad_tiling():
    q = jnp.zeros((1, 100, 4, 32))
    k = jnp.zeros((1, 128, 4, 32))
    with pytest.raises(ValueError):
        flash_attention(q, k, k, block_q=64, block_k=64)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
DEC_CASES = [
    (2, 256, 8, 2, 64, 200, 64),
    (1, 512, 4, 1, 128, 512, 128),
    (3, 128, 6, 6, 32, 1, 32),
    (2, 1024, 8, 2, 64, 700, 256),
]


@pytest.mark.parametrize("case", DEC_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    B, S, H, KH, D, kvl, bk = case
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, D), dtype)
    o = decode_attention(q, k, v, jnp.int32(kvl), block_k=bk)
    r = decode_attention_ref(q, k, v, kvl)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


def test_decode_attention_dynamic_kv_len_one_compile():
    """The same compiled kernel must serve every fill level (traced len)."""
    ks = jax.random.split(jax.random.key(2), 3)
    B, S, H, KH, D = 2, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)
    for kvl in [1, 63, 128, 256]:
        o = decode_attention(q, k, v, jnp.int32(kvl), block_k=64)
        r = decode_attention_ref(q, k, v, kvl)
        np.testing.assert_allclose(o, r, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
SSD_CASES = [
    (2, 64, 4, 16, 16, 16),
    (1, 128, 2, 32, 64, 32),
    (2, 256, 4, 64, 32, 64),
    (1, 64, 8, 16, 128, 64),  # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_matches_ref(case, dtype):
    B, L, H, P, N, Q = case
    ks = jax.random.split(jax.random.key(3), 4)
    x = (jax.random.normal(ks[0], (B, L, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    d_skip = jnp.ones((H,), jnp.float32)
    b_in = (jax.random.normal(ks[2], (B, L, N)) * 0.3).astype(dtype)
    c_in = (jax.random.normal(ks[3], (B, L, N)) * 0.3).astype(dtype)
    o = ssd(x, dt, a_log, d_skip, b_in, c_in, chunk=Q)
    r = ssd_ref(x, dt, a_log, d_skip, b_in, c_in)
    tol = dict(rtol=4e-2, atol=4e-2) if dtype == jnp.bfloat16 else dict(
        rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **tol)


@given(
    b=st.integers(1, 2),
    nc=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    p=st.sampled_from([16, 32]),
    n=st.sampled_from([16, 64]),
)
@settings(max_examples=10, deadline=None)
def test_ssd_property(b, nc, h, p, n):
    Q = 32
    L = nc * Q
    ks = jax.random.split(jax.random.key(nc * 13 + h), 4)
    x = jax.random.normal(ks[0], (b, L, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
    a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    d_skip = jnp.zeros((h,), jnp.float32)
    b_in = jax.random.normal(ks[2], (b, L, n)) * 0.3
    c_in = jax.random.normal(ks[3], (b, L, n)) * 0.3
    o = ssd(x, dt, a_log, d_skip, b_in, c_in, chunk=Q)
    r = ssd_ref(x, dt, a_log, d_skip, b_in, c_in)
    np.testing.assert_allclose(o, r, rtol=5e-4, atol=5e-4)


def test_ssd_state_continuity_across_chunks():
    """Chunk boundaries must be invisible: chunk=Q vs chunk=L agree."""
    B, L, H, P, N = 1, 128, 2, 16, 16
    ks = jax.random.split(jax.random.key(9), 4)
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a_log = jnp.zeros((H,), jnp.float32)
    d_skip = jnp.zeros((H,), jnp.float32)
    b_in = jax.random.normal(ks[2], (B, L, N)) * 0.3
    c_in = jax.random.normal(ks[3], (B, L, N)) * 0.3
    o_small = ssd(x, dt, a_log, d_skip, b_in, c_in, chunk=16)
    o_big = ssd(x, dt, a_log, d_skip, b_in, c_in, chunk=128)
    np.testing.assert_allclose(o_small, o_big, rtol=5e-4, atol=5e-4)
