"""Minimal property-test fallback for environments without ``hypothesis``.

Provides just enough of the ``hypothesis`` surface the suite uses —
``given``, ``settings``, ``strategies.floats/integers`` with ``.filter`` —
so tier-1 collection and a deterministic smoke-level version of each
property test run on a bare interpreter.  When ``hypothesis`` is installed
(see requirements-dev.txt) the real shrinking/fuzzing engine is used
instead; this fallback checks each property on a fixed diagonal of
boundary/interior points.
"""
from __future__ import annotations


class _Strategy:
    def __init__(self, draws):
        self.draws = list(draws)

    def filter(self, pred):
        return _Strategy(v for v in self.draws if pred(v))


class strategies:
    @staticmethod
    def floats(min_value, max_value, **_):
        lo, hi = float(min_value), float(max_value)
        return _Strategy([lo, hi, 0.5 * (lo + hi), lo + 0.1 * (hi - lo),
                          lo + 0.9 * (hi - lo)])

    @staticmethod
    def integers(min_value, max_value):
        lo, hi = int(min_value), int(max_value)
        return _Strategy(sorted({lo, hi, (lo + hi) // 2,
                                 min(lo + 1, hi), max(hi - 1, lo)}))

    @staticmethod
    def sampled_from(elements):
        return _Strategy(elements)

    @staticmethod
    def booleans():
        return _Strategy([False, True])


def given(**strats):
    names = list(strats)
    n_examples = max(len(strats[n].draws) for n in names)

    def deco(fn):
        # NOTE: deliberately no functools.wraps — pytest would follow
        # __wrapped__ to the original signature and demand the strategy
        # parameters as fixtures.  The wrapper takes no arguments.
        def wrapper():
            for i in range(n_examples):
                draw = {n: strats[n].draws[i % len(strats[n].draws)]
                        for n in names}
                fn(**draw)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def settings(**_kwargs):
    """No-op stand-in for ``hypothesis.settings``."""
    return lambda fn: fn


# `from _propcheck import strategies as st` mirrors the hypothesis import
st = strategies
