"""The observability contract (repro.obs + the engine ``telemetry=`` axis).

Four frozen guarantees:

  * **Zero-cost off** — ``telemetry=None`` reproduces today's stats
    bitwise on every loop × executor (the compiled program is identical:
    the telemetry fold is statically absent).
  * **Primary stats untouched on** — turning telemetry ON changes no
    base statistic's bits; it only *adds* fields.
  * **Executor equivalence** — telemetry counters/histograms follow the
    engine's executor contract: pallas == ref bitwise on everything;
    integer decision counts (TEL_INT_STATS) bitwise vs xla too (float
    ulp differences may flip a histogram boundary bin, so hists are
    exempt from the cross-layout comparison).
  * **Sketch accuracy** — P50/P90/P99 from the log-binned sketch land
    within the advertised relative error (γ − 1) of the exact empirical
    quantiles recovered from the event trace, across randomized market
    and region configs.
"""
from __future__ import annotations

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _stats import assert_same_distribution  # noqa: E402

from repro.core import (  # noqa: E402
    Exponential,
    ThreePhaseKernel,
    run_market_sim,
    run_market_sweep,
    run_region_sim,
    run_region_sweep,
    run_sim,
    run_sweep,
)
from repro.core.market import NoticeAwareKernel, SpotMarket, SpotPool
from repro.core.regions import Region, RegionTopology
from repro.obs import (
    EVENT_TYPES,
    TEL_INT_STATS,
    Telemetry,
    TraceRecorder,
    device_trace_records,
    sketch_quantile,
    to_perfetto,
)
from repro.obs.stats import EV_JOB, EV_SPOT, hist_bin

LAM, MU, K = 1.2, 0.9, 12.0
TEL = Telemetry(trace_cap=32)


def _market(n_pools: int = 2) -> SpotMarket:
    return SpotMarket(pools=tuple(
        SpotPool(Exponential(MU / n_pools), price=0.4 + 0.3 * i,
                 hazard=0.2 / (i + 1), notice=0.5 * (i % 2))
        for i in range(n_pools)))


def _topo(n_regions: int = 2) -> RegionTopology:
    return RegionTopology(regions=tuple(
        Region(Exponential(LAM / n_regions), Exponential(MU / n_regions),
               price=0.4 + 0.2 * i, hazard=0.1 / (i + 1))
        for i in range(n_regions)))


def _run(loop: str, impl: str, telemetry, **over):
    kw = dict(k=K, n_events=3_000, key=jax.random.key(11),
              chunk_events=1_024, telemetry=telemetry)
    if impl == "pallas":
        kw["interpret"] = True
    kw.update(over)
    params = {"r": jnp.float32(2.0)}
    kern = ThreePhaseKernel()
    if loop == "single":
        return run_sim(Exponential(LAM), Exponential(MU), kern, params,
                       impl=impl, rmax=4, **kw)
    if loop == "market":
        return run_market_sim(Exponential(LAM), _market(), kern, params,
                              impl=impl, rmax=4, **kw)
    return run_region_sim(_topo(), kern, params, impl=impl, **kw)


def _assert_same(a: dict, b: dict, keys=None, context: str = "") -> None:
    """Bitwise dict equality, descending one level into the trace dict."""
    for name in (keys if keys is not None else a):
        va, vb = a[name], b[name]
        if isinstance(va, dict):
            for sub in va:
                np.testing.assert_array_equal(
                    np.asarray(va[sub]), np.asarray(vb[sub]),
                    err_msg=f"{name}.{sub} diverged ({context})")
            continue
        np.testing.assert_array_equal(
            np.asarray(va), np.asarray(vb),
            err_msg=f"{name} diverged ({context})")


# ---------------------------------------------------------------------------
# Zero-cost off / primary-stats-untouched on
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "ref", "pallas"])
@pytest.mark.parametrize("loop", ["single", "market", "region"])
def test_telemetry_off_and_on_preserve_primary_stats(loop, impl):
    off = _run(loop, impl, None)
    on = _run(loop, impl, TEL)
    # off == today's program; on only ADDS fields, bitwise-preserving all
    # primary statistics
    assert set(off) < set(on)
    _assert_same(off, on, keys=off.keys(), context=f"{loop}/{impl}")
    added = set(on) - set(off)
    assert {"p50_wait", "p99_wait", "events", "spot_starts",
            "deadline_defects", "rejects", "trace"} <= added


@pytest.mark.parametrize("loop", ["single", "market", "region"])
def test_telemetry_executor_contract(loop):
    """pallas == ref bitwise on ALL fields; TEL_INT_STATS bitwise vs xla."""
    xla = _run(loop, "xla", TEL)
    ref = _run(loop, "ref", TEL)
    pal = _run(loop, "pallas", TEL)
    _assert_same(ref, pal, context=f"{loop} ref vs pallas")
    _assert_same(xla, ref, keys=TEL_INT_STATS,
                 context=f"{loop} xla vs ref (int decisions)")


def test_telemetry_sweep_grid_shapes():
    tel = Telemetry()
    out = run_sweep(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                    {"r": jnp.linspace(0.5, 3.0, 5)}, k=K, n_events=2_000,
                    key=jax.random.key(0), n_seeds=2, rmax=4,
                    telemetry=tel)
    assert out["p99_wait"].shape == (5, 2)
    assert out["wait_hist"].shape == (5, 2, tel.n_bins)
    assert out["events"].shape == (5, 2, len(EVENT_TYPES))
    # per-grid-point totals: every lane saw exactly n_events merged events
    np.testing.assert_array_equal(out["events"].sum(-1),
                                  np.full((5, 2), 2_000.0))


# ---------------------------------------------------------------------------
# Counter consistency against the base ledger
# ---------------------------------------------------------------------------
def test_counters_single_loop_ledger():
    out = _run("single", "xla", TEL, n_events=6_000)
    assert out["events"].sum() == 6_000
    assert out["events"][2] == 0  # no preempt clock in the single loop
    assert out["preempts_fired"] == 0 and out["notices_honored"] == 0
    # spot legs started == base spot_served; ondemand splits into
    # rejects (admission) + deadline_defects (budget expiry)
    assert out["spot_starts"] == out["spot_served"]
    assert out["rejects"] + out["deadline_defects"] == out["ondemand"]
    # wait samples: one per serve + one per defect
    assert out["wait_hist"].sum() == out["spot_served"] + \
        out["deadline_defects"]
    assert out["loc_defects"].sum() == out["deadline_defects"]


def test_counters_market_loop_ledger():
    out = run_market_sim(Exponential(LAM), _market(), NoticeAwareKernel(),
                         {"r": jnp.float32(2.0)}, k=K, n_events=8_000,
                         key=jax.random.key(3), rmax=4, telemetry=TEL)
    assert out["events"].sum() == 8_000
    # hazard firings >= hits on occupied pools (base preemptions)
    assert out["preempts_fired"] >= out["preemptions"]
    assert out["events"][2] == out["preempts_fired"]
    assert out["notices_honored"] == out["resumed"]
    assert out["loc_resumed"].sum() == out["resumed"]
    assert out["spot_starts"] == out["spot_served"]
    assert out["loc_defects"].sum() == out["deadline_defects"]


def test_counters_chunking_invariant():
    """Integer decisions are order-independent sums: chunked == one-shot."""
    one = _run("market", "xla", TEL, n_events=4_000, chunk_events=None)
    chunked = _run("market", "xla", TEL, n_events=4_000, chunk_events=512)
    _assert_same(one, chunked, keys=TEL_INT_STATS, context="chunking")


# ---------------------------------------------------------------------------
# Sketch accuracy: P50/P90/P99 vs exact quantiles from the event trace
# ---------------------------------------------------------------------------
def _trace_waits(out) -> np.ndarray:
    """Exact wait samples replayed from a full (never-wrapped) ring."""
    trace = out["trace"]
    n = np.asarray(trace["n"])
    cap = np.asarray(trace["val"]).shape[-1]
    assert n.max() <= cap, "ring wrapped; grow trace_cap for exact replay"
    vals = []
    for w in range(n.shape[-1]):
        vals.append(np.asarray(trace["val"])[..., w, : int(n[..., w])])
    v = np.concatenate([x.ravel() for x in vals])
    return v[v >= 0.0]


def _assert_quantiles_within_bound(out, tel: Telemetry, context: str):
    waits = _trace_waits(out)
    assert waits.size > 50, context
    re = tel.rel_error()
    n = waits.size
    s = np.sort(waits)
    for q, key in ((0.50, "p50_wait"), (0.90, "p90_wait"),
                   (0.99, "p99_wait")):
        # the sketch's rank rule: smallest value with cum count >= q*n
        exact = s[max(int(np.ceil(q * n)) - 1, 0)]
        est = float(out[key])
        lo_ok = exact / (1.0 + re) - tel.wait_lo
        hi_ok = exact * (1.0 + re) + tel.wait_lo
        assert lo_ok <= est <= hi_ok, (
            f"{context}: {key} estimate {est:.4g} outside "
            f"[{lo_ok:.4g}, {hi_ok:.4g}] around exact {exact:.4g} "
            f"(rel err bound {re:.3f})")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sketch_quantiles_market_random_configs(seed):
    rng = np.random.default_rng(seed)
    n_pools = int(rng.integers(1, 4))
    market = SpotMarket(pools=tuple(
        SpotPool(Exponential(float(rng.uniform(0.2, 0.6))),
                 price=float(rng.uniform(0.2, 0.9)),
                 hazard=float(rng.uniform(0.0, 0.3)),
                 notice=float(rng.choice([0.0, 0.25, 0.5])))
        for _ in range(n_pools)))
    n_events = 4_000
    tel = Telemetry(trace_cap=n_events)
    out = run_market_sim(
        Exponential(float(rng.uniform(0.8, 1.6))), market,
        NoticeAwareKernel(), {"r": jnp.float32(rng.uniform(1.0, 4.0))},
        k=K, n_events=n_events, key=jax.random.key(seed), rmax=8,
        chunk_events=None, telemetry=tel)
    _assert_quantiles_within_bound(out, tel, f"market[seed={seed}]")


@pytest.mark.parametrize("seed", [0, 1])
def test_sketch_quantiles_region_random_configs(seed):
    rng = np.random.default_rng(100 + seed)
    n_regions = int(rng.integers(2, 4))
    topo = RegionTopology(regions=tuple(
        Region(Exponential(float(rng.uniform(0.3, 0.8))),
               Exponential(float(rng.uniform(0.2, 0.6))),
               price=float(rng.uniform(0.2, 0.9)),
               hazard=float(rng.uniform(0.0, 0.2)))
        for _ in range(n_regions)))
    n_events = 4_000
    tel = Telemetry(trace_cap=n_events)
    out = run_region_sim(topo, ThreePhaseKernel(),
                         {"r": jnp.float32(rng.uniform(1.0, 4.0))}, k=K,
                         n_events=n_events, key=jax.random.key(seed),
                         chunk_events=None, telemetry=tel)
    _assert_quantiles_within_bound(out, tel, f"region[seed={seed}]")


def test_sketch_quantile_synthetic_exactness():
    """Log-normal host data: the sketch read-off honours its error bound."""
    tel = Telemetry()
    rng = np.random.default_rng(7)
    x = np.exp(rng.normal(0.5, 1.2, size=20_000)).astype(np.float64)
    edges = tel.wait_edges()
    idx = np.asarray(hist_bin(jnp.asarray(x, jnp.float32), tel.wait_lo,
                              tel.wait_hi, tel.n_bins))
    hist = np.bincount(idx, minlength=tel.n_bins)
    re = tel.rel_error()
    s = np.sort(x)
    for q in (0.1, 0.5, 0.9, 0.99, 0.999):
        exact = s[max(int(np.ceil(q * len(x))) - 1, 0)]
        est = float(sketch_quantile(hist, edges, q))
        assert exact / (1 + re) - 1e-9 <= est <= exact * (1 + re) + 1e-9, q


def test_wait_distribution_split_vs_slab():
    """Trace-replayed wait samples: rng='split' and rng='slab' draw from
    the same law (KS, reusing the suite's helper)."""
    tel = Telemetry(trace_cap=4_000)
    # key(5) is pinned (not drawn per-run) so the KS draw is deterministic:
    # H0 is exactly true here and the helper's alpha=1e-4 would otherwise
    # be a per-run flake probability (see _KS_SEEDS in test_event_rng.py)
    kw = dict(k=K, n_events=4_000, key=jax.random.key(5), rmax=8,
              chunk_events=None, telemetry=tel)
    a = run_sim(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                {"r": jnp.float32(2.0)}, rng="split", **kw)
    b = run_sim(Exponential(LAM), Exponential(MU), ThreePhaseKernel(),
                {"r": jnp.float32(2.0)}, rng="slab", **kw)
    assert_same_distribution(_trace_waits(a), _trace_waits(b),
                             name="trace waits split vs slab")


# ---------------------------------------------------------------------------
# Trace: ring semantics, record schema, Perfetto export
# ---------------------------------------------------------------------------
def test_trace_ring_wrap_counts_drops():
    tel = Telemetry(trace_cap=16)  # << events per window: must wrap
    out = _run("single", "xla", tel, n_events=2_000, chunk_events=1_024)
    trace = out["trace"]
    n = np.asarray(trace["n"])
    assert n.sum() == 2_000  # true per-window counts survive the wrap
    records = device_trace_records(trace, trace["time_windows"])
    assert len(records) == 16 * n.shape[-1]
    assert sum(r.get("dropped", 0) for r in records) == 2_000 - len(records)


def test_trace_records_schema_and_clock():
    tel = Telemetry(trace_cap=2_048)
    out = _run("market", "xla", tel, n_events=2_000, chunk_events=1_024)
    records = device_trace_records(out["trace"],
                                   out["trace"]["time_windows"])
    assert len(records) == 2_000
    ts = np.array([r["t"] for r in records])
    # window re-timing lands every record on one non-decreasing clock
    assert (np.diff(ts) >= 0).all()
    assert abs(ts[-1] - float(out["time"])) < 1e-3
    assert {r["type"] for r in records} <= set(EVENT_TYPES)
    assert all(0 <= r["loc"] < 2 for r in records)


def test_perfetto_export_schema():
    recorder = TraceRecorder(cap=8)
    for i in range(10):
        recorder.record(0.5 * i, "job" if i % 2 else "spot", loc=i % 2,
                        qlen=i, wait=0.1 * i)
    assert recorder.dropped == 2
    doc = to_perfetto(recorder.records, label="unit")
    instants = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(instants) == len(counters) == 8
    assert {m["args"]["name"] for m in metas} >= set(EVENT_TYPES)
    assert instants[0]["ts"] == 0.0 and instants[1]["ts"] == 0.5e6


def test_telemetry_rejects_wrong_type():
    with pytest.raises(TypeError):
        _run("single", "xla", telemetry="stats")
