"""Checkpoint manager + data pipeline tests (fault-tolerance substrate)."""
import os
import shutil
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataPipeline


@pytest.fixture
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    tree = _tree()
    mgr.save(7, tree, extra={"data_cursor": {"step": 123}}, blocking=True)
    restored, extra = mgr.restore(7, jax.eval_shape(lambda: tree))
    assert extra["data_cursor"]["step"] == 123
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save_then_wait(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_partial_checkpoint_ignored(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(1, _tree(), blocking=True)
    # simulate a kill mid-save: directory without manifest
    os.makedirs(os.path.join(ckpt_dir, "step_2"))
    assert mgr.latest_step() == 1


def test_gc_keeps_last_k(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir, keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_checksum_verification(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(5, _tree(), blocking=True)
    # corrupt a leaf
    d = os.path.join(ckpt_dir, "step_5")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    arr = arr + 1
    np.save(os.path.join(d, victim), arr)
    with pytest.raises(IOError):
        mgr.restore(5, jax.eval_shape(_tree), verify=True)


def test_elastic_restore_subprocess(ckpt_dir):
    """Save on a 4x2 mesh, restore onto 2x2 — the elastic-resize path."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager

        mesh1 = jax.make_mesh((4, 2), ("data", "model"),
                              devices=jax.devices())
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        w = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
        mgr = CheckpointManager({str(ckpt_dir)!r})
        mgr.save(3, {{"w": w}}, blocking=True)

        mesh2 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        restored, _ = mgr.restore(
            3, jax.eval_shape(lambda: {{"w": w}}), mesh=mesh2,
            specs={{"w": P("data", "model")}})
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64).reshape(8, 8))
        assert restored["w"].sharding.mesh.shape["data"] == 2
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=600)
    assert "OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic():
    a = DataPipeline(vocab_size=512, global_batch=4, seq_len=64, seed=3)
    b = DataPipeline(vocab_size=512, global_batch=4, seq_len=64, seed=3)
    for _ in range(3):
        ba, bb = a.next(), b.next()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_pipeline_resume_exact():
    a = DataPipeline(vocab_size=512, global_batch=4, seq_len=64, seed=3)
    for _ in range(5):
        a.next()
    cursor = a.state()
    expected = a.next()
    b = DataPipeline(vocab_size=512, global_batch=4, seq_len=64, seed=0)
    b.restore(cursor)
    got = b.next()
    np.testing.assert_array_equal(expected["tokens"], got["tokens"])


def test_pipeline_targets_are_shifted_tokens():
    p = DataPipeline(vocab_size=512, global_batch=2, seq_len=32, seed=1)
    b = p.next()
    assert b["tokens"].shape == (2, 32)
    assert b["targets"].shape == (2, 32)
    assert int(b["tokens"].min()) >= 1
    assert int(b["tokens"].max()) < 512


def test_pipeline_host_sharding_partitions():
    full = DataPipeline(vocab_size=512, global_batch=4, seq_len=16, seed=9,
                        host_index=0, host_count=1)
    shard0 = DataPipeline(vocab_size=512, global_batch=4, seq_len=16, seed=9,
                          host_index=0, host_count=2)
    shard1 = DataPipeline(vocab_size=512, global_batch=4, seq_len=16, seed=9,
                          host_index=1, host_count=2)
    assert shard0.host_batch == 2 and shard1.host_batch == 2
    b0, b1 = shard0.next(), shard1.next()
    # shards differ (different host streams)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_pipeline_elastic_reshard():
    p = DataPipeline(vocab_size=512, global_batch=8, seq_len=16, seed=2,
                     host_count=2)
    p.next()
    cursor = p.state()
    q = DataPipeline(vocab_size=512, global_batch=8, seq_len=16, seed=2)
    q.restore(cursor, host_index=0, host_count=4)
    assert q.host_batch == 2
    assert q.step == cursor["step"]
    q.next()
